"""The paper's two-phase training procedure (Sec. V-C) as a fault-tolerant
trainer.

Phase 1 — *pretrain*: plain LSTM + CBTD applied after every parameter
update (Alg. 2), alpha annealed 0 -> 1 by ``delta_alpha`` per epoch.
Phase 2 — *retrain*: weights copied into DeltaLSTM layers of the same
size, trained with alpha = 1 and a fixed delta threshold Theta.

Works single-host (CPU tests / examples) and under pjit (launch/train.py
re-uses ``train_step`` with sharded arguments).  CBTD runs *inside* the
jitted step so at scale it never leaves the device.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import alpha_at, cbtd_prune_tree, summarize_delta_aux
from repro.core.cbtd import CBTDConfig
from repro.data.speech import SpeechConfig, SpeechDataset
from repro.models import lstm_am
from repro.training.checkpoint import CheckpointManager
from repro.training.ctc import ctc_loss, greedy_decode, phone_error_rate
from repro.training.optimizer import AdamState, AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    model: lstm_am.LSTMAMConfig = lstm_am.LSTMAMConfig(hidden_dim=64, n_layers=2)
    data: SpeechConfig = SpeechConfig()
    opt: AdamWConfig = AdamWConfig(lr=3e-3)
    batch_size: int = 16
    steps_per_epoch: int = 25
    # CBTD (Alg. 2)
    cbtd_gamma: Optional[float] = 0.94
    cbtd_m: int = 64
    cbtd_delta_alpha: float = 1.0 / 30.0
    cbtd_stochastic: bool = False   # alpha<1 stochastic drops (paper) vs determ.
    # checkpointing
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    seed: int = 0


def _cbtd_layout(cfg: TrainConfig) -> Optional[Dict[str, CBTDConfig]]:
    if cfg.cbtd_gamma is None:
        return None
    c = CBTDConfig(gamma=cfg.cbtd_gamma, m=cfg.cbtd_m,
                   delta_alpha=cfg.cbtd_delta_alpha)
    return {"w_x": c, "w_h": c, "fcl/w": c}


def make_train_step(cfg: TrainConfig):
    layout = _cbtd_layout(cfg)

    def loss_fn(params, batch):
        feats, feat_lens, labels, label_lens = batch
        logits, _ = lstm_am.forward(params, cfg.model, feats)
        return ctc_loss(logits, labels, feat_lens, label_lens)

    @jax.jit
    def train_step(params, opt_state: AdamState, batch, alpha, key):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, cfg.opt)
        if layout is not None:
            prune_key = key if cfg.cbtd_stochastic else None
            params = cbtd_prune_tree(params, layout, alpha, prune_key)
        metrics = {"loss": loss, **metrics}
        return params, opt_state, metrics

    return train_step


@functools.partial(jax.jit, static_argnames=("cfg",))
def eval_logits(params, cfg: lstm_am.LSTMAMConfig, feats):
    logits, aux = lstm_am.forward(params, cfg, feats, collect_aux=True)
    return logits, aux


def evaluate_per(params, cfg: TrainConfig, dataset: SpeechDataset,
                 n_batches: int = 4) -> float:
    """Greedy-decode PER on freshly drawn eval batches (paper Sec. V-B)."""
    hyps, refs = [], []
    # disjoint held-out stream: same distribution (same class-means table),
    # different fold of the dataset key
    eval_ds = SpeechDataset(cfg.data, dataset.batch, process_index=10_000)
    for _ in range(n_batches):
        feats, feat_lens, labels, label_lens = next(eval_ds)
        logits, _ = eval_logits(params, cfg.model, feats)
        hyps += greedy_decode(logits, feat_lens)
        labels, label_lens = jax.device_get((labels, label_lens))
        refs += [list(labels[b, : int(label_lens[b])]) for b in range(labels.shape[0])]
    return phone_error_rate(hyps, refs)


def measure_delta_stats(params, cfg: TrainConfig, dataset: SpeechDataset,
                        n_batches: int = 2) -> Dict[str, Any]:
    """Run the DeltaLSTM forward collecting delta occupancy (Fig. 13a)."""
    assert cfg.model.delta, "delta stats need a DeltaLSTM model config"
    per_layer: Dict[int, Dict[str, list]] = {}
    for _ in range(n_batches):
        feats, *_ = next(dataset)
        _, aux = eval_logits(params, cfg.model, feats)
        for li, layer_aux in enumerate(aux["layers"]):
            d = per_layer.setdefault(li, {"nnz_dx": [], "nnz_dh": [],
                                          "dx_masks": [], "dh_masks": []})
            for k in d:
                d[k].append(layer_aux[k])
    stats = {}
    dims = [cfg.model.input_dim] + [cfg.model.hidden_dim] * (cfg.model.n_layers - 1)
    for li, d in per_layer.items():
        nnz_dx = jnp.concatenate([jnp.ravel(a) for a in d["nnz_dx"]])
        nnz_dh = jnp.concatenate([jnp.ravel(a) for a in d["nnz_dh"]])
        stats[f"layer{li}"] = summarize_delta_aux(
            {"nnz_dx": nnz_dx, "nnz_dh": nnz_dh}, dims[li], cfg.model.hidden_dim
        )
        # keep masks for balance-ratio analysis: [T', F] per layer
        stats[f"layer{li}"]["dx_masks"] = jnp.concatenate(
            [m.reshape(-1, m.shape[-1]) for m in d["dx_masks"]]
        )
        stats[f"layer{li}"]["dh_masks"] = jnp.concatenate(
            [m.reshape(-1, m.shape[-1]) for m in d["dh_masks"]]
        )
    return stats


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    losses: list
    final_loss: float
    steps: int
    wall_s: float


def train(
    cfg: TrainConfig,
    epochs: int = 2,
    params: Any = None,
    resume: bool = True,
    log_every: int = 0,
) -> TrainResult:
    """Run the training loop (one phase).  Checkpoint/restart-safe: if
    ``cfg.ckpt_dir`` is set and a committed checkpoint exists, training
    resumes from it (params, optimizer, data-iterator position, epoch)."""
    key = jax.random.key(cfg.seed)
    pkey, key = jax.random.split(key)
    if params is None:
        params = lstm_am.init_params(pkey, cfg.model)
    opt_state = adamw_init(params)
    dataset = SpeechDataset(cfg.data, cfg.batch_size)
    step = 0

    mgr = None
    if cfg.ckpt_dir:
        mgr = CheckpointManager(cfg.ckpt_dir, keep_last=2, process_index=0)
        if resume:
            (params, opt_state), meta, ck_step = mgr.restore_latest((params, opt_state))
            if ck_step is not None:
                step = int(meta.get("step", ck_step))
                dataset.load_state_dict({"step": meta.get("data_step", step)})

    train_step = make_train_step(cfg)
    losses = []
    t0 = time.time()
    total_steps = epochs * cfg.steps_per_epoch
    while step < total_steps:
        epoch = step // cfg.steps_per_epoch
        alpha = alpha_at(epoch, cfg.cbtd_delta_alpha) if cfg.cbtd_gamma else 0.0
        batch = next(dataset)
        key, skey = jax.random.split(key)
        params, opt_state, metrics = train_step(params, opt_state, batch, alpha, skey)
        losses.append(float(metrics["loss"]))
        step += 1
        if log_every and step % log_every == 0:
            print(f"step {step:5d} epoch {epoch:3d} alpha {float(alpha):.2f} "
                  f"loss {losses[-1]:.4f}")
        if mgr and step % cfg.ckpt_every == 0:
            mgr.save(step, (params, opt_state),
                     {"step": step, "data_step": dataset.step})
    if mgr:
        mgr.save(total_steps, (params, opt_state),
                 {"step": total_steps, "data_step": dataset.step})
        mgr.wait()
    return TrainResult(
        params=params, opt_state=opt_state, losses=losses,
        final_loss=float(jnp.mean(jnp.array(losses[-5:]))) if losses else float("nan"),
        steps=step, wall_s=time.time() - t0,
    )


def pretrain_retrain(
    cfg: TrainConfig, pretrain_epochs: int = 2, retrain_epochs: int = 1,
    theta: float = 0.1,
) -> Tuple[TrainResult, TrainResult, TrainConfig]:
    """The paper's full pipeline: LSTM+CBTD pretrain, then DeltaLSTM retrain
    with alpha=1 (Sec. V-C).  Returns both results + the retrain config."""
    pre = train(cfg, epochs=pretrain_epochs)
    retrain_cfg = dataclasses.replace(
        cfg,
        model=dataclasses.replace(cfg.model, delta=True, theta=theta),
        cbtd_delta_alpha=1.0,  # alpha = 1 from the first retrain epoch
    )
    post = train(retrain_cfg, epochs=retrain_epochs, params=pre.params)
    return pre, post, retrain_cfg
