"""Connectionist Temporal Classification loss in pure JAX (Sec. V-B).

The paper trains its acoustic models with CTC (Graves et al. 2006) so the
logit layer emits phonemes directly.  This is the standard log-space
forward algorithm over the blank-extended label sequence, implemented with
``jax.lax.scan`` (time) and vmapped over the batch.  Supports padded
logits and labels via explicit lengths.

Also provides the greedy decoder + edit distance used for the paper's PER
metric (greedy best-path decoding, Sec. V-B).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _extend_labels(labels: jax.Array, blank: int) -> jax.Array:
    """[L] -> blank-interleaved [2L+1]: (b, l1, b, l2, ..., b)."""
    l = labels.shape[0]
    ext = jnp.full((2 * l + 1,), blank, labels.dtype)
    return ext.at[1::2].set(labels)


def _ctc_loss_single(
    log_probs: jax.Array,   # [T, V] log-softmaxed
    labels: jax.Array,      # [L] padded with anything
    logit_len: jax.Array,   # scalar int
    label_len: jax.Array,   # scalar int
    blank: int,
) -> jax.Array:
    t_max, _ = log_probs.shape
    l_max = labels.shape[0]
    s = 2 * l_max + 1
    ext = _extend_labels(labels, blank)                       # [S]

    # Which extended positions may copy from s-2 (skip a blank): label
    # positions whose label differs from the previous label position.
    prev_label = jnp.roll(ext, 2)
    can_skip = (ext != blank) & (ext != prev_label)
    can_skip = can_skip.at[:2].set(False)                     # no s-2 for s<2

    emit0 = log_probs[0][ext]
    alpha0 = jnp.full((s,), NEG_INF).at[0].set(emit0[0]).at[1].set(
        jnp.where(label_len > 0, emit0[1], NEG_INF)
    )

    def step(alpha, t):
        emit = log_probs[t][ext]                              # [S]
        a_prev1 = jnp.concatenate([jnp.array([NEG_INF]), alpha[:-1]])
        a_prev2 = jnp.concatenate([jnp.array([NEG_INF, NEG_INF]), alpha[:-2]])
        a_prev2 = jnp.where(can_skip, a_prev2, NEG_INF)
        stacked = jnp.stack([alpha, a_prev1, a_prev2])
        new = jax.nn.logsumexp(stacked, axis=0) + emit
        # freeze past the true sequence length (padding frames):
        new = jnp.where(t < logit_len, new, alpha)
        return new, None

    alpha, _ = jax.lax.scan(step, alpha0, jnp.arange(1, t_max))

    end = 2 * label_len                                        # final blank pos
    last_label = jnp.where(label_len > 0, end - 1, 0)
    ll = jnp.logaddexp(
        alpha[end], jnp.where(label_len > 0, alpha[last_label], NEG_INF)
    )
    return -ll


@functools.partial(jax.jit, static_argnames=("blank",))
def ctc_loss(
    logits: jax.Array,      # [B, T, V]
    labels: jax.Array,      # [B, L] int
    logit_lens: jax.Array,  # [B]
    label_lens: jax.Array,  # [B]
    blank: int = 0,
) -> jax.Array:
    """Mean per-sequence negative log likelihood."""
    log_probs = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    losses = jax.vmap(_ctc_loss_single, in_axes=(0, 0, 0, 0, None))(
        log_probs, labels, logit_lens, label_lens, blank
    )
    return jnp.mean(losses)


def ctc_loss_brute_force(
    log_probs: np.ndarray, labels: np.ndarray, blank: int = 0
) -> float:
    """Enumerate every alignment — O(V^T); oracle for tiny test cases."""
    t, v = log_probs.shape
    total = NEG_INF

    def collapse(path):
        out, prev = [], None
        for p in path:
            if p != prev and p != blank:
                out.append(p)
            prev = p
        return out

    import itertools

    for path in itertools.product(range(v), repeat=t):
        if collapse(path) == list(labels):
            lp = sum(log_probs[i, p] for i, p in enumerate(path))
            total = np.logaddexp(total, lp)
    return -float(total)


def greedy_decode(logits: jax.Array, logit_lens: jax.Array, blank: int = 0):
    """Best-path decoding (paper: 'simple greedy decoder').  Returns a
    python list of label lists (host-side)."""
    best = np.asarray(jnp.argmax(logits, axis=-1))
    lens = np.asarray(logit_lens)
    out = []
    for b in range(best.shape[0]):
        seq, prev = [], None
        for tt in range(int(lens[b])):
            p = int(best[b, tt])
            if p != prev and p != blank:
                seq.append(p)
            prev = p
        out.append(seq)
    return out


def edit_distance(a, b) -> int:
    """Levenshtein distance (for PER: sub+ins+del / len(ref))."""
    if len(a) < len(b):
        a, b = b, a
    prev = list(range(len(b) + 1))
    for i, ca in enumerate(a, 1):
        cur = [i]
        for j, cb in enumerate(b, 1):
            cur.append(min(prev[j] + 1, cur[j - 1] + 1, prev[j - 1] + (ca != cb)))
        prev = cur
    return prev[-1]


def phone_error_rate(hyps, refs) -> float:
    """PER = total edit distance / total reference length."""
    dist = sum(edit_distance(h, r) for h, r in zip(hyps, refs))
    total = sum(len(r) for r in refs)
    return dist / max(total, 1)
