"""Fault-tolerant checkpointing (no orbax offline — built on numpy .npz).

Design for 1000+-node operation:
  * atomic: write to ``<dir>/tmp.<step>.<pid>`` then ``os.replace`` — a
    crash mid-write never corrupts the latest checkpoint;
  * per-process shard files (``proc{i}.npz``) — each host writes only its
    addressable shards, no cross-host traffic on the save path;
  * async: saves run on a single background thread; the train loop only
    blocks if a previous save is still in flight (bounded staleness = 1);
  * retention: keep the newest K checkpoints plus every multiple of
    ``keep_period`` (so post-mortems of long runs have anchors);
  * ``restore_latest`` skips incomplete checkpoints (missing COMMIT marker),
    which is what makes kill -9 / preemption recovery safe.

Pytrees are flattened to path-keyed arrays; the iterator state and a
metadata dict ride along, so a restart resumes the data stream exactly.
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def flatten_tree(tree) -> Dict[str, np.ndarray]:
    """Flatten any pytree to path-keyed host arrays (``"/"``-joined keys).

    Public because the serving checkpoint path (serving/checkpoint.py)
    rides the same machinery: a flat ``Dict[str, np.ndarray]`` is itself a
    pytree whose flatten keys are the dict keys, so pool snapshots go
    through ``CheckpointManager.save`` unchanged."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


_flatten = flatten_tree  # back-compat alias


def unflatten_into(tree, arrays: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


_unflatten_into = unflatten_into  # back-compat alias


class CheckpointManager:
    STEP_RE = re.compile(r"^step_(\d+)$")

    def __init__(
        self,
        directory: str,
        keep_last: int = 3,
        keep_period: Optional[int] = None,
        process_index: Optional[int] = None,
        async_save: bool = True,
    ):
        self.dir = directory
        self.keep_last = keep_last
        self.keep_period = keep_period
        self.process_index = (
            process_index if process_index is not None else jax.process_index()
        )
        os.makedirs(directory, exist_ok=True)
        self._pool = cf.ThreadPoolExecutor(max_workers=1) if async_save else None
        self._inflight: Optional[cf.Future] = None
        self._lock = threading.Lock()

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, metadata: Optional[Dict[str, Any]] = None):
        """Snapshot now (device_get), write async if enabled."""
        arrays = _flatten(tree)  # host copies — safe to mutate tree afterwards
        meta = dict(metadata or {})
        if self._pool is None:
            self._write(step, arrays, meta)
            return None
        self.wait()  # bound in-flight saves to 1
        self._inflight = self._pool.submit(self._write, step, arrays, meta)
        return self._inflight

    def wait(self):
        if self._inflight is not None:
            self._inflight.result()
            self._inflight = None

    def _write(self, step: int, arrays, meta):
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}.{self.process_index}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"proc{self.process_index}.npz"), **arrays)
        with open(os.path.join(tmp, f"meta{self.process_index}.json"), "w") as f:
            json.dump({"step": step, **meta}, f)
        # single-controller commit: proc 0 marks completeness
        if self.process_index == 0:
            with open(os.path.join(tmp, "COMMIT"), "w") as f:
                f.write(str(step))
        with self._lock:
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        keep = set(steps[-self.keep_last:]) if self.keep_last else set(steps)
        if self.keep_period:
            keep |= {s for s in steps if s % self.keep_period == 0}
        for s in steps:
            if s not in keep:
                shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                              ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = self.STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_arrays(
        self, step: int
    ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
        """Raw ``(arrays, meta)`` of one committed step — no template.

        This is the restore surface for consumers whose array set is not
        known ahead of time (the serving pool checkpoint stores a variable
        number of sessions; the session list lives in the metadata)."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        npz = np.load(os.path.join(path, f"proc{self.process_index}.npz"))
        arrays = {k: npz[k] for k in npz.files}
        with open(os.path.join(path, f"meta{self.process_index}.json")) as f:
            meta = json.load(f)
        return arrays, meta

    def restore(self, step: int, template) -> Tuple[Any, Dict[str, Any]]:
        arrays, meta = self.restore_arrays(step)
        return unflatten_into(template, arrays), meta

    def restore_latest(self, template):
        """(tree, meta, step) or (template, {}, None) if no checkpoint."""
        step = self.latest_step()
        if step is None:
            return template, {}, None
        tree, meta = self.restore(step, template)
        return tree, meta, step
