"""Optimizers & schedules (no optax offline — implemented from scratch).

AdamW with global-norm clipping, plus warmup-cosine / constant schedules.
States are plain pytrees so they shard/pjit/checkpoint like parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: object   # pytree like params
    v: object


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    schedule: str = "constant"      # constant | cosine | linear
    warmup_steps: int = 0
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule_fn(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "constant":
            decay = 1.0
        elif cfg.schedule == "cosine":
            frac = jnp.clip(
                (step - cfg.warmup_steps)
                / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                0.0, 1.0,
            )
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac)
            )
        elif cfg.schedule == "linear":
            frac = jnp.clip(
                (step - cfg.warmup_steps)
                / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                0.0, 1.0,
            )
            decay = 1.0 - (1 - cfg.min_lr_frac) * frac
        else:
            raise ValueError(cfg.schedule)
        return cfg.lr * warm * decay

    return fn


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def adamw_init(params) -> AdamState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(grads, state: AdamState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)

    step = state.step + 1
    lr = schedule_fn(cfg)(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr,
    }
