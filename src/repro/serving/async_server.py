"""Asyncio streaming front-end over the chunked session pool.

`serve_requests` (scheduler.py) is a synchronous drain loop: the full
request list is known up front, the driver owns the thread until every
utterance completes, and logits surface only at retirement.  Real online
speech serving (the Spartus target: ~1 us/frame streaming inference) is
the opposite shape — clients connect at arbitrary times, frames arrive
incrementally as audio is captured, and the decoder downstream wants
logits *as they are produced*, not after the utterance ends.

`AsyncSpartusServer` is that front-end, built directly on the
`SessionPool` primitives (`admit_stream`/`append_frames`/`tick`/
`take_partials`):

* **Clients** call ``await server.submit(feats)`` for a whole utterance,
  or ``await server.stream()`` for a `StreamHandle` they feed
  incrementally (``await h.send(frames)`` ... ``h.close()``) — or hand an
  async iterator of frame blocks to ``submit_stream``.  Partial logits
  stream back per chunk through the handle's `asyncio.Queue`
  (``async for rows in handle``); the final `RequestResult` resolves the
  handle's future.  ``h.cancel()`` abandons the utterance mid-stream and
  frees the slot at the next chunk boundary.
* **One background driver task** owns the pool.  Each iteration it moves
  client-buffered frames into the pool (admissions, appends, finishes,
  cancellations — all staged host-side, so client coroutines never touch
  device state), runs ONE ``pool.tick`` (at most one chunk dispatch,
  double-buffered exactly like the sync path), delivers the resolved
  partials/results to the per-client queues, and then sleeps until the
  next wall-clock chunk boundary (``target_chunk_ms``; 0 = free-run).
  With ``offload_ticks=True`` the tick runs in a worker thread so the
  event loop keeps serving client sends during the device sync.
* **Backpressure**: at most ``max_pending`` clients may sit in the
  admission queue; further ``submit``/``stream`` calls *await* until a
  slot train frees, so a load spike queues at the front door instead of
  growing unbounded host state.  Queue-wait and time-to-first-logit
  surface per request and as p50/p95/p99 in ``server.stats()``.
* **Bounded partial-logit queues**: each session's partials queue holds
  at most ``partial_queue_len`` blocks.  The driver never blocks on a
  slow consumer — when a queue is full the session is marked *lagging*:
  its per-chunk snapshots pause (`SessionPool.pause_partials`), nothing
  further is buffered host-side for it, and when the client drains the
  gap is recovered in ONE catch-up fetch from the device logits bank
  (`SessionPool.peek_rows`, which holds the whole utterance until
  retirement anyway).  A client that never drains costs a bounded queue
  plus its (already-allocated) slot — previously one stalled client
  accumulated every ``[C, n_classes]`` block of its stream forever.

The streamed rows are bit-identical to the synchronous path: the driver
runs the very same chunked `step_chunk` dispatch, so
``concat(partials) == result.logits == serve_requests(...)`` at 1e-5
(pinned in tests/test_async_serving.py and examples/streaming_server.py).
"""
from __future__ import annotations

import asyncio
import itertools
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import AsyncIterator, Deque, Dict, List, Optional

import numpy as np

from repro.serving.batched_engine import BatchedSpartusEngine
from repro.serving.faults import (
    AdmissionShed,
    BadRequest,
    DriverRecovered,
    FaultInjector,
    InjectedFault,
    SessionTimeout,
)
from repro.serving.metrics import NULL_TRACER, PoolObservability
from repro.serving.scheduler import (
    PartialLogits,
    RequestResult,
    ServeStats,
    SessionPool,
    aggregate_stats,
)

_EOS = object()   # end-of-stream sentinel on a handle's partials queue


class StreamClosed(RuntimeError):
    """Raised when sending frames to a closed or cancelled stream."""


class _ClientState:
    """Driver-side bookkeeping for one connected stream (loop thread only:
    clients buffer frames here; the driver moves them into the pool at
    chunk boundaries, so no client coroutine ever touches device state)."""

    __slots__ = ("req_id", "handle", "arrival_wall", "want_partials",
                 "buffered", "closed", "cancelled", "admitted",
                 "finish_sent", "delivered_t", "lagging", "token",
                 "last_activity")

    def __init__(self, req_id: int, handle: "StreamHandle",
                 arrival_wall: float, want_partials: bool,
                 token: Optional[str] = None):
        self.req_id = req_id
        self.handle = handle
        self.arrival_wall = arrival_wall
        self.want_partials = want_partials
        self.buffered: List[np.ndarray] = []
        self.closed = False
        self.cancelled = False
        self.admitted = False
        self.finish_sent = False
        self.delivered_t = 0      # frames enqueued on the partials queue
        self.lagging = False      # queue hit partial_queue_len: snapshots
        #                           paused until the client drains
        self.token = token        # idempotent re-admission token
        self.last_activity = arrival_wall   # idle-reaper clock


class StreamHandle:
    """Client-side handle to one streaming session.

    ``await send(frames)`` feeds more frames (any ``[n, D]`` block);
    ``close()`` marks the utterance complete; ``async for rows in handle``
    yields per-chunk partial logits (``PartialLogits``) until the stream
    ends; ``await result()`` returns the final `RequestResult` (its
    ``logits`` equal the concatenated partials).  ``cancel()`` abandons
    the utterance — ``result()`` then raises `asyncio.CancelledError` and
    the partials iterator stops.
    """

    def __init__(self, server: "AsyncSpartusServer", req_id: int):
        self._server = server
        self.req_id = req_id
        self._partials: asyncio.Queue = asyncio.Queue()
        self._result: asyncio.Future = (
            asyncio.get_running_loop().create_future())
        self._feed_task: Optional[asyncio.Task] = None  # submit_stream pump
        #: set once the session holds a pool slot (backpressure observability)
        self.admitted = asyncio.Event()

    async def send(self, frames: np.ndarray) -> None:
        """Feed one block of frames ``[n, D]`` (or a single frame ``[D]``).

        Sends only buffer host-side and set the driver's wake event —
        they do NOT yield per call (the old per-send ``sleep(0)`` poke
        context-switched into the driver once per client send; the driver
        drains every client's buffered ops in one batched pump per chunk
        boundary instead)."""
        self._server._client_send(self.req_id, frames)

    def close(self) -> None:
        """No more frames: the session retires once everything fed has
        been consumed."""
        self._server._client_close(self.req_id)

    def cancel(self) -> None:
        """Abandon the utterance; the slot frees at the next boundary."""
        self._server._client_cancel(self.req_id)

    async def result(self) -> RequestResult:
        """The final `RequestResult` (raises `asyncio.CancelledError` if
        the stream was cancelled)."""
        return await asyncio.shield(self._result)

    def __aiter__(self) -> "StreamHandle":
        return self

    async def __anext__(self) -> PartialLogits:
        item = await self._partials.get()
        # a lagging (slow-consumer) session's snapshots are paused; tell
        # the driver we drained so it can backfill + resume even if it is
        # otherwise idle (no-op for healthy sessions):
        self._server._note_drain(self.req_id)
        if item is _EOS:
            raise StopAsyncIteration
        return item


class AsyncSpartusServer:
    """Admission-while-running streaming server over one
    `BatchedSpartusEngine`.

    Parameters
    ----------
    engine / capacity / chunk_frames / max_frames / max_buffer_frames:
        forwarded to the underlying `SessionPool` (``chunk_frames >= 1``
        selects the chunked tick loop; the pool streams per-chunk partial
        logits).
    target_chunk_ms:
        wall-clock pacing of chunk boundaries: the driver sleeps out the
        remainder of this budget after each tick, so a chunk's worth of
        frames is consumed per period (real-time streaming). ``0`` =
        free-run (throughput mode: tick as fast as the device allows).
    max_pending:
        admission-queue bound: at most this many clients wait for a slot;
        further ``submit``/``stream`` calls await (backpressure).
        ``None`` = unbounded (open-loop load generation).
    partial_queue_len:
        per-session bound on buffered partial-logit blocks (the
        slow-consumer fix): when a client stops draining its queue, the
        driver marks the session lagging, pauses its per-chunk snapshots
        and buffers nothing more for it — the skipped range is recovered
        from the device logits bank in one fetch when the client drains
        (or arrives with the final result).  The driver never blocks and
        healthy sessions are unaffected.  ``None`` = the default bound
        (32); ``0`` = unbounded (the pre-fix behaviour, load-gen only).
    offload_ticks:
        run each ``pool.tick`` in a one-thread executor so the event loop
        stays responsive (client sends land mid-chunk) — the pool is only
        ever touched by one thread at a time, since the driver awaits the
        tick before pumping again.  ``False`` keeps ticks on the loop
        (slightly less overhead; fine when clients batch their sends).
    n_devices:
        shard the pool's slot dimension over this many devices
        (`SessionPool(n_devices=...)`: slot-parallel SPMD dispatch,
        least-loaded-shard admission).  ``None`` = single-device.
    observability:
        a `PoolObservability` (serving/metrics.py): the pool folds every
        chunk boundary into its registry/ring buffer, and the driver
        amends each boundary's sample with loop-side signals (lagging
        consumers, partial-queue depth, connected streams) and traces the
        delivery/pacing phases.  Thread-safe with ``offload_ticks`` (the
        registry and ring lock internally).  ``None`` = fully off.
    overload_policy:
        what happens when the admission queue (``max_pending``) is full:
        ``"wait"`` (default) blocks the caller until a slot frees — the
        pre-robustness behaviour; ``"shed"`` raises `AdmissionShed`
        immediately (retriable, with a ``retry_after_ms`` hint) so the
        caller's backpressure is explicit and bounded-latency.
    idle_timeout_s:
        reap sessions whose client has gone silent (no send/close) for
        this many wall-clock seconds: the slot frees and the client's
        handle fails with `SessionTimeout` (retriable).  ``None`` = never.
    watchdog:
        catch a crashed tick loop instead of failing every client: the
        driver snapshots the salvageable sessions (serving/checkpoint.py),
        rebuilds the pool, restores them and resumes.  Only sessions whose
        state is unrecoverable fail — with `DriverRecovered` (retriable) —
        everyone else continues bit-identically.  ``max_recoveries`` caps
        successive rebuilds; past it the driver fails loudly as before.
    faults:
        a `FaultInjector` threaded into the pool — deterministic chaos
        for the robustness suite (tests/test_faults.py).  ``None`` in
        production.
    """

    DEFAULT_PARTIAL_QUEUE_LEN = 32

    def __init__(self, engine: BatchedSpartusEngine, capacity: int, *,
                 chunk_frames: int = 8, target_chunk_ms: float = 0.0,
                 max_pending: Optional[int] = None, max_frames: int = 64,
                 max_buffer_frames: Optional[int] = None,
                 partial_queue_len: Optional[int] = None,
                 offload_ticks: bool = True,
                 n_devices: Optional[int] = None,
                 observability: Optional[PoolObservability] = None,
                 overload_policy: str = "wait",
                 idle_timeout_s: Optional[float] = None,
                 watchdog: bool = False,
                 max_recoveries: int = 8,
                 faults: Optional[FaultInjector] = None):
        if chunk_frames < 1:
            raise ValueError("AsyncSpartusServer requires chunk_frames >= 1 "
                             "(the per-chunk partial-logits contract)")
        if overload_policy not in ("wait", "shed"):
            raise ValueError(f"overload_policy must be 'wait' or 'shed', "
                             f"got {overload_policy!r}")
        self.obs = observability
        self._tracer = (observability.tracer if observability is not None
                        else NULL_TRACER)
        self._engine = engine
        # the watchdog rebuilds the pool from these exact kwargs (modulo
        # max_frames, which tracks the live pool's grown buffer bucket):
        self._pool_kwargs = dict(
            max_frames=max_frames, chunk_frames=chunk_frames,
            max_buffer_frames=max_buffer_frames, stream_partials=True,
            n_devices=n_devices, observability=observability, faults=faults)
        self.pool = SessionPool(engine, capacity, **self._pool_kwargs)
        self.capacity = capacity
        self.overload_policy = overload_policy
        self.idle_timeout_s = idle_timeout_s
        self.watchdog = watchdog
        self.max_recoveries = max_recoveries
        self.n_recoveries = 0
        self._tokens: Dict[str, StreamHandle] = {}
        self.chunk_frames = chunk_frames
        self.target_chunk_s = target_chunk_ms * 1e-3
        self.max_pending = max_pending
        self.partial_queue_len = (self.DEFAULT_PARTIAL_QUEUE_LEN
                                  if partial_queue_len is None
                                  else max(int(partial_queue_len), 0))
        self._sem = (asyncio.Semaphore(max_pending)
                     if max_pending is not None else None)
        self._offload = offload_ticks
        self._exec: Optional[ThreadPoolExecutor] = None
        self._ids = itertools.count()
        self._clients: Dict[int, _ClientState] = {}
        self._waiting: Deque[_ClientState] = deque()
        # batched-pump bookkeeping: only clients with buffered ops are
        # visited per boundary (the pump used to scan every client every
        # iteration), and the partial-snapshot toggle is a counter, not
        # an any() sweep:
        self._dirty: set = set()
        self._lagging: set = set()
        self._n_partial_subs = 0
        self._wake: Optional[asyncio.Event] = None
        self._driver: Optional[asyncio.Task] = None
        self._stopping = False
        self.now = 0            # scheduler tick clock (frames granularity)
        self._steps = 0         # ticks that advanced >= 1 slot (flush-only
        #                         iterations excluded, like serve_requests)
        self._completed: List[RequestResult] = []
        self._t_start: Optional[float] = None
        self._t_last: Optional[float] = None

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> None:
        if self._driver is not None:
            raise RuntimeError("server already started")
        self._wake = asyncio.Event()
        if self._offload:
            self._exec = ThreadPoolExecutor(max_workers=1,
                                            thread_name_prefix="spartus-tick")
        self._stopping = False
        self._t_start = time.perf_counter()
        self._driver = asyncio.create_task(self._drive(), name="spartus-drive")

    async def stop(self) -> None:
        """Drain: waits for every connected stream to finish (clients must
        ``close()`` or ``cancel()`` their streams), then stops the driver."""
        if self._driver is None:
            return
        self._stopping = True
        self._wake.set()
        try:
            await self._driver
        finally:
            self._driver = None
            if self._exec is not None:
                self._exec.shutdown(wait=False)
                self._exec = None

    async def __aenter__(self) -> "AsyncSpartusServer":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- client API ----------------------------------------------------------

    async def stream(self, feats: Optional[np.ndarray] = None, *,
                     want_partials: bool = True,
                     token: Optional[str] = None) -> StreamHandle:
        """Open a streaming session; under the default ``"wait"`` overload
        policy this awaits while the admission queue is full
        (backpressure); under ``"shed"`` it raises `AdmissionShed` instead.
        ``feats`` optionally seeds initial frames.  ``token`` makes the
        open idempotent: re-opening with a token that already names a live
        stream returns the SAME handle, so a client retrying after a
        dropped ack cannot double-admit its utterance."""
        if self._driver is None:
            raise RuntimeError("server is not started")
        if self._stopping:
            raise RuntimeError("server is stopping")
        if token is not None:
            existing = self._tokens.get(token)
            if existing is not None:
                return existing           # idempotent re-open
        arrival_wall = time.perf_counter()
        if feats is not None:
            # validate BEFORE anything is enqueued: a bad request must be
            # a per-request error, never a poisoned admission the driver
            # trips over later.
            feats = self._validated(feats)
        if self._sem is not None:
            if self.overload_policy == "shed" and self._sem.locked():
                if self.obs is not None:
                    self.obs.fold_shed()
                raise AdmissionShed(retry_after_ms=max(
                    self.target_chunk_s * 1e3, 50.0))
            await self._sem.acquire()     # <- the admission-queue bound
        req_id = next(self._ids)
        handle = StreamHandle(self, req_id)
        cs = _ClientState(req_id, handle, arrival_wall, want_partials,
                          token=token)
        if feats is not None:
            cs.buffered.append(feats)
        self._clients[req_id] = cs
        self._waiting.append(cs)
        if token is not None:
            self._tokens[token] = handle
        if want_partials:
            self._n_partial_subs += 1
        self._wake.set()
        return handle

    async def submit(self, feats: np.ndarray, *,
                     want_partials: bool = False) -> RequestResult:
        """Serve one complete utterance and await its result (the simplest
        client: no incremental feeding, partials off by default)."""
        handle = await self.stream(feats, want_partials=want_partials)
        handle.close()
        return await handle.result()

    async def submit_stream(
        self, blocks: AsyncIterator[np.ndarray], *,
        want_partials: bool = True,
    ) -> StreamHandle:
        """Open a session fed from an async iterator of frame blocks (a
        background task pumps it and closes the stream at exhaustion)."""
        handle = await self.stream(want_partials=want_partials)

        async def pump() -> None:
            try:
                async for block in blocks:
                    await handle.send(block)
                handle.close()
            except asyncio.CancelledError:
                handle.cancel()
                raise

        # keep a strong reference: the loop only holds tasks weakly, and a
        # GC'd feeder would silently starve the stream.
        handle._feed_task = asyncio.create_task(
            pump(), name=f"spartus-feed-{handle.req_id}")
        return handle

    # client ops are plain buffer writes on the loop thread; the driver
    # moves them into the pool at the next boundary:

    def _validated(self, frames: np.ndarray, already: int = 0) -> np.ndarray:
        """Shape/dim/dtype/finiteness/size checks at the client boundary,
        so malformed input raises in the offending client's call — as a
        typed `BadRequest` — and can never reach the pool (where it would
        crash the shared driver or, worse, poison a neighbour's chunk)."""
        try:
            arr = np.asarray(frames)
            if arr.dtype.kind not in "fiu":
                raise BadRequest(
                    f"frames have unsupported dtype {arr.dtype} "
                    f"(expected a float or integer array)")
            block = _as_frames(arr)
            if block.shape[-1] != self.pool.engine.input_dim:
                raise BadRequest(
                    f"frames must have feature dim "
                    f"{self.pool.engine.input_dim}, got {block.shape[-1]}")
            if not np.isfinite(block).all():
                raise BadRequest("frames contain NaN/Inf values")
            if already + block.shape[0] > self.pool.max_buffer_frames:
                raise BadRequest(
                    f"{already + block.shape[0]} frames would exceed the "
                    f"frame-buffer growth limit (max_buffer_frames="
                    f"{self.pool.max_buffer_frames})")
        except BadRequest:
            if self.obs is not None:
                self.obs.fold_bad_request()
            raise
        except ValueError as exc:       # _as_frames' shape complaint
            if self.obs is not None:
                self.obs.fold_bad_request()
            raise BadRequest(str(exc)) from exc
        return block

    def _client_send(self, req_id: int, frames: np.ndarray) -> None:
        cs = self._clients.get(req_id)
        if cs is None or cs.closed or cs.cancelled:
            raise StreamClosed(f"stream {req_id} is closed")
        in_pool = cs.admitted and req_id in self.pool._by_req
        already = (sum(b.shape[0] for b in cs.buffered)
                   + (self.pool._live(req_id).n_recv if in_pool else 0))
        cs.buffered.append(self._validated(frames, already))
        cs.last_activity = time.perf_counter()
        self._dirty.add(req_id)
        self._wake.set()

    def _client_close(self, req_id: int) -> None:
        cs = self._clients.get(req_id)
        if cs is None or cs.cancelled:
            return
        cs.closed = True
        cs.last_activity = time.perf_counter()
        self._dirty.add(req_id)
        self._wake.set()

    def _client_cancel(self, req_id: int) -> None:
        cs = self._clients.get(req_id)
        if cs is None or cs.cancelled:
            return
        cs.cancelled = True
        self._dirty.add(req_id)
        self._wake.set()

    def _note_drain(self, req_id: int) -> None:
        """A consumer took an item off its partials queue: if its session
        is lagging, wake the driver so `_service_lagging` can backfill
        and resume it even when the pool is otherwise idle."""
        if req_id in self._lagging and self._wake is not None:
            self._wake.set()

    # -- driver --------------------------------------------------------------

    def _pump(self) -> None:
        """Move client state into the pool (driver only, between ticks):
        admissions for waiting clients while slots are free, then frame
        appends / finishes / cancellations for the clients that actually
        changed since the last boundary (the dirty set) — one batched
        pass per chunk boundary instead of an every-client scan."""
        pool = self.pool
        # partial snapshots cost a per-chunk [B, C, n_classes] copy+fetch;
        # skip them entirely while nobody subscribed (pure-submit load).
        # Counter-maintained: the any()-over-clients sweep this replaces
        # was per-iteration O(clients):
        pool.stream_partials = self._n_partial_subs > 0
        # clients cancelled while still queued need no slot to settle:
        if self._waiting and any(cs.cancelled for cs in self._waiting):
            for cs in [c for c in self._waiting if c.cancelled]:
                self._waiting.remove(cs)
                self._settle_cancel(cs)
        while self._waiting and pool.n_free:
            cs = self._waiting[0]
            if cs.cancelled:
                self._waiting.popleft()
                self._settle_cancel(cs)
                continue
            feats = _concat(cs.buffered)
            cs.buffered.clear()
            try:
                admitted = pool.admit_stream(cs.req_id, self.now,
                                             feats=feats,
                                             arrival_wall=cs.arrival_wall)
            except Exception as exc:        # a bad request fails ITSELF,
                self._waiting.popleft()     # never the shared driver
                self._settle_error(cs, exc)
                continue
            if not admitted:
                break                       # raced a slot; retry next tick
            self._waiting.popleft()
            cs.admitted = True
            cs.handle.admitted.set()
            if self._sem is not None:
                self._sem.release()
            if cs.closed:
                pool.finish_stream(cs.req_id)
                cs.finish_sent = True
        dirty, self._dirty = self._dirty, set()
        for req_id in sorted(dirty):
            cs = self._clients.get(req_id)
            if cs is None or not cs.admitted:
                continue   # settled, or still waiting (its buffered ops
                #            ride along at admission time)
            if cs.cancelled:
                # the session may be live OR already inside the
                # retirement window (finished, host fetch outstanding):
                # pool.cancel covers both, suppressing the result at
                # resolve time so no stale logits are ever delivered.
                try:
                    pool.cancel(req_id)
                except KeyError:
                    pass                    # already fully resolved
                self._settle_cancel(cs)
                continue
            try:
                if cs.buffered:
                    pool.append_frames(req_id, _concat(cs.buffered))
                    cs.buffered.clear()
                if cs.closed and not cs.finish_sent:
                    pool.finish_stream(req_id)
                    cs.finish_sent = True
            except Exception as exc:
                try:
                    pool.cancel(req_id)
                except KeyError:
                    pass
                self._settle_error(cs, exc)

    def _forget(self, cs: _ClientState) -> None:
        """Drop driver-side bookkeeping for a client leaving the server."""
        self._dirty.discard(cs.req_id)
        self._lagging.discard(cs.req_id)
        if cs.token is not None:
            self._tokens.pop(cs.token, None)
        if cs.want_partials:
            self._n_partial_subs -= 1

    def _settle_cancel(self, cs: _ClientState) -> None:
        del self._clients[cs.req_id]
        self._forget(cs)
        if not cs.admitted and self._sem is not None:
            self._sem.release()
        cs.handle._partials.put_nowait(_EOS)
        if not cs.handle._result.done():
            cs.handle._result.cancel()

    def _settle_error(self, cs: _ClientState, exc: Exception) -> None:
        """Fail ONE client's handle with its own error (driver stays up)."""
        self._clients.pop(cs.req_id, None)
        self._forget(cs)
        if not cs.admitted and self._sem is not None:
            self._sem.release()
        cs.handle._partials.put_nowait(_EOS)
        if not cs.handle._result.done():
            cs.handle._result.set_exception(exc)

    def _push_partial(self, cs: _ClientState, t0: int,
                      rows: np.ndarray) -> None:
        """Enqueue one partial block, bounded: trim anything a backfill
        already covered, and on a full queue mark the session lagging —
        pause its pool-side snapshots, buffer nothing (the skipped rows
        stay in the device logits bank until the client drains)."""
        n = rows.shape[0]
        if t0 + n <= cs.delivered_t:
            return                       # backfill already covered it
        if t0 < cs.delivered_t:          # partial overlap after a backfill
            rows = rows[cs.delivered_t - t0:]
            t0 = cs.delivered_t
        q = cs.handle._partials
        if self.partial_queue_len and q.qsize() >= self.partial_queue_len:
            if not cs.lagging:
                cs.lagging = True
                self._lagging.add(cs.req_id)
                try:
                    self.pool.pause_partials(cs.req_id)
                except KeyError:
                    pass                 # retired already; the final
                    #                      result carries the tail
            return
        q.put_nowait(PartialLogits(req_id=cs.req_id, t0=t0, rows=rows))
        cs.delivered_t = t0 + rows.shape[0]

    def _service_lagging(self) -> None:
        """Resume sessions whose slow consumer drained below the bound:
        backfill the skipped range in ONE catch-up fetch from the device
        logits bank, then re-enable their per-chunk snapshots."""
        if not self._lagging:
            return
        for req_id in sorted(self._lagging):
            cs = self._clients.get(req_id)
            if cs is None:
                self._lagging.discard(req_id)
                continue
            q = cs.handle._partials
            if self.partial_queue_len and \
                    q.qsize() >= self.partial_queue_len:
                continue                 # still stalled
            if req_id in self.pool._by_req:
                rows = self.pool.peek_rows(req_id, cs.delivered_t)
                if rows.shape[0]:
                    q.put_nowait(PartialLogits(
                        req_id=req_id, t0=cs.delivered_t, rows=rows))
                    cs.delivered_t += rows.shape[0]
                self.pool.resume_partials(req_id)
            cs.lagging = False
            self._lagging.discard(req_id)

    def _deliver(self, partials: List[PartialLogits],
                 finished: List[RequestResult]) -> None:
        """One batched delivery pass per chunk boundary: every partial
        block and result lands on its client's queue/future here (the
        waiting tasks' wakeups are then scheduled together by the event
        loop, instead of interleaving per-session pokes with pool work)."""
        for p in partials:
            cs = self._clients.get(p.req_id)
            if cs is not None and cs.want_partials:
                self._push_partial(cs, p.t0, p.rows)
        if not finished:
            return
        self._t_last = time.perf_counter()   # one clock read per boundary
        for r in finished:
            self._completed.append(r)
            cs = self._clients.pop(r.req_id, None)
            if cs is None:
                continue
            self._forget(cs)
            if cs.want_partials and cs.delivered_t < r.logits.shape[0]:
                # lagging tail: the queue bound skipped blocks that never
                # got a drain; the result rows are host-side already, so
                # the catch-up block is one slice, not a device fetch.
                cs.handle._partials.put_nowait(PartialLogits(
                    req_id=r.req_id, t0=cs.delivered_t,
                    rows=r.logits[cs.delivered_t:]))
                cs.delivered_t = r.logits.shape[0]
            cs.handle._partials.put_nowait(_EOS)
            if not cs.handle._result.done():
                cs.handle._result.set_result(r)

    def _has_work(self) -> bool:
        pool = self.pool
        return (pool.max_chunk_advance() > 0 or pool.has_pending
                or pool.has_retirable
                or bool(self._waiting and pool.n_free))

    async def _drive(self) -> None:
        try:
            await self._drive_loop()
        except Exception as exc:
            # fail loudly: every connected client sees the driver's error
            # instead of hanging on a queue that will never fill.
            for cs in list(self._clients.values()):
                cs.handle._partials.put_nowait(_EOS)
                if not cs.handle._result.done():
                    cs.handle._result.set_exception(exc)
            self._clients.clear()
            self._waiting.clear()
            raise

    async def _drive_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            # re-read the pool EVERY iteration: the watchdog swaps it out
            # under our feet on recovery, and a cached local would tick a
            # dead pool forever.
            pool = self.pool
            self._wake.clear()
            self._pump()
            self._service_lagging()
            self._reap_idle()
            if not self._has_work():
                if self._stopping and not self._clients and \
                        not self._waiting:
                    break
                if self.idle_timeout_s is not None:
                    # poll so the reaper runs even with zero client
                    # activity (a wholly silent fleet still times out):
                    try:
                        await asyncio.wait_for(
                            self._wake.wait(),
                            timeout=max(self.idle_timeout_s / 4, 0.01))
                    except asyncio.TimeoutError:
                        pass
                else:
                    await self._wake.wait()
                continue
            t0 = loop.time()
            try:
                if self._exec is not None:
                    finished, adv = await loop.run_in_executor(
                        self._exec, pool.tick, self.now)
                else:
                    finished, adv = pool.tick(self.now)
            except asyncio.CancelledError:
                raise
            except Exception as exc:
                if not self.watchdog or \
                        self.n_recoveries >= self.max_recoveries:
                    raise       # -> _drive fails every client, loudly
                finished, adv = self._recover(exc)
            self.now += max(adv, 1)
            self._steps += adv
            with self._tracer.span("delivery_pump"):
                self._deliver(self.pool.take_partials(), finished)
            if self.obs is not None:
                self._fold_loop_side(dispatched=adv > 0)
            with self._tracer.span("pacing_idle"):
                if self.target_chunk_s > 0.0:
                    # wall-clock-paced boundaries: one chunk per period;
                    # the sleep is where client coroutines get the loop.
                    delay = self.target_chunk_s - (loop.time() - t0)
                    await asyncio.sleep(delay if delay > 0 else 0)
                else:
                    await asyncio.sleep(0)  # free-run, but stay preemptible

    # -- robustness ----------------------------------------------------------

    def _reap_idle(self) -> None:
        """Cancel sessions whose client has gone silent past
        ``idle_timeout_s`` — the slot frees, the handle fails with a
        retriable `SessionTimeout`.  Closed streams are exempt: their
        client finished sending and is legitimately waiting on the pool."""
        if self.idle_timeout_s is None or not self._clients:
            return
        now = time.perf_counter()
        for cs in list(self._clients.values()):
            if cs.closed or cs.cancelled:
                continue
            if now - cs.last_activity < self.idle_timeout_s:
                continue
            if cs.admitted:
                try:
                    self.pool.cancel(cs.req_id)
                except KeyError:
                    pass                 # already resolving
            else:
                try:
                    self._waiting.remove(cs)
                except ValueError:
                    pass
            if self.obs is not None:
                self.obs.fold_timeouts(1)
            self._settle_error(cs, SessionTimeout(
                f"session {cs.req_id} idle for >= {self.idle_timeout_s}s"))

    def _recover(self, exc: Exception):
        """Watchdog: the tick raised.  Salvage every session the device
        state still covers (serving/checkpoint.py snapshot), rebuild the
        pool, restore them, and resume — only the unsalvageable sessions
        fail, each with a retriable `DriverRecovered`.

        Deliberately a *sync* method called from the driver coroutine: the
        gathered device fetch inside is the recovery path, not the hot
        loop, and the loop SHOULD stall here — there is no pool to serve
        until the rebuild finishes."""
        from repro.serving import checkpoint as ckptlib
        t_rec = time.perf_counter()
        self.n_recoveries += 1
        old = self.pool
        if self.obs is not None and not isinstance(exc, InjectedFault):
            # injected faults were already folded by SessionPool._fire
            self.obs.fold_fault("driver")
        finished: List[RequestResult] = []
        failed: Dict[int, Exception] = {}
        # 1. resolve what the previous chunk already computed — those
        #    fetches were dispatched before the crash and are intact:
        try:
            finished.extend(old.flush())
        except Exception:
            pass    # the fetch itself was poisoned; those sessions fail
            #         below when their snapshots fail too
        # 2. snapshot the survivors: whole-pool first (one gathered
        #    fetch), per-session on failure so one poisoned slot doesn't
        #    take the rest down with it:
        snaps = []
        try:
            snaps = list(ckptlib.snapshot_pool(old).sessions)
        except Exception:
            for req_id in list(old._by_req):
                try:
                    snaps.append(ckptlib.snapshot_session(old, req_id))
                except Exception as sub:
                    failed[req_id] = sub
        # 3. fresh pool, same shape (max_frames tracks the old pool's
        #    grown bucket so restore never needs a regrow):
        kwargs = dict(self._pool_kwargs)
        kwargs["max_frames"] = old.pool_config()["max_frames"]
        new = SessionPool(self._engine, self.capacity, **kwargs)
        new.n_dispatches = old.n_dispatches          # stats continuity
        new._overlap_fracs = list(old._overlap_fracs)
        restored = []
        for snap in snaps:
            try:
                new.restore_session(snap)
                restored.append(snap)
            except Exception as sub:
                failed[snap.req_id] = sub
        self.pool = new
        # 4. restored streams with undelivered partial rows: mark them
        #    lagging so _service_lagging backfills [delivered_t, cursor)
        #    from the new pool's logits bank in one catch-up fetch:
        for snap in restored:
            cs = self._clients.get(snap.req_id)
            if cs is not None and cs.want_partials and not cs.lagging:
                cs.lagging = True
                self._lagging.add(cs.req_id)
                try:
                    new.pause_partials(cs.req_id)
                except KeyError:
                    pass
        # 5. the unsalvageable fail individually — retriable, the server
        #    is alive again:
        for req_id, sub in failed.items():
            cs = self._clients.get(req_id)
            if cs is not None:
                self._settle_error(cs, DriverRecovered(
                    f"session {req_id} lost in driver recovery "
                    f"({type(exc).__name__}: {exc}); cause: {sub}"))
        if self.obs is not None:
            self.obs.fold_recovery(
                salvaged=len(restored), lost=len(failed),
                seconds=time.perf_counter() - t_rec)
        return finished, 0

    # -- observability -------------------------------------------------------

    def _fold_loop_side(self, *, dispatched: bool) -> None:
        """Fold the driver-loop-side signals the pool cannot see: lagging
        consumers, the deepest partial queue, connected streams.  When
        this iteration dispatched a chunk, also amend the boundary sample
        the pool just appended — host bookkeeping only, no device work."""
        obs = self.obs
        lagging = len(self._lagging)
        depth = max((cs.handle._partials.qsize()
                     for cs in self._clients.values()), default=0)
        obs.g_lagging.set(lagging)
        obs.g_queue_depth.set(depth)
        obs.g_connected.set(len(self._clients))
        if dispatched:
            obs.timeseries.update_last({
                "lagging": lagging,
                "partial_queue_depth_max": depth,
            })

    @property
    def n_connected(self) -> int:
        """Streams currently open (admitted + waiting)."""
        return len(self._clients)

    def stats(self) -> ServeStats:
        """Aggregate stats over the requests completed so far (same shape
        as `serve_requests`' — latency/TTFL/queue-wait percentiles are
        wall-clock, measured under whatever concurrency actually ran)."""
        t0 = self._t_start if self._t_start is not None else 0.0
        t1 = self._t_last if self._t_last is not None else t0
        return aggregate_stats(
            self._completed,
            capacity=self.capacity,
            n_requests=len(self._completed),
            total_steps=self._steps,
            wall_s=max(t1 - t0, 0.0),
            sparsity=self.pool.measured_sparsity(),
            chunk_frames=self.chunk_frames,
            n_dispatches=self.pool.n_dispatches,
            host_overlap_frac=self.pool.mean_host_overlap_frac(),
            bytes_per_slot=self.pool.bytes_per_slot(),
        )


def _as_frames(x: np.ndarray) -> np.ndarray:
    arr = np.asarray(x, np.float32)
    if arr.ndim == 1:
        arr = arr[None]
    if arr.ndim != 2:
        raise ValueError(f"frames must be [n, D] or [D], got {arr.shape}")
    return arr


def _concat(blocks: List[np.ndarray]) -> Optional[np.ndarray]:
    if not blocks:
        return None
    return blocks[0] if len(blocks) == 1 else np.concatenate(blocks)
