"""Continuous-batching session scheduler for streaming DeltaLSTM serving.

The datacenter serving pattern (ESE's channel-multiplexed multi-voice
engine, SHARP's adaptive RNN scheduler) translated to software: one
weight-resident `BatchedSpartusEngine` and a `SessionPool` that
multiplexes many independent streaming requests across its fixed-capacity
slot dimension.

Lifecycle of a request:

  queued ──admit──> active(slot k) ──per-frame steps──> finished
            ^                                              │
            └── backpressure: waits while no slot is free ─┘

* `admit` attaches a request to a free slot; the slot's device state is
  re-initialised by the `reset` mask *inside* the next `step_batch`, so
  admission never triggers an extra dispatch or a recompile.
* `step` advances all active slots one frame in ONE jitted call, fetches
  the `[B, n_classes]` logits once, appends each active slot's row to its
  request, and retires slots whose utterance is exhausted.
* Idle slots ride along masked-out for free; the pool never reshapes, so
  the step function compiles exactly once per capacity.

`serve_requests` is the batteries-included driver: feed it an iterable of
requests with arrival times (in scheduler ticks), get per-request logits
plus queue/service/latency metrics back.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.serving.batched_engine import BatchedSpartusEngine, PoolState


@dataclasses.dataclass
class StreamRequest:
    """One streaming utterance: `feats [T, D]` arriving at `arrival_step`."""

    req_id: int
    arrival_step: int
    feats: np.ndarray

    @property
    def n_frames(self) -> int:
        return int(self.feats.shape[0])


@dataclasses.dataclass
class RequestResult:
    req_id: int
    arrival_step: int
    admit_step: int       # tick the request got a slot
    finish_step: int      # tick its last frame was produced
    logits: np.ndarray    # [T, n_classes]
    wall_latency_s: float  # wall time from eligibility to last frame

    @property
    def queue_steps(self) -> int:
        return self.admit_step - self.arrival_step

    @property
    def service_steps(self) -> int:
        return self.finish_step - self.admit_step + 1

    @property
    def turnaround_steps(self) -> int:
        return self.finish_step - self.arrival_step + 1


@dataclasses.dataclass
class _Session:
    request: StreamRequest
    admit_step: int
    arrival_wall: float
    cursor: int = 0
    needs_reset: bool = True
    rows: List[np.ndarray] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeStats:
    capacity: int
    n_requests: int
    total_frames: int
    total_steps: int
    wall_s: float
    frames_per_s: float
    p50_latency_s: float
    p95_latency_s: float
    p50_turnaround_steps: float
    p95_turnaround_steps: float
    # aggregated device-side telemetry (telemetry.measured_sparsity output),
    # the input to hwsim.spartus_model.evaluate_from_telemetry:
    sparsity: Dict[str, float] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


class SessionPool:
    """Fixed-capacity pool of device-resident streaming sessions."""

    def __init__(self, engine: BatchedSpartusEngine, capacity: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.engine = engine
        self.capacity = capacity
        self.state: PoolState = engine.init_state(capacity)
        self._slots: List[Optional[_Session]] = [None] * capacity
        # reused host-side staging buffer for the next frame of every slot:
        self._x = np.zeros((capacity, engine.input_dim), np.float32)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def n_free(self) -> int:
        return self.capacity - self.n_active

    def admit(self, request: StreamRequest, now: int,
              arrival_wall: Optional[float] = None) -> bool:
        """Attach `request` to a free slot; False if the pool is full."""
        if request.n_frames == 0:
            raise ValueError(f"request {request.req_id} has no frames")
        if request.feats.shape[-1] != self.engine.input_dim:
            raise ValueError(
                f"request {request.req_id}: feature dim "
                f"{request.feats.shape[-1]} != engine input dim "
                f"{self.engine.input_dim}")
        for k in range(self.capacity):
            if self._slots[k] is None:
                self._slots[k] = _Session(
                    request=request, admit_step=now,
                    arrival_wall=(time.perf_counter() if arrival_wall is None
                                  else arrival_wall))
                return True
        return False

    def step(self, now: int) -> List[RequestResult]:
        """Advance every active session one frame (one jitted call).
        Returns the requests that finished on this tick."""
        active = np.zeros((self.capacity,), bool)
        reset = np.zeros((self.capacity,), bool)
        self._x[:] = 0.0
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            active[k] = True
            reset[k] = sess.needs_reset
            self._x[k] = sess.request.feats[sess.cursor]
        if not active.any():
            return []

        self.state, logits = self.engine.step_batch(
            self.state, self._x, active, reset)
        logits_np = np.asarray(logits)          # ONE device->host fetch/tick

        finished: List[RequestResult] = []
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            sess.needs_reset = False
            sess.rows.append(logits_np[k].copy())  # detach from the batch row
            sess.cursor += 1
            if sess.cursor >= sess.request.n_frames:
                finished.append(RequestResult(
                    req_id=sess.request.req_id,
                    arrival_step=sess.request.arrival_step,
                    admit_step=sess.admit_step,
                    finish_step=now,
                    logits=np.stack(sess.rows),
                    wall_latency_s=time.perf_counter() - sess.arrival_wall,
                ))
                self._slots[k] = None
        return finished

    def measured_sparsity(self) -> Dict[str, float]:
        return self.engine.measured_sparsity(self.state)


RequestLike = Union[StreamRequest, Tuple[int, np.ndarray]]


def _normalize(requests: Iterable[RequestLike]) -> List[StreamRequest]:
    out: List[StreamRequest] = []
    for i, r in enumerate(requests):
        if isinstance(r, StreamRequest):
            out.append(r)
        else:
            arrival, feats = r
            out.append(StreamRequest(req_id=i, arrival_step=int(arrival),
                                     feats=np.asarray(feats, np.float32)))
    return sorted(out, key=lambda r: (r.arrival_step, r.req_id))


def serve_requests(
    engine: BatchedSpartusEngine,
    requests: Iterable[RequestLike],
    capacity: int,
    max_steps: Optional[int] = None,
) -> Tuple[List[RequestResult], ServeStats]:
    """Drive a request stream through a `SessionPool` to completion.

    requests: iterable of StreamRequest or `(arrival_step, feats [T, D])`.
    Admission is FIFO in arrival order; a request that finds the pool full
    waits (backpressure) and is admitted as soon as a slot frees.  Returns
    per-request results (logits + latency) and aggregate throughput stats.
    """
    pool = SessionPool(engine, capacity)
    pending = deque(_normalize(requests))
    n_requests = len(pending)
    waiting: deque[Tuple[StreamRequest, float]] = deque()
    results: List[RequestResult] = []
    now = 0
    total_steps = 0
    t0 = time.perf_counter()

    while pending or waiting or pool.n_active:
        # fast-forward over idle time to the next arrival:
        if not waiting and not pool.n_active and pending:
            now = max(now, pending[0].arrival_step)
        while pending and pending[0].arrival_step <= now:
            waiting.append((pending.popleft(), time.perf_counter()))
        while waiting and pool.n_free:
            req, arr_wall = waiting.popleft()
            pool.admit(req, now, arrival_wall=arr_wall)
        results.extend(pool.step(now))
        total_steps += 1
        now += 1
        if max_steps is not None and total_steps >= max_steps:
            break

    wall = time.perf_counter() - t0
    results.sort(key=lambda r: r.req_id)
    lat = np.array([r.wall_latency_s for r in results], np.float64)
    tas = np.array([r.turnaround_steps for r in results], np.float64)
    frames = int(sum(r.logits.shape[0] for r in results))
    stats = ServeStats(
        capacity=capacity,
        n_requests=n_requests,
        total_frames=frames,
        total_steps=total_steps,
        wall_s=wall,
        frames_per_s=frames / wall if wall > 0 else float("inf"),
        p50_latency_s=float(np.percentile(lat, 50)) if len(lat) else 0.0,
        p95_latency_s=float(np.percentile(lat, 95)) if len(lat) else 0.0,
        p50_turnaround_steps=float(np.percentile(tas, 50)) if len(tas) else 0.0,
        p95_turnaround_steps=float(np.percentile(tas, 95)) if len(tas) else 0.0,
        sparsity=pool.measured_sparsity(),
    )
    return results, stats
