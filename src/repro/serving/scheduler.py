"""Continuous-batching session scheduler for streaming DeltaLSTM serving.

The datacenter serving pattern (ESE's channel-multiplexed multi-voice
engine, SHARP's adaptive RNN scheduler) translated to software: one
weight-resident `BatchedSpartusEngine` and a `SessionPool` that
multiplexes many independent streaming requests across its fixed-capacity
slot dimension.

Lifecycle of a request:

  queued ──admit──> active(slot k) ──per-frame steps──> finished
            ^                                              │
            └── backpressure: waits while no slot is free ─┘

* `admit` attaches a request to a free slot and uploads its *whole*
  utterance `[T, D]` into the slot's device-resident feature buffer once;
  the slot's device state is re-initialised by the `reset` mask *inside*
  the next `step_frames`, so admission never triggers an extra dispatch
  or a recompile.
* `step` advances all active slots one frame in ONE jitted call
  (`step_frames`): each slot's current frame is gathered **on device** by
  the cursor carried in `PoolState` — the tick moves zero frame bytes
  host -> device — then the `[B, n_classes]` logits are fetched once,
  each active slot's row appended to its request, and slots whose
  utterance is exhausted retire.
* `step_chunk` (``chunk_frames >= 1``) amortises that dispatch over up to
  C frames: ONE `lax.scan`-backed dispatch advances every slot by up to C
  frames, banking logits in a per-slot device output buffer, and the pool
  runs **double-buffered**: while chunk t executes on device, the host
  does chunk t's retirement bookkeeping and the next admissions, and the
  device->host logits fetch of chunk t-1's retired sessions.  A finished
  session's logits leave the device once, at retirement, instead of one
  `[B, n_classes]` row fetch per tick.  Admission happens at chunk
  boundaries only.
* Idle slots ride along masked-out for free; the pool never reshapes (the
  frame buffer length is bucketed to powers of two), so the step function
  compiles once per (capacity, bucket).

`serve_requests` is the batteries-included driver: feed it an iterable of
requests with arrival times (in scheduler ticks), get per-request logits
plus queue/service/latency metrics back; ``chunk_frames=C`` selects the
chunked path (0 keeps the per-frame oracle path).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.batched_engine import BatchedSpartusEngine, PoolState


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _device_upload(
    frames: jax.Array, lengths: jax.Array, rows: jax.Array,
    slots: jax.Array, ts: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Scatter one admission wave's (bucket-padded) utterances + lengths
    into the pool's device buffers at DYNAMIC slot indices.

    rows [R, T_buf, D], slots/ts [R] int32; padding entries carry an
    out-of-bounds slot and are dropped.  Jitted with traced indices so it
    compiles once per (buffer shape, R-bucket): an eagerly dispatched
    ``frames.at[slot, :t].set(...)`` re-lowers per (slot, t) pair and
    cost ~2 ms PER ADMISSION on the CPU backend — an admission storm of
    16 requests used to spend longer staging frames than the device
    spends computing a 32-frame chunk.  The buffers are donated, so the
    scatter updates them in place instead of copying the whole slab (the
    runtime serializes the write against any in-flight chunk still
    reading the old frames)."""
    frames = frames.at[slots].set(rows, mode="drop")
    lengths = lengths.at[slots].set(ts, mode="drop")
    return frames, lengths


@jax.jit
def _snapshot(out_buf: jax.Array) -> jax.Array:
    """Copy the chunk's logits buffer in ONE device op (shape-stable: a
    single compile per pool, however many sessions retire), detaching the
    retirees' rows before the next chunk donates the buffer away.  The
    retired sessions' rows are then fetched in one D2H copy and sliced
    host-side — an eager slice + fetch per session cost ~0.5 ms each."""
    return out_buf.copy()


@dataclasses.dataclass
class StreamRequest:
    """One streaming utterance: `feats [T, D]` arriving at `arrival_step`."""

    req_id: int
    arrival_step: int
    feats: np.ndarray

    @property
    def n_frames(self) -> int:
        return int(self.feats.shape[0])


@dataclasses.dataclass
class RequestResult:
    req_id: int
    arrival_step: int
    admit_step: int       # tick the request got a slot
    finish_step: int      # tick its last frame was produced
    logits: np.ndarray    # [T, n_classes]
    wall_latency_s: float  # wall time from eligibility to last frame
    truncated: bool = False  # stopped by max_steps with frames still pending
    #                          (logits holds the frames produced so far)

    @property
    def queue_steps(self) -> int:
        return self.admit_step - self.arrival_step

    @property
    def service_steps(self) -> int:
        return self.finish_step - self.admit_step + 1

    @property
    def turnaround_steps(self) -> int:
        return self.finish_step - self.arrival_step + 1


@dataclasses.dataclass
class _Session:
    request: StreamRequest
    admit_step: int
    arrival_wall: float
    cursor: int = 0
    needs_reset: bool = True
    rows: List[np.ndarray] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _PendingChunk:
    """Sessions that finished inside an in-flight chunk: their logits rows
    were gathered out of the device output buffer in one op (async,
    BEFORE the next chunk donates that buffer away) and are fetched to
    host one chunk later — one D2H copy for all of them — overlapped with
    the next chunk's device execution."""

    sessions: List[_Session]
    slots: List[int]       # pool slot each session occupied
    finish_steps: List[int]
    rows: jax.Array        # [B, T_pad, n_classes] device-side snapshot


@dataclasses.dataclass
class ServeStats:
    capacity: int
    n_requests: int
    total_frames: int
    total_steps: int      # ticks that advanced >= 1 slot (idle ticks excluded)
    wall_s: float
    frames_per_s: float
    p50_latency_s: float
    p95_latency_s: float
    p50_turnaround_steps: float
    p95_turnaround_steps: float
    # aggregated device-side telemetry (telemetry.measured_sparsity output),
    # the input to hwsim.spartus_model.evaluate_from_telemetry:
    sparsity: Dict[str, float] = dataclasses.field(default_factory=dict)
    # True when max_steps stopped the run before every request completed;
    # in-flight sessions were drained into truncated RequestResults:
    truncated: bool = False
    # dispatch amortisation: jitted device dispatches issued and their
    # ratio to frames served — the per-frame path pays ~1/B dispatches per
    # frame, the chunked path ~1/(B*C):
    chunk_frames: int = 0            # 0 = per-frame path
    n_dispatches: int = 0
    dispatches_per_frame: float = 0.0
    # mean fraction of each step_chunk call's wall time the host spent on
    # useful work after the dispatch returned (retirement bookkeeping, the
    # device-side snapshot, the previous chunk's logits fetch) — all
    # concurrent with the in-flight device chunk; 0.0 on the per-frame
    # path, which syncs on its logits every tick:
    host_overlap_frac: float = 0.0

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _frame_bucket(n: int, floor: int = 64) -> int:
    """Frame-buffer length bucket: next power of two, >= ``floor``.  Keeps
    the device buffer shape (and thus the compiled step) stable across
    utterance lengths; growth past the bucket recompiles once."""
    b = floor
    while b < n:
        b *= 2
    return b


class SessionPool:
    """Fixed-capacity pool of device-resident streaming sessions.

    Request features live on device: ``admit`` uploads the whole utterance
    `[T, D]` into the slot's row of a `[B, T_buf, D]` buffer once, and every
    tick gathers each slot's current frame by the device cursor in
    ``PoolState`` — the steady state issues zero per-tick host staging
    copies (the old `step_batch` path re-staged every slot's frame on host
    each tick, which at large hidden sizes cost more than the math).

    With ``chunk_frames=C >= 1`` the pool runs the chunked tick loop:
    ``step_chunk`` advances every active slot up to C frames in ONE
    dispatch and banks logits in a per-slot device output buffer
    `[B, T_buf, n_classes]`; retired sessions' logits are fetched once, at
    retirement, double-buffered one chunk behind the in-flight dispatch.
    A chunked pool steps with ``step_chunk``/``flush`` only (``step``
    raises: the two modes account logits differently).
    """

    def __init__(self, engine: BatchedSpartusEngine, capacity: int,
                 max_frames: int = 64, chunk_frames: int = 0):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if chunk_frames < 0:
            raise ValueError("chunk_frames must be >= 0 (0 = per-frame)")
        self.engine = engine
        self.capacity = capacity
        self.chunk_frames = chunk_frames
        self.state: PoolState = engine.init_state(capacity)
        self._slots: List[Optional[_Session]] = [None] * capacity
        # device-resident per-slot feature buffers, uploaded at admission:
        self._t_buf = _frame_bucket(max_frames)
        self._frames = jnp.zeros((capacity, self._t_buf, engine.input_dim),
                                 jnp.float32)
        # per-slot utterance lengths (device) — the chunk masks a slot off
        # once its cursor reaches its length:
        self._lengths = jnp.zeros((capacity,), jnp.int32)
        # chunked mode: device logits buffer + retirements pending their
        # (overlapped) host fetch.  The time axis is padded by
        # chunk_frames so the chunk's banking slice never clamps: rows
        # past a session's length are scratch no reader consumes.
        self._out: Optional[jax.Array] = (
            engine.init_out_buf(capacity, self._t_buf + chunk_frames)
            if chunk_frames else None)
        self._pending: Optional[_PendingChunk] = None
        # admissions staged host-side, flushed to device in ONE batched
        # upload at the next step/chunk boundary:
        self._staged: List[Tuple[int, np.ndarray]] = []
        # observability: buffer growths (should be 0 when pre-sized),
        # dispatches issued, and per-chunk host-overlap fractions:
        self.n_frame_grows = 0
        self.n_dispatches = 0
        self._overlap_fracs: List[float] = []

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def n_free(self) -> int:
        return self.capacity - self.n_active

    @property
    def has_pending(self) -> bool:
        """Chunked mode: retired sessions whose logits fetch is still
        outstanding (resolved by the next ``step_chunk`` or ``flush``)."""
        return self._pending is not None

    def admit(self, request: StreamRequest, now: int,
              arrival_wall: Optional[float] = None) -> bool:
        """Attach `request` to a free slot; False if the pool is full."""
        if request.n_frames == 0:
            raise ValueError(f"request {request.req_id} has no frames")
        if request.feats.shape[-1] != self.engine.input_dim:
            raise ValueError(
                f"request {request.req_id}: feature dim "
                f"{request.feats.shape[-1]} != engine input dim "
                f"{self.engine.input_dim}")
        for k in range(self.capacity):
            if self._slots[k] is None:
                self._slots[k] = _Session(
                    request=request, admit_step=now,
                    arrival_wall=(time.perf_counter() if arrival_wall is None
                                  else arrival_wall))
                # host-side staging only; the device upload happens once
                # per admission wave, at the next step/chunk boundary
                self._staged.append(
                    (k, np.asarray(request.feats, np.float32)))
                return True
        return False

    def _flush_uploads(self) -> None:
        """One batched H2D copy of every utterance admitted since the last
        step (the whole admission wave: [R, T_buf, D] in one ``device_put``
        + one jitted scatter, with R bucketed to a power of two so at most
        log2(capacity) variants ever compile).

        The only host->device bytes are the new utterances themselves:
        when a long utterance outgrows the bucket, the frame slab is
        reallocated ONCE, straight to the new utterance's bucket, and the
        resident slots' frames are copied device->device — never re-staged
        from host (regression-tested in tests/test_chunked_serving.py).
        Growth recompiles the step for the new bucket, so drivers pre-size
        ``max_frames`` to the longest known utterance."""
        if not self._staged:
            return
        t_max = max(f.shape[0] for _, f in self._staged)
        if t_max > self._t_buf:
            old_t, new_t = self._t_buf, _frame_bucket(t_max,
                                                      floor=self._t_buf)
            grown = jnp.zeros((self.capacity, new_t, self.engine.input_dim),
                              jnp.float32)
            self._frames = grown.at[:, :old_t, :].set(self._frames)
            if self._out is not None:
                out = jnp.zeros((self.capacity, new_t + self.chunk_frames,
                                 self.engine.n_classes), jnp.float32)
                self._out = out.at[
                    :, :old_t + self.chunk_frames, :].set(self._out)
            self._t_buf = new_t
            self.n_frame_grows += 1
        rb = _frame_bucket(len(self._staged), floor=1)
        rows = np.zeros((rb, self._t_buf, self.engine.input_dim), np.float32)
        slots = np.full((rb,), self.capacity, np.int32)  # OOB pad: dropped
        ts = np.zeros((rb,), np.int32)
        for i, (k, feats) in enumerate(self._staged):
            rows[i, :feats.shape[0]] = feats  # zero tail clears stale rows
            slots[i] = k
            ts[i] = feats.shape[0]
        self._staged.clear()
        self._frames, self._lengths = _device_upload(
            self._frames, self._lengths, jax.device_put(rows), slots, ts)

    def _masks(self) -> Tuple[np.ndarray, np.ndarray]:
        active = np.zeros((self.capacity,), bool)
        reset = np.zeros((self.capacity,), bool)
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            active[k] = True
            reset[k] = sess.needs_reset
        return active, reset

    def step(self, now: int) -> List[RequestResult]:
        """Advance every active session one frame (one jitted call).
        Returns the requests that finished on this tick."""
        if self.chunk_frames:
            raise RuntimeError(
                "this pool was built with chunk_frames >= 1; "
                "drive it with step_chunk()/flush(), not step()")
        active, reset = self._masks()
        if not active.any():
            return []
        self._flush_uploads()

        self.state, logits = self.engine.step_frames(
            self.state, self._frames, active, reset)
        self.n_dispatches += 1
        logits_np = np.asarray(logits)          # ONE device->host fetch/tick

        finished: List[RequestResult] = []
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            sess.needs_reset = False
            sess.rows.append(logits_np[k].copy())  # detach from the batch row
            sess.cursor += 1
            if sess.cursor >= sess.request.n_frames:
                finished.append(RequestResult(
                    req_id=sess.request.req_id,
                    arrival_step=sess.request.arrival_step,
                    admit_step=sess.admit_step,
                    finish_step=now,
                    logits=np.stack(sess.rows),
                    wall_latency_s=time.perf_counter() - sess.arrival_wall,
                ))
                self._slots[k] = None
        return finished

    # -- chunked tick loop ---------------------------------------------------

    def max_chunk_advance(self) -> int:
        """Ticks the next ``step_chunk`` will consume: min(chunk_frames,
        longest remaining utterance).  0 when no session is active."""
        rem = [s.request.n_frames - s.cursor
               for s in self._slots if s is not None]
        return min(self.chunk_frames, max(rem)) if rem else 0

    def _chunk_len(self) -> int:
        """Scan length for the next chunk dispatch: the pow2 bucket of the
        actual advance, capped at chunk_frames.  Tail chunks therefore run
        a shorter scan instead of C mostly-masked iterations, and the jit
        compiles at most log2(C) variants."""
        adv = self.max_chunk_advance()
        return min(self.chunk_frames, _frame_bucket(adv, floor=1))

    def step_chunk(self, now: int) -> List[RequestResult]:
        """Advance every active session up to ``chunk_frames`` frames in
        ONE device dispatch, double-buffered.

        Returns the results of sessions that retired in the PREVIOUS
        chunk: their device->host logits fetch happens here, overlapped
        with the chunk just dispatched (JAX async dispatch returns before
        the device finishes).  Sessions finishing in THIS chunk have their
        output-buffer rows sliced off device-side now — before the next
        dispatch donates the buffer away — and surface on the next
        ``step_chunk``/``flush`` call.  Call ``flush()`` after the last
        chunk to collect the tail."""
        if not self.chunk_frames:
            raise RuntimeError(
                "this pool was built with chunk_frames=0; use step()")
        active, reset = self._masks()
        if not active.any():
            return self.flush()
        n = self._chunk_len()
        self._flush_uploads()

        t0 = time.perf_counter()
        self.state, self._out = self.engine.step_chunk(
            self.state, self._frames, self._lengths, active, reset,
            self._out, n_frames=n)
        self.n_dispatches += 1
        t_dispatched = time.perf_counter()

        # ---- everything below overlaps the in-flight device chunk ----
        retiring: List[_Session] = []
        slots: List[int] = []
        finish_steps: List[int] = []
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            sess.needs_reset = False
            adv = min(self.chunk_frames, sess.request.n_frames - sess.cursor)
            sess.cursor += adv
            if sess.cursor >= sess.request.n_frames:
                retiring.append(sess)
                slots.append(k)
                finish_steps.append(now + adv - 1)
                self._slots[k] = None
        newly = None
        if retiring:
            # snapshot the output buffer NOW, in one device op: it is
            # dispatched against this chunk's output before the next
            # step_chunk donates it, detaching the rows device-side; the
            # one-copy host fetch waits one more chunk.
            newly = _PendingChunk(sessions=retiring, slots=slots,
                                  finish_steps=finish_steps,
                                  rows=_snapshot(self._out))
        finished = self._resolve_pending()   # syncs on the PREVIOUS chunk
        t_end = time.perf_counter()
        self._pending = newly

        wall = t_end - t0
        if wall > 0:
            # fraction of this call's wall time spent doing useful host
            # work AFTER the dispatch returned — retirement bookkeeping,
            # the snapshot dispatch, and the previous chunk's logits
            # fetch — all concurrent with the device executing this chunk.
            self._overlap_fracs.append((t_end - t_dispatched) / wall)
        return finished

    def flush(self) -> List[RequestResult]:
        """Resolve retirements still pending from the last dispatched
        chunk (the double-buffer tail)."""
        return self._resolve_pending()

    def _resolve_pending(self) -> List[RequestResult]:
        if self._pending is None:
            return []
        p, self._pending = self._pending, None
        rows = np.asarray(p.rows)              # ONE fetch for all retirees
        out: List[RequestResult] = []
        for sess, k, fin in zip(p.sessions, p.slots, p.finish_steps):
            out.append(RequestResult(
                req_id=sess.request.req_id,
                arrival_step=sess.request.arrival_step,
                admit_step=sess.admit_step,
                finish_step=fin,
                logits=rows[k, :sess.request.n_frames].copy(),
                wall_latency_s=time.perf_counter() - sess.arrival_wall,
            ))
        return out

    def mean_host_overlap_frac(self) -> float:
        return float(np.mean(self._overlap_fracs)) if self._overlap_fracs \
            else 0.0

    def drain(self, now: int) -> List[RequestResult]:
        """Evict every in-flight session, returning truncated
        ``RequestResult``s with the logits produced so far (used when
        ``serve_requests`` hits ``max_steps`` mid-stream, so partial work is
        surfaced instead of silently dropped).  In chunked mode the
        already-finished (pending-fetch) sessions are resolved first, then
        partial sessions' rows are read from the device output buffer —
        truncation granularity is the chunk."""
        n_classes = self.engine.n_classes
        self._staged.clear()    # evicted sessions' uploads must not land
        out: List[RequestResult] = self._resolve_pending()
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            if self.chunk_frames:
                logits = (np.asarray(self._out[k, :sess.cursor])
                          if sess.cursor
                          else np.zeros((0, n_classes), np.float32))
            else:
                logits = (np.stack(sess.rows) if sess.rows
                          else np.zeros((0, n_classes), np.float32))
            out.append(RequestResult(
                req_id=sess.request.req_id,
                arrival_step=sess.request.arrival_step,
                admit_step=sess.admit_step,
                finish_step=now,
                logits=logits,
                wall_latency_s=time.perf_counter() - sess.arrival_wall,
                truncated=True,
            ))
            self._slots[k] = None
        return out

    def measured_sparsity(self) -> Dict[str, float]:
        return self.engine.measured_sparsity(self.state)


RequestLike = Union[StreamRequest, Tuple[int, np.ndarray]]


def _normalize(requests: Iterable[RequestLike]) -> List[StreamRequest]:
    out: List[StreamRequest] = []
    for i, r in enumerate(requests):
        if isinstance(r, StreamRequest):
            out.append(r)
        else:
            arrival, feats = r
            out.append(StreamRequest(req_id=i, arrival_step=int(arrival),
                                     feats=np.asarray(feats, np.float32)))
    return sorted(out, key=lambda r: (r.arrival_step, r.req_id))


def serve_requests(
    engine: BatchedSpartusEngine,
    requests: Iterable[RequestLike],
    capacity: int,
    max_steps: Optional[int] = None,
    chunk_frames: int = 0,
) -> Tuple[List[RequestResult], ServeStats]:
    """Drive a request stream through a `SessionPool` to completion.

    requests: iterable of StreamRequest or `(arrival_step, feats [T, D])`.
    Admission is FIFO in arrival order; a request that finds the pool full
    waits (backpressure) and is admitted as soon as a slot frees.  Returns
    per-request results (logits + latency) and aggregate throughput stats.

    ``chunk_frames=C >= 1`` selects the chunked tick loop: one device
    dispatch advances all active sessions up to C frames, logits are
    banked on device and fetched once per session at retirement
    (double-buffered behind the next chunk), and admission happens at
    chunk boundaries — higher throughput (fewer dispatches/frame), up to
    C-1 ticks of extra queueing latency.  ``chunk_frames=0`` (default)
    keeps the per-frame path, which is the chunked path's parity oracle.

    If ``max_steps`` stops the run early, in-flight sessions are drained
    into ``RequestResult``s with ``truncated=True`` holding their partial
    logits (never-admitted requests have no partial logits and are simply
    absent from the results); ``stats.truncated`` flags the cut — in
    chunked mode the cut lands on the first chunk boundary at or past
    ``max_steps``, so partial logits come in chunk granularity.
    ``total_steps`` counts only ticks that advanced at least one slot, so
    frames/step utilisation is not diluted by idle fast-forward ticks.
    """
    pending = deque(_normalize(requests))
    n_requests = len(pending)
    # pre-size the device frame buffers to the longest utterance so no
    # mid-run bucket growth (= recompile) can happen:
    max_frames = max((r.n_frames for r in pending), default=1)
    pool = SessionPool(engine, capacity, max_frames=max_frames,
                       chunk_frames=chunk_frames)
    waiting: deque[Tuple[StreamRequest, float]] = deque()
    results: List[RequestResult] = []
    now = 0
    total_steps = 0
    truncated = False
    t0 = time.perf_counter()

    while pending or waiting or pool.n_active or pool.has_pending:
        # fast-forward over idle time to the next arrival:
        if not waiting and not pool.n_active and pending:
            now = max(now, pending[0].arrival_step)
        while pending and pending[0].arrival_step <= now:
            waiting.append((pending.popleft(), time.perf_counter()))
        while waiting and pool.n_free:
            req, arr_wall = waiting.popleft()
            pool.admit(req, now, arrival_wall=arr_wall)
        # count only ticks that advance >= 1 slot: the arrival fast-forward
        # above makes idle iterations rare, but total_steps feeds per-step
        # utilisation metrics and must stay exact if the loop ever changes
        # (e.g. wall-clock-paced ticking instead of fast-forward).
        if chunk_frames:
            adv = pool.max_chunk_advance()
            results.extend(pool.step_chunk(now) if adv else pool.flush())
            total_steps += adv
            now += max(adv, 1)
        else:
            dispatched = pool.n_active > 0
            results.extend(pool.step(now))
            if dispatched:
                total_steps += 1
            now += 1
        if max_steps is not None and total_steps >= max_steps:
            truncated = bool(pending or waiting or pool.n_active)
            results.extend(pool.drain(now - 1))
            break

    wall = time.perf_counter() - t0
    results.sort(key=lambda r: r.req_id)
    lat = np.array([r.wall_latency_s for r in results], np.float64)
    tas = np.array([r.turnaround_steps for r in results], np.float64)
    frames = int(sum(r.logits.shape[0] for r in results))
    stats = ServeStats(
        capacity=capacity,
        n_requests=n_requests,
        total_frames=frames,
        total_steps=total_steps,
        wall_s=wall,
        frames_per_s=frames / wall if wall > 0 else float("inf"),
        p50_latency_s=float(np.percentile(lat, 50)) if len(lat) else 0.0,
        p95_latency_s=float(np.percentile(lat, 95)) if len(lat) else 0.0,
        p50_turnaround_steps=float(np.percentile(tas, 50)) if len(tas) else 0.0,
        p95_turnaround_steps=float(np.percentile(tas, 95)) if len(tas) else 0.0,
        sparsity=pool.measured_sparsity(),
        truncated=truncated,
        chunk_frames=chunk_frames,
        n_dispatches=pool.n_dispatches,
        dispatches_per_frame=pool.n_dispatches / frames if frames else 0.0,
        host_overlap_frac=pool.mean_host_overlap_frac(),
    )
    return results, stats
