"""Continuous-batching session scheduler for streaming DeltaLSTM serving.

The datacenter serving pattern (ESE's channel-multiplexed multi-voice
engine, SHARP's adaptive RNN scheduler) translated to software: one
weight-resident `BatchedSpartusEngine` and a `SessionPool` that
multiplexes many independent streaming requests across its fixed-capacity
slot dimension.

Lifecycle of a request:

  queued ──admit──> active(slot k) ──per-frame steps──> finished
            ^                                              │
            └── backpressure: waits while no slot is free ─┘

* `admit` attaches a request to a free slot and uploads its *whole*
  utterance `[T, D]` into the slot's device-resident feature buffer once;
  the slot's device state is re-initialised by the `reset` mask *inside*
  the next `step_frames`, so admission never triggers an extra dispatch
  or a recompile.  `admit_stream` admits a session whose utterance is
  still arriving: frames are appended incrementally (`append_frames`),
  the session simply idles ("starved") whenever it has consumed
  everything received so far, and `finish_stream` marks the end of the
  utterance.  A starved session costs nothing: it rides the chunk
  masked out, exactly like a free slot.
* `step` advances all active slots one frame in ONE jitted call
  (`step_frames`): each slot's current frame is gathered **on device** by
  the cursor carried in `PoolState` — the tick moves zero frame bytes
  host -> device — then the `[B, n_classes]` logits are fetched once,
  each active slot's row appended to its request, and slots whose
  utterance is exhausted retire.
* `step_chunk` (``chunk_frames >= 1``) amortises that dispatch over up to
  C frames: ONE `lax.scan`-backed dispatch advances every slot by up to C
  frames, banking logits in a per-slot device output buffer, and the pool
  runs **double-buffered**: while chunk t executes on device, the host
  does chunk t's retirement bookkeeping and the next admissions, and the
  device->host logits fetch of chunk t-1's retired sessions.  A finished
  session's logits leave the device once, at retirement, instead of one
  `[B, n_classes]` row fetch per tick.  Admission happens at chunk
  boundaries only.
* ``stream_partials=True`` additionally snapshots **each chunk's** rows
  for every live slot (`engine.snapshot_chunk`, a `[B, C, n_classes]`
  device copy — not the whole output buffer) and surfaces them one chunk
  later as `PartialLogits`, so a streaming consumer sees logits per
  chunk instead of only at retirement.  This is what the asyncio
  front-end (`serving/async_server.py`) feeds to its per-session queues.
* `tick` is the non-blocking driver entry point: one call does at most
  one dispatch (chunk or frame), retires sessions that finished without
  needing another dispatch, and returns `(finished_results,
  frames_advanced)` without waiting for the device (JAX async dispatch;
  the only sync is the previous chunk's one-copy logits fetch).
* Idle slots ride along masked-out for free; the pool never reshapes (the
  frame buffer length is bucketed to powers of two), so the step function
  compiles once per (capacity, bucket).  Growth past
  ``max_buffer_frames`` is refused at admission time with a clear error
  instead of silently truncating.

`serve_requests` is the batteries-included synchronous driver: feed it an
iterable of requests with arrival times (in scheduler ticks), get
per-request logits plus queue/service/latency metrics back;
``chunk_frames=C`` selects the chunked path (0 keeps the per-frame oracle
path).  It is also the parity oracle the async front-end is pinned
against in tests.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import lockorder
from repro.serving.batched_engine import BatchedSpartusEngine, PoolState
from repro.serving.faults import FaultInjector
from repro.serving.metrics import NULL_TRACER, PoolObservability
from repro.serving import sharding as shardlib
from repro.serving import telemetry as tele

#: default ceiling on the per-slot frame-buffer length (frames).  The device
#: buffers grow by pow2 buckets up to this; an utterance that could not fit
#: is rejected at admission with a ValueError instead of being truncated at
#: some later chunk boundary.
DEFAULT_MAX_BUFFER_FRAMES = 4096


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _device_upload(
    frames: jax.Array, lengths: jax.Array, rows: jax.Array,
    slots: jax.Array, ts: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Scatter one admission wave's (bucket-padded) utterances + lengths
    into the pool's device buffers at DYNAMIC slot indices.

    rows [R, T_buf, D], slots/ts [R] int32; padding entries carry an
    out-of-bounds slot and are dropped.  Jitted with traced indices so it
    compiles once per (buffer shape, R-bucket): an eagerly dispatched
    ``frames.at[slot, :t].set(...)`` re-lowers per (slot, t) pair and
    cost ~2 ms PER ADMISSION on the CPU backend — an admission storm of
    16 requests used to spend longer staging frames than the device
    spends computing a 32-frame chunk.  The buffers are donated, so the
    scatter updates them in place instead of copying the whole slab (the
    runtime serializes the write against any in-flight chunk still
    reading the old frames)."""
    frames = frames.at[slots].set(rows, mode="drop")
    lengths = lengths.at[slots].set(ts, mode="drop")
    return frames, lengths


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _device_append(
    frames: jax.Array, lengths: jax.Array, rows: jax.Array,
    slots: jax.Array, starts: jax.Array, ts: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Append one wave of mid-stream frame blocks into live slots' buffers.

    rows [R, A, D] (A = pow2 bucket of the wave's longest block), slots
    [R] int32 (out-of-bounds = padding, dropped), starts [R] int32 (frame
    offset of each block = frames received so far), ts [R] int32 new total
    length.  One gather + vmapped ``dynamic_update_slice`` + one scatter,
    jitted so incremental streaming admission costs one dispatch per wave
    like the full-utterance upload.  The caller guarantees
    ``start + A <= T_buf`` (growing the buffer first if needed) so the
    slice never clamps into earlier frames."""
    safe = jnp.minimum(slots, frames.shape[0] - 1)
    cur = frames[safe]                                     # [R, T_buf, D]
    upd = jax.vmap(
        lambda b, r, st: jax.lax.dynamic_update_slice(b, r, (st, 0))
    )(cur, rows, starts)
    frames = frames.at[slots].set(upd, mode="drop")
    lengths = lengths.at[slots].set(ts, mode="drop")
    return frames, lengths


def validated_frames(feats, req_id: int,
                     input_dim: Optional[int] = None) -> np.ndarray:
    """Admission-time payload validation (shared by ``admit``,
    ``append_frames`` and the async server): reject non-numeric dtypes
    and NaN/Inf values with a clear ValueError BEFORE the frames reach
    the shared device batch — one poisoned utterance must never corrupt
    neighbour sessions' logits.  Returns the float32 frame array.

    Host-side and admission-only: the isfinite scan runs once per
    received frame block, never per tick, so the hot path is untouched.
    """
    arr = np.asarray(feats)
    if arr.dtype.kind not in "fiu":
        raise ValueError(
            f"request {req_id}: frames have unsupported dtype {arr.dtype} "
            f"(expected a float or integer array)")
    arr = np.asarray(arr, np.float32)
    if input_dim is not None and arr.size and arr.shape[-1] != input_dim:
        raise ValueError(
            f"request {req_id}: feature dim {arr.shape[-1]} != "
            f"engine input dim {input_dim}")
    if not np.isfinite(arr).all():
        raise ValueError(
            f"request {req_id}: frames contain NaN/Inf values")
    return arr


@dataclasses.dataclass
class StreamRequest:
    """One streaming utterance: `feats [T, D]` arriving at `arrival_step`."""

    req_id: int
    arrival_step: int
    feats: np.ndarray

    @property
    def n_frames(self) -> int:
        return int(self.feats.shape[0])


@dataclasses.dataclass
class RequestResult:
    req_id: int
    arrival_step: int
    admit_step: int       # tick the request got a slot
    finish_step: int      # tick its last frame was produced
    logits: np.ndarray    # [T, n_classes]
    wall_latency_s: float  # wall time from eligibility to last frame
    truncated: bool = False  # stopped by max_steps with frames still pending
    #                          (logits holds the frames produced so far)
    queue_wait_s: float = 0.0  # wall time from eligibility to slot admission
    ttfl_s: float = 0.0        # time to first logit: wall time from
    #                            eligibility until the first logits row was
    #                            available host-side (== wall_latency_s when
    #                            logits only surface at retirement)

    @property
    def queue_steps(self) -> int:
        return self.admit_step - self.arrival_step

    @property
    def service_steps(self) -> int:
        return self.finish_step - self.admit_step + 1

    @property
    def turnaround_steps(self) -> int:
        return self.finish_step - self.arrival_step + 1


@dataclasses.dataclass
class PartialLogits:
    """One streamed block of logits for a live session (``stream_partials``):
    rows ``[n, n_classes]`` covering frames ``[t0, t0 + n)``."""

    req_id: int
    t0: int
    rows: np.ndarray


@dataclasses.dataclass
class _Session:
    req_id: int
    arrival_step: int
    admit_step: int
    arrival_wall: float
    admit_wall: float
    total: Optional[int]   # utterance length; None while the client streams
    n_recv: int = 0        # frames received (staged for device upload)
    cursor: int = 0        # frames consumed by the engine
    last_step: int = 0     # tick of the most recent consumed frame
    needs_reset: bool = True
    cancelled: bool = False
    partials_paused: bool = False  # slow consumer: skip snapshot_chunk
    #                                entries for this slot until resumed
    first_logit_wall: float = 0.0  # 0.0 = no logits surfaced yet
    rows: List[np.ndarray] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        """Every frame of a finished utterance has been consumed."""
        return self.total is not None and self.cursor >= self.total

    @property
    def available(self) -> int:
        """Frames received but not yet consumed."""
        return self.n_recv - self.cursor

    def result(self, logits: np.ndarray, *, truncated: bool = False,
               finish_step: Optional[int] = None) -> RequestResult:
        t_done = time.perf_counter()
        first = self.first_logit_wall if self.first_logit_wall else t_done
        return RequestResult(
            req_id=self.req_id,
            arrival_step=self.arrival_step,
            admit_step=self.admit_step,
            finish_step=self.last_step if finish_step is None else finish_step,
            logits=logits,
            wall_latency_s=t_done - self.arrival_wall,
            truncated=truncated,
            queue_wait_s=self.admit_wall - self.arrival_wall,
            ttfl_s=first - self.arrival_wall,
        )


@dataclasses.dataclass
class _PendingChunk:
    """Sessions that finished inside an in-flight chunk: their logits rows
    were gathered out of the device output buffer in one op (async,
    BEFORE the next chunk donates that buffer away) and are fetched to
    host one chunk later — one D2H copy for all of them — overlapped with
    the next chunk's device execution."""

    sessions: List[_Session]
    slots: List[int]       # pool slot each session occupied
    rows: jax.Array        # [B, T_pad, n_classes] device-side snapshot


@dataclasses.dataclass
class _PendingPartials:
    """One chunk's per-slot logits rows (``engine.snapshot_chunk``),
    snapshotted device-side before the next dispatch donates the output
    buffer and fetched one chunk later, overlapped like retirements."""

    entries: List[Tuple[_Session, int, int, int]]  # (session, slot, t0, n)
    rows: jax.Array                                # [B, C, n_classes]


@dataclasses.dataclass
class ServeStats:
    capacity: int
    n_requests: int
    total_frames: int
    total_steps: int      # ticks that advanced >= 1 slot (idle ticks excluded)
    wall_s: float
    frames_per_s: float
    p50_latency_s: float
    p95_latency_s: float
    p50_turnaround_steps: float
    p95_turnaround_steps: float
    # aggregated device-side telemetry (telemetry.measured_sparsity output),
    # the input to hwsim.spartus_model.evaluate_from_telemetry:
    sparsity: Dict[str, float] = dataclasses.field(default_factory=dict)
    # True when max_steps stopped the run before every request completed;
    # in-flight sessions were drained into truncated RequestResults:
    truncated: bool = False
    # dispatch amortisation: jitted device dispatches issued and their
    # ratio to frames served — the per-frame path pays ~1/B dispatches per
    # frame, the chunked path ~1/(B*C):
    chunk_frames: int = 0            # 0 = per-frame path
    n_dispatches: int = 0
    dispatches_per_frame: float = 0.0
    # mean fraction of each step_chunk call's wall time the host spent on
    # useful work after the dispatch returned (retirement bookkeeping, the
    # device-side snapshot, the previous chunk's logits fetch) — all
    # concurrent with the in-flight device chunk; 0.0 on the per-frame
    # path, which syncs on its logits every tick:
    host_overlap_frac: float = 0.0
    # tail latency + streaming responsiveness under concurrency:
    p99_latency_s: float = 0.0
    # queue wait: wall time from request eligibility to slot admission
    # (the backpressure component of the latency):
    p50_queue_wait_s: float = 0.0
    p95_queue_wait_s: float = 0.0
    p99_queue_wait_s: float = 0.0
    # time-to-first-logit: how long a client waits before logits start
    # streaming back (== full latency when logits only surface at
    # retirement, i.e. the sync chunked path without stream_partials):
    p50_ttfl_s: float = 0.0
    p95_ttfl_s: float = 0.0
    p99_ttfl_s: float = 0.0
    # device bytes per resident session (SessionPool.bytes_per_slot):
    # per-slot state slabs + frame/logits rows + the slot's share of the
    # shared packed weights — the capacity currency the int8 quantized
    # pack (EngineConfig.quant) buys back:
    bytes_per_slot: float = 0.0

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def aggregate_stats(
    results: Sequence[RequestResult],
    *,
    capacity: int,
    n_requests: int,
    total_steps: int,
    wall_s: float,
    sparsity: Dict[str, float],
    truncated: bool = False,
    chunk_frames: int = 0,
    n_dispatches: int = 0,
    host_overlap_frac: float = 0.0,
    bytes_per_slot: float = 0.0,
) -> ServeStats:
    """Reduce per-request results to the aggregate `ServeStats` (shared by
    the synchronous `serve_requests` driver and the asyncio front-end)."""
    frames = int(sum(r.logits.shape[0] for r in results))
    lat = [r.wall_latency_s for r in results]
    tas = np.array([r.turnaround_steps for r in results], np.float64)
    pl = tele.percentile_summary(lat, "latency_s")
    pq = tele.percentile_summary([r.queue_wait_s for r in results],
                                 "queue_wait_s")
    pt = tele.percentile_summary([r.ttfl_s for r in results], "ttfl_s")
    return ServeStats(
        capacity=capacity,
        n_requests=n_requests,
        total_frames=frames,
        total_steps=total_steps,
        wall_s=wall_s,
        frames_per_s=frames / wall_s if wall_s > 0 else float("inf"),
        p50_latency_s=pl["p50_latency_s"],
        p95_latency_s=pl["p95_latency_s"],
        p99_latency_s=pl["p99_latency_s"],
        p50_turnaround_steps=float(np.percentile(tas, 50)) if len(tas) else 0.0,
        p95_turnaround_steps=float(np.percentile(tas, 95)) if len(tas) else 0.0,
        sparsity=sparsity,
        truncated=truncated,
        chunk_frames=chunk_frames,
        n_dispatches=n_dispatches,
        dispatches_per_frame=n_dispatches / frames if frames else 0.0,
        host_overlap_frac=host_overlap_frac,
        p50_queue_wait_s=pq["p50_queue_wait_s"],
        p95_queue_wait_s=pq["p95_queue_wait_s"],
        p99_queue_wait_s=pq["p99_queue_wait_s"],
        p50_ttfl_s=pt["p50_ttfl_s"],
        p95_ttfl_s=pt["p95_ttfl_s"],
        p99_ttfl_s=pt["p99_ttfl_s"],
        bytes_per_slot=bytes_per_slot,
    )


def _frame_bucket(n: int, floor: int = 64) -> int:
    """Frame-buffer length bucket: next power of two, >= ``floor``.  Keeps
    the device buffer shape (and thus the compiled step) stable across
    utterance lengths; growth past the bucket recompiles once."""
    b = floor
    while b < n:
        b *= 2
    return b


class SessionPool:
    """Fixed-capacity pool of device-resident streaming sessions.

    Request features live on device: ``admit`` uploads the whole utterance
    `[T, D]` into the slot's row of a `[B, T_buf, D]` buffer once, and every
    tick gathers each slot's current frame by the device cursor in
    ``PoolState`` — the steady state issues zero per-tick host staging
    copies (the old `step_batch` path re-staged every slot's frame on host
    each tick, which at large hidden sizes cost more than the math).

    ``admit_stream`` admits a session before its utterance is complete:
    `append_frames` stages further frame blocks (uploaded one jitted wave
    per boundary, like admissions), `finish_stream` closes the utterance,
    and `cancel` abandons it (the slot frees at the next boundary).  A
    session that has consumed everything received so far simply idles.

    With ``chunk_frames=C >= 1`` the pool runs the chunked tick loop:
    ``step_chunk`` advances every active slot up to C frames in ONE
    dispatch and banks logits in a per-slot device output buffer
    `[B, T_buf, n_classes]`; retired sessions' logits are fetched once, at
    retirement, double-buffered one chunk behind the in-flight dispatch.
    ``stream_partials=True`` also snapshots each chunk's `[B, C,
    n_classes]` rows so live sessions stream partial logits per chunk
    (``take_partials``).  A chunked pool steps with
    ``step_chunk``/``flush``/``tick`` only (``step`` raises: the two modes
    account logits differently).

    An utterance longer than ``max_buffer_frames`` (whether declared at
    admission or accumulated by appends) is rejected with a ValueError:
    the device frame buffers grow in pow2 buckets up to that ceiling and
    nothing in the pool ever truncates silently.

    ``n_devices=N >= 1`` shards the pool's slot dimension over a 1-D
    ``("data",)`` mesh (`serving/sharding.py`): every per-slot device
    slab — layer state, frame buffers, cursors, lengths, the logits
    bank, telemetry — is partitioned into contiguous slot blocks, one
    per device, and the same jitted step/chunk dispatch runs SPMD with
    zero cross-device communication in the steady state (slots are
    independent).  Admission places each session on the least-loaded
    shard; a capacity not divisible by N falls back to replication (the
    never-invalid rule), which is correct but not parallel.  The public
    API is unchanged — only placement differs.
    """

    # Machine-checked lock discipline (repro.analysis.concurrency; see
    # docs/concurrency.md).  Every listed field is rebound at dispatch
    # boundaries by jitted calls that DONATE the old buffers, while
    # cross-thread readers — the async server's ``stats()``, the admin
    # endpoint, checkpoint snapshots — may hold stale references; an
    # unlocked read can fetch a deleted buffer.  Host bookkeeping
    # (``_slots``, ``_by_req``, ``_staged``, ``_staged_appends``,
    # ``_partials``) is tick/driver-thread-only and deliberately absent.
    _guarded_by_ = {
        "state": "_state_lock",
        "_frames": "_state_lock",
        "_lengths": "_state_lock",
        "_out": "_state_lock",
        "_pending": "_state_lock",
        "_pending_partials": "_state_lock",
    }

    def __init__(self, engine: BatchedSpartusEngine, capacity: int,
                 max_frames: int = 64, chunk_frames: int = 0,
                 max_buffer_frames: Optional[int] = None,
                 stream_partials: bool = False,
                 n_devices: Optional[int] = None,
                 observability: Optional[PoolObservability] = None,
                 faults: Optional[FaultInjector] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if chunk_frames < 0:
            raise ValueError("chunk_frames must be >= 0 (0 = per-frame)")
        self.engine = engine
        self.capacity = capacity
        self.chunk_frames = chunk_frames
        self.stream_partials = stream_partials
        self.max_buffer_frames = (DEFAULT_MAX_BUFFER_FRAMES
                                  if max_buffer_frames is None
                                  else int(max_buffer_frames))
        if max_frames > self.max_buffer_frames:
            raise ValueError(
                f"max_frames={max_frames} exceeds max_buffer_frames="
                f"{self.max_buffer_frames}")
        # slot-dimension data parallelism (None = single-device layout,
        # bit-for-bit the pre-sharding pool):
        self._n_devices = n_devices
        # seeded fault-injection hook (serving/faults.py): `_fire(site)`
        # raises InjectedFault at the scheduled invocations; None = off,
        # zero cost (one attribute check per boundary, nothing compiled)
        self.faults = faults
        self._mesh = (shardlib.make_pool_mesh(int(n_devices))
                      if n_devices is not None else None)
        self.n_shards = (shardlib.n_pool_shards(self._mesh, capacity)
                         if self._mesh is not None else 1)
        self.state: PoolState = engine.init_state(capacity)
        self._slots: List[Optional[_Session]] = [None] * capacity
        self._by_req: Dict[int, int] = {}
        # device-resident per-slot feature buffers, uploaded at admission:
        self._t_buf = _frame_bucket(max_frames)
        self._frames = jnp.zeros((capacity, self._t_buf, engine.input_dim),
                                 jnp.float32)
        # per-slot utterance lengths (device) — the chunk masks a slot off
        # once its cursor reaches its length:
        self._lengths = jnp.zeros((capacity,), jnp.int32)
        # chunked mode: device logits buffer + retirements pending their
        # (overlapped) host fetch.  The time axis is padded by
        # chunk_frames so the chunk's banking slice never clamps: rows
        # past a session's length are scratch no reader consumes.
        self._out: Optional[jax.Array] = (
            engine.init_out_buf(capacity, self._t_buf + chunk_frames)
            if chunk_frames else None)
        if self._mesh is not None:
            # one placement pass at construction; the step functions
            # donate every slab, so the sharding persists tick over tick.
            self.state = shardlib.shard_pool_state(self.state, self._mesh)
            self._frames = shardlib.shard_slot_array(self._frames, self._mesh)
            self._lengths = shardlib.shard_slot_array(self._lengths,
                                                      self._mesh)
            if self._out is not None:
                self._out = shardlib.shard_slot_array(self._out, self._mesh)
        self._pending: List[_PendingChunk] = []
        self._pending_partials: List[_PendingPartials] = []
        self._partials: List[PartialLogits] = []
        # admissions staged host-side, flushed to device in ONE batched
        # upload at the next step/chunk boundary; appends staged likewise:
        self._staged: List[Tuple[int, np.ndarray]] = []
        self._staged_appends: List[Tuple[int, int, np.ndarray]] = []
        # observability: buffer growths (should be 0 when pre-sized),
        # dispatches issued, and per-chunk host-overlap fractions:
        self.n_frame_grows = 0
        self.n_dispatches = 0
        self._overlap_fracs: List[float] = []
        # live observability (metrics.PoolObservability): all sources are
        # folded at dispatch boundaries only, on host values the pool
        # already computed — the one device-derived signal (incremental
        # sparsity) is a [3] reduction enqueued here and fetched one
        # boundary later, so observability never syncs on the in-flight
        # chunk and never changes the compiled step (pinned in
        # tests/test_observability.py).  None = fully off; the tracer
        # falls back to the shared no-op NULL_TRACER.
        self.obs = observability
        self._tracer = (observability.tracer if observability is not None
                        else NULL_TRACER)
        self._adm_since_fold = 0
        # Guards the dispatch-and-rebind of ``self.state`` against readers
        # on other threads (the async server's ``stats()`` / the admin
        # endpoint call ``measured_sparsity()`` from the event loop while
        # ``offload_ticks`` runs the tick in a worker).  Dispatch donates
        # the old state's buffers the instant it is issued, so a reader
        # holding a stale reference would fetch a deleted buffer; making
        # (dispatch + rebind) atomic and reading under the same lock means
        # readers only ever see the live (possibly in-flight) state.
        # Created through the lock-order factory so the chaos job's
        # recorder (repro.analysis.lockorder) sees every acquisition; a
        # plain threading.Lock when no recorder is installed.
        self._state_lock = lockorder.make_lock("SessionPool._state_lock")

    def _fire(self, site: str) -> None:
        """Fault-injection hook: raise if the plan scheduled a failure at
        this invocation of ``site``.  A ``"poison"`` payload additionally
        invalidates the device state first — modelling a crash *after* a
        dispatch donated the buffers away, so per-slot salvage must fail
        and the watchdog's lost-session path is exercised."""
        if self.faults is None:
            return
        try:
            self.faults.fire(site)
        except Exception as exc:
            if self.obs is not None:
                self.obs.fold_fault(site)
            if getattr(exc, "payload", None) == "poison":
                with self._state_lock:
                    for leaf in jax.tree_util.tree_leaves(self.state):
                        leaf.delete()
            raise

    def _dev1d(self, arr: np.ndarray) -> jax.Array:
        """Place a per-slot host vector (active/reset masks, chunk-start
        cursors) to match the pool's slot sharding.  Identity-cost when
        unsharded (the jitted step converts host arrays itself); in
        sharded mode an explicit placement keeps every dispatch input on
        the agreed layout so GSPMD never has to guess (a differently
        placed mask would recompile the step)."""
        if self._mesh is None:
            return arr
        return shardlib.shard_slot_array(jnp.asarray(arr), self._mesh)

    def _ensure_slot_sharding(self) -> None:
        """Re-pin the frame/length buffers to the slot sharding if an
        upload scatter's output landed elsewhere (GSPMD usually preserves
        the operand sharding; this is the cheap invariant check that
        makes it a guarantee).  No-op when unsharded."""
        if self._mesh is None:
            return
        fs = shardlib.slot_sharding(self._frames.shape, self._mesh)
        if self._frames.sharding != fs:
            self._frames = jax.device_put(self._frames, fs)
        ls = shardlib.slot_sharding(self._lengths.shape, self._mesh)
        if self._lengths.sharding != ls:
            self._lengths = jax.device_put(self._lengths, ls)

    def shard_loads(self) -> List[int]:
        """Occupied-slot count per shard (admission placement telemetry)."""
        per = self.capacity // self.n_shards
        return [sum(self._slots[k] is not None
                    for k in range(s * per, (s + 1) * per))
                for s in range(self.n_shards)]

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def n_free(self) -> int:
        return self.capacity - self.n_active

    @property
    def has_pending(self) -> bool:
        """Chunked mode: retired sessions (or streamed chunks) whose host
        fetch is still outstanding (resolved by the next ``step_chunk``,
        ``tick`` or ``flush``)."""
        with self._state_lock:
            return bool(self._pending or self._pending_partials
                        or self._partials)

    @property
    def has_retirable(self) -> bool:
        """Sessions that can retire (or be reaped) without another
        dispatch: finished-and-fully-consumed streams, and cancellations
        awaiting their boundary."""
        return any(s is not None and (s.done or s.cancelled)
                   for s in self._slots)

    # -- admission -----------------------------------------------------------

    def admit(self, request: StreamRequest, now: int,
              arrival_wall: Optional[float] = None) -> bool:
        """Attach `request` (a complete utterance) to a free slot; False if
        the pool is full.  Raises ValueError if the utterance could never
        fit the frame buffers (``max_buffer_frames``)."""
        if request.n_frames == 0:
            raise ValueError(f"request {request.req_id} has no frames")
        feats = validated_frames(request.feats, request.req_id)
        return self._bind(request.req_id, request.arrival_step, now, feats,
                          total=request.n_frames, arrival_wall=arrival_wall)

    def admit_stream(self, req_id: int, now: int,
                     feats: Optional[np.ndarray] = None,
                     arrival_step: Optional[int] = None,
                     arrival_wall: Optional[float] = None) -> bool:
        """Admit a session whose utterance is still arriving; False if the
        pool is full.  ``feats`` optionally carries the frames received so
        far; more arrive via ``append_frames`` and ``finish_stream`` closes
        the utterance.  The session idles (masked out, free) whenever it
        has consumed everything received."""
        feats = (np.zeros((0, self.engine.input_dim), np.float32)
                 if feats is None else validated_frames(feats, req_id))
        return self._bind(req_id, now if arrival_step is None else
                          arrival_step, now, feats, total=None,
                          arrival_wall=arrival_wall)

    def _bind(self, req_id: int, arrival_step: int, now: int,
              feats: np.ndarray, total: Optional[int],
              arrival_wall: Optional[float]) -> bool:
        if req_id in self._by_req:
            raise ValueError(f"request {req_id} is already in the pool")
        if feats.size and feats.shape[-1] != self.engine.input_dim:
            raise ValueError(
                f"request {req_id}: feature dim {feats.shape[-1]} != "
                f"engine input dim {self.engine.input_dim}")
        n = int(feats.shape[0])
        if max(n, total or 0) > self.max_buffer_frames:
            raise ValueError(
                f"request {req_id}: utterance of {max(n, total or 0)} frames "
                f"exceeds the frame-buffer growth limit "
                f"(max_buffer_frames={self.max_buffer_frames}); split the "
                f"stream or build the pool with a larger limit")
        k = self._pick_slot()
        if k is None:
            return False
        wall = (time.perf_counter() if arrival_wall is None
                else arrival_wall)
        self._slots[k] = _Session(
            req_id=req_id, arrival_step=arrival_step,
            admit_step=now, arrival_wall=wall,
            admit_wall=time.perf_counter(), total=total,
            n_recv=n, last_step=now - 1)
        self._by_req[req_id] = k
        # host-side staging only; the device upload happens once
        # per admission wave, at the next step/chunk boundary.
        # Zero-length stagings still clear the slot's stale device
        # length from its previous occupant.
        self._staged.append((k, feats))
        self._adm_since_fold += 1
        if self.obs is not None:
            self.obs.fold_admissions(1)
        return True

    def _pick_slot(self) -> Optional[int]:
        """Device-aware slot placement: the first free slot on the
        least-loaded shard (ties toward the lower shard index), so
        admissions spread evenly across devices instead of filling shard
        0 first and leaving the others' slot blocks masked idle.
        Unsharded pools (n_shards == 1) keep the first-free policy —
        identical slot assignment to the pre-sharding pool."""
        if self.n_shards <= 1:
            for k, s in enumerate(self._slots):
                if s is None:
                    return k
            return None
        per = self.capacity // self.n_shards
        best_k, best_load = None, per + 1
        for s in range(self.n_shards):
            free_k, load = None, 0
            for k in range(s * per, (s + 1) * per):
                if self._slots[k] is None:
                    if free_k is None:
                        free_k = k
                else:
                    load += 1
            if free_k is not None and load < best_load:
                best_k, best_load = free_k, load
        return best_k

    def _live(self, req_id: int) -> _Session:
        if req_id not in self._by_req:
            raise KeyError(f"request {req_id} is not in the pool")
        sess = self._slots[self._by_req[req_id]]
        assert sess is not None
        return sess

    def append_frames(self, req_id: int, feats: np.ndarray) -> None:
        """Stage additional frames for a live streaming session (uploaded
        in one jitted wave at the next boundary)."""
        sess = self._live(req_id)
        if sess.total is not None:
            raise ValueError(f"request {req_id} is already finished")
        if sess.cancelled:
            raise ValueError(f"request {req_id} was cancelled")
        feats = validated_frames(feats, req_id)
        if feats.ndim != 2 or feats.shape[-1] != self.engine.input_dim:
            raise ValueError(
                f"request {req_id}: appended frames must be [n, "
                f"{self.engine.input_dim}], got {feats.shape}")
        if feats.shape[0] == 0:
            return
        new_total = sess.n_recv + int(feats.shape[0])
        if new_total > self.max_buffer_frames:
            raise ValueError(
                f"request {req_id}: appending {feats.shape[0]} frames would "
                f"reach {new_total} frames, past the frame-buffer growth "
                f"limit (max_buffer_frames={self.max_buffer_frames})")
        self._staged_appends.append(
            (self._by_req[req_id], sess.n_recv, feats))
        sess.n_recv = new_total

    def finish_stream(self, req_id: int) -> None:
        """No more frames: the session retires once it has consumed
        everything received (possibly without another dispatch)."""
        sess = self._live(req_id)
        if sess.total is None:
            sess.total = sess.n_recv

    def cancel(self, req_id: int) -> None:
        """Abandon a session: its slot frees at the next boundary and no
        result is produced.  Also covers the retirement window — a
        session that already finished inside an in-flight chunk (its
        device-side snapshot taken, the one-chunk-later host fetch still
        outstanding) is suppressed at resolve time, so a cancel can never
        race the double buffer into delivering a dead session's logits.
        Raises KeyError only for a request the pool has no trace of."""
        if req_id in self._by_req:
            sess = self._slots[self._by_req[req_id]]
            assert sess is not None
            if not sess.cancelled and self.obs is not None:
                self.obs.fold_cancelled(1)
            sess.cancelled = True
            return
        with self._state_lock:
            pending = list(self._pending)
        for p in pending:
            for sess in p.sessions:
                if sess.req_id == req_id:
                    if not sess.cancelled and self.obs is not None:
                        self.obs.fold_cancelled(1)
                    sess.cancelled = True
                    return
        raise KeyError(f"request {req_id} is not in the pool")

    def pause_partials(self, req_id: int) -> None:
        """Stop snapshotting partial-logit chunks for one live session (a
        lagging consumer): its frames keep advancing and its logits keep
        banking in the device output buffer, but no further per-chunk
        host copies are made for it until ``resume_partials``.  The
        missed range stays recoverable via ``peek_rows`` (or the final
        ``RequestResult``) — this is the pool half of the async server's
        bounded-queue slow-consumer policy.  Chunked pools only: the
        per-frame path has no logits bank to backfill from, so pausing
        there would silently drop rows."""
        if not self.chunk_frames:
            raise RuntimeError("pause_partials requires a chunked pool "
                               "(chunk_frames >= 1)")
        self._live(req_id).partials_paused = True

    def resume_partials(self, req_id: int) -> None:
        """Re-enable per-chunk partial snapshots for a live session (the
        consumer drained; the caller backfills the gap via ``peek_rows``)."""
        if not self.chunk_frames:
            raise RuntimeError("resume_partials requires a chunked pool "
                               "(chunk_frames >= 1)")
        self._live(req_id).partials_paused = False

    def peek_rows(self, req_id: int, t0: int = 0) -> np.ndarray:
        """Fetch a live session's banked logits rows ``[t0, cursor)`` from
        the device output buffer (chunked mode only).

        This is the slow-consumer backfill path: rows the partial stream
        skipped while the session was paused are still in the logits bank
        (it holds the whole utterance until retirement), so a consumer
        that drains late pays one catch-up fetch instead of the server
        having buffered every skipped chunk host-side.  The fetch syncs
        on the in-flight chunk (the rows include frames it is writing) —
        an explicitly rare, caller-initiated sync, not a steady-state one.
        """
        if not self.chunk_frames:
            raise RuntimeError("peek_rows requires a chunked pool "
                               "(chunk_frames >= 1)")
        sess = self._live(req_id)
        hi = sess.cursor
        if t0 >= hi:
            return np.zeros((0, self.engine.n_classes), np.float32)
        # Same discipline as ``measured_sparsity``: the lock keeps an
        # offloaded tick from donating ``self._out`` away mid-fetch (the
        # PR 6 deleted-buffer race, this time on the logits bank).
        with self._state_lock:
            return np.asarray(self._out[self._by_req[req_id], t0:hi])

    def _reap_cancelled(self) -> None:
        """Free cancelled sessions' slots and drop their staged uploads
        (called at every boundary, before masks are computed)."""
        dead = [k for k, s in enumerate(self._slots)
                if s is not None and s.cancelled]
        if not dead:
            return
        gone = set(dead)
        for k in dead:
            sess = self._slots[k]
            del self._by_req[sess.req_id]
            self._slots[k] = None
        self._staged = [(k, f) for k, f in self._staged if k not in gone]
        self._staged_appends = [(k, st, f) for k, st, f in
                                self._staged_appends if k not in gone]

    # -- device upload staging ----------------------------------------------

    def _merged_appends(self) -> List[Tuple[int, int, np.ndarray]]:
        """Coalesce staged append blocks per slot (they are contiguous by
        construction) so the wave carries one entry per slot."""
        merged: Dict[int, Tuple[int, List[np.ndarray]]] = {}
        for k, start, feats in self._staged_appends:
            if k in merged:
                merged[k][1].append(feats)
            else:
                merged[k] = (start, [feats])
        return [(k, start, np.concatenate(blocks) if len(blocks) > 1
                 else blocks[0]) for k, (start, blocks) in merged.items()]

    def _grow_buffers(self, t_need: int) -> None:
        """ONE device-side realloc straight to ``t_need``'s pow2 bucket;
        resident slots' frames are copied device->device, never re-staged
        from host (regression-tested in tests/test_chunked_serving.py)."""
        old_t = self._t_buf
        new_t = _frame_bucket(t_need, floor=old_t)
        grown = jnp.zeros((self.capacity, new_t, self.engine.input_dim),
                          jnp.float32)
        if self._mesh is not None:
            grown = shardlib.shard_slot_array(grown, self._mesh)
        # lint: allow(eager-scatter) one-time realloc
        self._frames = grown.at[:, :old_t, :].set(self._frames)
        if self._out is not None:
            out = jnp.zeros((self.capacity, new_t + self.chunk_frames,
                             self.engine.n_classes), jnp.float32)
            if self._mesh is not None:
                out = shardlib.shard_slot_array(out, self._mesh)
            self._out = out.at[  # lint: allow(eager-scatter) one-time realloc
                :, :old_t + self.chunk_frames, :].set(self._out)
        self._t_buf = new_t
        self.n_frame_grows += 1

    def _flush_uploads(self) -> None:
        """One batched H2D copy of every utterance admitted — and every
        frame block appended — since the last step (the whole admission
        wave: [R, T_buf, D] in one ``device_put`` + one jitted scatter,
        with R bucketed to a power of two so at most log2(capacity)
        variants ever compile; appends go in a second [R, A, D] wave).

        The only host->device bytes are the new frames themselves: when a
        long utterance outgrows the bucket, the frame slab is reallocated
        ONCE, straight to the needed bucket, and the resident slots'
        frames are copied device->device — never re-staged from host.
        Growth recompiles the step for the new bucket, so drivers pre-size
        ``max_frames`` to the longest known utterance."""
        self._fire("admission_upload")
        appends = self._merged_appends()
        a_pad = (_frame_bucket(max(f.shape[0] for _, _, f in appends),
                               floor=1) if appends else 0)
        t_need = max(
            [f.shape[0] for _, f in self._staged] +
            [start + a_pad for _, start, _ in appends] + [0])
        # The upload scatters DONATE ``self._frames``/``self._lengths``
        # (and a growth rebinds them): the same deleted-buffer hazard as
        # the step dispatch, against a concurrent checkpoint snapshot or
        # admin scrape holding a stale reference — so the whole
        # rebind sequence holds the state lock (the guarded-by checker
        # enforces this; the staged host lists stay driver-thread-only).
        with self._state_lock:
            if t_need > self._t_buf:
                self._grow_buffers(t_need)
            if self._staged:
                rb = _frame_bucket(len(self._staged), floor=1)
                rows = np.zeros((rb, self._t_buf, self.engine.input_dim),
                                np.float32)
                slots = np.full((rb,), self.capacity, np.int32)  # OOB: drop
                ts = np.zeros((rb,), np.int32)
                for i, (k, feats) in enumerate(self._staged):
                    rows[i, :feats.shape[0]] = feats  # zero tail clears stale
                    slots[i] = k
                    ts[i] = feats.shape[0]
                self._staged.clear()
                self._frames, self._lengths = _device_upload(
                    self._frames, self._lengths, jax.device_put(rows),
                    slots, ts)
            if appends:
                rb = _frame_bucket(len(appends), floor=1)
                rows = np.zeros((rb, a_pad, self.engine.input_dim),
                                np.float32)
                slots = np.full((rb,), self.capacity, np.int32)
                starts = np.zeros((rb,), np.int32)
                ts = np.zeros((rb,), np.int32)
                for i, (k, start, feats) in enumerate(appends):
                    rows[i, :feats.shape[0]] = feats
                    slots[i] = k
                    starts[i] = start
                    ts[i] = start + feats.shape[0]
                self._staged_appends.clear()
                self._frames, self._lengths = _device_append(
                    self._frames, self._lengths, jax.device_put(rows), slots,
                    starts, ts)
            self._ensure_slot_sharding()

    def _masks(self) -> Tuple[np.ndarray, np.ndarray]:
        """active = occupied AND has unconsumed frames (a starved streaming
        session rides along masked out); reset = admitted since the last
        dispatch (applied even if the slot starts starved)."""
        active = np.zeros((self.capacity,), bool)
        reset = np.zeros((self.capacity,), bool)
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            active[k] = sess.available > 0
            reset[k] = sess.needs_reset
        return active, reset

    # -- per-frame tick loop -------------------------------------------------

    def step(self, now: int) -> List[RequestResult]:
        """Advance every active session one frame (one jitted call).
        Returns the requests that finished on this tick."""
        if self.chunk_frames:
            raise RuntimeError(
                "this pool was built with chunk_frames >= 1; "
                "drive it with step_chunk()/flush(), not step()")
        self._reap_cancelled()
        active, reset = self._masks()
        if not active.any():
            return []
        with self._tracer.span("admission_upload"):
            self._flush_uploads()
        self._fire("dispatch")

        t0 = time.perf_counter()
        with self._tracer.span("dispatch"), self._state_lock:
            self.state, logits = self.engine.step_frames(
                self.state, self._frames, self._dev1d(active),
                self._dev1d(reset))
        self.n_dispatches += 1
        t_dispatched = time.perf_counter()
        with self._tracer.span("snapshot_fetch"):
            logits_np = np.asarray(logits)      # ONE device->host fetch/tick

        finished: List[RequestResult] = []
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            sess.needs_reset = False
            if not active[k]:
                continue                        # starved: rode along masked
            row = logits_np[k].copy()           # detach from the batch row
            sess.rows.append(row)
            if not sess.first_logit_wall:
                sess.first_logit_wall = time.perf_counter()
            if self.stream_partials:
                self._partials.append(PartialLogits(
                    req_id=sess.req_id, t0=sess.cursor, rows=row[None]))
            sess.cursor += 1
            sess.last_step = now
            if sess.done:
                finished.append(sess.result(np.stack(sess.rows)))
                self._free(k)
        if self.obs is not None:
            self.obs.fold_results(finished)
            self._fold_boundary(
                n_active=int(active.sum()), frames=int(active.sum()),
                dispatch_s=t_dispatched - t0,
                chunk_s=time.perf_counter() - t0,
                overlap=0.0, retirements=len(finished))
        return finished

    def _free(self, k: int) -> None:
        sess = self._slots[k]
        if sess is not None:
            del self._by_req[sess.req_id]
        self._slots[k] = None

    # -- chunked tick loop ---------------------------------------------------

    def max_chunk_advance(self) -> int:
        """Ticks the next ``step_chunk`` will consume: min(chunk_frames,
        most unconsumed frames any session holds).  0 when every session
        is starved (or none is active)."""
        rem = [s.available for s in self._slots if s is not None]
        return min(self.chunk_frames, max(rem)) if rem else 0

    def _chunk_len(self) -> int:
        """Scan length for the next chunk dispatch: the pow2 bucket of the
        actual advance, capped at chunk_frames.  Tail chunks therefore run
        a shorter scan instead of C mostly-masked iterations, and the jit
        compiles at most log2(C) variants."""
        adv = self.max_chunk_advance()
        return min(self.chunk_frames, _frame_bucket(adv, floor=1))

    def step_chunk(self, now: int) -> List[RequestResult]:
        """Advance every active session up to ``chunk_frames`` frames in
        ONE device dispatch, double-buffered.

        Returns the results of sessions that retired in the PREVIOUS
        chunk: their device->host logits fetch happens here, overlapped
        with the chunk just dispatched (JAX async dispatch returns before
        the device finishes).  Sessions finishing in THIS chunk have their
        output-buffer rows sliced off device-side now — before the next
        dispatch donates the buffer away — and surface on the next
        ``step_chunk``/``flush`` call.  With ``stream_partials`` every
        advancing session's chunk rows are snapshotted and surface as
        ``PartialLogits`` (``take_partials``) on the same one-chunk-later
        cadence.  Call ``flush()`` after the last chunk to collect the
        tail."""
        if not self.chunk_frames:
            raise RuntimeError(
                "this pool was built with chunk_frames=0; use step()")
        self._reap_cancelled()
        self._queue_done_retirements()
        active, reset = self._masks()
        if not active.any():
            return self.flush()
        n = self._chunk_len()
        starts = np.array([0 if s is None else s.cursor
                           for s in self._slots], np.int32)
        with self._tracer.span("admission_upload"):
            self._flush_uploads()
        self._fire("dispatch")

        t0 = time.perf_counter()
        with self._tracer.span("dispatch"), self._state_lock:
            self.state, self._out = self.engine.step_chunk(
                self.state, self._frames, self._lengths, self._dev1d(active),
                self._dev1d(reset), self._out, n_frames=n)
        self.n_dispatches += 1
        t_dispatched = time.perf_counter()

        # ---- everything below overlaps the in-flight device chunk ----
        retiring: List[_Session] = []
        slots: List[int] = []
        partial_entries: List[Tuple[_Session, int, int, int]] = []
        frames_this = 0
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            sess.needs_reset = False
            adv = min(n, sess.available)
            if adv <= 0:
                continue
            frames_this += adv
            sess.cursor += adv
            sess.last_step = now + adv - 1
            if self.stream_partials and not sess.partials_paused:
                partial_entries.append((sess, k, int(starts[k]), adv))
            if sess.done:
                retiring.append(sess)
                slots.append(k)
                self._free(k)
        newly: List[_PendingChunk] = []
        newly_partials: List[_PendingPartials] = []
        if retiring or partial_entries:
            with self._state_lock:
                if retiring:
                    # snapshot the output buffer NOW, in one device op: it
                    # is dispatched against this chunk's output before the
                    # next step_chunk donates it, detaching the rows
                    # device-side; the one-copy host fetch waits one more
                    # chunk.
                    newly.append(_PendingChunk(
                        sessions=retiring, slots=slots,
                        rows=self.engine.snapshot_out(self._out)))
                if partial_entries:
                    # likewise for the streamed chunk rows — but only this
                    # chunk's [B, n, n_classes] window, not the whole
                    # buffer:
                    newly_partials.append(_PendingPartials(
                        entries=partial_entries,
                        rows=self.engine.snapshot_chunk(self._out,
                                                        self._dev1d(starts),
                                                        n_frames=n)))
        with self._tracer.span("snapshot_fetch"):
            finished = self._resolve()       # syncs on the PREVIOUS chunk
        t_end = time.perf_counter()
        with self._state_lock:
            self._pending.extend(newly)
            self._pending_partials.extend(newly_partials)

        wall = t_end - t0
        overlap = 0.0
        if wall > 0:
            # fraction of this call's wall time spent doing useful host
            # work AFTER the dispatch returned — retirement bookkeeping,
            # the snapshot dispatch, and the previous chunk's logits
            # fetch — all concurrent with the device executing this chunk.
            overlap = (t_end - t_dispatched) / wall
            self._overlap_fracs.append(overlap)
        if self.obs is not None:
            self._fold_boundary(
                n_active=int(active.sum()), frames=frames_this,
                dispatch_s=t_dispatched - t0, chunk_s=wall,
                overlap=overlap, retirements=len(finished))
        return finished

    def _queue_done_retirements(self) -> None:
        """Retire sessions that are already done WITHOUT another dispatch
        (a stream finished after its last received frame was consumed, or
        finished with zero frames): snapshot their banked rows now; the
        results surface at the next resolve like any other retirement."""
        retiring: List[_Session] = []
        slots: List[int] = []
        for k, sess in enumerate(self._slots):
            if sess is not None and sess.done:
                retiring.append(sess)
                slots.append(k)
                self._free(k)
        if retiring:
            with self._state_lock:
                self._pending.append(_PendingChunk(
                    sessions=retiring, slots=slots,
                    rows=self.engine.snapshot_out(self._out)))

    def flush(self) -> List[RequestResult]:
        """Resolve retirements (and streamed partials) still pending from
        the last dispatched chunk (the double-buffer tail)."""
        if self.chunk_frames:
            self._reap_cancelled()
            self._queue_done_retirements()
        return self._resolve()

    def tick(self, now: int) -> Tuple[List[RequestResult], int]:
        """Non-blocking driver entry: at most one dispatch, in either mode.

        Returns ``(finished_results, frames_advanced)``.  Safe to call
        with nothing to do (returns ``([], 0)``); handles cancellations,
        dispatch-free retirements and the double-buffer tail.  The call
        does not wait for the device — the only host sync is the previous
        chunk's one-copy logits fetch (per-frame mode syncs on its own
        logits, as always)."""
        if self.chunk_frames:
            adv = self.max_chunk_advance()
            if adv:
                return self.step_chunk(now), adv
            return self.flush(), 0
        self._reap_cancelled()
        finished: List[RequestResult] = []
        # dispatch-free retirements (finished streams with nothing left):
        for k, sess in enumerate(self._slots):
            if sess is not None and sess.done:
                finished.append(sess.result(
                    np.stack(sess.rows) if sess.rows else np.zeros(
                        (0, self.engine.n_classes), np.float32)))
                self._free(k)
        if self.obs is not None:
            self.obs.fold_results(finished)
        active, _ = self._masks()
        if active.any():
            return finished + self.step(now), 1
        return finished, 0

    def take_partials(self) -> List[PartialLogits]:
        """Drain the streamed per-chunk logits resolved so far (in frame
        order per session; ``stream_partials`` only)."""
        out, self._partials = self._partials, []
        return out

    def _resolve(self) -> List[RequestResult]:
        self._resolve_partials()
        return self._resolve_pending()

    def _resolve_partials(self) -> None:
        with self._state_lock:
            pend, self._pending_partials = self._pending_partials, []
        if not pend:
            return
        for p in pend:
            rows = np.asarray(p.rows)          # ONE fetch per chunk
            for sess, k, t0, adv in p.entries:
                if sess.cancelled:
                    continue                   # cancelled mid-window
                if not sess.first_logit_wall:
                    sess.first_logit_wall = time.perf_counter()
                self._partials.append(PartialLogits(
                    req_id=sess.req_id, t0=t0, rows=rows[k, :adv].copy()))

    def _resolve_pending(self) -> List[RequestResult]:
        with self._state_lock:
            pend, self._pending = self._pending, []
        if not pend:
            return []
        out: List[RequestResult] = []
        for p in pend:
            rows = np.asarray(p.rows)          # ONE fetch for all retirees
            for sess, k in zip(p.sessions, p.slots):
                if sess.cancelled:
                    continue   # cancelled inside the retirement window:
                    #            the snapshot is dropped, never delivered
                out.append(sess.result(rows[k, :sess.cursor].copy()))
        if self.obs is not None:
            self.obs.fold_results(out)
        return out

    def _fold_boundary(self, *, n_active: int, frames: int,
                       dispatch_s: float, chunk_s: float, overlap: float,
                       retirements: int) -> None:
        """One dispatch boundary's fold into the observability layer —
        host values only, plus the (device, un-fetched) telemetry-totals
        dispatch that the NEXT boundary's fold will diff."""
        adm, self._adm_since_fold = self._adm_since_fold, 0
        # the totals reduction reads ``self.state``: take the lock so an
        # interleaved reader/dispatch cannot hand it a deleted buffer.
        with self._state_lock:
            totals = self.engine.telemetry_totals(self.state)
        self.obs.fold_chunk(
            occupancy=self.n_active,
            capacity=self.capacity,
            n_active=n_active,
            frames_advanced=frames,
            dispatch_s=dispatch_s,
            chunk_s=chunk_s,
            host_overlap_frac=overlap,
            admissions=adm,
            retirements=retirements,
            shard_loads=self.shard_loads(),
            telemetry_totals=totals,
        )

    def mean_host_overlap_frac(self) -> float:
        return float(np.mean(self._overlap_fracs)) if self._overlap_fracs \
            else 0.0

    def drain(self, now: int) -> List[RequestResult]:
        """Evict every in-flight session, returning truncated
        ``RequestResult``s with the logits produced so far (used when
        ``serve_requests`` hits ``max_steps`` mid-stream, so partial work is
        surfaced instead of silently dropped).  In chunked mode the
        already-finished (pending-fetch) sessions are resolved first, then
        partial sessions' rows are read from the device output buffer —
        truncation granularity is the chunk."""
        n_classes = self.engine.n_classes
        self._staged.clear()    # evicted sessions' uploads must not land
        self._staged_appends.clear()
        self._reap_cancelled()
        out: List[RequestResult] = self._resolve()
        drained: List[RequestResult] = []
        with self._state_lock:
            for k, sess in enumerate(self._slots):
                if sess is None:
                    continue
                if self.chunk_frames:
                    logits = (np.asarray(self._out[k, :sess.cursor])
                              if sess.cursor
                              else np.zeros((0, n_classes), np.float32))
                else:
                    logits = (np.stack(sess.rows) if sess.rows
                              else np.zeros((0, n_classes), np.float32))
                drained.append(sess.result(logits, truncated=not sess.done,
                                           finish_step=now))
                self._free(k)
        if self.obs is not None:
            self.obs.fold_results(drained)
        return out + drained

    def measured_sparsity(self) -> Dict[str, float]:
        # Thread-safe against an in-flight offloaded tick: holding the
        # lock keeps the next dispatch from donating ``self.state`` out
        # from under the host fetch (the fetch itself may block until the
        # current chunk completes, which is the intended sync point).
        with self._state_lock:
            return self.engine.measured_sparsity(self.state)

    def bytes_per_slot(self) -> float:
        """Device bytes held per resident session: the slot's share of the
        recurrent-state slabs (incl. telemetry and cursors), its frame
        buffer row, its logits-bank row, and the per-slot share of the
        shared packed weights (``engine.weight_bytes() / capacity``).
        Pure shape arithmetic — no device sync.  Quantized packing
        (``EngineConfig.quant``) shrinks the weight term ~4x; the fp32
        session state is format-independent.  Folds the
        ``spartus_slot_bytes`` gauge when observability is attached."""
        def nbytes(a) -> int:
            return int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize

        # Shape arithmetic only — but reading the slab references while a
        # concurrent dispatch donates-and-rebinds them can hand this loop
        # a deleted buffer whose ``.shape`` access throws (the PR 6 race,
        # audited here for the admin endpoint's ``stats()`` path).
        with self._state_lock:
            total = sum(nbytes(l)
                        for l in jax.tree_util.tree_leaves(self.state))
            total += nbytes(self._frames) + nbytes(self._lengths)
            if self._out is not None:
                total += nbytes(self._out)
        total += self.engine.weight_bytes()
        per_slot = total / self.capacity
        if self.obs is not None:
            self.obs.fold_slot_bytes(per_slot)
        return float(per_slot)

    # -- checkpoint / restore (serving/checkpoint.py) ------------------------

    def pool_config(self) -> Dict[str, object]:
        """Constructor kwargs that rebuild an equivalent (empty) pool —
        the watchdog's recovery recipe.  ``max_frames`` reports the
        CURRENT buffer bucket so the rebuilt pool needs no regrow (and
        therefore no step recompile) to receive the restored sessions."""
        return dict(
            capacity=self.capacity,
            max_frames=self._t_buf,
            chunk_frames=self.chunk_frames,
            max_buffer_frames=self.max_buffer_frames,
            stream_partials=self.stream_partials,
            n_devices=self._n_devices,
        )

    def snapshot(self):
        """In-memory whole-pool snapshot (``PoolCheckpoint``): every live
        session in one gathered D2H fetch.  Call ``flush()`` first if the
        double-buffer tail must be resolved rather than dropped."""
        from repro.serving import checkpoint as ckptlib

        return ckptlib.snapshot_pool(self)

    def snapshot_session(self, req_id: int):
        """Serialize one live session (``SessionSnapshot``) in a single
        gathered fetch of its slot's rows."""
        from repro.serving import checkpoint as ckptlib

        return ckptlib.snapshot_session(self, req_id)

    def restore_session(self, snap) -> bool:
        """Restore one ``SessionSnapshot`` into a free slot; False when
        the pool is full.  The session continues bit-identically — slot
        index, capacity and shard count are placement, not semantics."""
        from repro.serving import checkpoint as ckptlib

        return ckptlib.restore_session(self, snap)

    def checkpoint(self, path: str) -> List[RequestResult]:
        """Write the whole pool to a checkpoint directory (atomic,
        committed, retained — `training.checkpoint.CheckpointManager`).
        Flushes the double-buffer tail first and returns those finished
        results: completed sessions belong to the caller, not the file."""
        from repro.serving import checkpoint as ckptlib

        return ckptlib.save_pool(self, path)

    def restore(self, path: str, step: Optional[int] = None) -> None:
        """Load a pool checkpoint into THIS (fresh, empty) pool.  The
        shard count and capacity may differ from the writer's — this is
        the migration primitive for rebalancing and preemption recovery."""
        from repro.serving import checkpoint as ckptlib

        ckptlib.restore_into(self, ckptlib.load_checkpoint(path, step))


RequestLike = Union[StreamRequest, Tuple[int, np.ndarray]]


def _normalize(requests: Iterable[RequestLike]) -> List[StreamRequest]:
    out: List[StreamRequest] = []
    for i, r in enumerate(requests):
        if isinstance(r, StreamRequest):
            out.append(r)
        else:
            arrival, feats = r
            out.append(StreamRequest(req_id=i, arrival_step=int(arrival),
                                     feats=np.asarray(feats, np.float32)))
    return sorted(out, key=lambda r: (r.arrival_step, r.req_id))


def serve_requests(
    engine: BatchedSpartusEngine,
    requests: Iterable[RequestLike],
    capacity: int,
    max_steps: Optional[int] = None,
    chunk_frames: int = 0,
    n_devices: Optional[int] = None,
    observability: Optional[PoolObservability] = None,
) -> Tuple[List[RequestResult], ServeStats]:
    """Drive a request stream through a `SessionPool` to completion.

    requests: iterable of StreamRequest or `(arrival_step, feats [T, D])`.
    Admission is FIFO in arrival order; a request that finds the pool full
    waits (backpressure) and is admitted as soon as a slot frees.  Returns
    per-request results (logits + latency) and aggregate throughput stats.

    ``chunk_frames=C >= 1`` selects the chunked tick loop: one device
    dispatch advances all active sessions up to C frames, logits are
    banked on device and fetched once per session at retirement
    (double-buffered behind the next chunk), and admission happens at
    chunk boundaries — higher throughput (fewer dispatches/frame), up to
    C-1 ticks of extra queueing latency.  ``chunk_frames=0`` (default)
    keeps the per-frame path, which is the chunked path's parity oracle.

    If ``max_steps`` stops the run early, in-flight sessions are drained
    into ``RequestResult``s with ``truncated=True`` holding their partial
    logits (never-admitted requests have no partial logits and are simply
    absent from the results); ``stats.truncated`` flags the cut — in
    chunked mode the cut lands on the first chunk boundary at or past
    ``max_steps``, so partial logits come in chunk granularity.
    ``total_steps`` counts only ticks that advanced at least one slot, so
    frames/step utilisation is not diluted by idle fast-forward ticks.

    ``n_devices=N`` shards the pool's slot dimension over N devices
    (`SessionPool(n_devices=...)`): same API, same results, one SPMD
    dispatch per tick across all devices.

    ``observability=PoolObservability(...)`` attaches the live metrics /
    time-series / tracing layer (serving/metrics.py): every dispatch
    boundary is folded into its registry and ring buffer, at zero added
    host syncs.  Results and throughput are identical with it on or off.
    """
    pending = deque(_normalize(requests))
    n_requests = len(pending)
    # pre-size the device frame buffers to the longest utterance so no
    # mid-run bucket growth (= recompile) can happen:
    max_frames = max((r.n_frames for r in pending), default=1)
    pool = SessionPool(
        engine, capacity, max_frames=max_frames, chunk_frames=chunk_frames,
        max_buffer_frames=max(max_frames, DEFAULT_MAX_BUFFER_FRAMES),
        n_devices=n_devices, observability=observability)
    waiting: deque[Tuple[StreamRequest, float]] = deque()
    results: List[RequestResult] = []
    now = 0
    total_steps = 0
    truncated = False
    t0 = time.perf_counter()

    while pending or waiting or pool.n_active or pool.has_pending:
        # fast-forward over idle time to the next arrival:
        if not waiting and not pool.n_active and pending:
            now = max(now, pending[0].arrival_step)
        while pending and pending[0].arrival_step <= now:
            waiting.append((pending.popleft(), time.perf_counter()))
        while waiting and pool.n_free:
            req, arr_wall = waiting.popleft()
            pool.admit(req, now, arrival_wall=arr_wall)
        # count only ticks that advance >= 1 slot: the arrival fast-forward
        # above makes idle iterations rare, but total_steps feeds per-step
        # utilisation metrics and must stay exact if the loop ever changes
        # (e.g. wall-clock-paced ticking instead of fast-forward).
        if chunk_frames:
            adv = pool.max_chunk_advance()
            results.extend(pool.step_chunk(now) if adv else pool.flush())
            total_steps += adv
            now += max(adv, 1)
        else:
            dispatched = pool.n_active > 0
            results.extend(pool.step(now))
            if dispatched:
                total_steps += 1
            now += 1
        if max_steps is not None and total_steps >= max_steps:
            truncated = bool(pending or waiting or pool.n_active)
            results.extend(pool.drain(now - 1))
            break

    wall = time.perf_counter() - t0
    if observability is not None:
        observability.flush_totals()
    results.sort(key=lambda r: r.req_id)
    stats = aggregate_stats(
        results,
        capacity=capacity,
        n_requests=n_requests,
        total_steps=total_steps,
        wall_s=wall,
        sparsity=pool.measured_sparsity(),
        truncated=truncated,
        chunk_frames=chunk_frames,
        n_dispatches=pool.n_dispatches,
        host_overlap_frac=pool.mean_host_overlap_frac(),
        bytes_per_slot=pool.bytes_per_slot(),
    )
    return results, stats
