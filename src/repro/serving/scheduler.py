"""Continuous-batching session scheduler for streaming DeltaLSTM serving.

The datacenter serving pattern (ESE's channel-multiplexed multi-voice
engine, SHARP's adaptive RNN scheduler) translated to software: one
weight-resident `BatchedSpartusEngine` and a `SessionPool` that
multiplexes many independent streaming requests across its fixed-capacity
slot dimension.

Lifecycle of a request:

  queued ──admit──> active(slot k) ──per-frame steps──> finished
            ^                                              │
            └── backpressure: waits while no slot is free ─┘

* `admit` attaches a request to a free slot and uploads its *whole*
  utterance `[T, D]` into the slot's device-resident feature buffer once;
  the slot's device state is re-initialised by the `reset` mask *inside*
  the next `step_frames`, so admission never triggers an extra dispatch
  or a recompile.
* `step` advances all active slots one frame in ONE jitted call
  (`step_frames`): each slot's current frame is gathered **on device** by
  the cursor carried in `PoolState` — the tick moves zero frame bytes
  host -> device — then the `[B, n_classes]` logits are fetched once,
  each active slot's row appended to its request, and slots whose
  utterance is exhausted retire.
* Idle slots ride along masked-out for free; the pool never reshapes (the
  frame buffer length is bucketed to powers of two), so the step function
  compiles once per (capacity, bucket).

`serve_requests` is the batteries-included driver: feed it an iterable of
requests with arrival times (in scheduler ticks), get per-request logits
plus queue/service/latency metrics back.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.serving.batched_engine import BatchedSpartusEngine, PoolState


@dataclasses.dataclass
class StreamRequest:
    """One streaming utterance: `feats [T, D]` arriving at `arrival_step`."""

    req_id: int
    arrival_step: int
    feats: np.ndarray

    @property
    def n_frames(self) -> int:
        return int(self.feats.shape[0])


@dataclasses.dataclass
class RequestResult:
    req_id: int
    arrival_step: int
    admit_step: int       # tick the request got a slot
    finish_step: int      # tick its last frame was produced
    logits: np.ndarray    # [T, n_classes]
    wall_latency_s: float  # wall time from eligibility to last frame
    truncated: bool = False  # stopped by max_steps with frames still pending
    #                          (logits holds the frames produced so far)

    @property
    def queue_steps(self) -> int:
        return self.admit_step - self.arrival_step

    @property
    def service_steps(self) -> int:
        return self.finish_step - self.admit_step + 1

    @property
    def turnaround_steps(self) -> int:
        return self.finish_step - self.arrival_step + 1


@dataclasses.dataclass
class _Session:
    request: StreamRequest
    admit_step: int
    arrival_wall: float
    cursor: int = 0
    needs_reset: bool = True
    rows: List[np.ndarray] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeStats:
    capacity: int
    n_requests: int
    total_frames: int
    total_steps: int      # ticks that advanced >= 1 slot (idle ticks excluded)
    wall_s: float
    frames_per_s: float
    p50_latency_s: float
    p95_latency_s: float
    p50_turnaround_steps: float
    p95_turnaround_steps: float
    # aggregated device-side telemetry (telemetry.measured_sparsity output),
    # the input to hwsim.spartus_model.evaluate_from_telemetry:
    sparsity: Dict[str, float] = dataclasses.field(default_factory=dict)
    # True when max_steps stopped the run before every request completed;
    # in-flight sessions were drained into truncated RequestResults:
    truncated: bool = False

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _frame_bucket(n: int, floor: int = 64) -> int:
    """Frame-buffer length bucket: next power of two, >= ``floor``.  Keeps
    the device buffer shape (and thus the compiled step) stable across
    utterance lengths; growth past the bucket recompiles once."""
    b = floor
    while b < n:
        b *= 2
    return b


class SessionPool:
    """Fixed-capacity pool of device-resident streaming sessions.

    Request features live on device: ``admit`` uploads the whole utterance
    `[T, D]` into the slot's row of a `[B, T_buf, D]` buffer once, and every
    tick gathers each slot's current frame by the device cursor in
    ``PoolState`` — the steady state issues zero per-tick host staging
    copies (the old `step_batch` path re-staged every slot's frame on host
    each tick, which at large hidden sizes cost more than the math).
    """

    def __init__(self, engine: BatchedSpartusEngine, capacity: int,
                 max_frames: int = 64):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.engine = engine
        self.capacity = capacity
        self.state: PoolState = engine.init_state(capacity)
        self._slots: List[Optional[_Session]] = [None] * capacity
        # device-resident per-slot feature buffers, uploaded at admission:
        self._t_buf = _frame_bucket(max_frames)
        self._frames = jnp.zeros((capacity, self._t_buf, engine.input_dim),
                                 jnp.float32)

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def n_free(self) -> int:
        return self.capacity - self.n_active

    def admit(self, request: StreamRequest, now: int,
              arrival_wall: Optional[float] = None) -> bool:
        """Attach `request` to a free slot; False if the pool is full."""
        if request.n_frames == 0:
            raise ValueError(f"request {request.req_id} has no frames")
        if request.feats.shape[-1] != self.engine.input_dim:
            raise ValueError(
                f"request {request.req_id}: feature dim "
                f"{request.feats.shape[-1]} != engine input dim "
                f"{self.engine.input_dim}")
        for k in range(self.capacity):
            if self._slots[k] is None:
                self._slots[k] = _Session(
                    request=request, admit_step=now,
                    arrival_wall=(time.perf_counter() if arrival_wall is None
                                  else arrival_wall))
                self._upload(k, request.feats)
                return True
        return False

    def _upload(self, slot: int, feats: np.ndarray) -> None:
        """One-time H2D copy of a whole utterance into the slot's buffer
        (grows the bucket — and recompiles the step — only when an
        utterance exceeds every previous one)."""
        t = feats.shape[0]
        if t > self._t_buf:
            new_t = _frame_bucket(t, floor=self._t_buf)
            self._frames = jnp.pad(
                self._frames, ((0, 0), (0, new_t - self._t_buf), (0, 0)))
            self._t_buf = new_t
        self._frames = self._frames.at[slot, :t].set(
            jnp.asarray(feats, jnp.float32))

    def step(self, now: int) -> List[RequestResult]:
        """Advance every active session one frame (one jitted call).
        Returns the requests that finished on this tick."""
        active = np.zeros((self.capacity,), bool)
        reset = np.zeros((self.capacity,), bool)
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            active[k] = True
            reset[k] = sess.needs_reset
        if not active.any():
            return []

        self.state, logits = self.engine.step_frames(
            self.state, self._frames, active, reset)
        logits_np = np.asarray(logits)          # ONE device->host fetch/tick

        finished: List[RequestResult] = []
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            sess.needs_reset = False
            sess.rows.append(logits_np[k].copy())  # detach from the batch row
            sess.cursor += 1
            if sess.cursor >= sess.request.n_frames:
                finished.append(RequestResult(
                    req_id=sess.request.req_id,
                    arrival_step=sess.request.arrival_step,
                    admit_step=sess.admit_step,
                    finish_step=now,
                    logits=np.stack(sess.rows),
                    wall_latency_s=time.perf_counter() - sess.arrival_wall,
                ))
                self._slots[k] = None
        return finished

    def drain(self, now: int) -> List[RequestResult]:
        """Evict every in-flight session, returning truncated
        ``RequestResult``s with the logits produced so far (used when
        ``serve_requests`` hits ``max_steps`` mid-stream, so partial work is
        surfaced instead of silently dropped)."""
        n_classes = self.engine.n_classes
        out: List[RequestResult] = []
        for k, sess in enumerate(self._slots):
            if sess is None:
                continue
            out.append(RequestResult(
                req_id=sess.request.req_id,
                arrival_step=sess.request.arrival_step,
                admit_step=sess.admit_step,
                finish_step=now,
                logits=(np.stack(sess.rows) if sess.rows
                        else np.zeros((0, n_classes), np.float32)),
                wall_latency_s=time.perf_counter() - sess.arrival_wall,
                truncated=True,
            ))
            self._slots[k] = None
        return out

    def measured_sparsity(self) -> Dict[str, float]:
        return self.engine.measured_sparsity(self.state)


RequestLike = Union[StreamRequest, Tuple[int, np.ndarray]]


def _normalize(requests: Iterable[RequestLike]) -> List[StreamRequest]:
    out: List[StreamRequest] = []
    for i, r in enumerate(requests):
        if isinstance(r, StreamRequest):
            out.append(r)
        else:
            arrival, feats = r
            out.append(StreamRequest(req_id=i, arrival_step=int(arrival),
                                     feats=np.asarray(feats, np.float32)))
    return sorted(out, key=lambda r: (r.arrival_step, r.req_id))


def serve_requests(
    engine: BatchedSpartusEngine,
    requests: Iterable[RequestLike],
    capacity: int,
    max_steps: Optional[int] = None,
) -> Tuple[List[RequestResult], ServeStats]:
    """Drive a request stream through a `SessionPool` to completion.

    requests: iterable of StreamRequest or `(arrival_step, feats [T, D])`.
    Admission is FIFO in arrival order; a request that finds the pool full
    waits (backpressure) and is admitted as soon as a slot frees.  Returns
    per-request results (logits + latency) and aggregate throughput stats.

    If ``max_steps`` stops the run early, in-flight sessions are drained
    into ``RequestResult``s with ``truncated=True`` holding their partial
    logits (never-admitted requests have no partial logits and are simply
    absent from the results); ``stats.truncated`` flags the cut.
    ``total_steps`` counts only ticks that advanced at least one slot, so
    frames/step utilisation is not diluted by idle fast-forward ticks.
    """
    pending = deque(_normalize(requests))
    n_requests = len(pending)
    # pre-size the device frame buffers to the longest utterance so no
    # mid-run bucket growth (= recompile) can happen:
    max_frames = max((r.n_frames for r in pending), default=1)
    pool = SessionPool(engine, capacity, max_frames=max_frames)
    waiting: deque[Tuple[StreamRequest, float]] = deque()
    results: List[RequestResult] = []
    now = 0
    total_steps = 0
    truncated = False
    t0 = time.perf_counter()

    while pending or waiting or pool.n_active:
        # fast-forward over idle time to the next arrival:
        if not waiting and not pool.n_active and pending:
            now = max(now, pending[0].arrival_step)
        while pending and pending[0].arrival_step <= now:
            waiting.append((pending.popleft(), time.perf_counter()))
        while waiting and pool.n_free:
            req, arr_wall = waiting.popleft()
            pool.admit(req, now, arrival_wall=arr_wall)
        # count only ticks that advance >= 1 slot: the arrival fast-forward
        # above makes idle iterations rare, but total_steps feeds per-step
        # utilisation metrics and must stay exact if the loop ever changes
        # (e.g. wall-clock-paced ticking instead of fast-forward).
        dispatched = pool.n_active > 0
        results.extend(pool.step(now))
        if dispatched:
            total_steps += 1
        now += 1
        if max_steps is not None and total_steps >= max_steps:
            truncated = bool(pending or waiting or pool.n_active)
            results.extend(pool.drain(now - 1))
            break

    wall = time.perf_counter() - t0
    results.sort(key=lambda r: r.req_id)
    lat = np.array([r.wall_latency_s for r in results], np.float64)
    tas = np.array([r.turnaround_steps for r in results], np.float64)
    frames = int(sum(r.logits.shape[0] for r in results))
    stats = ServeStats(
        capacity=capacity,
        n_requests=n_requests,
        total_frames=frames,
        total_steps=total_steps,
        wall_s=wall,
        frames_per_s=frames / wall if wall > 0 else float("inf"),
        p50_latency_s=float(np.percentile(lat, 50)) if len(lat) else 0.0,
        p95_latency_s=float(np.percentile(lat, 95)) if len(lat) else 0.0,
        p50_turnaround_steps=float(np.percentile(tas, 50)) if len(tas) else 0.0,
        p95_turnaround_steps=float(np.percentile(tas, 95)) if len(tas) else 0.0,
        sparsity=pool.measured_sparsity(),
        truncated=truncated,
    )
    return results, stats
