"""Device-resident sparsity telemetry + latency summaries for the serving
pool and the asyncio front-end.

The batch-1 `SpartusEngine` appends a Python dict per (step, layer) with
`int()` host syncs on every frame — fine for one utterance, fatal for a
server.  Here telemetry is three `[L, B]` accumulators (layer x slot)
that live on device and are folded into `BatchedSpartusEngine.step_batch`
itself, so the steady state does zero host round-trips.  The accumulators
ride the chunked tick loop for free: they are part of the `lax.scan`
carry in `step_chunk`, so one chunk dispatch folds in L x C (layer,
frame) samples — only frames a slot actually consumed count, since
`accumulate` masks by the per-iteration active mask.  Keeping the slot
dimension (rather than summing over it per step) is what lets the
sharded pool (docs/serving.md, slot-dimension data parallelism) carry
telemetry with ZERO cross-device traffic: each device accumulates its
own slots' columns and the reduction over B happens once, host-side, in
`measured_sparsity` — which fetches the accumulators on demand and
reduces them to the same summary statistics the batch-1 engine reports:

  temporal_sparsity      = 1 - mean over (active step, layer) of nnz/n_cols
  capacity_overflow_rate = fraction of samples where the NZI list dropped
  mean_active_columns    = mean nnz per sample

Because the per-layer column count is static, the mean-of-ratios reduces
exactly to sums:  mean(nnz/cols) = (sum_l nnz_sum_l / n_cols_l) / sum_l steps_l,
so the aggregate numbers equal what the per-step dict path would report.

``percentile_summary`` is the shared latency reduction: every serving
surface (sync `serve_requests`, the async front-end, the load benchmark)
reports wall latency, queue wait and time-to-first-logit through it so
p50/p95/p99 mean the same thing everywhere.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import hotpath_contract


class TelemetryState(NamedTuple):
    """Per-(layer, slot) accumulators over (active slot, frame) samples.

    float32, not int32: a long-running server would wrap an int32 counter
    (garbage statistics), whereas float32 sums stay exact up to 2^24 and
    then round — the reported *ratios* keep ~1e-7 relative accuracy for
    the life of the process (int64/float64 need jax x64, off by default).

    The slot dimension is kept unreduced on purpose: it makes every
    accumulator a `[.., B]` slab that shards over the pool's slot axis
    exactly like the layer state, so the sharded pool's step needs no
    cross-device reduction (a per-step ``sum(axis=-1)`` would be an
    all-reduce per scan iteration).
    """

    nnz_sum: jax.Array         # [L, B] float32: total fired deltas
    overflow_steps: jax.Array  # [L, B] float32: samples where capacity
    #                            dropped deltas
    steps: jax.Array           # [L, B] float32: number of samples


def init_telemetry(n_layers: int, n_slots: int) -> TelemetryState:
    # three DISTINCT buffers: the serving step/chunk functions donate the
    # whole PoolState, and donating one buffer aliased into three leaves
    # fails with "attempt to donate the same buffer twice"
    def z() -> jax.Array:
        return jnp.zeros((n_layers, n_slots), jnp.float32)

    return TelemetryState(nnz_sum=z(), overflow_steps=z(), steps=z())


def accumulate(
    tel: TelemetryState,
    layer: int,
    nnz: jax.Array,      # [B] int32 fired-delta counts
    dropped: jax.Array,  # [B] int32 overflow drop counts
    active: jax.Array,   # [B] bool slot mask
) -> TelemetryState:
    """Fold one layer-step of one batch into the accumulators (traced)."""
    act = active.astype(jnp.float32)
    # traced-only helper: called from inside the jitted step, never eagerly
    return TelemetryState(
        nnz_sum=tel.nnz_sum.at[layer].add(  # lint: allow(eager-scatter)
            nnz.astype(jnp.float32) * act),
        # lint: allow(eager-scatter)
        overflow_steps=tel.overflow_steps.at[layer].add(
            (dropped > 0).astype(jnp.float32) * act),
        steps=tel.steps.at[layer].add(act),  # lint: allow(eager-scatter)
    )


def accumulate_layers(
    tel: TelemetryState,
    nnz: jax.Array,      # [L, B] int32 fired-delta counts, all layers
    dropped: jax.Array,  # [L, B] int32 overflow drop counts
    active: jax.Array,   # [B] bool slot mask
) -> TelemetryState:
    """Fold one whole step (all layers at once) into the accumulators.

    Same math as L calls to ``accumulate``, but as three [L, B] slab adds
    instead of 3L row scatters — the scatters were measurable per-tick
    overhead on the CPU backend, and inside the chunked ``lax.scan`` this
    runs once per frame.  Purely elementwise over the slot dimension, so
    a slot-sharded pool accumulates with zero cross-device traffic."""
    act = active.astype(jnp.float32)
    return TelemetryState(
        nnz_sum=tel.nnz_sum + nnz.astype(jnp.float32) * act,
        overflow_steps=tel.overflow_steps
        + (dropped > 0).astype(jnp.float32) * act,
        steps=tel.steps + act,
    )


def percentile_summary(
    values: Sequence[float], name: str, qs: Sequence[int] = (50, 95, 99),
) -> Dict[str, float]:
    """Reduce a latency sample list to ``{"p<q>_<name>": value}`` entries
    (0.0 for an empty sample, so stats stay well-formed on empty runs)."""
    arr = np.asarray(list(values), np.float64)
    if arr.size == 0:
        return {f"p{q}_{name}": 0.0 for q in qs}
    return {f"p{q}_{name}": float(np.percentile(arr, q)) for q in qs}


@hotpath_contract("fold_totals",
                  forbid_ops=("dot", "gather", "scatter",
                              "dynamic-update-slice"))
def fold_totals(tel: TelemetryState, n_cols: Sequence[int]) -> jax.Array:
    """Reduce the `[L, B]` accumulators to the three running totals that
    `measured_sparsity` is built from, ON DEVICE (traced / jittable):

        [sum_l nnz_sum_l / n_cols_l,  overflow.sum(),  steps.sum()]

    This is what the observability layer diffs between chunk boundaries
    to report *incremental* sparsity: the `[3]` result is dispatched at
    one boundary and fetched at the next, so the live metrics never add
    a host sync against an in-flight chunk (metrics.PoolObservability).
    Host-side, ``measured_sparsity(tel, cols)`` equals the summary
    computed from ``fold_totals(tel, cols)``'s three numbers."""
    cols = jnp.asarray(n_cols, jnp.float32)[:, None]   # [L, 1] vs [L, B]
    return jnp.stack([
        (tel.nnz_sum / cols).sum(),
        tel.overflow_steps.sum(),
        tel.steps.sum(),
    ])


def measured_sparsity(
    tel: TelemetryState, n_cols: Sequence[int]
) -> Dict[str, float]:
    """Reduce the accumulators to the engine's summary dict.  This is the
    only host fetch in the telemetry path — and, for a sharded pool, the
    only place the per-slot columns are ever reduced across devices.

    An idle pool (no samples yet) returns the full key set zeroed, like
    ``percentile_summary`` on an empty sample — callers can always index
    the summary without guarding for `KeyError`."""
    nnz, ovf, steps = (np.asarray(jax.device_get(a), np.float64) for a in tel)
    total = steps.sum()
    if total == 0:
        return {
            "temporal_sparsity": 0.0,
            "capacity_overflow_rate": 0.0,
            "mean_active_columns": 0.0,
        }
    cols = np.asarray(n_cols, np.float64)[:, None]   # [L, 1] vs [L, B]
    return {
        "temporal_sparsity": float(1.0 - (nnz / cols).sum() / total),
        "capacity_overflow_rate": float(ovf.sum() / total),
        "mean_active_columns": float(nnz.sum() / total),
    }
