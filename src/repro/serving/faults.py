"""Typed serving errors, a seeded fault-injection harness, and backoff.

This module is the vocabulary of the robustness layer (docs/robustness.md):

* **Error taxonomy** — every failure a client can observe is a
  :class:`ServingError` with a stable ``code`` string and a ``retriable``
  flag.  The JSON-lines protocol (launch/serve.py) serializes them with
  :func:`error_payload`, so a client never has to parse prose to decide
  whether to retry.  ``BadRequest`` deliberately subclasses ``ValueError``
  as well: the pool's host-side validation raises plain ``ValueError``
  and callers that predate the taxonomy keep working.

* **Fault injection** — a :class:`FaultPlan` is a *seeded, deterministic*
  schedule of :class:`FaultEvent` s at named sites (:data:`SITES`).  The
  pool/driver call :meth:`FaultInjector.fire` at each site; the injector
  counts invocations per site and raises :class:`InjectedFault` exactly at
  the scheduled invocation indices.  Determinism is the whole point: the
  chaos suite (tests/test_faults.py) replays the same plan against the
  same workload and asserts every *surviving* session is bit-identical to
  the fault-free run.  Sites the pool cannot raise at (a client vanishing,
  a consumer stalling, a process being preempted) are *harness-enacted*:
  the plan still schedules them deterministically and the test enacts the
  behaviour (``events_for(site)``).

* **Backoff** — seeded full-jitter exponential backoff for retriable
  errors.  ``delay(attempt)`` is a pure function of ``(seed, attempt)``,
  so client retry schedules are reproducible in tests while still
  decorrelating real fleets (every client seeds with its own id).

Stdlib + numpy only — no jax import, so the scheduler, async driver and
launcher can all import it without cycles or device initialisation.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# -- error taxonomy -----------------------------------------------------------


class ServingError(Exception):
    """Base of every typed serving failure.

    ``code`` is the stable wire identifier (see docs/robustness.md for the
    catalog); ``retriable`` tells a client whether the same request can
    succeed later without modification.
    """

    code: str = "internal"
    retriable: bool = False

    def __init__(self, message: str = "", *,
                 code: Optional[str] = None,
                 retriable: Optional[bool] = None) -> None:
        super().__init__(message or self.__class__.code)
        if code is not None:
            self.code = code
        if retriable is not None:
            self.retriable = retriable


class BadRequest(ServingError, ValueError):
    """The payload itself is invalid (NaN/Inf, wrong dtype/shape, too
    long).  Never retriable: resending the same bytes fails the same way.
    Subclasses ``ValueError`` so pre-taxonomy callers catch it unchanged."""

    code = "bad_request"
    retriable = False


class AdmissionShed(ServingError):
    """The server refused admission under overload (``max_pending``
    saturated and the overload policy is ``"shed"``).  Retriable: back off
    and re-open — ideally with the same re-admission token."""

    code = "shed"
    retriable = True

    def __init__(self, message: str = "admission shed under overload", *,
                 retry_after_ms: float = 50.0) -> None:
        super().__init__(message)
        self.retry_after_ms = float(retry_after_ms)


class SessionTimeout(ServingError):
    """The idle reaper cancelled a silent session (``idle_timeout_s``).
    Retriable: the client may open a new stream and resend."""

    code = "timeout"
    retriable = True


class DriverRecovered(ServingError):
    """The driver watchdog rebuilt the pool but could not salvage this
    session (its chunk was mid-flight, or its snapshot/restore failed).
    Retriable: the server is alive again; resend the utterance."""

    code = "retriable_internal"
    retriable = True


class ProtocolError(ServingError):
    """A malformed message on the JSON-lines transport (bad JSON, unknown
    op, frames before open, oversized line...).  The ``code`` is chosen at
    raise time; never retriable — the *message* was wrong, not the state
    of the server."""

    def __init__(self, code: str, message: str) -> None:
        super().__init__(message, code=code, retriable=False)


class InjectedFault(ServingError):
    """A scheduled failure fired by the :class:`FaultInjector`.  Retriable
    by construction: the injected failure models a transient infrastructure
    fault, not a bad request."""

    code = "injected"
    retriable = True

    def __init__(self, site: str, invocation: int,
                 payload: Optional[str] = None) -> None:
        super().__init__(
            f"injected fault at site {site!r} (invocation {invocation})"
            + (f" payload={payload!r}" if payload else ""))
        self.site = site
        self.invocation = invocation
        self.payload = payload


def error_payload(exc: BaseException) -> Dict[str, Any]:
    """Serialize any exception to the wire error fields.

    ``ServingError`` carries its own code/retriable; a plain ``ValueError``
    (the pool's validation errors) maps to ``bad_request``; anything else
    is a fatal ``internal``.  The result is merged into the JSON-lines
    ``{"event": "error", ...}`` frame by launch/serve.py.
    """
    if isinstance(exc, ServingError):
        out: Dict[str, Any] = {
            "code": exc.code,
            "retriable": bool(exc.retriable),
            "message": str(exc),
        }
        retry_after = getattr(exc, "retry_after_ms", None)
        if retry_after is not None:
            out["retry_after_ms"] = retry_after
        return out
    if isinstance(exc, ValueError):
        return {"code": "bad_request", "retriable": False,
                "message": str(exc)}
    return {"code": "internal", "retriable": False,
            "message": f"{type(exc).__name__}: {exc}"}


# -- fault plans --------------------------------------------------------------

#: Named injection sites.  The first two are raised *by the pool itself*
#: (``SessionPool._fire``); the rest are harness-enacted — the chaos tests
#: read them from the plan and perform the behaviour.
SITES: Tuple[str, ...] = (
    "admission_upload",   # pool: staged H2D upload wave fails
    "dispatch",           # pool: tick/step_chunk dispatch raises
    "client_disconnect",  # harness: client vanishes mid-utterance
    "slow_consumer",      # harness: client stops draining partials
    "corrupt_frame",      # harness: payload arrives NaN-poisoned
    "preempt",            # harness: kill the pool, restore from checkpoint
)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled failure: fire at the ``at``-th invocation of
    ``site`` (0-indexed, counted by the injector).  ``payload`` refines
    the behaviour (e.g. ``"poison"`` on a dispatch fault additionally
    invalidates the device state to model a crash after donation)."""

    site: str
    at: int
    req_id: Optional[int] = None
    payload: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, replayable schedule of fault events."""

    events: Tuple[FaultEvent, ...] = ()
    seed: Optional[int] = None

    @classmethod
    def seeded(cls, seed: int, *, n_events: int = 4,
               sites: Sequence[str] = SITES,
               max_at: int = 8) -> "FaultPlan":
        """Draw a deterministic plan: ``n_events`` events over ``sites``
        with invocation indices in ``[0, max_at)``.  Same seed, same
        plan — the contract the chaos grid is built on."""
        rng = np.random.default_rng(seed)
        events = []
        for _ in range(n_events):
            site = sites[int(rng.integers(len(sites)))]
            events.append(FaultEvent(site=site, at=int(rng.integers(max_at))))
        return cls(events=tuple(events), seed=seed)

    def events_for(self, site: str) -> Tuple[FaultEvent, ...]:
        """The schedule for one site, ordered by invocation index —
        how the harness enacts the sites the pool cannot raise at."""
        return tuple(sorted((e for e in self.events if e.site == site),
                            key=lambda e: e.at))

    def with_events(self, *events: FaultEvent) -> "FaultPlan":
        return FaultPlan(events=self.events + tuple(events), seed=self.seed)


class FaultInjector:
    """Counts invocations per site and raises at the scheduled ones.

    Thread-safe (the pool may tick from the async server's offload
    thread).  Each event fires exactly once; ``fired`` records the events
    that actually triggered, in order, for post-hoc assertions."""

    # machine-checked lock discipline (repro.analysis.concurrency):
    _guarded_by_ = {"_counts": "_lock", "_pending": "_lock",
                    "fired": "_lock"}

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {}
        self._pending: Dict[str, Dict[int, FaultEvent]] = {}
        for ev in plan.events:
            self._pending.setdefault(ev.site, {})[ev.at] = ev
        self.fired: List[FaultEvent] = []

    def count(self, site: str) -> int:
        with self._lock:
            return self._counts.get(site, 0)

    def fire(self, site: str) -> None:
        """Record one invocation of ``site``; raise if it is scheduled."""
        with self._lock:
            n = self._counts.get(site, 0)
            self._counts[site] = n + 1
            ev = self._pending.get(site, {}).pop(n, None)
            if ev is not None:
                self.fired.append(ev)
        if ev is not None:
            raise InjectedFault(site, n, payload=ev.payload)


# -- backoff ------------------------------------------------------------------


class Backoff:
    """Seeded full-jitter exponential backoff (the AWS "full jitter"
    policy): ``delay(k) ~ Uniform(0, min(cap, base * factor**k))``.

    Deterministic per ``(seed, attempt)`` — two instances with the same
    seed produce the same schedule, so tests can pin retry timing while
    production clients decorrelate by seeding with their own id."""

    def __init__(self, *, base_s: float = 0.05, cap_s: float = 2.0,
                 factor: float = 2.0, seed: int = 0) -> None:
        self.base_s = float(base_s)
        self.cap_s = float(cap_s)
        self.factor = float(factor)
        self.seed = int(seed)

    def ceiling(self, attempt: int) -> float:
        return min(self.cap_s, self.base_s * self.factor ** attempt)

    def delay(self, attempt: int) -> float:
        rng = np.random.default_rng((self.seed, attempt))
        return float(rng.uniform(0.0, self.ceiling(attempt)))
