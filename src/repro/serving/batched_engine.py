"""Continuous-batching Spartus engine: all pool slots advance one frame
in a single jitted call.

`SpartusEngine` (engine.py) is the paper-faithful batch-1 datapath: a
Python loop per frame and per layer with host syncs for telemetry.  This
module is its server-grade twin: the per-layer state of every session in
a fixed-capacity pool is stored as stacked device slabs
(`BatchedLayerState`, shapes `[B, ...]`), and `step_batch` runs

    IPU   delta_encode_batch            (vmap over slots)
    CTRL  select_active_columns_batch   (scatter route; the dense-mirror
    MACs  stsp_spmv_batch                route fuses both into
                                         delta_spmv_dense_topk_batch)
    HPE   lstm_pointwise_batch

for every layer, plus the FCL/logit head, inside one jit.  An `active`
mask freezes idle slots (their state is carried through unchanged), and
a `reset` mask re-initialises slots at admission time so attach/detach
never recompiles.  Telemetry is accumulated on device (telemetry.py) and
fetched only when `measured_sparsity` is called.

Three step entry points share the same core: `step_batch` takes this
tick's host-staged frames `x [B, D]` (reference semantics, tests);
`step_frames` reads from pre-uploaded per-slot feature buffers
`[B, T_buf, D]` indexed by the device cursor in `PoolState` — the
steady-state serving tick (`SessionPool.step`) therefore performs no
host->device frame copy at all; and `step_chunk` advances every active
slot up to `n_frames` frames in ONE dispatch via `jax.lax.scan` over the
same core, banking each frame's logits in a per-slot device output
buffer `[B, T_buf, n_classes]` instead of returning them per tick — a
finished slot's logits leave the device once, at retirement.  The
serving-path functions (`step_frames`, `step_chunk`) donate the incoming
`PoolState` (and the chunk output buffer), so the state slabs are reused
in place tick over tick instead of reallocating.

Because the output buffer is donated, anything that must outlive the
next chunk is detached device-side first: `snapshot_out` copies the
whole buffer (retiring sessions' rows), and `snapshot_chunk` slices just
one chunk's `[B, C, n_classes]` window (the partial-logits stream for
live sessions — `SessionPool.stream_partials` / the async front-end).
Both are dispatched before the next `step_chunk` and fetched one chunk
later, overlapped with the in-flight dispatch.

Per-slot numerics are identical to `SpartusEngine`: the batched kernels
are vmaps of the very same ops, so a session's logits do not depend on
what the other slots are doing (verified in tests/test_serving_pool.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.contracts import hotpath_contract
from repro.kernels import ops
from repro.models.lstm_am import LSTMAMConfig
from repro.serving import telemetry as tele
from repro.serving.engine import (
    EngineConfig, PackedLayer, PackedSpartusModel, active_quant,
)


class BatchedLayerState(NamedTuple):
    """Stacked per-slot state of one DeltaLSTM layer."""

    s_hat: jax.Array  # [B, D+H] concatenated x̂ / ĥ references
    c: jax.Array      # [B, H] cell state
    h: jax.Array      # [B, H] hidden state
    dm: jax.Array     # [B, 4H] delta memories


class PoolState(NamedTuple):
    """Full device-resident state of the session pool."""

    layers: Tuple[BatchedLayerState, ...]
    telemetry: tele.TelemetryState
    cursor: jax.Array  # [B] int32 per-slot frame cursor into the pool's
    #                    device-resident feature buffers (step_frames);
    #                    carried through unchanged by the legacy step_batch


def _fresh_layer_state(layer: PackedLayer, n_slots: int) -> BatchedLayerState:
    d, h = layer.input_dim, layer.hidden_dim
    dm0 = jnp.broadcast_to(layer.bias.astype(jnp.float32).reshape(-1),
                           (n_slots, 4 * h))
    return BatchedLayerState(
        s_hat=jnp.zeros((n_slots, d + h), jnp.float32),
        c=jnp.zeros((n_slots, h), jnp.float32),
        h=jnp.zeros((n_slots, h), jnp.float32),
        dm=dm0,
    )


class BatchedSpartusEngine(PackedSpartusModel):
    """Weight-resident multi-session engine: one CBCSC weight set, B
    independent streaming sessions multiplexed across it."""

    def __init__(self, am_params: Dict[str, Any], am_cfg: LSTMAMConfig,
                 cfg: EngineConfig = EngineConfig()):
        super().__init__(am_params, am_cfg, cfg)
        self._step = jax.jit(self._step_impl)
        # serving paths donate the incoming PoolState (and the chunk's
        # output buffer) so the slabs are reused in place, never
        # reallocated per tick; step_batch stays non-donating because the
        # tests use it as the reference oracle and may re-step old states.
        self._step_frames = jax.jit(self._step_frames_impl,
                                    donate_argnums=(0,))
        self._step_chunk = jax.jit(self._step_chunk_impl,
                                   static_argnames=("n_frames",),
                                   donate_argnums=(0, 5))
        # output-buffer snapshots (chunked serving): full-buffer copy for
        # retirements, chunk-window slice for streamed partial logits.
        # Both are dispatched BEFORE the next step_chunk donates the
        # buffer away, detaching the rows device-side; the host fetch
        # happens one chunk later, overlapped with the next dispatch.
        self._snapshot_out = jax.jit(lambda out: out.copy())
        self._snapshot_chunk = jax.jit(ops.gather_rows,
                                       static_argnames=("n",))
        # observability: [3] device reduction of the telemetry slabs
        # (nnz/cols, overflow, steps totals).  Non-donating — it reads
        # the accumulators the chunk just produced, and is dispatched at
        # one boundary / fetched at the next, same detach-now/fetch-
        # later cadence as the output-buffer snapshots above.
        self._tel_totals = jax.jit(
            lambda t: tele.fold_totals(t, self.n_cols))

    # -- state management ----------------------------------------------------

    def init_state(self, n_slots: int) -> PoolState:
        return PoolState(
            layers=tuple(_fresh_layer_state(l, n_slots) for l in self.layers),
            telemetry=tele.init_telemetry(len(self.layers), n_slots),
            cursor=jnp.zeros((n_slots,), jnp.int32),
        )

    def init_out_buf(self, n_slots: int, t_buf: int) -> jax.Array:
        """Per-slot device logits buffer for the chunked tick loop."""
        return jnp.zeros((n_slots, t_buf, self.n_classes), jnp.float32)

    def _apply_reset(
        self, state: PoolState, reset: jax.Array, *, reset_cursor: bool,
    ) -> PoolState:
        """Re-initialise reset slots' layer state (and optionally their
        device cursor) — admission, fused into the step/chunk dispatch so
        attach never costs an extra dispatch or recompiles.  Applied ONCE
        per dispatch, at the boundary: inside a chunk no slot resets."""
        n_slots = state.cursor.shape[0]
        rm = reset[:, None]
        layers = []
        for layer, st in zip(self.layers, state.layers):
            fresh = _fresh_layer_state(layer, n_slots)
            layers.append(BatchedLayerState(
                s_hat=jnp.where(rm, fresh.s_hat, st.s_hat),
                c=jnp.where(rm, fresh.c, st.c),
                h=jnp.where(rm, fresh.h, st.h),
                dm=jnp.where(rm, fresh.dm, st.dm),
            ))
        cursor = jnp.where(reset, 0, state.cursor) if reset_cursor \
            else state.cursor
        return PoolState(tuple(layers), state.telemetry, cursor)

    # -- the batched step ----------------------------------------------------

    def _step_core(
        self, state: PoolState, x: jax.Array, active: jax.Array,
        cursor: jax.Array,
    ) -> Tuple[PoolState, jax.Array]:
        cfg = self.cfg
        quant = active_quant(cfg)
        act_kw = (
            {"act_bits": quant.act_bits, "act_frac_bits": quant.act_frac_bits}
            if quant is not None else {}
        )
        n_slots = x.shape[0]
        new_layers = []
        nnz_layers, dropped_layers = [], []
        h = x
        for layer, st in zip(self.layers, state.layers):
            wscale = layer.scale if quant is not None else None
            val, lidx, mirror = layer.enc.val, layer.enc.lidx, layer.w_dense_t
            if quant is not None:
                # int8 at rest inside the compiled module: without the
                # barrier XLA folds convert(s8 const) into a baked f32
                # constant, restoring the fp32 footprint at rest.
                if mirror is not None:
                    mirror = jax.lax.optimization_barrier(mirror)
                else:
                    val, lidx = jax.lax.optimization_barrier((val, lidx))
            s = jnp.concatenate([h, st.h], axis=-1)           # [B, D+H]
            delta, s_hat, nnz = ops.delta_encode_batch(
                s, st.s_hat, cfg.theta, use_pallas=cfg.use_pallas, **act_kw
            )
            if mirror is not None:
                # dense-mirror route: capacity enforced in the dense
                # domain (no NZI list, no scatter) — bit-identical to the
                # select + dense-gather chain, measurably faster on CPU.
                y, dropped = ops.delta_spmv_dense_topk_batch(
                    mirror, delta, layer.capacity, scale=wscale)
            else:
                idx, vals, dropped = ops.select_active_columns_batch(
                    delta, layer.capacity
                )
                y = ops.stsp_spmv_batch(
                    val, lidx, idx, vals,
                    s=layer.enc.s, use_pallas=cfg.use_pallas, scale=wscale,
                )
            dm = st.dm + y.astype(st.dm.dtype)
            h_new, c_new = ops.lstm_pointwise_batch(
                dm.reshape(n_slots, 4, layer.hidden_dim), st.c,
                use_pallas=cfg.use_pallas,
            )
            am = active[:, None]
            new_layers.append(BatchedLayerState(
                s_hat=jnp.where(am, s_hat, st.s_hat),
                c=jnp.where(am, c_new, st.c),
                h=jnp.where(am, h_new, st.h),
                dm=jnp.where(am, dm, st.dm),
            ))
            nnz_layers.append(nnz)
            dropped_layers.append(dropped)
            h = h_new
        tel = tele.accumulate_layers(
            state.telemetry, jnp.stack(nnz_layers),
            jnp.stack(dropped_layers), active)
        h = jax.nn.relu(h @ self.fcl["w"].T + self.fcl["b"])
        logits = h @ self.logit["w"].T + self.logit["b"]
        return PoolState(tuple(new_layers), tel, cursor), logits

    def _step_impl(
        self, state: PoolState, x: jax.Array, active: jax.Array,
        reset: jax.Array,
    ) -> Tuple[PoolState, jax.Array]:
        # legacy host-staged entry: the caller supplies this tick's frames,
        # the device cursor rides along untouched.
        state = self._apply_reset(state, reset, reset_cursor=False)
        return self._step_core(state, x, active, state.cursor)

    @hotpath_contract("step_frames", donates=("state",),
                      op_budget={"transpose": 0})
    def _step_frames_impl(
        self, state: PoolState, frames: jax.Array, active: jax.Array,
        reset: jax.Array,
    ) -> Tuple[PoolState, jax.Array]:
        # device-resident entry: gather each slot's current frame from the
        # pre-uploaded [B, T_buf, D] buffers by the cursor carried in
        # PoolState — a tick moves zero frame bytes host -> device.
        state = self._apply_reset(state, reset, reset_cursor=True)
        x = ops.gather_frames(frames, state.cursor)
        new_cur = state.cursor + active.astype(state.cursor.dtype)
        return self._step_core(state, x, active, new_cur)

    @hotpath_contract("step_chunk", donates=("state", "out_buf"),
                      op_budget={"transpose": 0, "dynamic-update-slice": 8})
    def _step_chunk_impl(
        self, state: PoolState, frames: jax.Array, lengths: jax.Array,
        active: jax.Array, reset: jax.Array, out_buf: jax.Array,
        *, n_frames: int,
    ) -> Tuple[PoolState, jax.Array]:
        # chunked entry: admission resets happen once at the chunk
        # boundary, then lax.scan advances every slot up to n_frames
        # frames with zero host involvement.  A slot whose cursor reaches
        # its utterance length mid-chunk goes inactive for the remaining
        # iterations: its state freezes and it contributes no telemetry —
        # exactly as if the host had masked it.  The scan stacks each
        # iteration's logits (static-offset writes), and ONE vmapped
        # dynamic-slice banks the whole [C, B, n_classes] block into the
        # per-slot output buffers at the chunk-start cursors; rows past a
        # session's length are scratch no reader consumes.
        state = self._apply_reset(state, reset, reset_cursor=True)
        start = state.cursor

        def body(st, _):
            act = jnp.logical_and(active, st.cursor < lengths)
            x = ops.gather_frames(frames, st.cursor)
            new_st, logits = self._step_core(
                st, x, act, st.cursor + act.astype(st.cursor.dtype))
            return new_st, logits

        state, ys = jax.lax.scan(body, state, None, length=n_frames)
        return state, ops.bank_rows(out_buf, ys, start)

    def step_batch(
        self, state: PoolState, x: jax.Array, active: jax.Array,
        reset: jax.Array | None = None,
    ) -> Tuple[PoolState, jax.Array]:
        """Advance every active slot one frame from host-staged frames.

        x      [B, D]  next input frame per slot (zeros for idle slots)
        active [B]     slots that consume a frame this tick
        reset  [B]     slots to re-initialise *before* stepping (admission)

        Returns (new_state, logits [B, n_classes]); logits rows of inactive
        slots are garbage and must be ignored by the caller.
        """
        if reset is None:
            reset = jnp.zeros(active.shape, bool)
        return self._step(state, jnp.asarray(x, jnp.float32),
                          jnp.asarray(active, bool), jnp.asarray(reset, bool))

    def step_frames(
        self, state: PoolState, frames: jax.Array, active: jax.Array,
        reset: jax.Array | None = None,
    ) -> Tuple[PoolState, jax.Array]:
        """Advance every active slot one frame from device-resident buffers.

        frames [B, T_buf, D]  per-slot feature buffers already on device
                              (SessionPool.admit uploads each utterance once)
        active / reset        as in ``step_batch``

        Each slot's frame is selected by ``state.cursor`` *on device* (reset
        slots restart at 0; active slots advance by 1), so the steady-state
        tick issues no host staging copy at all.  Numerics are identical to
        feeding the same frames through ``step_batch``.
        """
        if reset is None:
            reset = jnp.zeros(active.shape, bool)
        return self._step_frames(state, frames, jnp.asarray(active, bool),
                                 jnp.asarray(reset, bool))

    def step_chunk(
        self, state: PoolState, frames: jax.Array, lengths: jax.Array,
        active: jax.Array, reset: jax.Array, out_buf: jax.Array,
        *, n_frames: int,
    ) -> Tuple[PoolState, jax.Array]:
        """Advance every active slot up to ``n_frames`` frames in ONE
        dispatch (`jax.lax.scan` over the per-frame core).

        frames  [B, T_buf, D]          device-resident feature buffers
        lengths [B] int32              per-slot utterance length; a slot
                                       stops (state frozen, no logits, no
                                       telemetry) once its cursor reaches it
        active  [B] bool               occupied slots
        reset   [B] bool               slots admitted at this chunk boundary
                                       (layer state + cursor re-initialised
                                       before the first frame)
        out_buf [B, T_pad, n_classes]  device logits buffer; frame t of slot
                                       b lands in ``out_buf[b, t]``.  T_pad
                                       must be >= T_buf + n_frames: the
                                       chunk banks its stacked logits with
                                       one dynamic slice per slot, and rows
                                       past a session's length are scratch
                                       (never read — retirement fetches
                                       ``[:n_frames]``)

        Returns ``(new_state, new_out_buf)``.  Both the incoming ``state``
        and ``out_buf`` are DONATED: the caller must drop its references
        and use the returned arrays (slice a retiring slot's rows *before*
        the next call).  Logits never leave the device here — fetch a
        finished slot's rows from the output buffer once, at retirement.
        Numerics per consumed frame are identical to ``step_frames``.
        """
        return self._step_chunk(
            state, frames, jnp.asarray(lengths, jnp.int32),
            jnp.asarray(active, bool), jnp.asarray(reset, bool), out_buf,
            n_frames=int(n_frames))

    def snapshot_out(self, out_buf: jax.Array) -> jax.Array:
        """Device-side copy of the whole chunk output buffer (ONE op,
        shape-stable: a single compile per pool however many sessions
        retire).  Used to detach retiring sessions' rows before the next
        ``step_chunk`` donates the buffer away; the retirees' rows are
        then fetched in one D2H copy one chunk later."""
        return self._snapshot_out(out_buf)

    def snapshot_chunk(self, out_buf: jax.Array, starts: jax.Array,
                       *, n_frames: int) -> jax.Array:
        """Device-side slice of ONE chunk's rows for every slot:
        ``out_buf [B, T_pad, n_classes]``, per-slot chunk-start cursors
        ``starts [B]`` -> ``[B, n_frames, n_classes]``.

        This is the live-slot counterpart of ``snapshot_out``: partial-
        logits streaming needs every chunk's rows for every advancing
        session, and copying the whole output buffer per chunk would
        scale with utterance length — the window slice scales with the
        chunk only.  Same detach-before-donation contract."""
        return self._snapshot_chunk(out_buf, jnp.asarray(starts, jnp.int32),
                                    n=int(n_frames))

    # -- telemetry -----------------------------------------------------------

    def measured_sparsity(self, state: PoolState) -> Dict[str, float]:
        """Single host fetch of the device-resident accumulators."""
        return tele.measured_sparsity(state.telemetry, self.n_cols)

    def telemetry_totals(self, state: PoolState) -> jax.Array:
        """Dispatch (NOT fetch) the `[3]` running-totals reduction of the
        telemetry accumulators: ``[sum nnz/cols, sum overflow, sum
        steps]``.  The observability fold enqueues this each chunk
        boundary and reads the value one boundary later, so live
        incremental-sparsity reporting never syncs on the in-flight
        chunk (see telemetry.fold_totals)."""
        return self._tel_totals(state.telemetry)
