"""Continuous-batching Spartus engine: all pool slots advance one frame
in a single jitted call.

`SpartusEngine` (engine.py) is the paper-faithful batch-1 datapath: a
Python loop per frame and per layer with host syncs for telemetry.  This
module is its server-grade twin: the per-layer state of every session in
a fixed-capacity pool is stored as stacked device slabs
(`BatchedLayerState`, shapes `[B, ...]`), and `step_batch` runs

    IPU   delta_encode_batch          (vmap over slots)
    CTRL  select_active_columns_batch
    MACs  stsp_spmv_batch             (CBCSC weights broadcast)
    HPE   lstm_pointwise_batch

for every layer, plus the FCL/logit head, inside one jit.  An `active`
mask freezes idle slots (their state is carried through unchanged), and
a `reset` mask re-initialises slots at admission time so attach/detach
never recompiles.  Telemetry is accumulated on device (telemetry.py) and
fetched only when `measured_sparsity` is called.

Two step entry points share the same core: `step_batch` takes this
tick's host-staged frames `x [B, D]` (reference semantics, tests), while
`step_frames` reads from pre-uploaded per-slot feature buffers
`[B, T_buf, D]` indexed by the device cursor in `PoolState` — the
steady-state serving tick (`SessionPool.step`) therefore performs no
host->device frame copy at all.

Per-slot numerics are identical to `SpartusEngine`: the batched kernels
are vmaps of the very same ops, so a session's logits do not depend on
what the other slots are doing (verified in tests/test_serving_pool.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.lstm_am import LSTMAMConfig
from repro.serving import telemetry as tele
from repro.serving.engine import EngineConfig, PackedLayer, PackedSpartusModel


class BatchedLayerState(NamedTuple):
    """Stacked per-slot state of one DeltaLSTM layer."""

    s_hat: jax.Array  # [B, D+H] concatenated x̂ / ĥ references
    c: jax.Array      # [B, H] cell state
    h: jax.Array      # [B, H] hidden state
    dm: jax.Array     # [B, 4H] delta memories


class PoolState(NamedTuple):
    """Full device-resident state of the session pool."""

    layers: Tuple[BatchedLayerState, ...]
    telemetry: tele.TelemetryState
    cursor: jax.Array  # [B] int32 per-slot frame cursor into the pool's
    #                    device-resident feature buffers (step_frames);
    #                    carried through unchanged by the legacy step_batch


def _fresh_layer_state(layer: PackedLayer, n_slots: int) -> BatchedLayerState:
    d, h = layer.input_dim, layer.hidden_dim
    dm0 = jnp.broadcast_to(layer.bias.astype(jnp.float32).reshape(-1),
                           (n_slots, 4 * h))
    return BatchedLayerState(
        s_hat=jnp.zeros((n_slots, d + h), jnp.float32),
        c=jnp.zeros((n_slots, h), jnp.float32),
        h=jnp.zeros((n_slots, h), jnp.float32),
        dm=dm0,
    )


class BatchedSpartusEngine(PackedSpartusModel):
    """Weight-resident multi-session engine: one CBCSC weight set, B
    independent streaming sessions multiplexed across it."""

    def __init__(self, am_params: Dict[str, Any], am_cfg: LSTMAMConfig,
                 cfg: EngineConfig = EngineConfig()):
        super().__init__(am_params, am_cfg, cfg)
        self._step = jax.jit(self._step_impl)
        self._step_frames = jax.jit(self._step_frames_impl)

    # -- state management ----------------------------------------------------

    def init_state(self, n_slots: int) -> PoolState:
        return PoolState(
            layers=tuple(_fresh_layer_state(l, n_slots) for l in self.layers),
            telemetry=tele.init_telemetry(len(self.layers)),
            cursor=jnp.zeros((n_slots,), jnp.int32),
        )

    # -- the batched step ----------------------------------------------------

    def _step_core(
        self, state: PoolState, x: jax.Array, active: jax.Array,
        reset: jax.Array, cursor: jax.Array,
    ) -> Tuple[PoolState, jax.Array]:
        cfg = self.cfg
        n_slots = x.shape[0]
        tel = state.telemetry
        new_layers = []
        h = x
        for li, (layer, st) in enumerate(zip(self.layers, state.layers)):
            # admission-time reset, fused into the step (no extra dispatch):
            fresh = _fresh_layer_state(layer, n_slots)
            rm = reset[:, None]
            st = BatchedLayerState(
                s_hat=jnp.where(rm, fresh.s_hat, st.s_hat),
                c=jnp.where(rm, fresh.c, st.c),
                h=jnp.where(rm, fresh.h, st.h),
                dm=jnp.where(rm, fresh.dm, st.dm),
            )
            s = jnp.concatenate([h, st.h], axis=-1)           # [B, D+H]
            delta, s_hat, nnz = ops.delta_encode_batch(
                s, st.s_hat, cfg.theta, use_pallas=cfg.use_pallas
            )
            idx, vals, dropped = ops.select_active_columns_batch(
                delta, layer.capacity
            )
            y = ops.stsp_spmv_batch(
                layer.enc.val, layer.enc.lidx, idx, vals, s=layer.enc.s,
                use_pallas=cfg.use_pallas, w_dense=layer.w_dense,
            ).astype(st.dm.dtype)
            dm = st.dm + y
            h_new, c_new = ops.lstm_pointwise_batch(
                dm.reshape(n_slots, 4, layer.hidden_dim), st.c,
                use_pallas=cfg.use_pallas,
            )
            am = active[:, None]
            new_layers.append(BatchedLayerState(
                s_hat=jnp.where(am, s_hat, st.s_hat),
                c=jnp.where(am, c_new, st.c),
                h=jnp.where(am, h_new, st.h),
                dm=jnp.where(am, dm, st.dm),
            ))
            tel = tele.accumulate(tel, li, nnz, dropped, active)
            h = h_new
        h = jax.nn.relu(h @ self.fcl["w"].T + self.fcl["b"])
        logits = h @ self.logit["w"].T + self.logit["b"]
        return PoolState(tuple(new_layers), tel, cursor), logits

    def _step_impl(
        self, state: PoolState, x: jax.Array, active: jax.Array,
        reset: jax.Array,
    ) -> Tuple[PoolState, jax.Array]:
        # legacy host-staged entry: the caller supplies this tick's frames,
        # the device cursor rides along untouched.
        return self._step_core(state, x, active, reset, state.cursor)

    def _step_frames_impl(
        self, state: PoolState, frames: jax.Array, active: jax.Array,
        reset: jax.Array,
    ) -> Tuple[PoolState, jax.Array]:
        # device-resident entry: gather each slot's current frame from the
        # pre-uploaded [B, T_buf, D] buffers by the cursor carried in
        # PoolState — a tick moves zero frame bytes host -> device.
        n_slots, t_buf, _ = frames.shape
        cur = jnp.where(reset, 0, state.cursor)
        x = frames[jnp.arange(n_slots), jnp.minimum(cur, t_buf - 1)]
        new_cur = cur + active.astype(cur.dtype)
        return self._step_core(state, x, active, reset, new_cur)

    def step_batch(
        self, state: PoolState, x: jax.Array, active: jax.Array,
        reset: jax.Array | None = None,
    ) -> Tuple[PoolState, jax.Array]:
        """Advance every active slot one frame from host-staged frames.

        x      [B, D]  next input frame per slot (zeros for idle slots)
        active [B]     slots that consume a frame this tick
        reset  [B]     slots to re-initialise *before* stepping (admission)

        Returns (new_state, logits [B, n_classes]); logits rows of inactive
        slots are garbage and must be ignored by the caller.
        """
        if reset is None:
            reset = jnp.zeros(active.shape, bool)
        return self._step(state, jnp.asarray(x, jnp.float32),
                          jnp.asarray(active, bool), jnp.asarray(reset, bool))

    def step_frames(
        self, state: PoolState, frames: jax.Array, active: jax.Array,
        reset: jax.Array | None = None,
    ) -> Tuple[PoolState, jax.Array]:
        """Advance every active slot one frame from device-resident buffers.

        frames [B, T_buf, D]  per-slot feature buffers already on device
                              (SessionPool.admit uploads each utterance once)
        active / reset        as in ``step_batch``

        Each slot's frame is selected by ``state.cursor`` *on device* (reset
        slots restart at 0; active slots advance by 1), so the steady-state
        tick issues no host staging copy at all.  Numerics are identical to
        feeding the same frames through ``step_batch``.
        """
        if reset is None:
            reset = jnp.zeros(active.shape, bool)
        return self._step_frames(state, frames, jnp.asarray(active, bool),
                                 jnp.asarray(reset, bool))

    # -- telemetry -----------------------------------------------------------

    def measured_sparsity(self, state: PoolState) -> Dict[str, float]:
        """Single host fetch of the device-resident accumulators."""
        return tele.measured_sparsity(state.telemetry, self.n_cols)
