"""Slot-dimension data parallelism for the serving pool.

Spartus scales by replicating sparse PEs across a bigger FPGA with a
balanced workload; the serving-pool analogue is to partition the pool's
*slot* dimension across devices.  Every per-slot slab the pool owns —
layer state, delta memories, frame buffers, cursors, lengths, the logits
bank, telemetry — is placed with a `NamedSharding` over a 1-D
``("data",)`` mesh, so the existing jitted `step_frames`/`step_chunk`
dispatches run SPMD across all devices: each device advances its own
block of slots and, because slots are fully independent (the batched
kernels are vmaps of per-session ops and telemetry is kept per-slot),
the steady-state chunk contains **zero cross-device communication** —
the partitioned program is the single-device program, n_devices times in
parallel.  Only admission (the host-staged upload scatter) and
retirement (the one-copy D2H fetch) touch per-shard rows.

Placement follows `distributed/sharding.py`'s never-invalid rule
(`slot_spec`): a slot dimension not divisible by the mesh's data-axis
size falls back to replication, so any (capacity, n_devices) pair is
valid — it just stops being parallel.  `SessionPool(n_devices=N)` is the
public knob; everything here is the plumbing underneath it.

CI has no multi-device hardware: the mesh is emulated with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before jax
import), which exercises the identical GSPMD partitioning path on CPU.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
from jax.sharding import NamedSharding

from repro.distributed.sharding import slot_spec
from repro.launch.mesh import axis_size, data_axes, make_data_mesh
from repro.serving.batched_engine import PoolState


def make_pool_mesh(n_devices: int):
    """1-D ``("data",)`` mesh over ``n_devices`` local devices."""
    return make_data_mesh(n_devices)


def mesh_data_size(mesh) -> int:
    """Number of shards the mesh's data axes provide."""
    return axis_size(mesh, *data_axes(mesh))


def n_pool_shards(mesh, capacity: int) -> int:
    """Effective shard count for a ``capacity``-slot pool on ``mesh``:
    the data-axis size when it divides capacity, else 1 (the pool slabs
    replicate — `slot_spec`'s never-invalid fallback)."""
    size = mesh_data_size(mesh)
    return size if size > 1 and capacity % size == 0 else 1


def shard_bounds(capacity: int, n_shards: int) -> List[Tuple[int, int]]:
    """``[lo, hi)`` slot ranges owned by each shard (contiguous blocks:
    `NamedSharding` over dim 0 splits the slot axis into equal runs)."""
    per = capacity // n_shards
    return [(s * per, (s + 1) * per) for s in range(n_shards)]


def slot_sharding(shape, mesh, dim: int = 0) -> NamedSharding:
    """`NamedSharding` for one per-slot slab (``dim`` = the slot axis)."""
    return NamedSharding(mesh, slot_spec(tuple(shape), mesh, dim=dim))


def shard_slot_array(x: jax.Array, mesh, dim: int = 0) -> jax.Array:
    """Place one per-slot slab; replicates when the dim doesn't divide."""
    return jax.device_put(x, slot_sharding(x.shape, mesh, dim=dim))


def pool_state_shardings(state: PoolState, mesh) -> PoolState:
    """`NamedSharding` pytree matching a `PoolState`: layer slabs and the
    cursor shard the slot axis at dim 0; the `[L, B]` telemetry
    accumulators shard it at dim 1."""
    dim0 = lambda leaf: slot_sharding(leaf.shape, mesh, dim=0)  # noqa: E731
    dim1 = lambda leaf: slot_sharding(leaf.shape, mesh, dim=1)  # noqa: E731
    return PoolState(
        layers=jax.tree.map(dim0, state.layers),
        telemetry=jax.tree.map(dim1, state.telemetry),
        cursor=dim0(state.cursor),
    )


def shard_pool_state(state: PoolState, mesh) -> PoolState:
    """Place every `PoolState` slab on the mesh (one `device_put` of the
    whole pytree).  Done once at pool construction; the step functions
    donate the state, so the placement persists tick over tick."""
    return jax.device_put(state, pool_state_shardings(state, mesh))
