"""Serving: the Spartus datapath as an inference service.

- `engine`         — paper-faithful batch-1 streaming engine (SpartusEngine)
- `batched_engine` — continuous-batching multi-session engine (step_batch /
                     step_frames / chunked step_chunk + output snapshots)
- `scheduler`      — SessionPool admission/eviction (incl. incremental
                     streaming admission + partial-logits snapshots) and
                     the synchronous serve_requests driver
- `async_server`   — asyncio streaming front-end (AsyncSpartusServer):
                     admission-while-running, wall-clock-paced chunks,
                     per-chunk partial logits to bounded per-session
                     queues (lagging/backfill slow-consumer policy)
- `sharding`       — slot-dimension data parallelism: NamedSharding
                     placement of every pool slab over a 1-D ("data",)
                     mesh (SessionPool(n_devices=N))
- `telemetry`      — device-resident per-(layer, slot) sparsity counters
                     + the shared latency percentile reduction
- `metrics`        — live observability: metrics registry (Prometheus
                     text + JSON snapshot), per-chunk time-series ring,
                     driver-phase Chrome tracing (PoolObservability,
                     folded at chunk boundaries only)
- `checkpoint`     — session checkpoint/restore: per-slot snapshots of
                     the full recurrent state (h/c, delta memories, frame
                     cursor, logits-bank rows), whole-pool save/restore
                     through training/checkpoint.py's atomic writer, and
                     cross-shard-count migration (bit-identical resume)
- `faults`         — the robustness vocabulary: typed retriable-vs-fatal
                     serving errors (wire codes), the seeded deterministic
                     fault-injection harness, and full-jitter backoff

See docs/serving.md for the architecture, docs/robustness.md for the
failure model, and docs/architecture.md for how serving fits the full
pipeline.
"""
from repro.serving.async_server import (
    AsyncSpartusServer,
    StreamClosed,
    StreamHandle,
)
from repro.serving.checkpoint import (
    PoolCheckpoint,
    SessionSnapshot,
    engine_fingerprint,
    load_checkpoint,
    restore_into,
    save_pool,
    snapshot_pool,
    snapshot_session,
)
from repro.serving.faults import (
    AdmissionShed,
    Backoff,
    BadRequest,
    DriverRecovered,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    InjectedFault,
    ProtocolError,
    ServingError,
    SessionTimeout,
    error_payload,
)
from repro.serving.batched_engine import (
    BatchedLayerState,
    BatchedSpartusEngine,
    PoolState,
)
from repro.serving.engine import EngineConfig, PackedLayer, SpartusEngine
from repro.serving.metrics import (
    MetricsRegistry,
    PoolObservability,
    TimeSeries,
    Tracer,
)
from repro.serving.scheduler import (
    PartialLogits,
    RequestResult,
    ServeStats,
    SessionPool,
    StreamRequest,
    serve_requests,
)
from repro.serving.telemetry import (
    TelemetryState,
    init_telemetry,
    measured_sparsity,
    percentile_summary,
)
