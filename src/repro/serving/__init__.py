"""Serving: the Spartus datapath as an inference service.

- `engine`         — paper-faithful batch-1 streaming engine (SpartusEngine)
- `batched_engine` — continuous-batching multi-session engine (step_batch)
- `scheduler`      — SessionPool admission/eviction + serve_requests driver
- `telemetry`      — device-resident aggregated sparsity counters
"""
from repro.serving.batched_engine import (
    BatchedLayerState,
    BatchedSpartusEngine,
    PoolState,
)
from repro.serving.engine import EngineConfig, PackedLayer, SpartusEngine
from repro.serving.scheduler import (
    RequestResult,
    ServeStats,
    SessionPool,
    StreamRequest,
    serve_requests,
)
from repro.serving.telemetry import TelemetryState, init_telemetry, measured_sparsity
