"""Live serving observability: metrics registry, per-chunk time series,
and driver-phase tracing.

The serving stack's headline quantities — frames/s, per-session latency,
the measured spatio-temporal sparsity behind the paper's 46x speedup —
were only reportable *after* a run ended (`ServeStats` is reduced once in
`aggregate_stats`; `measured_sparsity` is a one-shot fetch).  A pool
serving long-lived streams needs them live: ESE frames sparse-LSTM
serving as a system whose batch occupancy must be observable under real
traffic, and SHARP's dynamic scheduling presupposes runtime activity
statistics.  This module is that data plane, in three pieces:

* **`MetricsRegistry`** — process-wide counters, gauges and fixed-bucket
  histograms with Prometheus-style text exposition
  (`render_prometheus`) and a JSON snapshot (`snapshot`).  Thread-safe:
  the async driver may fold from a worker thread while an admin
  endpoint scrapes from the event loop.
* **`TimeSeries`** — a bounded ring buffer (default 4096 samples) of
  per-chunk pool-health samples: occupancy, active fraction, dispatch
  wall time, host overlap, admissions/retirements per chunk, per-shard
  loads, lagging sessions, partial-queue depths, and the *incremental*
  temporal sparsity of just that window.
* **`Tracer`** — Chrome-trace-event span instrumentation of the tick
  loop's phases (admission-wave upload, dispatch, snapshot D2H fetch,
  delivery pump, pacing idle), loadable in Perfetto / `chrome://tracing`.
  Disabled tracing costs one attribute read and a no-op context manager
  per phase (`NULL_TRACER`), so the hot path never pays for it.

`PoolObservability` bundles the three and owns the **boundary-fold
design rule** (the `TelemetryState` rule extended): every hot-path
source is folded at chunk boundaries ONLY, on host values the pool
already has — never a new per-frame host sync.  The one device-derived
signal, incremental sparsity, is obtained by *diffing the existing
`[L, B]` telemetry accumulators between boundaries*: after each chunk
dispatch a tiny jitted reduction (`telemetry.fold_totals`, three
scalars) is enqueued against the fresh accumulators, and its value is
fetched one boundary later — the same detach-now/fetch-next-chunk
cadence as retirement logits, so the in-flight chunk is never synced on
and the compiled step function is bit-identical with observability on
or off (pinned in tests/test_observability.py).
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "TimeSeries", "Tracer", "NULL_TRACER", "PoolObservability",
    "DEFAULT_TIMESERIES_LEN",
]

#: default bound on the per-chunk time-series ring buffer (samples).
DEFAULT_TIMESERIES_LEN = 4096

#: default histogram buckets (seconds) for dispatch/chunk wall times:
#: roughly log-spaced from 100 us to 3 s, covering CPU dev boxes through
#: accelerator chunks.
DEFAULT_TIME_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1,
                        1.0, 3.0)


def _make_lock(name: str) -> threading.Lock:
    """Lock factory: a plain ``threading.Lock`` normally, an instrumented
    lock feeding the acquisition-order recorder when one is installed
    (``repro.analysis.lockorder`` — imported lazily, at first registry /
    ring construction, so merely importing this module stays light)."""
    try:
        from repro.analysis import lockorder
    except ImportError:          # analysis layer absent: never block serving
        return threading.Lock()
    return lockorder.make_lock(name)


def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class Counter:
    """Monotonically increasing counter (float, exact to 2^53)."""

    _guarded_by_ = {"_value": "_lock"}

    def __init__(self, name: str, help: str, lock: threading.Lock,
                 labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.help = help
        self.labels = labels
        self._lock = lock
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """A value that goes up and down (occupancy, queue depth, ...)."""

    _guarded_by_ = {"_value": "_lock"}

    def __init__(self, name: str, help: str, lock: threading.Lock,
                 labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.help = help
        self.labels = labels
        self._lock = lock
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram (cumulative-bucket exposition, Prometheus
    convention: ``bucket[i]`` counts observations <= ``buckets[i]``, plus
    an implicit +Inf bucket)."""

    _guarded_by_ = {"_counts": "_lock", "_sum": "_lock", "_count": "_lock"}

    def __init__(self, name: str, help: str, lock: threading.Lock,
                 buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                 labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.help = help
        self.labels = labels
        self._lock = lock
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError(f"histogram {name}: needs >= 1 bucket")
        # per-bucket (non-cumulative) counts + the overflow bucket:
        self._counts = [0] * (len(self.buckets) + 1)
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = int(np.searchsorted(self.buckets, v, side="left"))
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def cumulative(self) -> List[Tuple[float, int]]:
        """[(le, cumulative_count)] including the +Inf bucket."""
        return self.stats()[2]

    def stats(self) -> Tuple[int, float, List[Tuple[float, int]]]:
        """``(count, sum, cumulative buckets)`` read under ONE lock
        acquisition — the only way to get a self-consistent view while
        observers keep folding.  Reading ``count``/``sum``/
        ``cumulative()`` separately can tear: an ``observe`` landing
        between the reads makes the +Inf bucket disagree with ``_count``
        (scrapers and Prometheus recording rules treat that as data
        corruption)."""
        out: List[Tuple[float, int]] = []
        acc = 0
        with self._lock:
            for le, c in zip(self.buckets, self._counts):
                acc += c
                out.append((le, acc))
            out.append((float("inf"), acc + self._counts[-1]))
            return self._count, self._sum, out


class MetricsRegistry:
    """Process-wide named metrics with Prometheus text exposition and a
    JSON snapshot API.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent for
    the same (name, labels); re-declaring a name as a different type
    raises).  One registry is typically shared by the pool, the async
    driver and the admin endpoint.
    """

    _guarded_by_ = {"_metrics": "_lock"}

    def __init__(self) -> None:
        # one shared lock for the registry map AND every metric it
        # creates (passed into each constructor), made through the
        # lock-order factory so the chaos recorder sees it:
        self._lock = _make_lock("MetricsRegistry._lock")
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Any] = {}

    def _get(self, cls, name: str, help: str,
             labels: Optional[Dict[str, str]], **kw):
        lab = tuple(sorted((labels or {}).items()))
        key = (name, lab)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, help, self._lock, labels=lab, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                  labels: Optional[Dict[str, str]] = None) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready dict: ``{name{labels}: {"type", "value"|...}}``."""
        out: Dict[str, Any] = {}
        with self._lock:
            metrics = list(self._metrics.items())
        for (name, lab), m in metrics:
            key = name + _fmt_labels(lab)
            if isinstance(m, Histogram):
                count, total, cum = m.stats()   # one lock: no torn reads
                out[key] = {
                    "type": "histogram", "count": count, "sum": total,
                    "buckets": {str(le): c for le, c in cum
                                if np.isfinite(le)},
                }
            else:
                out[key] = {
                    "type": "counter" if isinstance(m, Counter) else "gauge",
                    "value": m.value,
                }
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format, version 0.0.4."""
        with self._lock:
            metrics = list(self._metrics.items())
        lines: List[str] = []
        seen_header = set()
        for (name, lab), m in sorted(metrics, key=lambda kv: kv[0]):
            kind = ("counter" if isinstance(m, Counter)
                    else "gauge" if isinstance(m, Gauge) else "histogram")
            if name not in seen_header:
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                lines.append(f"# TYPE {name} {kind}")
                seen_header.add(name)
            if isinstance(m, Histogram):
                count, total, cum = m.stats()   # one lock: no torn reads
                for le, c in cum:
                    le_s = "+Inf" if not np.isfinite(le) else repr(le)
                    extra = dict(lab)
                    extra["le"] = le_s
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels(tuple(sorted(extra.items())))} {c}")
                lines.append(f"{name}_sum{_fmt_labels(lab)} {total}")
                lines.append(f"{name}_count{_fmt_labels(lab)} {count}")
            else:
                v = m.value
                v_s = repr(v) if v != int(v) else str(int(v))
                lines.append(f"{name}{_fmt_labels(lab)} {v_s}")
        return "\n".join(lines) + "\n"


class TimeSeries:
    """Bounded ring buffer of per-chunk samples (plain dicts).

    Appends are O(1) and drop the oldest sample past ``maxlen`` — a
    long-running server holds a fixed-size window, not its whole
    history.  ``snapshot(last=N)`` returns copies, safe to serialize
    while the driver keeps appending."""

    _guarded_by_ = {"_samples": "_lock", "_n_appended": "_lock"}

    def __init__(self, maxlen: int = DEFAULT_TIMESERIES_LEN):
        if maxlen < 1:
            raise ValueError("TimeSeries maxlen must be >= 1")
        self.maxlen = maxlen
        self._lock = _make_lock("TimeSeries._lock")
        self._samples: deque = deque(maxlen=maxlen)
        self._n_appended = 0    # total ever appended (detects drops)

    def __len__(self) -> int:
        with self._lock:
            return len(self._samples)

    @property
    def n_appended(self) -> int:
        with self._lock:
            return self._n_appended

    @property
    def n_dropped(self) -> int:
        # one acquisition: reading the pair separately can tear (an
        # append between the reads yields a phantom drop count).
        with self._lock:
            return self._n_appended - len(self._samples)

    def append(self, sample: Dict[str, Any]) -> None:
        with self._lock:
            self._samples.append(sample)
            self._n_appended += 1

    def update_last(self, fields: Dict[str, Any]) -> None:
        """Merge fields into the most recent sample (the async driver
        amends the pool's boundary sample with loop-side signals —
        lagging count, queue depths — after the tick returns)."""
        with self._lock:
            if self._samples:
                self._samples[-1].update(fields)

    def snapshot(self, last: Optional[int] = None) -> List[Dict[str, Any]]:
        with self._lock:
            samples = list(self._samples)
        if last is not None and last >= 0:
            samples = samples[-last:]
        return [dict(s) for s in samples]


class _NullSpan:
    """Reusable no-op context manager: disabled tracing allocates
    nothing per phase."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_t0")

    def __init__(self, tracer: "Tracer", name: str):
        self._tracer = tracer
        self._name = name

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._tracer._complete(self._name, self._t0, time.perf_counter())


class Tracer:
    """Chrome trace-event recorder for the driver's tick-loop phases.

    ``with tracer.span("dispatch"): ...`` records one complete ("ph":
    "X") event; ``to_json()`` / ``dump(path)`` emit the
    ``{"traceEvents": [...]}`` JSON that Perfetto and chrome://tracing
    load directly.  Events are bounded (``max_events``, oldest dropped)
    so an always-on tracer cannot grow without bound.  A disabled tracer
    (``enabled=False``, or the shared `NULL_TRACER`) returns a no-op
    span: the instrumentation sites cost one attribute check.
    """

    _guarded_by_ = {"_events": "_lock"}

    def __init__(self, enabled: bool = True, max_events: int = 1_000_000):
        self.enabled = enabled
        # a disabled tracer (incl. the module-level NULL_TRACER) keeps a
        # plain lock so importing this module never touches the analysis
        # layer; enabled tracers go through the recorder factory.
        self._lock = (_make_lock("Tracer._lock") if enabled
                      else threading.Lock())
        self._events: deque = deque(maxlen=max_events)
        self._epoch = time.perf_counter()

    def span(self, name: str):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name)

    def _complete(self, name: str, t0: float, t1: float) -> None:
        ev = {
            "name": name, "ph": "X", "pid": 1,
            "tid": threading.get_ident() & 0xFFFF,
            "ts": (t0 - self._epoch) * 1e6,
            "dur": (t1 - t0) * 1e6,
        }
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, args: Optional[Dict[str, Any]] = None
                ) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "ph": "i", "s": "g", "pid": 1,
              "tid": threading.get_ident() & 0xFFFF,
              "ts": (time.perf_counter() - self._epoch) * 1e6}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    @property
    def n_events(self) -> int:
        with self._lock:
            return len(self._events)

    def phase_names(self) -> List[str]:
        with self._lock:
            return sorted({e["name"] for e in self._events})

    def to_json(self) -> str:
        with self._lock:
            events = list(self._events)
        return json.dumps({"traceEvents": events, "displayTimeUnit": "ms"})

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


#: the shared disabled tracer: pool/driver phase sites call
#: ``tracer.span(...)`` unconditionally; against NULL_TRACER that is one
#: attribute read and a shared no-op context manager.
NULL_TRACER = Tracer(enabled=False, max_events=1)


class PoolObservability:
    """The pool/driver-facing bundle: one registry + one time-series ring
    + one tracer, plus the boundary-fold state machine.

    Construction registers the metric family below; `SessionPool` calls
    ``fold_chunk`` once per dispatch boundary (and ``fold_results`` /
    ``fold_admissions`` as the bookkeeping happens), all on host values
    the pool already computed — the fold never adds a device sync (the
    incremental-sparsity totals are enqueued now, fetched at the NEXT
    boundary, exactly like retirement logits).

    Metric catalog (see docs/observability.md):

    counters
        ``spartus_dispatches_total``      jitted step/chunk dispatches
        ``spartus_frames_total``          (slot, frame) samples consumed
        ``spartus_admissions_total``      sessions bound to a slot
        ``spartus_completed_total``       results delivered, complete
        ``spartus_truncated_total``       results delivered, truncated
        ``spartus_cancelled_total``       sessions reaped by cancel()
        ``spartus_timeseries_dropped_total``  ring-buffer evictions
        ``spartus_faults_total{site=}``   faults observed, by site
        ``spartus_shed_total``            admissions shed under overload
        ``spartus_idle_timeouts_total``   sessions reaped by idle timeout
        ``spartus_bad_requests_total``    payloads rejected by validation
        ``spartus_recoveries_total``      watchdog pool rebuilds
        ``spartus_sessions_salvaged_total``  sessions restored by recovery
        ``spartus_sessions_lost_total``   sessions failed by recovery
        ``spartus_checkpoints_total``     pool checkpoints written
        ``spartus_sessions_restored_total``  sessions restored from ckpt
    gauges
        ``spartus_occupancy``             occupied slots at the boundary
        ``spartus_active_fraction``       active slots / capacity
        ``spartus_shard_load{shard=}``    occupied slots per shard
        ``spartus_lagging_sessions``      async slow consumers (paused)
        ``spartus_partial_queue_depth_max``  deepest client queue
        ``spartus_connected_clients``     async streams open
        ``spartus_host_overlap_frac``     last chunk's overlap fraction
        ``spartus_temporal_sparsity``     incremental, last window
        ``spartus_slot_bytes``            device bytes per resident session
    histograms
        ``spartus_dispatch_seconds``      dispatch call wall time
        ``spartus_chunk_seconds``         full boundary wall time
        ``spartus_chunk_advance_frames``  frames advanced per chunk
        ``spartus_restore_seconds``       checkpoint/restore wall time
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 timeseries_len: int = DEFAULT_TIMESERIES_LEN,
                 tracer: Optional[Tracer] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.timeseries = TimeSeries(timeseries_len)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        r = self.registry
        self.c_dispatches = r.counter(
            "spartus_dispatches_total", "jitted step/chunk dispatches")
        self.c_frames = r.counter(
            "spartus_frames_total", "(slot, frame) samples consumed")
        self.c_admissions = r.counter(
            "spartus_admissions_total", "sessions bound to a pool slot")
        self.c_completed = r.counter(
            "spartus_completed_total", "complete results delivered")
        self.c_truncated = r.counter(
            "spartus_truncated_total", "truncated results delivered")
        self.c_cancelled = r.counter(
            "spartus_cancelled_total", "sessions reaped by cancel()")
        self.c_ts_dropped = r.counter(
            "spartus_timeseries_dropped_total",
            "time-series samples evicted by the ring bound")
        self.g_occupancy = r.gauge(
            "spartus_occupancy", "occupied slots at the last boundary")
        self.g_active_frac = r.gauge(
            "spartus_active_fraction", "active slots / capacity")
        self.g_lagging = r.gauge(
            "spartus_lagging_sessions", "async slow consumers (paused)")
        self.g_queue_depth = r.gauge(
            "spartus_partial_queue_depth_max",
            "deepest async partial-logit queue")
        self.g_connected = r.gauge(
            "spartus_connected_clients", "async streams open")
        self.g_overlap = r.gauge(
            "spartus_host_overlap_frac",
            "host-work fraction of the last chunk's wall time")
        self.g_sparsity = r.gauge(
            "spartus_temporal_sparsity",
            "incremental temporal sparsity of the last folded window")
        self.g_slot_bytes = r.gauge(
            "spartus_slot_bytes",
            "device bytes per resident session (state + buffers + the "
            "slot's share of the packed weights)")
        self.h_dispatch = r.histogram(
            "spartus_dispatch_seconds", "dispatch call wall time")
        self.h_chunk = r.histogram(
            "spartus_chunk_seconds", "chunk boundary wall time")
        self.h_advance = r.histogram(
            "spartus_chunk_advance_frames", "frames advanced per chunk",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256))
        # robustness layer (docs/robustness.md): fault/shed/timeout
        # counters, recovery outcome counters, restore-latency histogram.
        self.c_shed = r.counter(
            "spartus_shed_total", "admissions shed under overload")
        self.c_timeouts = r.counter(
            "spartus_idle_timeouts_total",
            "sessions reaped by the idle timeout")
        self.c_bad_requests = r.counter(
            "spartus_bad_requests_total",
            "payloads rejected by admission validation")
        self.c_recoveries = r.counter(
            "spartus_recoveries_total", "driver watchdog pool rebuilds")
        self.c_salvaged = r.counter(
            "spartus_sessions_salvaged_total",
            "sessions checkpoint-restored by a watchdog recovery")
        self.c_lost = r.counter(
            "spartus_sessions_lost_total",
            "sessions a watchdog recovery could not salvage")
        self.c_checkpoints = r.counter(
            "spartus_checkpoints_total", "pool checkpoints written")
        self.c_restored = r.counter(
            "spartus_sessions_restored_total",
            "sessions restored from a checkpoint")
        self.h_restore = r.histogram(
            "spartus_restore_seconds",
            "checkpoint snapshot / restore wall time")
        self._fault_counters: Dict[str, Counter] = {}
        # boundary-fold state: the previous boundary's (not-yet-fetched)
        # telemetry totals and the last fetched values for diffing.
        self._chunk_seq = 0
        self._pending_totals: Optional[Any] = None   # device [3] array
        self._last_totals = np.zeros((3,), np.float64)
        self._shard_gauges: Dict[int, Gauge] = {}

    # -- source hooks (host-side bookkeeping the pool already does) ---------

    def fold_admissions(self, n: int) -> None:
        if n:
            self.c_admissions.inc(n)

    def fold_results(self, results: Sequence[Any]) -> None:
        """Count delivered RequestResults (complete vs truncated)."""
        n_trunc = sum(1 for r in results if getattr(r, "truncated", False))
        if n_trunc:
            self.c_truncated.inc(n_trunc)
        if len(results) - n_trunc:
            self.c_completed.inc(len(results) - n_trunc)

    def fold_cancelled(self, n: int) -> None:
        if n:
            self.c_cancelled.inc(n)

    def fold_slot_bytes(self, per_slot: float) -> None:
        """Record the pool's per-slot device footprint (host shape
        arithmetic from ``SessionPool.bytes_per_slot`` — no device sync)."""
        self.g_slot_bytes.set(float(per_slot))

    # -- robustness-layer hooks (serving/faults.py, serving/checkpoint.py,
    #    the async watchdog / reaper / shed paths) --------------------------

    def fold_fault(self, site: str) -> None:
        """Count one observed fault at ``site`` (labelled counter,
        get-or-create like the per-shard load gauges)."""
        c = self._fault_counters.get(site)
        if c is None:
            c = self.registry.counter(
                "spartus_faults_total", "faults observed, by site",
                labels={"site": site})
            self._fault_counters[site] = c
        c.inc()

    def fold_shed(self) -> None:
        self.c_shed.inc()

    def fold_timeouts(self, n: int) -> None:
        if n:
            self.c_timeouts.inc(n)

    def fold_bad_request(self) -> None:
        self.c_bad_requests.inc()

    def fold_checkpoint(self, *, n_sessions: int, seconds: float) -> None:
        self.c_checkpoints.inc()
        self.h_restore.observe(seconds)

    def fold_restore(self, *, n_sessions: int, seconds: float) -> None:
        if n_sessions:
            self.c_restored.inc(n_sessions)
        self.h_restore.observe(seconds)

    def fold_recovery(self, *, salvaged: int, lost: int,
                      seconds: float) -> None:
        """One watchdog recovery: pool rebuilt, ``salvaged`` sessions
        restored, ``lost`` sessions failed with a retriable error."""
        self.c_recoveries.inc()
        if salvaged:
            self.c_salvaged.inc(salvaged)
        if lost:
            self.c_lost.inc(lost)
        self.h_restore.observe(seconds)

    # -- the per-boundary fold ----------------------------------------------

    def _diff_totals(self, new_totals: Optional[Any]
                     ) -> Tuple[float, float, float]:
        """Resolve the PREVIOUS boundary's enqueued telemetry totals (its
        chunk has since completed, so this fetch does not sync on the
        in-flight dispatch), diff against the running values, and enqueue
        ``new_totals`` for the next boundary.  Returns the window's
        (temporal_sparsity, overflow_rate, steps)."""
        inc = (0.0, 0.0, 0.0)
        if self._pending_totals is not None:
            now = np.asarray(self._pending_totals, np.float64)
            d = now - self._last_totals
            self._last_totals = now
            d_steps = d[2]
            if d_steps > 0:
                inc = (float(1.0 - d[0] / d_steps),
                       float(d[1] / d_steps), float(d_steps))
        self._pending_totals = new_totals
        return inc

    def fold_chunk(
        self, *,
        occupancy: int,
        capacity: int,
        n_active: int,
        frames_advanced: int,
        dispatch_s: float,
        chunk_s: float,
        host_overlap_frac: float,
        admissions: int,
        retirements: int,
        shard_loads: Optional[Sequence[int]] = None,
        telemetry_totals: Optional[Any] = None,
    ) -> Dict[str, Any]:
        """Fold one dispatch boundary into counters, gauges and the time
        series.  Every argument is a host value the pool computed anyway;
        ``telemetry_totals`` is the (device, un-fetched) [3] reduction of
        the `[L, B]` accumulators after this chunk — it is only *fetched*
        at the next boundary.  Returns the appended sample (the async
        driver amends it with loop-side fields via
        ``timeseries.update_last``)."""
        self._chunk_seq += 1
        sp_inc, ovf_inc, steps_inc = self._diff_totals(telemetry_totals)
        self.c_dispatches.inc()
        self.c_frames.inc(frames_advanced)
        self.g_occupancy.set(occupancy)
        self.g_active_frac.set(n_active / capacity if capacity else 0.0)
        self.g_overlap.set(host_overlap_frac)
        if steps_inc > 0:
            self.g_sparsity.set(sp_inc)
        self.h_dispatch.observe(dispatch_s)
        self.h_chunk.observe(chunk_s)
        self.h_advance.observe(frames_advanced)
        if shard_loads is not None:
            for i, load in enumerate(shard_loads):
                g = self._shard_gauges.get(i)
                if g is None:
                    g = self.registry.gauge(
                        "spartus_shard_load", "occupied slots per shard",
                        labels={"shard": str(i)})
                    self._shard_gauges[i] = g
                g.set(load)
        dropped_before = self.timeseries.n_dropped
        sample: Dict[str, Any] = {
            "chunk": self._chunk_seq,
            "t_wall": time.time(),
            "occupancy": occupancy,
            "active_frac": n_active / capacity if capacity else 0.0,
            "frames": frames_advanced,
            "dispatch_s": dispatch_s,
            "chunk_s": chunk_s,
            "host_overlap_frac": host_overlap_frac,
            "admissions": admissions,
            "retirements": retirements,
            "shard_loads": list(shard_loads) if shard_loads is not None
            else [occupancy],
            "lagging": 0,
            "partial_queue_depth_max": 0,
            # incremental sparsity of the PREVIOUS window (one-boundary
            # lag: its totals were fetched here, never syncing the
            # in-flight chunk):
            "temporal_sparsity_inc": sp_inc,
            "overflow_rate_inc": ovf_inc,
            "samples_inc": steps_inc,
        }
        self.timeseries.append(sample)
        if self.timeseries.n_dropped > dropped_before:
            self.c_ts_dropped.inc(self.timeseries.n_dropped - dropped_before)
        return sample

    def flush_totals(self) -> None:
        """Resolve any still-pending telemetry totals (end of run), so
        the final sample-diff state is consistent with
        `measured_sparsity`."""
        self._diff_totals(None)
