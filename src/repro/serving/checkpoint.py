"""Session checkpoint/restore for the serving pool — the recovery and
migration primitive of the robustness layer (docs/robustness.md).

What a session *is*, for checkpoint purposes, is exactly the state the
chunked tick loop threads through `engine.step_chunk` plus the host-side
bookkeeping the scheduler keeps per slot:

  * per-layer recurrent slabs — ``s_hat`` (delta references), ``c``,
    ``h``, ``dm`` (delta memories) rows of each `BatchedLayerState`;
  * the per-slot telemetry columns (sparsity accumulators);
  * the device frame cursor and the frames received so far (device
    feature buffer row, with any *staged-but-not-yet-uploaded* host
    blocks overlaid — a snapshot never has to force an upload flush);
  * the banked logits rows ``[0, cursor)`` of the device output buffer
    (chunked mode) or the host row list (per-frame mode) — the rows a
    client may not have consumed yet;
  * the `_Session` metadata (req id, totals, needs_reset, ...).

Because every slot is computationally independent (the batched kernels
are vmaps of per-session ops — the zero-collectives property the sharded
pool is built on), a session restored into *any* slot of *any* pool with
the same engine weights continues bit-identically: slot index, pool
capacity and shard count are placement, not semantics.  That is what
makes the whole-pool checkpoint double as the **migration primitive**:
``SessionPool.restore`` works into a pool with a different ``n_devices``
(or capacity) than the one that wrote the checkpoint.

Fetch discipline: `snapshot_pool` performs ONE gathered device->host
fetch of the whole pool pytree (state, frames, lengths, out) under the
pool's state lock — it syncs on the in-flight chunk (checkpoints happen
at boundaries) and adds nothing to the compiled step, which is pinned by
the ``step_chunk/post-restore`` hot-path contract (analysis/cases.py).

File IO rides `training/checkpoint.py`: the flattened array dict *is* a
pytree, so `CheckpointManager` provides the atomic tmp-dir + ``os.replace``
+ COMMIT-marker write, retention and restore machinery unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import time
from typing import Any, Dict, List, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import sharding as shardlib
from repro.serving import telemetry as tele
from repro.serving.batched_engine import BatchedLayerState, PoolState
from repro.training.checkpoint import CheckpointManager

if TYPE_CHECKING:  # import cycle: scheduler imports this module's consumers
    from repro.serving.scheduler import RequestResult, SessionPool

FORMAT = "spartus-pool"
VERSION = 1

_LAYER_FIELDS = ("s_hat", "c", "h", "dm")


# -- snapshot containers ------------------------------------------------------


@dataclasses.dataclass
class SessionSnapshot:
    """One session's full state: JSON-able ``meta`` + named host arrays.

    Array keys: ``layer{i}/{s_hat,c,h,dm}``, ``telemetry`` ``[3, L]``
    (nnz_sum / overflow_steps / steps columns), ``frames`` ``[n_recv, D]``
    and ``rows`` ``[cursor, n_classes]`` (the banked logits)."""

    meta: Dict[str, Any]
    arrays: Dict[str, np.ndarray]

    @property
    def req_id(self) -> int:
        return int(self.meta["req_id"])


@dataclasses.dataclass
class PoolCheckpoint:
    """A whole pool's live sessions plus the engine fingerprint that
    guards restore compatibility."""

    meta: Dict[str, Any]
    sessions: List[SessionSnapshot]


def engine_fingerprint(engine) -> Dict[str, Any]:
    """The engine identity a checkpoint is only valid against: layer
    shapes and the sparsity/quantization parameters that change the
    computed numbers.  (Weight *values* are assumed managed by the model
    checkpoint path — serving snapshots carry state, not parameters.)

    The quantization entry keeps a quantized pool from restoring an fp32
    pool's sessions (and vice versa): the recurrent state evolves on a
    different numeric grid, so resuming across formats would silently
    diverge rather than fail."""
    from repro.serving.engine import active_quant

    quant = active_quant(engine.cfg)
    return {
        "input_dim": int(engine.input_dim),
        "n_classes": int(engine.n_classes),
        "layers": [[int(l.input_dim), int(l.hidden_dim)]
                   for l in engine.layers],
        "theta": float(engine.cfg.theta),
        "gamma": float(engine.cfg.gamma),
        "quant": (None if quant is None else
                  [int(quant.weight_bits), int(quant.act_bits),
                   int(quant.act_frac_bits)]),
    }


def _fp_key(fp: Dict[str, Any]) -> str:
    return json.dumps(fp, sort_keys=True)


def _check_engine(pool: "SessionPool", meta: Dict[str, Any]) -> None:
    have = engine_fingerprint(pool.engine)
    want = meta.get("engine")
    if want is None or _fp_key(have) != _fp_key(want):
        raise ValueError(
            f"checkpoint engine fingerprint {want} does not match the "
            f"pool's engine {have}; restore requires the same model "
            f"shapes and sparsity config (theta/gamma)")


# -- session snapshot ---------------------------------------------------------


def _session_meta(sess) -> Dict[str, Any]:
    return {
        "req_id": int(sess.req_id),
        "arrival_step": int(sess.arrival_step),
        "admit_step": int(sess.admit_step),
        "total": None if sess.total is None else int(sess.total),
        "n_recv": int(sess.n_recv),
        "cursor": int(sess.cursor),
        "last_step": int(sess.last_step),
        "needs_reset": bool(sess.needs_reset),
        "partials_paused": bool(sess.partials_paused),
        "had_first_logit": bool(sess.first_logit_wall),
    }


def _overlay_frames(pool: "SessionPool", sess, k: int,
                    dev_row: Optional[np.ndarray]) -> np.ndarray:
    """The session's frames ``[n_recv, D]``: the device buffer row
    overlaid with any host-staged blocks not yet uploaded.  Host-side
    ``n_recv`` is authoritative (the device length can lag a staged
    admission/append by one boundary), so a snapshot never needs to
    force an upload flush first."""
    fr = np.zeros((sess.n_recv, pool.engine.input_dim), np.float32)
    if dev_row is not None and sess.n_recv:
        n_dev = min(sess.n_recv, dev_row.shape[0])
        fr[:n_dev] = dev_row[:n_dev]
    for slot, feats in pool._staged:
        if slot == k:
            fr[:feats.shape[0]] = feats
    for slot, start, feats in pool._staged_appends:
        if slot == k:
            fr[start:start + feats.shape[0]] = feats
    return fr


def _session_rows(pool: "SessionPool", sess, k: int,
                  out_row: Optional[np.ndarray]) -> np.ndarray:
    """The banked logits rows ``[0, cursor)`` — from the device output
    bank (chunked) or the host row list (per-frame)."""
    n_classes = pool.engine.n_classes
    if pool.chunk_frames:
        if out_row is None or not sess.cursor:
            return np.zeros((0, n_classes), np.float32)
        return np.asarray(out_row[:sess.cursor], np.float32).copy()
    if not sess.rows:
        return np.zeros((0, n_classes), np.float32)
    return np.stack(sess.rows).astype(np.float32)


def _snap(pool: "SessionPool", sess, k: int, layer_rows, tel_col,
          frames_row, out_row) -> SessionSnapshot:
    arrays: Dict[str, np.ndarray] = {}
    for i, row in enumerate(layer_rows):
        for name, val in zip(_LAYER_FIELDS, row):
            arrays[f"layer{i}/{name}"] = np.asarray(val, np.float32).copy()
    arrays["telemetry"] = np.asarray(np.stack(tel_col), np.float32)
    arrays["frames"] = _overlay_frames(pool, sess, k, frames_row)
    arrays["rows"] = _session_rows(pool, sess, k, out_row)
    return SessionSnapshot(meta=_session_meta(sess), arrays=arrays)


def snapshot_session(pool: "SessionPool", req_id: int) -> SessionSnapshot:
    """Serialize ONE live session (one gathered D2H fetch of its rows).

    Raises KeyError for a request the pool has no live slot for — a
    session inside the retirement window is already past snapshotting
    (its result is in flight; resolve it with ``flush()``)."""
    if req_id not in pool._by_req:
        raise KeyError(f"request {req_id} is not live in the pool")
    k = pool._by_req[req_id]
    sess = pool._slots[k]
    with pool._state_lock:
        state = pool.state
        layer_rows, tel_col, frames_row, out_row = jax.device_get((
            tuple(tuple(getattr(st, f)[k] for f in _LAYER_FIELDS)
                  for st in state.layers),
            (state.telemetry.nnz_sum[:, k],
             state.telemetry.overflow_steps[:, k],
             state.telemetry.steps[:, k]),
            pool._frames[k],
            pool._out[k] if pool._out is not None else None,
        ))
    return _snap(pool, sess, k, layer_rows, tel_col, frames_row, out_row)


def snapshot_pool(pool: "SessionPool") -> PoolCheckpoint:
    """Serialize every live session in ONE gathered device->host fetch
    of the pool pytree (state, frames, out) — the single-sync snapshot
    the whole-pool checkpoint and the watchdog are built on.  Sessions
    inside the retirement window are NOT included (their logits are in
    flight to the host); call ``flush()`` first to resolve them."""
    with pool._state_lock:
        state, frames, out = jax.device_get(
            (pool.state, pool._frames, pool._out))
    sessions: List[SessionSnapshot] = []
    for k, sess in enumerate(pool._slots):
        if sess is None:
            continue
        layer_rows = tuple(tuple(getattr(st, f)[k] for f in _LAYER_FIELDS)
                           for st in state.layers)
        tel_col = (state.telemetry.nnz_sum[:, k],
                   state.telemetry.overflow_steps[:, k],
                   state.telemetry.steps[:, k])
        sessions.append(_snap(pool, sess, k, layer_rows, tel_col,
                              frames[k], out[k] if out is not None else None))
    meta = {
        "format": FORMAT,
        "version": VERSION,
        "engine": engine_fingerprint(pool.engine),
        "chunk_frames": int(pool.chunk_frames),
        "capacity": int(pool.capacity),
        "n_sessions": len(sessions),
    }
    return PoolCheckpoint(meta=meta, sessions=sessions)


# -- restore ------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_row(slab: jax.Array, row: jax.Array, k: jax.Array) -> jax.Array:
    """Scatter one session's row into a per-slot slab at a traced index
    (compiles once per slab shape, like the admission upload)."""
    return slab.at[k].set(row, mode="drop")


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_col(slab: jax.Array, col: jax.Array, k: jax.Array) -> jax.Array:
    """Scatter one telemetry column ``[L]`` into a ``[L, B]`` slab."""
    return slab.at[:, k].set(col, mode="drop")


def _make_session(pool: "SessionPool", snap: SessionSnapshot, k: int,
                  now_wall: float):
    from repro.serving.scheduler import _Session

    m = snap.meta
    sess = _Session(
        req_id=int(m["req_id"]),
        arrival_step=int(m["arrival_step"]),
        admit_step=int(m["admit_step"]),
        arrival_wall=now_wall,
        admit_wall=now_wall,
        total=None if m["total"] is None else int(m["total"]),
        n_recv=int(m["n_recv"]),
        cursor=int(m["cursor"]),
        last_step=int(m["last_step"]),
        needs_reset=bool(m["needs_reset"]),
        partials_paused=bool(m["partials_paused"]),
        # wall clocks re-base to restore time: latency numbers measure
        # this process's service, not the epoch of the dead one
        first_logit_wall=now_wall if m["had_first_logit"] else 0.0,
    )
    if not pool.chunk_frames:
        sess.rows = [np.array(r) for r in snap.arrays["rows"]]
    pool._slots[k] = sess
    pool._by_req[sess.req_id] = k
    # frames go through the standard staged-upload wave at the next
    # boundary — one jitted H2D scatter, no eager per-slot writes; a
    # zero-length staging still clears the slot's stale device length
    pool._staged.append((k, np.asarray(snap.arrays["frames"], np.float32)))
    return sess


def restore_session(pool: "SessionPool", snap: SessionSnapshot) -> bool:
    """Restore ONE session into a free slot of a live pool (the
    single-session migration primitive).  Returns False if the pool is
    full; raises on an incompatible engine or duplicate request id.

    Device writes are jitted donated scatters at a traced slot index, so
    repeated restores compile once per slab shape — and the compiled
    ``step_chunk`` itself is untouched (the post-restore contract pin)."""
    m = snap.meta
    if int(m["req_id"]) in pool._by_req:
        raise ValueError(f"request {m['req_id']} is already in the pool")
    if int(m["n_recv"]) > pool.max_buffer_frames:
        raise ValueError(
            f"request {m['req_id']}: snapshot holds {m['n_recv']} frames, "
            f"past this pool's max_buffer_frames={pool.max_buffer_frames}")
    k = pool._pick_slot()
    if k is None:
        return False
    if int(m["n_recv"]) > pool._t_buf:
        pool._grow_buffers(int(m["n_recv"]))
    sess = _make_session(pool, snap, k, time.perf_counter())
    kk = np.int32(k)
    with pool._state_lock:
        state = pool.state
        layers = []
        for i, st in enumerate(state.layers):
            layers.append(BatchedLayerState(**{
                f: _write_row(getattr(st, f),
                              jnp.asarray(snap.arrays[f"layer{i}/{f}"]), kk)
                for f in _LAYER_FIELDS}))
        telemetry = tele.TelemetryState(
            nnz_sum=_write_col(state.telemetry.nnz_sum,
                               jnp.asarray(snap.arrays["telemetry"][0]), kk),
            overflow_steps=_write_col(
                state.telemetry.overflow_steps,
                jnp.asarray(snap.arrays["telemetry"][1]), kk),
            steps=_write_col(state.telemetry.steps,
                             jnp.asarray(snap.arrays["telemetry"][2]), kk),
        )
        cursor = _write_row(state.cursor, jnp.int32(sess.cursor), kk)
        new_state = PoolState(tuple(layers), telemetry, cursor)
        if pool._mesh is not None:
            new_state = shardlib.shard_pool_state(new_state, pool._mesh)
        pool.state = new_state
        if pool.chunk_frames:
            rows = snap.arrays["rows"]
            row_full = np.zeros((pool._out.shape[1], pool.engine.n_classes),
                                np.float32)
            row_full[:rows.shape[0]] = rows
            out = _write_row(pool._out, jnp.asarray(row_full), kk)
            if pool._mesh is not None:
                out = shardlib.shard_slot_array(out, pool._mesh)
            pool._out = out
    return True


def restore_into(pool: "SessionPool", ckpt: PoolCheckpoint) -> None:
    """Restore every session of a checkpoint into a FRESH, empty pool.

    The target pool may have a different capacity and a different shard
    count (``n_devices``) than the writer — slot placement is re-derived
    by the pool's own admission policy, and per-slot independence makes
    the continued logits bit-identical either way.  The new `PoolState`
    is assembled host-side in one pass and placed (sharded) in one
    ``device_put`` per slab; frames ride the standard staged-upload wave
    at the first boundary.  Nothing here touches the compiled step."""
    t0 = time.perf_counter()
    _check_engine(pool, ckpt.meta)
    if (pool.n_active or pool._staged or pool._staged_appends
            or pool.has_pending):
        raise ValueError("restore_into requires an empty pool with no "
                         "staged or pending work")
    if len(ckpt.sessions) > pool.capacity:
        raise ValueError(
            f"checkpoint holds {len(ckpt.sessions)} sessions, pool "
            f"capacity is {pool.capacity}")
    t_need = max((int(s.meta["n_recv"]) for s in ckpt.sessions), default=0)
    if t_need > pool.max_buffer_frames:
        raise ValueError(
            f"checkpoint session holds {t_need} frames, past this pool's "
            f"max_buffer_frames={pool.max_buffer_frames}")
    if t_need > pool._t_buf:
        pool._grow_buffers(t_need)

    # host-side assembly on top of the fresh-init values (so untouched
    # slots keep the exact fresh state, dm bias rows included):
    base = jax.device_get(pool.state)
    layers = [{f: np.array(getattr(st, f)) for f in _LAYER_FIELDS}
              for st in base.layers]
    # three DISTINCT arrays: the step donates the whole state and aliased
    # telemetry leaves reject donation (the init_telemetry bug)
    tel_n = np.array(base.telemetry.nnz_sum)
    tel_o = np.array(base.telemetry.overflow_steps)
    tel_s = np.array(base.telemetry.steps)
    cursor = np.array(base.cursor)
    out_np = (np.zeros((pool.capacity, pool._t_buf + pool.chunk_frames,
                        pool.engine.n_classes), np.float32)
              if pool.chunk_frames else None)

    now_wall = time.perf_counter()
    for snap in ckpt.sessions:
        if int(snap.meta["req_id"]) in pool._by_req:
            raise ValueError(f"duplicate request {snap.meta['req_id']} "
                             "in checkpoint")
        k = pool._pick_slot()
        assert k is not None  # capacity checked above
        sess = _make_session(pool, snap, k, now_wall)
        for i in range(len(layers)):
            for f in _LAYER_FIELDS:
                layers[i][f][k] = snap.arrays[f"layer{i}/{f}"]
        tel_n[:, k] = snap.arrays["telemetry"][0]
        tel_o[:, k] = snap.arrays["telemetry"][1]
        tel_s[:, k] = snap.arrays["telemetry"][2]
        cursor[k] = sess.cursor
        if out_np is not None:
            rows = snap.arrays["rows"]
            out_np[k, :rows.shape[0]] = rows

    new_state = PoolState(
        layers=tuple(BatchedLayerState(**{f: jnp.asarray(d[f])
                                          for f in _LAYER_FIELDS})
                     for d in layers),
        telemetry=tele.TelemetryState(nnz_sum=jnp.asarray(tel_n),
                                      overflow_steps=jnp.asarray(tel_o),
                                      steps=jnp.asarray(tel_s)),
        cursor=jnp.asarray(cursor),
    )
    with pool._state_lock:
        if pool._mesh is not None:
            new_state = shardlib.shard_pool_state(new_state, pool._mesh)
        pool.state = new_state
        if out_np is not None:
            out = jnp.asarray(out_np)
            if pool._mesh is not None:
                out = shardlib.shard_slot_array(out, pool._mesh)
            pool._out = out
    if pool.obs is not None:
        pool.obs.fold_restore(n_sessions=len(ckpt.sessions),
                              seconds=time.perf_counter() - t0)


# -- file IO (rides training/checkpoint.py) -----------------------------------


def _flatten_ckpt(ckpt: PoolCheckpoint):
    arrays: Dict[str, np.ndarray] = {}
    metas: List[Dict[str, Any]] = []
    for i, snap in enumerate(ckpt.sessions):
        metas.append(snap.meta)
        for key, arr in snap.arrays.items():
            arrays[f"s{i}/{key}"] = arr
    meta = dict(ckpt.meta)
    meta["sessions"] = metas
    return arrays, meta


def _unflatten_ckpt(arrays: Dict[str, np.ndarray],
                    meta: Dict[str, Any]) -> PoolCheckpoint:
    if meta.get("format") != FORMAT:
        raise ValueError(f"not a {FORMAT} checkpoint: {meta.get('format')!r}")
    if int(meta.get("version", -1)) > VERSION:
        raise ValueError(f"checkpoint version {meta['version']} is newer "
                         f"than this code ({VERSION})")
    sessions = []
    for i, smeta in enumerate(meta["sessions"]):
        prefix = f"s{i}/"
        sarr = {k[len(prefix):]: np.asarray(v)
                for k, v in arrays.items() if k.startswith(prefix)}
        sessions.append(SessionSnapshot(meta=dict(smeta), arrays=sarr))
    pmeta = {k: v for k, v in meta.items() if k != "sessions"}
    return PoolCheckpoint(meta=pmeta, sessions=sessions)


def save_pool(pool: "SessionPool", path: str, *,
              keep_last: int = 3,
              async_save: bool = False) -> List["RequestResult"]:
    """Checkpoint the whole pool to ``path`` (a checkpoint *directory*:
    atomic write, COMMIT marker, retention — `CheckpointManager`).

    Flushes the double-buffer tail first and RETURNS those finished
    results: sessions in the retirement window at checkpoint time have
    completed — their logits belong to the caller, not the checkpoint.
    The checkpoint step number is the pool's dispatch count."""
    results = pool.flush()
    t0 = time.perf_counter()
    ckpt = snapshot_pool(pool)
    arrays, meta = _flatten_ckpt(ckpt)
    mgr = CheckpointManager(path, keep_last=keep_last, process_index=0,
                            async_save=async_save)
    mgr.save(pool.n_dispatches, arrays, metadata=meta)
    mgr.wait()
    if pool.obs is not None:
        pool.obs.fold_checkpoint(n_sessions=len(ckpt.sessions),
                                 seconds=time.perf_counter() - t0)
    return results


def load_checkpoint(path: str, step: Optional[int] = None) -> PoolCheckpoint:
    """Read a committed pool checkpoint back (latest step by default).
    Incomplete checkpoints (no COMMIT marker) are never offered — the
    kill -9 safety property inherited from `CheckpointManager`."""
    mgr = CheckpointManager(path, process_index=0, async_save=False)
    if step is None:
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {path}")
    arrays, meta = mgr.restore_arrays(step)
    return _unflatten_ckpt(arrays, meta)
