"""Spartus serving engine: streaming DeltaLSTM inference over CBCSC
weights — the software twin of the accelerator datapath (Fig. 4).

Per step and per layer:
  IPU   -> kernels.ops.delta_encode   (thresholded Δ, reference update)
  CTRL  -> kernels.ops.select_active_columns (fixed-capacity NZI list)
  MACs  -> kernels.ops.stsp_spmv      (CBCSC spatio-temporal SpMxSpV)
  HPE   -> kernels.ops.lstm_pointwise (gates + cell update)

The engine exports any trained LSTM AM (models/lstm_am.py) into packed
CBCSC + int8 form, runs batched streaming sessions, and records the
per-step NZI occupancies that drive the hwsim performance model.

``use_pallas`` switches the kernel implementations (interpret mode on
CPU, compiled Pallas on TPU); the XLA path is numerically identical.

`SpartusEngine` is deliberately slow and simple — a Python loop per
frame with host syncs for telemetry — because it is the parity oracle:
the batched pool, the chunked tick loop and the async front-end are all
pinned against its logits at 1e-5 (see docs/serving.md).  The shared
CBCSC export (`pack_lstm_layer`, via `PackedSpartusModel`) enforces
`blen_for(gamma)` at pack time and fixes each layer's SpMV route —
scatter kernels vs the pack-time dense mirror (docs/kernels.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CBCSC, blen_for, cbcsc_decode, cbcsc_encode, int8_pack,
)
from repro.core.delta_lstm import stacked_weight_matrix
from repro.core.quantization import QuantConfig
from repro.kernels import ops
from repro.models.lstm_am import LSTMAMConfig


@dataclasses.dataclass
class PackedLayer:
    enc: CBCSC                 # CBCSC arrays (values already int8-dequantized)
    scale: jax.Array           # int8 weight scale
    bias: jax.Array            # [4, H] initial delta memories
    input_dim: int
    hidden_dim: int
    capacity: int              # NZI list capacity
    pack_overflow: int = 0     # nonzeros clipped enforcing BLEN at pack time
    # [D+H, 4H] PRE-TRANSPOSED dense mirror (dense SpMV path): stored in
    # GEMM-contraction layout because XLA CPU re-transposes `w.T` on every
    # tick otherwise (~3x the dot cost at hidden=128)
    w_dense_t: Optional[jax.Array] = None


@dataclasses.dataclass
class EngineConfig:
    theta: float = 0.1
    gamma: float = 0.9375
    m: int = 64                # PEs per column (CBCSC granularity)
    capacity_frac: float = 0.5  # NZI capacity as fraction of columns
    use_pallas: bool = False
    quant_bits: int = 8
    # SpMV implementation: "auto" routes layers with S*(1-gamma) >= 1 to the
    # dense-gather mirror (ops.spmv_use_dense_gather); "scatter" forces the
    # CBCSC scatter path, "dense" forces the mirror.
    spmv_path: str = "auto"
    # Quantized serving (docs/quantization.md): None keeps the fp32 path
    # byte-identical to before; a QuantConfig with enabled=True stores the
    # CBCSC payload and dense mirror as int8 at rest (dequantized in the
    # SpMV epilogue) and runs the delta threshold on the Qm.n activation
    # grid.  enabled=False behaves exactly like None.
    quant: Optional[QuantConfig] = None


def active_quant(cfg: EngineConfig) -> Optional[QuantConfig]:
    """The engine's quantization config iff quantization is actually on."""
    q = cfg.quant
    return q if (q is not None and q.enabled) else None


def pack_lstm_layer(params: Dict[str, Any], cfg: EngineConfig) -> PackedLayer:
    """Export one (CBTD-pruned) LSTM layer to the serving format.

    BLEN is *enforced* at ``blen_for(gamma)`` (Alg. 3), clipping the
    smallest-magnitude overflow nonzeros per subcolumn, rather than derived
    from max occupancy: an untrained or partially-pruned matrix used to
    inflate BLEN to S, silently voiding the format's bandwidth contract
    (and making ``weight_sparsity()`` report near 0).  The clipped count is
    recorded as ``pack_overflow`` — 0 for any properly CBTD-pruned model.
    """
    if cfg.spmv_path not in ("auto", "scatter", "dense"):
        raise ValueError(f"spmv_path must be 'auto', 'scatter' or 'dense', "
                         f"got {cfg.spmv_path!r}")
    w = stacked_weight_matrix(params)              # [4H, D+H]
    q8, scale = int8_pack(w)
    wq = q8.astype(jnp.float32) * scale            # dequantized int8 grid
    wq = wq * (w != 0)                             # keep pruned zeros exact
    h4, n_cols = wq.shape
    m = cfg.m
    while h4 % m:
        m //= 2
    blen = blen_for(h4, m, cfg.gamma)
    enc = cbcsc_encode(wq, m, blen=blen, on_overflow="clip")
    overflow = int(jax.device_get(jnp.sum(wq != 0) - jnp.sum(enc.valid)))
    s = enc.s
    if cfg.spmv_path == "dense" or (
        cfg.spmv_path == "auto" and ops.spmv_use_dense_gather(s, cfg.gamma)
    ):
        # pack-time dense mirror: decoded from the (clipped) CBCSC arrays so
        # every SpMV path computes from identical weights; materialised
        # transposed, in the per-tick GEMM's contraction layout.
        w_dense_t = jnp.asarray(cbcsc_decode(enc, jnp.float32).T)
    else:
        w_dense_t = None
    if active_quant(cfg) is not None:
        # Int8 at rest: the fp32 payload above is already on the int8 grid
        # (wq = q8 * scale with a pow2 per-tensor scale), so dividing back
        # by the scale is exact and y*scale in the SpMV epilogue reproduces
        # the fp32 path bit for bit.  Weight memory drops 4x per element.
        # The local indices pack to the paper's 8-bit LIDX when they fit
        # (S <= 128; the kernels widen to int32 before any row math).
        lidx = enc.lidx.astype(jnp.int8) if s <= 128 else enc.lidx
        enc = dataclasses.replace(
            enc, val=jnp.round(enc.val / scale).astype(jnp.int8), lidx=lidx)
        if w_dense_t is not None:
            w_dense_t = jnp.round(w_dense_t / scale).astype(jnp.int8)
    capacity = max(int(n_cols * cfg.capacity_frac), 8)
    return PackedLayer(
        enc=enc, scale=scale, bias=params["b"],
        input_dim=w.shape[1] - params["w_h"].shape[1],
        hidden_dim=params["w_h"].shape[1], capacity=capacity,
        pack_overflow=overflow, w_dense_t=w_dense_t,
    )


class LayerState:
    """Mutable per-session state of one DeltaLSTM layer (x̂/ĥ/c/h/DM)."""

    def __init__(self, layer: PackedLayer, dtype=jnp.float32):
        d, h = layer.input_dim, layer.hidden_dim
        self.s_hat = jnp.zeros((d + h,), dtype)    # concatenated x̂ / ĥ
        self.c = jnp.zeros((h,), dtype)
        self.h = jnp.zeros((h,), dtype)
        self.dm = layer.bias.astype(dtype).reshape(-1)  # [4H]


def _step_layer(
    layer: PackedLayer, state: LayerState, x: jax.Array, cfg: EngineConfig
) -> Tuple[jax.Array, Dict[str, int]]:
    """One streaming step of one layer.  x: [D] -> h: [H]."""
    quant = active_quant(cfg)
    act_kw = (
        {"act_bits": quant.act_bits, "act_frac_bits": quant.act_frac_bits}
        if quant is not None else {}
    )
    wscale = layer.scale if quant is not None else None
    val, lidx, mirror = layer.enc.val, layer.enc.lidx, layer.w_dense_t
    if quant is not None:
        # int8 at rest INSIDE the compiled module too: the weights are
        # closed-over constants, and without a barrier XLA folds
        # convert(s8 const) into a baked f32 constant — silently
        # restoring the fp32 footprint the quant mode exists to shed.
        if mirror is not None:
            mirror = jax.lax.optimization_barrier(mirror)
        else:
            val, lidx = jax.lax.optimization_barrier((val, lidx))
    s = jnp.concatenate([x, state.h])
    delta, s_hat, nnz = ops.delta_encode(
        s, state.s_hat, cfg.theta, use_pallas=cfg.use_pallas, **act_kw
    )
    if mirror is not None:
        # B=1 leg of the exact batched dense-mirror computation, so pooled
        # and batch-1 logits stay bit-comparable on the dense path:
        y, dropped = ops.delta_spmv_dense_topk_batch(
            mirror, delta[None], layer.capacity, scale=wscale)
        y, dropped = y[0], dropped[0]
    else:
        idx, vals, dropped = ops.select_active_columns(delta, layer.capacity)
        y = ops.stsp_spmv(
            val, lidx, idx, vals, s=layer.enc.s,
            use_pallas=cfg.use_pallas, scale=wscale,
        )
    dm = state.dm + y.astype(state.dm.dtype)
    h_new, c_new = ops.lstm_pointwise(
        dm.reshape(4, layer.hidden_dim), state.c, use_pallas=cfg.use_pallas
    )
    state.s_hat = s_hat
    state.c = c_new
    state.h = h_new
    state.dm = dm
    stats = {"nnz": int(nnz), "dropped": int(dropped),
             "n_cols": int(s.shape[0])}
    return h_new, stats


class PackedSpartusModel:
    """CBCSC export + weight accounting shared by the batch-1 engine and
    the continuous-batching engine (serving/batched_engine.py)."""

    def __init__(self, am_params: Dict[str, Any], am_cfg: LSTMAMConfig,
                 cfg: EngineConfig = EngineConfig()):
        self.cfg = cfg
        self.layers = [pack_lstm_layer(lp, cfg) for lp in am_params["lstm"]]
        self.fcl = am_params["fcl"]
        self.logit = am_params["logit"]
        self.am_cfg = am_cfg

    @property
    def input_dim(self) -> int:
        return self.layers[0].input_dim

    @property
    def n_classes(self) -> int:
        return self.logit["w"].shape[0]

    @property
    def n_cols(self) -> List[int]:
        """Stacked-matrix column count per layer (telemetry reduction)."""
        return [l.input_dim + l.hidden_dim for l in self.layers]

    def weight_sparsity(self) -> float:
        """Fraction of zero weights in the packed layers.  Because pack time
        enforces BLEN = blen_for(gamma), this is >= 1 - BLEN/S even for an
        unpruned matrix (overflow is clipped, see ``pack_overflow_count``)
        instead of collapsing to ~0 when BLEN used to track max occupancy."""
        dense = sum(l.enc.h * l.enc.q for l in self.layers)
        nnz = sum(float(jnp.sum(l.enc.valid)) for l in self.layers)
        return 1.0 - nnz / dense

    def pack_overflow_count(self) -> int:
        """Total nonzeros clipped across layers enforcing BLEN at pack time
        (0 for a properly CBTD-pruned model; > 0 flags that the exported
        weights deviate from the training-time matrix)."""
        return sum(l.pack_overflow for l in self.layers)

    def weight_bytes(self) -> int:
        """Bytes of packed weight memory at rest: CBCSC payloads (val +
        lidx + valid), dense mirrors, biases, and the fc/logit head.  This
        is the model's share of a pool's device footprint — with
        ``cfg.quant`` enabled the val/mirror terms are int8 (4x smaller),
        while the int32 lidx bookkeeping and the fp32 head are unchanged
        (docs/quantization.md has the per-term table)."""
        def nbytes(a) -> int:
            return int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize

        total = 0
        for l in self.layers:
            total += nbytes(l.enc.val) + nbytes(l.enc.lidx)
            total += nbytes(l.enc.valid) + nbytes(l.bias)
            total += nbytes(l.scale)
            if l.w_dense_t is not None:
                total += nbytes(l.w_dense_t)
        for p in (self.fcl, self.logit):
            total += sum(nbytes(a) for a in p.values())
        return total

    def weight_payload_bytes(self) -> int:
        """CBCSC val/lidx streams + dense mirrors only — the weight memory
        the paper's WMEM actually stores per layer (excludes the validity
        mask, biases and the fp32 fc/logit head, which are O(H) or
        amortised).  The ~4x int8 reduction applies to this term."""
        def nbytes(a) -> int:
            return int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize

        total = 0
        for l in self.layers:
            total += nbytes(l.enc.val) + nbytes(l.enc.lidx)
            if l.w_dense_t is not None:
                total += nbytes(l.w_dense_t)
        return total


class SpartusEngine(PackedSpartusModel):
    """Multi-layer streaming engine with per-step sparsity telemetry."""

    def __init__(self, am_params: Dict[str, Any], am_cfg: LSTMAMConfig,
                 cfg: EngineConfig = EngineConfig()):
        super().__init__(am_params, am_cfg, cfg)
        self.telemetry: List[Dict[str, int]] = []

    def new_session(self) -> List[LayerState]:
        return [LayerState(l) for l in self.layers]

    def step(self, session: List[LayerState], x: jax.Array) -> jax.Array:
        """One frame through the whole AM -> logits [n_classes]."""
        h = x
        for li, (layer, st) in enumerate(zip(self.layers, session)):
            h, stats = _step_layer(layer, st, h, self.cfg)
            stats["layer"] = li
            self.telemetry.append(stats)
        h = jax.nn.relu(h @ self.fcl["w"].T + self.fcl["b"])
        return h @ self.logit["w"].T + self.logit["b"]

    def run_utterance(self, feats: jax.Array) -> jax.Array:
        """feats: [T, D] -> logits [T, n_classes] (batch-1 streaming)."""
        session = self.new_session()
        return jnp.stack([self.step(session, feats[t])
                          for t in range(feats.shape[0])])

    # -- telemetry -> hardware model -----------------------------------------

    def measured_sparsity(self) -> Dict[str, float]:
        if not self.telemetry:
            return {}
        nnz = np.array([t["nnz"] for t in self.telemetry], np.float64)
        cols = np.array([t["n_cols"] for t in self.telemetry], np.float64)
        dropped = np.array([t["dropped"] for t in self.telemetry], np.float64)
        return {
            "temporal_sparsity": float(1.0 - (nnz / cols).mean()),
            "capacity_overflow_rate": float((dropped > 0).mean()),
            "mean_active_columns": float(nnz.mean()),
        }
