"""Performance-variant flags for the §Perf hillclimb.

A module-level (trace-time) configuration consulted by the sharding rules
and the model code.  The dry-run sets a variant, lowers, and compares
roofline terms against the baseline — every flag corresponds to one
hypothesis in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass
class PerfVariant:
    name: str = "baseline"
    # training layout: replace TP (activation all-reduces per layer) with
    # 2-axis FSDP + sequence parallelism (per-layer weight all-gathers)
    fsdp_sp: bool = False
    # decode: keep seq-sharded KV local (distributed flash-decode combine)
    # instead of gathering the cache every step
    seq_sharded_decode: bool = True
    # serving quantization: store params / KV cache in int8
    int8_weights: bool = False
    int8_kv: bool = False
    # microbatch override (None = heuristic)
    microbatches: Optional[int] = None
    # logical mesh re-aspect for the same chip count, e.g. ((32, 8),
    # ("data", "model")) — halves TP activation all-reduce bytes when the
    # batch can shard wider (EXPERIMENTS.md §Perf granite train iteration 2)
    mesh_override: Optional[Tuple[Tuple[int, ...], Tuple[str, ...]]] = None


_CURRENT = PerfVariant()


def current() -> PerfVariant:
    return _CURRENT


@contextlib.contextmanager
def variant(v: PerfVariant):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = v
    try:
        yield
    finally:
        _CURRENT = prev
