"""Column-Balanced Targeted Dropout (CBTD) — Alg. 1 & 2 of the paper.

A weight matrix ``W [H, Q]`` (H = output rows = "column height", Q = input
columns) is viewed as Q columns; each column is split into M *subcolumns*
by interleaving rows across the M PEs (row r -> PE ``r % M``, local index
``r // M`` — Fig. 2/3 of the paper).  In each subcolumn, the smallest
``floor(H/M * gamma)`` elements by magnitude are dropped, each with
probability ``alpha``.  At ``alpha=1`` every subcolumn of every column has
*exactly* ``ceil(H/M * (1-gamma))`` nonzeros — the balance invariant that
makes the hardware workload uniform (property-tested).

Two granularities are provided:
  * element-granular (``cbtd_mask``) — bit-faithful Alg. 1;
  * tile-granular (``cbtd_tile_mask``) — the TPU-native adaptation where
    the "PE" is an MXU tile row and pruning keeps a balanced number of
    (tr x tc) tiles per tile-column (DESIGN.md §2).

``CBTDSchedule`` implements Alg. 2's annealing: alpha ramps 0 -> 1 with
step ``delta_alpha`` per epoch while gamma stays fixed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def _subcolumn_view(w: jax.Array, m: int) -> jax.Array:
    """[H, Q] -> [M, H/M, Q] with interleaved row assignment (row r -> PE r%M)."""
    h, q = w.shape
    if h % m != 0:
        raise ValueError(f"column height {h} not divisible by M={m}")
    # rows r = k*M + i  ->  (i, k):  reshape splits r into (k, i).
    return w.reshape(h // m, m, q).transpose(1, 0, 2)


def _subcolumn_unview(s: jax.Array) -> jax.Array:
    """Inverse of _subcolumn_view: [M, H/M, Q] -> [H, Q]."""
    m, k, q = s.shape
    return s.transpose(1, 0, 2).reshape(m * k, q)


def drop_count(h: int, m: int, gamma: float) -> int:
    """Alg. 1: number of dropped elements per subcolumn = floor(H/M * gamma)."""
    return int((h // m) * gamma)


def keep_count(h: int, m: int, gamma: float) -> int:
    """Nonzeros per subcolumn after CBTD at alpha=1 (= CBCSC BLEN, Alg. 3)."""
    return (h // m) - drop_count(h, m, gamma)


def _rank_by_magnitude(s: jax.Array) -> jax.Array:
    """Rank (0 = smallest |.|) of every element along axis=1 of [M, S, Q]."""
    order = jnp.argsort(jnp.abs(s), axis=1)           # positions sorted by |.|
    ranks = jnp.argsort(order, axis=1)                # inverse permutation
    return ranks


def cbtd_mask(
    w: jax.Array,
    gamma: float,
    m: int,
    alpha: float | jax.Array = 1.0,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Alg. 1: boolean keep-mask for ``w`` under CBTD.

    alpha < 1 requires ``key`` (stochastic targeted dropout).  alpha == 1 is
    deterministic and gives the exact balance invariant.
    """
    h, q = w.shape
    s = _subcolumn_view(w, m)                          # [M, S, Q]
    k_drop = drop_count(h, m, gamma)
    ranks = _rank_by_magnitude(s)
    candidates = ranks < k_drop                        # smallest-k per subcolumn

    alpha = jnp.asarray(alpha, w.dtype)
    if key is None:
        drop = candidates & (alpha >= 1.0)
    else:
        u = jax.random.uniform(key, s.shape, dtype=w.dtype)
        drop = candidates & (u < alpha)
    return _subcolumn_unview(~drop)


def apply_cbtd(
    w: jax.Array,
    gamma: float,
    m: int,
    alpha: float | jax.Array = 1.0,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Alg. 1 applied: returns the pruned matrix (w * mask)."""
    return w * cbtd_mask(w, gamma, m, alpha, key).astype(w.dtype)


# Tile-granular variant (TPU adaptation) -----------------------------------


def cbtd_tile_mask(
    w: jax.Array,
    gamma: float,
    tile: Tuple[int, int] = (8, 128),
    alpha: float | jax.Array = 1.0,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Tile-balanced CBTD: keep a fixed number of (tr x tc) tiles per
    tile-column, ranked by tile Frobenius norm.  The serving kernel then
    skips whole missing tiles (MXU-friendly).  Balance invariant: every
    tile-column keeps exactly ``ceil(n_tile_rows * (1-gamma))`` tiles when
    alpha = 1."""
    tr, tc = tile
    h, q = w.shape
    if h % tr or q % tc:
        raise ValueError(f"shape {w.shape} not divisible by tile {tile}")
    n_r, n_c = h // tr, q // tc
    tiles = w.reshape(n_r, tr, n_c, tc)
    norms = jnp.sqrt(jnp.sum(tiles.astype(jnp.float32) ** 2, axis=(1, 3)))  # [n_r, n_c]
    k_drop = int(n_r * gamma)
    ranks = jnp.argsort(jnp.argsort(norms, axis=0), axis=0)
    candidates = ranks < k_drop
    alpha = jnp.asarray(alpha, jnp.float32)
    if key is None:
        drop = candidates & (alpha >= 1.0)
    else:
        u = jax.random.uniform(key, norms.shape)
        drop = candidates & (u < alpha)
    keep = ~drop                                        # [n_r, n_c]
    return jnp.repeat(jnp.repeat(keep, tr, axis=0), tc, axis=1)


# Training schedule (Alg. 2) ------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CBTDConfig:
    """Per-layer CBTD configuration."""

    gamma: float = 0.94          # target sparsity
    m: int = 64                  # PEs per column (subcolumn granularity)
    delta_alpha: float = 1.0 / 30.0  # alpha ramp per epoch (paper: 1/30)
    granularity: str = "element"     # "element" | "tile"
    tile: Tuple[int, int] = (8, 128)

    def mask_fn(self, w, alpha=1.0, key=None):
        if self.granularity == "element":
            return cbtd_mask(w, self.gamma, self.m, alpha, key)
        return cbtd_tile_mask(w, self.gamma, self.tile, alpha, key)


def alpha_at(epoch: int | jax.Array, delta_alpha: float) -> jax.Array:
    """Alg. 2: alpha ramps from 0 by delta_alpha per epoch, clipped at 1."""
    return jnp.minimum(jnp.asarray(epoch, jnp.float32) * delta_alpha, 1.0)


def effective_m(h: int, m: int) -> int:
    """Largest power-of-two divisor of ``h`` that is <= m (CBTD needs
    M | H; stacked-model matrices have odd heights like 3352)."""
    while m > 1 and h % m:
        m //= 2
    return max(m, 1)


def cbtd_prune_tree(
    params,
    layout: Dict[str, CBTDConfig],
    alpha: float | jax.Array,
    key: Optional[jax.Array] = None,
):
    """Apply CBTD to every matching weight (by '/'-joined tree-path
    substring).  2-D leaves are pruned directly; >=3-D leaves (layer-stacked
    [L, H, Q] or expert-stacked [L, E, H, Q]) are pruned per trailing
    matrix via vmap.  Non-matching leaves pass through.  This is the
    trainer's post-update hook (Alg. 2)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    n = len(flat)
    keys = (
        jax.random.split(key, n) if key is not None else [None] * n
    )
    out = []
    for i, (path, leaf) in enumerate(flat):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        cfg = _match_layout(name, layout)
        if cfg is None or leaf.ndim < 2:
            out.append(leaf)
            continue
        h = leaf.shape[-2]
        m_eff = effective_m(h, cfg.m) if cfg.granularity == "element" else cfg.m

        def prune2d(w, k=keys[i], cfg=cfg, m_eff=m_eff):
            if cfg.granularity == "element":
                mask = cbtd_mask(w, cfg.gamma, m_eff, alpha, k)
            else:
                mask = cbtd_tile_mask(w, cfg.gamma, cfg.tile, alpha, k)
            return w * mask.astype(w.dtype)

        if leaf.ndim == 2:
            out.append(prune2d(leaf))
        else:
            lead = leaf.shape[:-2]
            flat_w = leaf.reshape((-1,) + leaf.shape[-2:])
            pruned = jax.vmap(prune2d)(flat_w)
            out.append(pruned.reshape(lead + leaf.shape[-2:]))
    return jax.tree_util.tree_unflatten(treedef, out)


def _match_layout(name: str, layout: Dict[str, CBTDConfig]) -> Optional[CBTDConfig]:
    for pat, cfg in layout.items():
        if pat == "*" or pat in name:
            return cfg
    return None
