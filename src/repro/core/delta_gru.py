"""DeltaGRU — the prior Delta Network RNN (Neil et al. 2017; DeltaRNN FPGA'18).

Implemented as the baseline the paper extends (Sec. II: "The DN algorithm
was only studied and implemented as DeltaGRU. The DeltaLSTM extends the DN
algorithm to LSTM RNNs").  Used in benchmarks to compare DeltaLSTM against
the prior art's algorithmic behaviour.

GRU formulation (cuDNN variant, as used by DeltaGRU so that the reset gate
applies to the *recurrent matmul output* — this is what makes the delta
memory decomposition exact):

    r_t = σ(W_xr x_t + W_hr h_{t-1} + b_r)
    u_t = σ(W_xu x_t + W_hu h_{t-1} + b_u)
    c_t = tanh(W_xc x_t + r_t ⊙ (W_hc h_{t-1} + b_hc) + b_xc)
    h_t = (1-u_t) ⊙ c_t + u_t ⊙ h_{t-1}

Delta memories: M_r, M_u accumulate both matmul streams; the candidate gate
needs the recurrent stream kept separate (M_hc) because of the r_t gating.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.delta_lstm import delta_threshold

Params = Dict[str, Any]


class DeltaGRUState(NamedTuple):
    h: jax.Array       # [H]
    x_hat: jax.Array   # [D]
    h_hat: jax.Array   # [H]
    m_r: jax.Array     # [H]
    m_u: jax.Array     # [H]
    m_xc: jax.Array    # [H]
    m_hc: jax.Array    # [H]


def init_gru_params(
    key: jax.Array, input_dim: int, hidden_dim: int, dtype=jnp.float32
) -> Params:
    k1, k2 = jax.random.split(key)
    bound = 1.0 / jnp.sqrt(hidden_dim)
    # stacked (r, u, c) along the first axis
    w_x = jax.random.uniform(k1, (3 * hidden_dim, input_dim), dtype, -bound, bound)
    w_h = jax.random.uniform(k2, (3 * hidden_dim, hidden_dim), dtype, -bound, bound)
    b_x = jnp.zeros((3, hidden_dim), dtype)
    b_h = jnp.zeros((3, hidden_dim), dtype)
    return {"w_x": w_x, "w_h": w_h, "b_x": b_x, "b_h": b_h}


def gru_step(params: Params, h: jax.Array, x: jax.Array) -> jax.Array:
    hdim = h.shape[-1]
    px = (params["w_x"] @ x).reshape(3, hdim) + params["b_x"]
    ph = (params["w_h"] @ h).reshape(3, hdim) + params["b_h"]
    r = jax.nn.sigmoid(px[0] + ph[0])
    u = jax.nn.sigmoid(px[1] + ph[1])
    c = jnp.tanh(px[2] + r * ph[2])
    return (1.0 - u) * c + u * h


def init_delta_gru_state(
    input_dim: int, hidden_dim: int, params: Optional[Params] = None, dtype=jnp.float32
) -> DeltaGRUState:
    # one buffer per field: leaves sharing a buffer reject donation if the
    # state is ever passed through a donating entry point
    def z() -> jnp.ndarray:
        return jnp.zeros((hidden_dim,), dtype)

    if params is not None:
        b_x, b_h = params["b_x"].astype(dtype), params["b_h"].astype(dtype)
        m_r, m_u = b_x[0] + b_h[0], b_x[1] + b_h[1]
        m_xc, m_hc = b_x[2], b_h[2]
    else:
        m_r, m_u, m_xc, m_hc = z(), z(), z(), z()
    return DeltaGRUState(
        h=z(), x_hat=jnp.zeros((input_dim,), dtype), h_hat=z(),
        m_r=m_r, m_u=m_u, m_xc=m_xc, m_hc=m_hc,
    )


def delta_gru_step(
    params: Params, state: DeltaGRUState, x: jax.Array, theta: float | jax.Array
) -> Tuple[DeltaGRUState, jax.Array, Dict[str, jax.Array]]:
    hdim = state.h.shape[-1]
    dx, x_hat = delta_threshold(x, state.x_hat, theta)
    dh, h_hat = delta_threshold(state.h, state.h_hat, theta)

    px = (params["w_x"] @ dx).reshape(3, hdim)
    ph = (params["w_h"] @ dh).reshape(3, hdim)
    m_r = state.m_r + px[0] + ph[0]
    m_u = state.m_u + px[1] + ph[1]
    m_xc = state.m_xc + px[2]
    m_hc = state.m_hc + ph[2]

    r = jax.nn.sigmoid(m_r)
    u = jax.nn.sigmoid(m_u)
    c = jnp.tanh(m_xc + r * m_hc)
    h = (1.0 - u) * c + u * state.h

    aux = {
        "nnz_dx": jnp.sum(dx != 0).astype(jnp.int32),
        "nnz_dh": jnp.sum(dh != 0).astype(jnp.int32),
    }
    new = DeltaGRUState(h=h, x_hat=x_hat, h_hat=h_hat,
                        m_r=m_r, m_u=m_u, m_xc=m_xc, m_hc=m_hc)
    return new, h, aux


def gru_layer(params: Params, xs: jax.Array) -> jax.Array:
    hdim = params["w_h"].shape[-1]

    def step(h, x):
        h = gru_step(params, h, x)
        return h, h

    _, hs = jax.lax.scan(step, jnp.zeros((hdim,), xs.dtype), xs)
    return hs


def delta_gru_layer(
    params: Params, xs: jax.Array, theta: float | jax.Array,
    state: Optional[DeltaGRUState] = None,
) -> Tuple[jax.Array, DeltaGRUState, Dict[str, jax.Array]]:
    input_dim = params["w_x"].shape[-1]
    hdim = params["w_h"].shape[-1]
    if state is None:
        state = init_delta_gru_state(input_dim, hdim, params, xs.dtype)

    def step(carry, x):
        carry, h, aux = delta_gru_step(params, carry, x, theta)
        return carry, (h, aux["nnz_dx"], aux["nnz_dh"])

    state, (hs, nnz_dx, nnz_dh) = jax.lax.scan(step, state, xs)
    return hs, state, {"nnz_dx": nnz_dx, "nnz_dh": nnz_dh}
