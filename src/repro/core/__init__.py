"""Core: the paper's contributions as composable JAX modules.

- DeltaLSTM / DeltaGRU / DeltaLinear (temporal sparsity, Sec. II)
- CBTD structured pruning (spatial sparsity, Sec. III-A/B)
- CBCSC sparse format (Sec. III-C)
- fixed-point quantization (Sec. IV-E)
- sparsity statistics / op accounting (eqs. 9-10, Tables II/IV)
"""
from repro.core.cbcsc import CBCSC, blen_for, cbcsc_decode, cbcsc_encode, cbcsc_spmv_reference
from repro.core.cbtd import (
    CBTDConfig,
    alpha_at,
    apply_cbtd,
    cbtd_mask,
    cbtd_prune_tree,
    cbtd_tile_mask,
    drop_count,
    keep_count,
)
from repro.core.delta_gru import (
    DeltaGRUState,
    delta_gru_layer,
    delta_gru_step,
    gru_layer,
    gru_step,
    init_delta_gru_state,
    init_gru_params,
)
from repro.core.delta_linear import (
    DeltaLinearState,
    delta_linear_over_time,
    delta_linear_step,
    init_delta_linear_state,
)
from repro.core.delta_lstm import (
    DeltaLSTMState,
    delta_lstm_layer,
    delta_lstm_layer_batched,
    delta_lstm_step,
    delta_threshold,
    init_delta_lstm_state,
    init_lstm_params,
    lstm_layer,
    lstm_layer_batched,
    lstm_step,
    stacked_weight_matrix,
)
from repro.core.quantization import (
    QuantConfig,
    fake_quant_act_ste,
    fake_quant_ste,
    int8_pack,
    int8_unpack,
    quantize,
    quantize_act,
    quantize_tree,
)
from repro.core.stats import (
    balance_ratio,
    effective_mac_trace,
    lstm_layer_macs,
    lstm_layer_ops,
    model_size_mb,
    op_saving,
    sparse_model_size_mb,
    summarize_delta_aux,
    temporal_sparsity,
    tree_weight_sparsity,
    weight_sparsity,
)
