"""DeltaLinear — eq. (2) generalised to any linear layer applied over time.

    y_t = W Δx_t + y_{t-1},   Δx_t thresholded per eqs. (4)-(5)

This is the framework's generalisation of the paper's insight beyond the
LSTM: *any* time-distributed linear layer over a temporally smooth signal
(speech frames, SSM conv features, recurrent-block inputs) can skip weight
columns for sub-threshold deltas.  For token-embedding inputs (text LMs)
the mechanism is supported but yields near-zero sparsity — measured and
reported, see DESIGN.md §Arch-applicability.

State per layer: (x̂ reference input, y running output).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.delta_lstm import delta_threshold


class DeltaLinearState(NamedTuple):
    x_hat: jax.Array  # [..., D]
    y: jax.Array      # [..., O]


def init_delta_linear_state(
    batch_shape: Tuple[int, ...], input_dim: int, out_dim: int,
    bias: Optional[jax.Array] = None, dtype=jnp.float32,
) -> DeltaLinearState:
    y0 = jnp.zeros(batch_shape + (out_dim,), dtype)
    if bias is not None:
        y0 = y0 + bias.astype(dtype)
    return DeltaLinearState(
        x_hat=jnp.zeros(batch_shape + (input_dim,), dtype), y=y0
    )


def delta_linear_step(
    w: jax.Array,
    state: DeltaLinearState,
    x: jax.Array,
    theta: float | jax.Array,
) -> Tuple[DeltaLinearState, jax.Array, Dict[str, jax.Array]]:
    """One step. w: [O, D]; x: [..., D] -> y: [..., O]."""
    dx, x_hat = delta_threshold(x, state.x_hat, theta)
    y = state.y + dx @ w.T
    aux = {"nnz_dx": jnp.sum(dx != 0, axis=-1).astype(jnp.int32)}
    return DeltaLinearState(x_hat=x_hat, y=y), y, aux


def delta_linear_over_time(
    w: jax.Array,
    xs: jax.Array,
    theta: float | jax.Array,
    bias: Optional[jax.Array] = None,
    state: Optional[DeltaLinearState] = None,
) -> Tuple[jax.Array, DeltaLinearState, Dict[str, jax.Array]]:
    """Scan over the leading (time) axis. xs: [T, ..., D] -> [T, ..., O]."""
    out_dim, input_dim = w.shape
    if state is None:
        state = init_delta_linear_state(xs.shape[1:-1], input_dim, out_dim,
                                        bias, xs.dtype)

    def step(carry, x):
        carry, y, aux = delta_linear_step(w, carry, x, theta)
        return carry, (y, aux["nnz_dx"])

    state, (ys, nnz) = jax.lax.scan(step, state, xs)
    return ys, state, {"nnz_dx": nnz}
