"""DeltaLSTM — the paper's core algorithm (Sec. II-B, eqs. 3-7).

An LSTM whose gate pre-activations are *delta memories* ``D`` accumulated
over time from thresholded temporal deltas of the input and hidden state:

    D_{g,t} = W_xg Δx_t + W_hg Δh_{t-1} + D_{g,t-1}

Zeroing deltas below the threshold Θ makes the delta vectors sparse, which
on sparsity-aware hardware skips entire columns of the stacked weight
matrix (temporal sparsity).  Reference states ``x̂ / ĥ`` are updated only
when the corresponding delta crosses the threshold, so no error accumulates
(eqs. 4-7).

At Θ=0 the DeltaLSTM is mathematically identical to the plain LSTM (tested
bit-for-bit up to float associativity in tests/test_delta_lstm.py).

Gate stacking order everywhere in this repo follows eq. (8): (i, g, f, o).
Weights are stored stacked: W_x [4H, D], W_h [4H, H] so the hardware view
of eq. (8) — one [4H, D+H] matrix multiplied by the concatenated delta
state vector — is a single concatenation away (see core/cbcsc.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


class DeltaLSTMState(NamedTuple):
    """Carried state of one DeltaLSTM layer (one batch row: shapes [·])."""

    h: jax.Array      # hidden state            [H]
    c: jax.Array      # cell state              [H]
    x_hat: jax.Array  # reference input  x̂      [D]
    h_hat: jax.Array  # reference hidden ĥ      [H]
    dm: jax.Array     # delta memories D        [4, H]


def init_lstm_params(
    key: jax.Array, input_dim: int, hidden_dim: int, dtype=jnp.float32
) -> Params:
    """Standard LSTM parameter init (uniform fan-in, forget-bias 1)."""
    k1, k2 = jax.random.split(key)
    bound = 1.0 / jnp.sqrt(hidden_dim)
    w_x = jax.random.uniform(k1, (4 * hidden_dim, input_dim), dtype, -bound, bound)
    w_h = jax.random.uniform(k2, (4 * hidden_dim, hidden_dim), dtype, -bound, bound)
    b = jnp.zeros((4, hidden_dim), dtype)
    # forget gate (index 2 in i,g,f,o order) bias = 1: standard trick.
    b = b.at[2].set(1.0)
    return {"w_x": w_x, "w_h": w_h, "b": b}


def init_delta_lstm_state(
    input_dim: int, hidden_dim: int, params: Optional[Params] = None, dtype=jnp.float32
) -> DeltaLSTMState:
    """Initial state. Per the paper, delta memories at t=1 equal the biases."""
    dm0 = (
        params["b"].astype(dtype)
        if params is not None
        else jnp.zeros((4, hidden_dim), dtype)
    )
    return DeltaLSTMState(
        h=jnp.zeros((hidden_dim,), dtype),
        c=jnp.zeros((hidden_dim,), dtype),
        x_hat=jnp.zeros((input_dim,), dtype),
        h_hat=jnp.zeros((hidden_dim,), dtype),
        dm=dm0,
    )


def _gates(pre: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """pre: [4, H] stacked (i, g, f, o) pre-activations."""
    i = jax.nn.sigmoid(pre[0])
    g = jnp.tanh(pre[1])
    f = jax.nn.sigmoid(pre[2])
    o = jax.nn.sigmoid(pre[3])
    return i, g, f, o


def lstm_step(
    params: Params, h: jax.Array, c: jax.Array, x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Plain LSTM step, eq. (1). Shapes: x [D], h,c [H]."""
    hdim = h.shape[-1]
    pre = (params["w_x"] @ x + params["w_h"] @ h).reshape(4, hdim) + params["b"]
    i, g, f, o = _gates(pre)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def delta_threshold(
    cur: jax.Array, ref: jax.Array, theta: float | jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Eqs. (4)-(7): thresholded delta and updated reference state.

    Returns (delta, new_ref) where delta[i] = cur[i]-ref[i] if |·|>Θ else 0,
    and new_ref[i] = cur[i] if the delta fired else ref[i].
    """
    raw = cur - ref
    fired = jnp.abs(raw) > theta
    delta = jnp.where(fired, raw, jnp.zeros_like(raw))
    new_ref = jnp.where(fired, cur, ref)
    return delta, new_ref


def delta_lstm_step(
    params: Params,
    state: DeltaLSTMState,
    x: jax.Array,
    theta: float | jax.Array,
) -> Tuple[DeltaLSTMState, jax.Array, Dict[str, jax.Array]]:
    """One DeltaLSTM step, eqs. (3)-(7).

    Returns (new_state, h, aux) where aux carries the delta vectors'
    occupancy needed for sparsity statistics and the hardware model.
    """
    hdim = state.h.shape[-1]
    dx, x_hat = delta_threshold(x, state.x_hat, theta)
    dh, h_hat = delta_threshold(state.h, state.h_hat, theta)

    dm = state.dm + (params["w_x"] @ dx + params["w_h"] @ dh).reshape(4, hdim)
    i, g, f, o = _gates(dm)
    c = f * state.c + i * g
    h = o * jnp.tanh(c)

    aux = {
        "nnz_dx": jnp.sum(dx != 0).astype(jnp.int32),
        "nnz_dh": jnp.sum(dh != 0).astype(jnp.int32),
        "dx_mask": dx != 0,
        "dh_mask": dh != 0,
    }
    return DeltaLSTMState(h=h, c=c, x_hat=x_hat, h_hat=h_hat, dm=dm), h, aux


def lstm_layer(
    params: Params, xs: jax.Array, h0: Optional[jax.Array] = None,
    c0: Optional[jax.Array] = None
) -> jax.Array:
    """Plain LSTM over a sequence. xs: [T, D] -> [T, H]."""
    hdim = params["w_h"].shape[-1]
    h = jnp.zeros((hdim,), xs.dtype) if h0 is None else h0
    c = jnp.zeros((hdim,), xs.dtype) if c0 is None else c0

    def step(carry, x):
        h, c = carry
        h, c = lstm_step(params, h, c, x)
        return (h, c), h

    _, hs = jax.lax.scan(step, (h, c), xs)
    return hs


def delta_lstm_layer(
    params: Params,
    xs: jax.Array,
    theta: float | jax.Array,
    state: Optional[DeltaLSTMState] = None,
) -> Tuple[jax.Array, DeltaLSTMState, Dict[str, jax.Array]]:
    """DeltaLSTM over a sequence. xs: [T, D] -> (hs [T, H], final state, aux).

    aux["nnz_dx"]/["nnz_dh"]: per-step nonzero delta counts [T] — these are
    exactly the NZV stream occupancies that the Spartus IPU would emit, and
    they drive both the hardware performance model and the balance-ratio
    statistic (eq. 10).
    """
    input_dim = params["w_x"].shape[-1]
    hdim = params["w_h"].shape[-1]
    if state is None:
        state = init_delta_lstm_state(input_dim, hdim, params, xs.dtype)

    def step(carry, x):
        carry, h, aux = delta_lstm_step(params, carry, x, theta)
        return carry, (h, aux["nnz_dx"], aux["nnz_dh"], aux["dx_mask"], aux["dh_mask"])

    state, (hs, nnz_dx, nnz_dh, dx_masks, dh_masks) = jax.lax.scan(step, state, xs)
    aux = {
        "nnz_dx": nnz_dx,
        "nnz_dh": nnz_dh,
        "dx_masks": dx_masks,
        "dh_masks": dh_masks,
    }
    return hs, state, aux


# Batched wrappers --------------------------------------------------------

lstm_layer_batched = jax.vmap(lstm_layer, in_axes=(None, 0))


@functools.partial(jax.vmap, in_axes=(None, 0, None, 0))
def _delta_lstm_layer_batched(params, xs, theta, state):
    return delta_lstm_layer(params, xs, theta, state)


def delta_lstm_layer_batched(
    params: Params,
    xs: jax.Array,
    theta: float | jax.Array,
    state: Optional[DeltaLSTMState] = None,
):
    """Batched DeltaLSTM. xs: [B, T, D]."""
    bsz = xs.shape[0]
    input_dim = params["w_x"].shape[-1]
    hdim = params["w_h"].shape[-1]
    if state is None:
        s = init_delta_lstm_state(input_dim, hdim, params, xs.dtype)
        state = jax.tree.map(lambda a: jnp.broadcast_to(a, (bsz,) + a.shape), s)
    return _delta_lstm_layer_batched(params, xs, theta, state)


def stacked_weight_matrix(params: Params) -> jax.Array:
    """Eq. (8): the [4H, D+H] stacked matrix the accelerator actually stores."""
    return jnp.concatenate([params["w_x"], params["w_h"]], axis=1)
