"""Fixed-point quantization (Sec. IV-E / V-B).

The paper trains with "dual-copy rounding" (Stromatias et al. 2015): a
full-precision shadow copy receives gradient updates while the forward
pass sees the quantized weights.  In JAX this is the straight-through
estimator: ``w + stop_gradient(q(w) - w)``.

Formats used by the hardware: INT8 weights (Q1.7-style per-tensor scale),
INT16 activations (Q8.8 in EdgeDRNN lineage).  We keep scales as powers of
two — exactly what the FPGA shifts implement.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    weight_bits: int = 8
    act_bits: int = 16
    # fractional bits for activations (Q8.8 by default, like EdgeDRNN/Spartus)
    act_frac_bits: int = 8
    enabled: bool = True


def pow2_scale_for(w: jax.Array, bits: int) -> jax.Array:
    """Smallest power-of-two scale covering max|w| in a signed ``bits`` grid."""
    amax = jnp.max(jnp.abs(w))
    amax = jnp.maximum(amax, 1e-8)
    qmax = 2.0 ** (bits - 1) - 1
    # scale = 2^ceil(log2(amax/qmax))
    return 2.0 ** jnp.ceil(jnp.log2(amax / qmax))


def quantize(w: jax.Array, bits: int, scale: Optional[jax.Array] = None) -> jax.Array:
    """Uniform symmetric fake-quant to ``bits`` with round-to-nearest.

    The grid is symmetric: codes span [-qmax, qmax], not the full two's
    complement range.  The per-tensor scale is derived from qmax, so
    admitting the extra -qmax-1 code would make ``quantize(-w)`` differ
    from ``-quantize(w)`` for tensors that saturate on the negative side.
    """
    if scale is None:
        scale = pow2_scale_for(w, bits)
    qmax = 2.0 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax)
    return q * scale


def fake_quant_ste(w: jax.Array, bits: int, scale: Optional[jax.Array] = None) -> jax.Array:
    """Dual-copy rounding: forward = quantized, backward = identity."""
    return w + jax.lax.stop_gradient(quantize(w, bits, scale) - w)


def quantize_act(x: jax.Array, bits: int = 16, frac_bits: int = 8) -> jax.Array:
    """Fixed-point Qm.n activation quantization (deterministic scale 2^-n).

    Unlike ``quantize``, the grid deliberately keeps the -2^(bits-1)
    two's-complement endpoint: the scale here is fixed by the format
    (2^-n), not derived from the data, and the hardware saturating
    arithmetic clamps to the full signed range.  Values saturate (never
    wrap) at both endpoints.
    """
    scale = 2.0 ** (-frac_bits)
    qmax = 2.0 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    return q * scale


def fake_quant_act_ste(x: jax.Array, bits: int = 16, frac_bits: int = 8) -> jax.Array:
    return x + jax.lax.stop_gradient(quantize_act(x, bits, frac_bits) - x)


def quantize_tree(params, bits: int = 8):
    """Quantize every floating-point leaf (deployment-time, no STE)."""
    def q(leaf):
        if jnp.issubdtype(leaf.dtype, jnp.floating) and leaf.ndim >= 1:
            return quantize(leaf, bits)
        return leaf
    return jax.tree.map(q, params)


def int8_pack(w: jax.Array, scale: Optional[jax.Array] = None):
    """Actual int8 storage (for footprint accounting / serving export).

    Clips to the symmetric [-127, 127] grid to match ``quantize`` — the
    auto pow2 scale already covers max|w| with code 127, so the clip only
    binds for a caller-supplied undersized scale.
    """
    if scale is None:
        scale = pow2_scale_for(w, 8)
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_unpack(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) * scale
