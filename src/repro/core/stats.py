"""Sparsity statistics & op accounting — eqs. (9)-(10), Table II/IV columns.

Everything the paper measures about sparsity is reproduced here from the
actual delta masks / weight masks of the JAX model:

  * temporal sparsity (fraction of zero deltas; Fig. 13a),
  * weight sparsity (fraction of zero weights; Table II),
  * balance ratio BR across N MAC arrays (eq. 10; Fig. 12),
  * arithmetic-op savings of the MxV (Table II last column),
  * model size in MB at a given weight precision (Table II).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def temporal_sparsity(delta_masks: jax.Array) -> jax.Array:
    """Fraction of *zero* deltas.  delta_masks: bool, True = nonzero."""
    return 1.0 - jnp.mean(delta_masks.astype(jnp.float32))


def weight_sparsity(w: jax.Array) -> jax.Array:
    return jnp.mean((w == 0).astype(jnp.float32))


def tree_weight_sparsity(params) -> float:
    leaves = [l for l in jax.tree.leaves(params) if hasattr(l, "ndim") and l.ndim == 2]
    zeros = sum(float(jnp.sum(l == 0)) for l in leaves)
    total = sum(l.size for l in leaves)
    return zeros / max(total, 1)


def balance_ratio(delta_masks: jax.Array, n_arrays: int) -> jax.Array:
    """Eq. (10).  delta_masks: [T, F] bool (True = nonzero delta element).

    The state vector is partitioned into N contiguous segments, one per MAC
    array (Sec. IV-B: "the state vector is partitioned into N equal
    segments, each of which is fed into a DPE").  WL_t^n = nonzeros in
    segment n at step t.  BR = sum_t mean_n WL / sum_t max_n WL.
    """
    t, f = delta_masks.shape
    pad = (-f) % n_arrays
    if pad:
        delta_masks = jnp.pad(delta_masks, ((0, 0), (0, pad)))
    wl = jnp.sum(
        delta_masks.reshape(t, n_arrays, -1).astype(jnp.float32), axis=-1
    )  # [T, N]
    mean_wl = jnp.mean(wl, axis=1)
    max_wl = jnp.max(wl, axis=1)
    return jnp.sum(mean_wl) / jnp.maximum(jnp.sum(max_wl), 1.0)


def lstm_layer_macs(input_dim: int, hidden_dim: int) -> int:
    """Dense MxV MACs of one LSTM step (the 8 stacked matrices, eq. 8)."""
    return 4 * hidden_dim * (input_dim + hidden_dim)


def lstm_layer_ops(input_dim: int, hidden_dim: int) -> int:
    """Op count (1 MAC = 2 Op), the unit of the paper's TOp/s numbers."""
    return 2 * lstm_layer_macs(input_dim, hidden_dim)


def op_saving(weight_sparsity: float, temporal_sparsity: float) -> float:
    """Table II 'Arithmetic Operations Saving': dense ops / remaining ops.

    Spatial sparsity removes (gamma) of each column; temporal sparsity
    removes whole columns.  Savings compose multiplicatively:
        saving = 1 / ((1 - ws) * (1 - ts)).
    E.g. ws=93.75%, ts=90.6%  ->  1/(0.0625*0.094) = 170x  (Table II).
    """
    rem = (1.0 - weight_sparsity) * (1.0 - temporal_sparsity)
    return 1.0 / max(rem, 1e-12)


def model_size_mb(n_params: int, bits: int) -> float:
    return n_params * bits / 8 / 1e6


def sparse_model_size_mb(n_params: int, ws: float, val_bits: int, idx_bits: int) -> float:
    """Compressed size with CBCSC (VAL + LIDX per nonzero)."""
    nnz = n_params * (1.0 - ws)
    return nnz * (val_bits + idx_bits) / 8 / 1e6


def effective_mac_trace(
    nnz_dx: jax.Array, nnz_dh: jax.Array, input_dim: int, hidden_dim: int,
    weight_sparsity: float,
) -> jax.Array:
    """Per-step MACs actually executed by a spatio-temporally sparse MxV:
    (active columns) x (nonzeros per column).  nnz_*: [T] int."""
    rows = 4 * hidden_dim * (1.0 - weight_sparsity)
    return (nnz_dx + nnz_dh).astype(jnp.float32) * rows


def summarize_delta_aux(aux: Dict[str, jax.Array], input_dim: int, hidden_dim: int):
    """Roll an aux dict from delta_lstm_layer into the paper's statistics."""
    ts_x = 1.0 - float(jnp.mean(aux["nnz_dx"]) / input_dim)
    ts_h = 1.0 - float(jnp.mean(aux["nnz_dh"]) / hidden_dim)
    total = float(jnp.mean(aux["nnz_dx"] + aux["nnz_dh"])) / (input_dim + hidden_dim)
    return {
        "temporal_sparsity_dx": ts_x,
        "temporal_sparsity_dh": ts_h,
        "temporal_sparsity": 1.0 - total,
    }
