"""Column-Balanced Compressed Sparse Column (CBCSC) — Alg. 3 / Fig. 3.

Encodes a CBTD-pruned matrix ``W [H, Q]`` into:
  * ``val  [Q, M, BLEN]`` — nonzero values, PE-aligned (PE i owns rows
    ``r % M == i``; local index ``k = r // M``),
  * ``lidx [Q, M, BLEN]`` — local index k of each value inside its
    subcolumn (0 <= k < S, S = H/M),
  * ``blen`` — scalar, nonzeros per subcolumn: ``ceil(H/M * (1-gamma))``.

Because CBTD guarantees the same number of nonzeros in every subcolumn,
``val`` needs no column pointers and no per-PE arbitration — every PE
reads exactly BLEN (value, index) pairs per column.  ``to_stream`` emits
the exact for-j/for-i/for-k element order of Alg. 3 (used by tests).

The same arrays are the storage format of the TPU serving kernel
(``kernels/stsp_spmv.py``): the on-the-fly decompression uses an S-wide
one-hot contraction per subcolumn, which is VPU-cheap for small S (the
sublane-aligned analogue of the per-PE LUTRAM scatter; DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class CBCSC:
    val: jax.Array    # [Q, M, BLEN]
    lidx: jax.Array   # [Q, M, BLEN] int32
    valid: jax.Array  # [Q, M, BLEN] bool (False = padding)
    h: int            # original column height
    m: int            # number of PEs
    blen: int         # burst length

    @property
    def q(self) -> int:
        return self.val.shape[0]

    @property
    def s(self) -> int:
        """Subcolumn length H/M."""
        return self.h // self.m

    def global_row_idx(self) -> jax.Array:
        """[Q, M, BLEN] row index in the dense matrix: r = lidx*M + i."""
        i = jnp.arange(self.m, dtype=jnp.int32)[None, :, None]
        # int32 math: the serving pack may hold lidx int8 (paper's 8-bit
        # LIDX), which would overflow at lidx*M
        return self.lidx.astype(jnp.int32) * self.m + i

    def to_stream(self) -> Tuple[jax.Array, jax.Array]:
        """Alg. 3 element order (for j / for i / for k): 1-D VAL, LIDX."""
        return self.val.reshape(-1), self.lidx.reshape(-1)

    def nbytes(self, val_bits: int = 8, idx_bits: int = 8) -> int:
        """Storage footprint in bytes (paper: INT8 VAL + 8/10-bit LIDX)."""
        n = int(np.prod(self.val.shape))
        return (n * val_bits + n * idx_bits + 7) // 8


def blen_for(h: int, m: int, gamma: float) -> int:
    """Alg. 3: BLEN = ceil(H/M * (1 - gamma))."""
    return math.ceil((h // m) * (1.0 - gamma))


def cbcsc_encode(
    w: jax.Array, m: int, blen: int | None = None, on_overflow: str = "raise"
) -> CBCSC:
    """Encode a (column-balanced) sparse matrix.  If any subcolumn has more
    than ``blen`` nonzeros, ``on_overflow`` decides: ``"raise"`` (default)
    rejects the matrix (it was not CBTD-pruned to the promised gamma);
    ``"clip"`` keeps the ``blen`` largest-magnitude nonzeros per subcolumn
    and drops the rest — the pack-time enforcement of the format's BLEN
    contract for untrained / partially-pruned matrices (the dropped count
    is ``nnz(w) - sum(valid)``).  ``blen=None`` uses the max subcolumn
    occupancy (always lossless)."""
    if on_overflow not in ("raise", "clip"):
        raise ValueError(f"on_overflow must be 'raise' or 'clip', got "
                         f"{on_overflow!r}")
    h, q = w.shape
    if h % m:
        raise ValueError(f"H={h} not divisible by M={m}")
    s = h // m
    # [M, S, Q] subcolumn view (interleaved rows), then [Q, M, S]:
    sub = w.reshape(s, m, q).transpose(2, 1, 0)
    nz = sub != 0
    counts = jnp.sum(nz, axis=-1)
    max_occ = int(jax.device_get(jnp.max(counts)))
    if blen is None:
        blen = max(max_occ, 1)
    elif max_occ > blen:
        if on_overflow == "raise":
            raise ValueError(
                f"subcolumn occupancy {max_occ} exceeds BLEN={blen}; "
                "matrix is not column-balanced to the promised sparsity"
            )
        # clip: per subcolumn keep the blen largest |w|, zero the rest
        # (magnitude order only selects survivors; k order is restored by
        # the stable sort below, so to_stream keeps Alg. 3 element order).
        mag = jnp.where(nz, jnp.abs(sub), -jnp.inf)
        top = jnp.argsort(-mag, axis=-1)[..., :blen]           # [Q, M, BLEN]
        keep = jnp.any(
            top[..., None] == jnp.arange(s, dtype=top.dtype), axis=-2
        )                                                      # [Q, M, S]
        nz = nz & keep
        sub = sub * keep.astype(sub.dtype)
    # stable sort brings nonzero positions first, preserving k order:
    order = jnp.argsort(~nz, axis=-1, stable=True)[..., :blen]
    val = jnp.take_along_axis(sub, order, axis=-1)
    valid = jnp.take_along_axis(nz, order, axis=-1)
    val = val * valid.astype(val.dtype)
    lidx = jnp.where(valid, order, 0).astype(jnp.int32)
    return CBCSC(val=val, lidx=lidx, valid=valid, h=h, m=m, blen=blen)


def cbcsc_decode(enc: CBCSC, dtype=None) -> jax.Array:
    """Exact inverse of cbcsc_encode (up to the original zeros)."""
    dtype = dtype or enc.val.dtype
    q, m, blen = enc.val.shape
    s = enc.s
    # scatter val into [Q, M, S] via one-hot over the local index:
    onehot = enc.lidx[..., None] == jnp.arange(s, dtype=jnp.int32)
    onehot = onehot & enc.valid[..., None]
    sub = jnp.sum(enc.val[..., None] * onehot.astype(dtype), axis=2)  # [Q, M, S]
    return sub.transpose(2, 1, 0).reshape(enc.h, q)


def cbcsc_spmv_reference(enc: CBCSC, ds: jax.Array) -> jax.Array:
    """y = W @ ds computed straight from the CBCSC arrays (no decode):
    the mathematical spec of what the Spartus MAC arrays do.  ds: [Q]."""
    contrib = enc.val * ds[:, None, None]                  # [Q, M, BLEN]
    s = enc.s
    onehot = (enc.lidx[..., None] == jnp.arange(s, dtype=jnp.int32)) & enc.valid[..., None]
    sub = jnp.einsum("qmb,qmbs->ms", contrib, onehot.astype(contrib.dtype))
    return sub.transpose(1, 0).reshape(enc.h)              # [H]
