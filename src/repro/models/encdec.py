"""Encoder-decoder transformer backbone (seamless-m4t-medium, audio).

The modality frontend is a STUB per the assignment: ``input_specs()``
provides precomputed speech-frame embeddings [B, S, d] to the encoder.
The decoder is a causal transformer with cross-attention to the encoder
output.  This is the best-fit assigned arch for the paper's technique —
speech frames are temporally smooth, so DeltaLinear on the encoder's
time-distributed projections yields real measured sparsity (DESIGN.md §4).

Shapes contract:
  train:    enc frames [B, S, d] + dec tokens [B, S_dec]  -> CE loss
  prefill:  encoder forward over S frames + cross-KV build
  decode:   one decoder token against cached cross-KV (len S) + self cache
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models.scan import scan_layers

Params = Dict[str, Any]

DEC_SELF_CACHE = 1024  # decoder self-attention cache length


def init_enc_layer(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.hd, False, False, dtype),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "mlp": L.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def init_dec_layer(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "self_attn": L.init_attention(k1, cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.hd, False, False, dtype),
        "cross_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "cross_attn": L.init_attention(k2, cfg.d_model, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.hd, False, False, dtype),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "mlp": L.init_swiglu(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ke, k1, k2, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(k1, cfg.n_enc_layers)
    dec_keys = jax.random.split(k2, cfg.n_dec_layers)
    return {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg, dtype))(enc_keys),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg, dtype))(dec_keys),
        "enc_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "lm_head": L.init_linear(kh, cfg.d_model, cfg.vocab, False, dtype),
    }


def encode(params: Params, cfg: ArchConfig, frames: jax.Array,
           *, q_chunk: int = 0, remat: bool = False) -> jax.Array:
    """frames: [B, S, d] (frontend stub) -> encoder states [B, S, d]."""
    def body(carry, lp):
        x = carry
        h = L.attention_forward(
            lp["attn"], L.rms_norm(lp["attn_norm"], x), n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, hd=cfg.hd, causal=False,
            q_chunk=q_chunk, rope_base=1e4,
        )
        x = x + h
        from repro.distributed import hints
        x = x + L.swiglu(lp["mlp"], L.rms_norm(lp["mlp_norm"], x))
        return hints.constrain(x, "batch", "model", None), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = scan_layers(body, frames, params["enc_layers"])
    return L.rms_norm(params["enc_norm"], x)


def decode_train_hidden(params: Params, cfg: ArchConfig, tokens: jax.Array,
                        enc_out: jax.Array, *, q_chunk: int = 0,
                        remat: bool = False) -> jax.Array:
    """Teacher-forced decoder -> final hidden [B, S_dec, d]."""
    x = params["embed"][tokens]

    def body(carry, lp):
        x = carry
        h = L.attention_forward(
            lp["self_attn"], L.rms_norm(lp["self_norm"], x),
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, hd=cfg.hd,
            causal=True, q_chunk=q_chunk, rope_base=1e4,
        )
        x = x + h
        h = L.attention_forward(
            lp["cross_attn"], L.rms_norm(lp["cross_norm"], x),
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, hd=cfg.hd,
            causal=False, q_chunk=q_chunk, kv_x=enc_out,
        )
        x = x + h
        from repro.distributed import hints
        x = x + L.swiglu(lp["mlp"], L.rms_norm(lp["mlp_norm"], x))
        return hints.constrain(x, "batch", "model", None), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = scan_layers(body, x, params["dec_layers"])
    return L.rms_norm(params["final_norm"], x)


def decode_train(params: Params, cfg: ArchConfig, tokens: jax.Array,
                 enc_out: jax.Array, *, q_chunk: int = 0,
                 remat: bool = False) -> jax.Array:
    """Teacher-forced decoder -> logits [B, S_dec, V]."""
    x = decode_train_hidden(params, cfg, tokens, enc_out,
                            q_chunk=q_chunk, remat=remat)
    return x @ params["lm_head"]["w"].T


def build_cross_cache(params: Params, cfg: ArchConfig, enc_out: jax.Array):
    """Precompute per-layer cross-attention K/V (the prefill product)."""
    b, s, _ = enc_out.shape

    def per_layer(lp):
        k = L.linear(lp["cross_attn"]["k"], enc_out).reshape(
            b, s, cfg.n_kv_heads, cfg.hd)
        v = L.linear(lp["cross_attn"]["v"], enc_out).reshape(
            b, s, cfg.n_kv_heads, cfg.hd)
        return {"k": k, "v": v}

    return jax.vmap(per_layer)(params["dec_layers"])


def init_cache(cfg: ArchConfig, batch: int, enc_len: int, dtype=jnp.float32):
    self_kv = {
        "k": jnp.zeros((cfg.n_dec_layers, batch, DEC_SELF_CACHE,
                        cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((cfg.n_dec_layers, batch, DEC_SELF_CACHE,
                        cfg.n_kv_heads, cfg.hd), dtype),
    }
    cross_kv = {
        "k": jnp.zeros((cfg.n_dec_layers, batch, enc_len,
                        cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((cfg.n_dec_layers, batch, enc_len,
                        cfg.n_kv_heads, cfg.hd), dtype),
    }
    return {"self": self_kv, "cross": cross_kv, "pos": jnp.zeros((), jnp.int32)}


def decode_step(params: Params, cfg: ArchConfig, tokens: jax.Array, cache):
    """One decoder token with cached cross-KV. tokens: [B, 1]."""
    pos = cache["pos"]
    x = params["embed"][tokens]
    b = x.shape[0]

    def body(carry, scanned):
        lp, self_kc, cross_kc = scanned
        x = carry
        h, self_new = L.attention_decode_step(
            lp["self_attn"], L.rms_norm(lp["self_norm"], x), self_kc, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, hd=cfg.hd,
            rope_base=1e4,
        )
        x = x + h
        # cross-attention against the fixed encoder KV (no RoPE, no update)
        y = L.rms_norm(lp["cross_norm"], x)
        q = L.linear(lp["cross_attn"]["q"], y).reshape(b, 1, cfg.n_heads, cfg.hd)
        from repro.models.layers import _attn_block, _expand_gqa
        o = _attn_block(q, _expand_gqa(cross_kc["k"], cfg.n_heads),
                        _expand_gqa(cross_kc["v"], cfg.n_heads),
                        jnp.zeros((1,), jnp.int32),
                        jnp.arange(cross_kc["k"].shape[1]), causal=False,
                        window=0, kv_len=None)
        h = L.linear(lp["cross_attn"]["o"],
                     o.reshape(b, 1, cfg.n_heads * cfg.hd))
        x = x + h
        x = x + L.swiglu(lp["mlp"], L.rms_norm(lp["mlp_norm"], x))
        return x, self_new

    x, new_self = scan_layers(
        body, x, (params["dec_layers"], cache["self"], cache["cross"])
    )
    x = L.rms_norm(params["final_norm"], x)
    logits = x @ params["lm_head"]["w"].T
    return logits, {"self": new_self, "cross": cache["cross"], "pos": pos + 1}
