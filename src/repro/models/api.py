"""Unified model API over every assigned architecture.

    init_params(cfg, key, dtype)            -> params pytree
    train_loss(params, cfg, batch, ...)     -> scalar CE loss
    init_cache(cfg, batch, s_cache, dtype)  -> decode cache pytree
    serve_step(params, cfg, inputs, cache)  -> (logits, new cache)
    input_specs(cfg, cell)                  -> ShapeDtypeStructs for dry-run

The paper's technique hooks in at two points:
  * ``cbtd_layout(cfg)`` — CBTD pruning patterns for every linear in the
    arch (used by the trainer and the pruning benchmarks);
  * serving engines may wrap time-distributed projections in DeltaLinear
    (serving/engine.py) where ``cfg.delta_applicable``.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, ShapeCell
from repro.models import encdec, mamba2, rglru, transformer

DEC_TRAIN_FRAC = 8  # enc-dec: decoder length = seq_len / 8 in train cells


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_params(key, cfg, dtype)
    if cfg.family == "ssm":
        return mamba2.init_params(key, cfg, dtype)
    if cfg.family == "hybrid":
        return rglru.init_params(key, cfg, dtype)
    if cfg.family == "audio":
        return encdec.init_params(key, cfg, dtype)
    raise ValueError(cfg.family)


def train_loss(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
               *, q_chunk: int = 0, remat: bool = False) -> jax.Array:
    """batch keys by family:
      dense/moe/ssm/hybrid: tokens, targets
      vlm:                  inputs_embeds, targets
      audio:                frames, dec_tokens, dec_targets
    """
    from repro.models.transformer import chunked_ce_loss, head_weight

    if cfg.family in ("dense", "moe"):
        x = transformer.forward_hidden(params, cfg, batch["tokens"],
                                       q_chunk=q_chunk, remat=remat)
        return chunked_ce_loss(x, head_weight(params, cfg), batch["targets"])
    if cfg.family == "vlm":
        x = transformer.forward_hidden(params, cfg, None,
                                       inputs_embeds=batch["inputs_embeds"],
                                       q_chunk=q_chunk, remat=remat)
        return chunked_ce_loss(x, head_weight(params, cfg), batch["targets"])
    if cfg.family == "ssm":
        x = mamba2.forward_hidden(params, cfg, batch["tokens"], remat=remat)
        return chunked_ce_loss(x, params["lm_head"]["w"], batch["targets"])
    if cfg.family == "hybrid":
        x = rglru.forward_hidden(params, cfg, batch["tokens"],
                                 q_chunk=q_chunk, remat=remat)
        return chunked_ce_loss(x, params["lm_head"]["w"], batch["targets"])
    if cfg.family == "audio":
        enc_out = encdec.encode(params, cfg, batch["frames"],
                                q_chunk=q_chunk, remat=remat)
        x = encdec.decode_train_hidden(params, cfg, batch["dec_tokens"],
                                       enc_out, q_chunk=q_chunk, remat=remat)
        return chunked_ce_loss(x, params["lm_head"]["w"], batch["dec_targets"])
    raise ValueError(cfg.family)


def init_cache(cfg: ArchConfig, batch: int, s_cache: int, dtype=jnp.float32):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init_cache(cfg, batch, s_cache, dtype)
    if cfg.family == "ssm":
        return mamba2.init_cache(cfg, batch, dtype)
    if cfg.family == "hybrid":
        return rglru.init_cache(cfg, batch, dtype)
    if cfg.family == "audio":
        return encdec.init_cache(cfg, batch, s_cache, dtype)
    raise ValueError(cfg.family)


def serve_step(params, cfg: ArchConfig, inputs, cache):
    """One decode step.  ``inputs``: tokens [B,1] (or embeds [B,1,d] for vlm)."""
    if cfg.family in ("dense", "moe"):
        return transformer.decode_step(params, cfg, inputs, cache)
    if cfg.family == "vlm":
        return transformer.decode_step(params, cfg, None, cache,
                                       inputs_embeds=inputs)
    if cfg.family == "ssm":
        return mamba2.decode_step(params, cfg, inputs, cache)
    if cfg.family == "hybrid":
        return rglru.decode_step(params, cfg, inputs, cache)
    if cfg.family == "audio":
        return encdec.decode_step(params, cfg, inputs, cache)
    raise ValueError(cfg.family)


def prefill(params, cfg: ArchConfig, inputs, *, q_chunk: int = 0):
    """Full-sequence forward — the prefill_32k workload.  Returns the
    LAST-position logits [B, 1, V] (what a serving system samples from;
    full [B, S, V] logits at a 49k non-16-divisible vocab replicated
    14 GiB/device on granite-moe — EXPERIMENTS.md §Dry-run).  For the
    enc-dec arch this is encoder forward + cross-KV build."""
    def last_logits(x, head_w):
        return x[:, -1:, :] @ head_w.T

    if cfg.family in ("dense", "moe"):
        x = transformer.forward_hidden(params, cfg, inputs, q_chunk=q_chunk)
        return last_logits(x, transformer.head_weight(params, cfg))
    if cfg.family == "vlm":
        x = transformer.forward_hidden(params, cfg, None, inputs_embeds=inputs,
                                       q_chunk=q_chunk)
        return last_logits(x, transformer.head_weight(params, cfg))
    if cfg.family == "ssm":
        x = mamba2.forward_hidden(params, cfg, inputs)
        return last_logits(x, params["lm_head"]["w"])
    if cfg.family == "hybrid":
        x = rglru.forward_hidden(params, cfg, inputs, q_chunk=q_chunk)
        return last_logits(x, params["lm_head"]["w"])
    if cfg.family == "audio":
        enc_out = encdec.encode(params, cfg, inputs, q_chunk=q_chunk)
        return encdec.build_cross_cache(params, cfg, enc_out)
    raise ValueError(cfg.family)


# -- dry-run input specs -----------------------------------------------------

def input_specs(cfg: ArchConfig, cell: ShapeCell,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell
    (weak-type-correct, shardable, no allocation)."""
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cell.kind in ("train",):
        if cfg.family == "vlm":
            return {
                "inputs_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype),
                "targets": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cfg.family == "audio":
            s_dec = s // DEC_TRAIN_FRAC
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype),
                "dec_tokens": jax.ShapeDtypeStruct((b, s_dec), i32),
                "dec_targets": jax.ShapeDtypeStruct((b, s_dec), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
    if cell.kind == "prefill":
        if cfg.family in ("vlm", "audio"):
            return {"inputs": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)}
        return {"inputs": jax.ShapeDtypeStruct((b, s), i32)}
    if cell.kind == "decode":
        if cfg.family == "vlm":
            return {"inputs": jax.ShapeDtypeStruct((b, 1, cfg.d_model), dtype)}
        return {"inputs": jax.ShapeDtypeStruct((b, 1), i32)}
    raise ValueError(cell.kind)


def make_train_batch(cfg: ArchConfig, key: jax.Array, batch: int, seq: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Materialised random batch matching input_specs (smoke tests/examples)."""
    k1, k2 = jax.random.split(key)
    if cfg.family == "vlm":
        return {
            "inputs_embeds": jax.random.normal(k1, (batch, seq, cfg.d_model), dtype),
            "targets": jax.random.randint(k2, (batch, seq), 0, cfg.vocab),
        }
    if cfg.family == "audio":
        s_dec = max(seq // DEC_TRAIN_FRAC, 4)
        return {
            "frames": jax.random.normal(k1, (batch, seq, cfg.d_model), dtype),
            "dec_tokens": jax.random.randint(k2, (batch, s_dec), 0, cfg.vocab),
            "dec_targets": jax.random.randint(k2, (batch, s_dec), 0, cfg.vocab),
        }
    toks = jax.random.randint(k1, (batch, seq + 1), 0, cfg.vocab)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def cbtd_layout(cfg: ArchConfig, gamma: float = 0.94, m: int = 64):
    """CBTD patterns covering every prunable linear of the arch (embeddings,
    norms and the logit/lm head excluded, per the paper's practice)."""
    from repro.core.cbtd import CBTDConfig

    c = CBTDConfig(gamma=gamma, m=m)
    pats = {}
    if cfg.family in ("dense", "moe", "vlm"):
        pats.update({"attn/q/w": c, "attn/k/w": c, "attn/v/w": c, "attn/o/w": c})
        if cfg.family == "moe":
            pats.update({"moe/gate": c, "moe/up": c, "moe/down": c})
        else:
            pats.update({"mlp/gate/w": c, "mlp/up/w": c, "mlp/down/w": c})
    elif cfg.family == "ssm":
        pats.update({"in_proj/w": c, "out_proj/w": c})
    elif cfg.family == "hybrid":
        pats.update({
            "attn/q/w": c, "attn/k/w": c, "attn/v/w": c, "attn/o/w": c,
            "rglru/in_x/w": c, "rglru/in_y/w": c, "rglru/out/w": c,
            "rglru/gate_a/w": c, "rglru/gate_i/w": c,
            "mlp/gate/w": c, "mlp/up/w": c, "mlp/down/w": c,
        })
    elif cfg.family == "audio":
        pats.update({
            "attn/q/w": c, "attn/k/w": c, "attn/v/w": c, "attn/o/w": c,
            "self_attn/q/w": c, "self_attn/k/w": c, "self_attn/v/w": c,
            "self_attn/o/w": c, "cross_attn/q/w": c, "cross_attn/k/w": c,
            "cross_attn/v/w": c, "cross_attn/o/w": c,
            "mlp/gate/w": c, "mlp/up/w": c, "mlp/down/w": c,
        })
    return pats
