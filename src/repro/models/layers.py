"""Shared transformer building blocks (pure functional JAX).

Conventions:
  * linear weights are stored ``[out, in]`` (y = x @ w.T) — the same
    [column-height, column] orientation as the paper's stacked matrices,
    so CBTD/CBCSC apply to every linear in the zoo unchanged;
  * attention is grouped-query with optional QKV bias (qwen2), QK-norm
    (qwen3), sliding window (recurrentgemma), and q-chunked streaming
    softmax so 32k prefill never materialises an [S, S] score matrix;
  * all sequence layers take/return [B, S, ...]; decode-step variants take
    a cache pytree and a scalar position.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

NEG_INF = -1e30


# -- init -------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": jax.random.normal(key, (d_out, d_in), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].T
    if "b" in p:
        y = y + p["b"]
    return y


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# -- RoPE ---------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, base: float = 1e6) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]                            # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# -- attention ----------------------------------------------------------------

def _expand_gqa(k: jax.Array, hq: int) -> jax.Array:
    """[B, S, Hkv, hd] -> [B, S, Hq, hd] by repeating each kv head G times.

    GQA is evaluated in expanded-head MHA form so the head axis stays
    TP-shardable (a [Hkv, G] reshape of a sharded head dim would force XLA
    to reshard; a repeat of replicated kv heads does not)."""
    hkv = k.shape[2]
    if hkv == hq:
        return k
    return jnp.repeat(k, hq // hkv, axis=2)


def _attn_block(
    q: jax.Array,          # [B, Sq, H, hd]
    k: jax.Array,          # [B, Skv, H, hd]  (GQA pre-expanded)
    v: jax.Array,          # [B, Skv, H, hd]
    q_pos: jax.Array,      # [Sq] absolute positions of the q rows
    kv_pos: jax.Array,     # [Skv]
    causal: bool,
    window: int,
    kv_len: Optional[jax.Array],  # mask kv_pos >= kv_len (decode)
    apply_hints: bool = True,     # decode paths pre-constrain their layout
) -> jax.Array:
    from repro.distributed import hints

    hd = q.shape[-1]
    if apply_hints:
        q, k, v = hints.shard_attn(q, k, v)
    scores = jnp.einsum(
        "bqhd,bthd->bhqt", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd ** -0.5)
    mask = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        mask &= kv_pos[None, :] < kv_len
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqt,bthd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention(
    q: jax.Array,          # [B, Sq, Hq, hd]
    k: jax.Array,          # [B, Skv, Hkv, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 0,
    q_offset: int = 0,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """GQA attention.  With ``q_chunk``, scans over query blocks so peak
    memory is O(Sq/nc * Skv) — required for the 32k shapes."""
    b, sq, hq, hd = q.shape
    skv = k.shape[1]
    k = _expand_gqa(k, hq)
    v = _expand_gqa(v, hq)
    kv_pos = jnp.arange(skv)

    if q_chunk and sq > q_chunk and sq % q_chunk == 0:
        nc = sq // q_chunk
        qs = q.reshape(b, nc, q_chunk, hq, hd).transpose(1, 0, 2, 3, 4)

        def body(_, inp):
            ci, qblk = inp
            q_pos = q_offset + ci * q_chunk + jnp.arange(q_chunk)
            if window and skv > window + q_chunk:
                # local attention: only the [start, start+w+qc) kv slab matters
                span = window + q_chunk
                start = jnp.clip(ci * q_chunk + q_offset - window, 0, skv - span)
                kb = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
                vb = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
                kp = start + jnp.arange(span)
                out = _attn_block(qblk, kb, vb, q_pos, kp, causal, window, kv_len)
            else:
                out = _attn_block(qblk, k, v, q_pos, kv_pos, causal, window, kv_len)
            return None, out

        from repro.models.scan import scan_layers
        # checkpoint each q-chunk: backward recomputes one chunk's scores
        # instead of stashing [B, H, qc, Skv] fp32 probs per chunk
        body = jax.checkpoint(body, prevent_cse=False)
        _, outs = scan_layers(body, None, (jnp.arange(nc), qs))
        return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, hd)

    q_pos = q_offset + jnp.arange(sq)
    return _attn_block(q, k, v, q_pos, kv_pos, causal, window, kv_len)


# -- attention module (params + cache) ---------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, hd: int,
                   qkv_bias: bool, qk_norm: bool, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "q": init_linear(ks[0], d_model, n_heads * hd, qkv_bias, dtype),
        "k": init_linear(ks[1], d_model, n_kv_heads * hd, qkv_bias, dtype),
        "v": init_linear(ks[2], d_model, n_kv_heads * hd, qkv_bias, dtype),
        "o": init_linear(ks[3], n_heads * hd, d_model, False, dtype),
    }
    if qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def attention_forward(
    p: Params, x: jax.Array, *, n_heads: int, n_kv_heads: int, hd: int,
    causal: bool = True, window: int = 0, q_chunk: int = 0,
    rope_base: float = 1e6, positions: Optional[jax.Array] = None,
    kv_x: Optional[jax.Array] = None,
) -> jax.Array:
    """Self-attention (or cross-attention when kv_x is given) over [B,S,d]."""
    b, s, _ = x.shape
    src = kv_x if kv_x is not None else x
    skv = src.shape[1]
    q = linear(p["q"], x).reshape(b, s, n_heads, hd)
    k = linear(p["k"], src).reshape(b, skv, n_kv_heads, hd)
    v = linear(p["v"], src).reshape(b, skv, n_kv_heads, hd)
    if "q_norm" in p:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    if kv_x is None:  # RoPE only for self-attention
        pos = positions if positions is not None else jnp.arange(s)
        q = rope(q, jnp.broadcast_to(pos, (s,)), rope_base)
        k = rope(k, jnp.arange(skv), rope_base)
    out = attention(q, k, v, causal=causal, window=window, q_chunk=q_chunk)
    return linear(p["o"], out.reshape(b, s, n_heads * hd))


def attention_decode_step(
    p: Params, x: jax.Array, cache: Dict[str, jax.Array], pos: jax.Array,
    *, n_heads: int, n_kv_heads: int, hd: int, window: int = 0,
    rope_base: float = 1e6,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step. x: [B, 1, d]; cache: {k,v: [B, S_cache, Hkv, hd]}.
    For windowed attention the cache is a ring buffer of size window."""
    b = x.shape[0]
    s_cache = cache["k"].shape[1]
    q = linear(p["q"], x).reshape(b, 1, n_heads, hd)
    k = linear(p["k"], x).reshape(b, 1, n_kv_heads, hd)
    v = linear(p["v"], x).reshape(b, 1, n_kv_heads, hd)
    if "q_norm" in p:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    q = rope(q, pos[None], rope_base)
    k = rope(k, pos[None], rope_base)

    slot = pos % s_cache if window else pos
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)

    from repro.distributed import hints

    ke = _expand_gqa(new_k, n_heads)
    ve = _expand_gqa(new_v, n_heads)
    q, ke, ve = hints.shard_attn_decode(q, ke, ve, n_kv_heads)
    if window:
        # ring buffer: recover absolute positions of each slot to mask
        kv_pos = jnp.arange(s_cache)
        ring_pos = jnp.where(
            kv_pos <= slot, pos - slot + kv_pos, pos - slot - s_cache + kv_pos
        )
        valid = ring_pos >= jnp.maximum(pos - window + 1, 0)
        scores = jnp.einsum("bqhd,bthd->bhqt", q.astype(jnp.float32),
                            ke.astype(jnp.float32)) * (hd ** -0.5)
        scores = jnp.where(valid[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqt,bthd->bqhd", probs, ve.astype(jnp.float32))
        out = out.astype(x.dtype)
    else:
        out = _attn_block(q, ke, ve, pos[None], jnp.arange(s_cache),
                          causal=False, window=0, kv_len=pos + 1,
                          apply_hints=False)
    y = linear(p["o"], out.reshape(b, 1, n_heads * hd))
    return y, {"k": new_k, "v": new_v}


def init_kv_cache(batch: int, s_cache: int, n_kv_heads: int, hd: int,
                  dtype=jnp.float32) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((batch, s_cache, n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, s_cache, n_kv_heads, hd), dtype),
    }


# -- MLP ----------------------------------------------------------------------

def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "gate": init_linear(ks[0], d_model, d_ff, False, dtype),
        "up": init_linear(ks[1], d_model, d_ff, False, dtype),
        "down": init_linear(ks[2], d_ff, d_model, False, dtype),
    }


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


# -- MoE ------------------------------------------------------------------------

def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    return {
        "router": init_linear(ks[0], d_model, n_experts, False, dtype),
        "gate": jax.random.normal(ks[1], (n_experts, d_ff, d_model), dtype) * s_in,
        "up": jax.random.normal(ks[2], (n_experts, d_ff, d_model), dtype) * s_in,
        "down": jax.random.normal(ks[3], (n_experts, d_model, d_ff), dtype) * s_ff,
    }


def moe_forward(p: Params, x: jax.Array, *, top_k: int,
                capacity_factor: float = 1.25) -> jax.Array:
    """Top-k token-choice MoE with static per-row capacity.

    Dispatch is sort-based and vmapped over the batch rows so the scatter/
    gather stay batch-sharded under pjit; the [B, E, C, d] dispatch buffer
    is annotated (batch x expert) so XLA lowers the dispatch to the
    canonical expert-parallel all-to-all (DESIGN.md §5).  Overflow beyond
    capacity drops tokens (standard Switch semantics)."""
    from repro.distributed import hints

    b, s, d = x.shape
    e = p["router"]["w"].shape[0]

    # long sequences dispatch in sequence blocks: per-(row, block) sort +
    # capacity keeps scatter/gather buffers bounded (32k prefill would
    # otherwise build multi-GiB per-device dispatch intermediates).  The
    # batch-major reshape keeps the fused (B*nb) dim batch-sharded.
    block = 2048
    if s > block and s % block == 0:
        nb = s // block
        xb = x.reshape(b * nb, block, d)
        # the merge of (batch-sharded b) x (seq-sharded nb) is not
        # representable — pin the fused dim to batch sharding explicitly
        # (without this, multi-pod prefill replicated the dispatch:
        # 128 GiB/device on olmoe, EXPERIMENTS.md §Dry-run)
        xb = hints.constrain(xb, "batch", None, None)
        yb = moe_forward(p, xb, top_k=top_k, capacity_factor=capacity_factor)
        yb = hints.constrain(yb, "batch", None, None)
        return yb.reshape(b, s, d)

    cap = int(max(1, round(s * top_k / e * capacity_factor)))

    logits = linear(p["router"], x.astype(jnp.float32))              # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, top_k)                    # [B, S, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    def routing_row(eids_r):
        """eids_r: [S, K] -> (dest [S*K], keep, token_of) via a stable sort
        by expert id; rank within the expert's segment is the capacity slot."""
        flat_e = eids_r.reshape(-1)                                  # [S*K]
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos = jnp.arange(s * top_k) - seg_start
        dest = sorted_e * cap + pos
        keep = pos < cap
        token_of = order // top_k
        return order, dest, keep, token_of

    def dispatch_row(xr, eids_r):
        _, dest, keep, token_of = routing_row(eids_r)
        buf = jnp.zeros((e * cap, d), xr.dtype)
        buf = buf.at[jnp.where(keep, dest, e * cap)].set(
            xr[token_of], mode="drop"
        )
        return buf.reshape(e, cap, d)

    buf = jax.vmap(dispatch_row)(x, eids)                            # [B,E,C,d]
    buf = hints.constrain(buf, "batch", "model", None, None)

    act = jax.nn.silu(jnp.einsum("becd,efd->becf", buf, p["gate"])) * jnp.einsum(
        "becd,efd->becf", buf, p["up"]
    )
    o = jnp.einsum("becf,edf->becd", act, p["down"])
    o = hints.constrain(o, "batch", "model", None, None)
    o = o.reshape(b, e * cap, d)

    def combine_row(o_r, eids_r, gate_r):
        order, dest, keep, token_of = routing_row(eids_r)
        gathered = jnp.where(keep[:, None], o_r[jnp.where(keep, dest, 0)], 0.0)
        weighted = gathered * gate_r.reshape(-1)[order][:, None].astype(o_r.dtype)
        return jnp.zeros((s, d), o_r.dtype).at[token_of].add(weighted)

    return jax.vmap(combine_row)(o, eids, gate_vals)


def moe_aux_loss(p: Params, x: jax.Array, top_k: int) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style f*P)."""
    b, s, d = x.shape
    e = p["router"]["w"].shape[0]
    logits = linear(p["router"], x.reshape(-1, d).astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    _, eids = jax.lax.top_k(probs, top_k)
    f = jnp.mean(jax.nn.one_hot(eids, e), axis=(0, 1))
    pmean = jnp.mean(probs, axis=0)
    return e * jnp.sum(f * pmean)
