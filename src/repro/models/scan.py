"""Layer-stack execution: lax.scan by default, python-unrolled on demand.

XLA's ``cost_analysis`` counts a while-loop body ONCE, so flops/bytes of
scanned layer stacks are undercounted by ~n_layers (measured; see
EXPERIMENTS.md §Dry-run methodology).  The dry-run therefore compiles a
reduced-depth *unrolled* probe (1 and 2 stacks) and extrapolates exact
per-layer costs, while the full scanned compile proves sharding coherence
and memory fit.  ``unrolled()`` is the context flag the probe sets.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

_UNROLL = False


@contextlib.contextmanager
def unrolled(enable: bool = True):
    global _UNROLL
    prev = _UNROLL
    _UNROLL = enable
    try:
        yield
    finally:
        _UNROLL = prev


def unroll_active() -> bool:
    return _UNROLL


def scan_layers(body: Callable, carry, xs) -> Tuple[Any, Any]:
    """drop-in for ``jax.lax.scan(body, carry, xs)`` over layer stacks."""
    if not _UNROLL:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked
