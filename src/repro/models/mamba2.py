"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in JAX.

Chunked SSD: within a chunk the recurrence is evaluated as a masked
matmul (MXU-friendly "dual" attention form); chunk-boundary states are
carried by a short ``lax.scan``.  All decays stay in log space and are
<= 0, so every exp() is bounded by 1.

The SSD recurrence itself is elementwise-gated (no W·h matmul), so the
paper's *recurrent* delta trick does not apply to the state update —
DeltaLinear applies to the time-distributed projections instead
(DESIGN.md §4: Arch-applicability).

Decode carries (conv ring state, SSD state [B, H, P, N]) per layer —
O(1) in sequence length, which is why this arch runs ``long_500k``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models.scan import scan_layers

Params = Dict[str, Any]


def _dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def init_layer(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    d_inner, n_heads, conv_dim = _dims(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d_in_proj = 2 * d_inner + 2 * cfg.ssm_state + n_heads
    # A init in [1, 16) (log-uniform), dt bias via inverse softplus of ~0.01-0.1
    a = jnp.exp(jax.random.uniform(k3, (n_heads,), jnp.float32,
                                   jnp.log(1.0), jnp.log(16.0)))
    dt = jnp.exp(jax.random.uniform(k4, (n_heads,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "norm": L.init_rmsnorm(cfg.d_model, dtype),
        "in_proj": L.init_linear(k1, cfg.d_model, d_in_proj, False, dtype),
        "conv_w": jax.random.normal(k2, (cfg.ssm_conv, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "gated_norm": L.init_rmsnorm(d_inner, dtype),
        "out_proj": L.init_linear(k5, d_inner, cfg.d_model, False, dtype),
    }


def pick_chunk(s: int, chunk: int) -> int:
    """Largest divisor of ``s`` that is <= chunk (SSD needs chunk | S)."""
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    return chunk


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. x: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # window sum: sum_j w[j] * x[t - (K-1) + j]
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + pad[:, j : j + x.shape[1], :] * w[j]
    return out + b


def ssd_chunked(
    x: jax.Array,     # [B, S, H, P]
    dt: jax.Array,    # [B, S, H] (post-softplus)
    a: jax.Array,     # [H] (negative)
    b_in: jax.Array,  # [B, S, N]
    c_in: jax.Array,  # [B, S, N]
    chunk: int,
    init_state: Optional[jax.Array] = None,  # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    chunk = pick_chunk(s, chunk)
    nc = s // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_in.reshape(bsz, nc, chunk, n)
    cc = c_in.reshape(bsz, nc, chunk, n)

    da = dtc * a                                    # [b,nc,l,h], <= 0
    l_cum = jnp.cumsum(da, axis=2)

    # intra-chunk ("attention" dual form)
    diff = l_cum[:, :, :, None, :] - l_cum[:, :, None, :, :]   # [b,nc,i,j,h]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)
    w = cb[..., None] * decay * dtc[:, :, None, :, :]          # [b,nc,i,j,h]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # chunk-boundary states
    decay_to_end = jnp.exp(l_cum[:, :, -1:, :] - l_cum)        # [b,nc,l,h]
    z = jnp.einsum("bclh,bclhp,bcln->bchpn", decay_to_end * dtc, xc, bc)
    chunk_decay = jnp.exp(l_cum[:, :, -1, :])                  # [b,nc,h]

    def step(state, inp):
        z_c, cd_c = inp                                        # [b,h,p,n],[b,h]
        new = cd_c[..., None, None] * state + z_c
        return new, state                                      # emit state at chunk START

    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))
    final, s_starts = scan_layers(
        step, s0, (z.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    )
    s_starts = s_starts.transpose(1, 0, 2, 3, 4)               # [b,nc,h,p,n]

    y_cross = jnp.einsum(
        "bcin,bchpn,bcih->bcihp", cc, s_starts, jnp.exp(l_cum)
    )
    y = (y_intra + y_cross).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final


def block_forward(lp: Params, cfg: ArchConfig, x: jax.Array,
                  chunk: int = 128) -> jax.Array:
    d_inner, n_heads, conv_dim = _dims(cfg)
    bsz, s, _ = x.shape
    zxbcdt = L.linear(lp["in_proj"], x)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + conv_dim]
    dt_raw = zxbcdt[..., d_inner + conv_dim :]
    xbc = jax.nn.silu(causal_conv(xbc, lp["conv_w"], lp["conv_b"]))
    xs = xbc[..., :d_inner]
    b_in = xbc[..., d_inner : d_inner + cfg.ssm_state]
    c_in = xbc[..., d_inner + cfg.ssm_state :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"])
    xh = xs.reshape(bsz, s, n_heads, cfg.ssm_head_dim)
    y, _ = ssd_chunked(xh, dt, a, b_in, c_in, chunk)
    y = y + lp["d_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, s, d_inner)
    y = L.rms_norm(lp["gated_norm"], y * jax.nn.silu(z))
    return L.linear(lp["out_proj"], y)


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    return {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "layers": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "lm_head": L.init_linear(kh, cfg.d_model, cfg.vocab, False, dtype),
    }


def forward_hidden(params: Params, cfg: ArchConfig, tokens: jax.Array,
                   *, chunk: int = 128, remat: bool = False) -> jax.Array:
    x = params["embed"][tokens]

    def body(carry, lp):
        from repro.distributed import hints
        h = block_forward(lp, cfg, L.rms_norm(lp["norm"], carry), chunk)
        return hints.constrain(carry + h, "batch", "model", None), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = scan_layers(body, x, params["layers"])
    return L.rms_norm(params["final_norm"], x)


def forward(params: Params, cfg: ArchConfig, tokens: jax.Array,
            *, chunk: int = 128, remat: bool = False) -> jax.Array:
    x = forward_hidden(params, cfg, tokens, chunk=chunk, remat=remat)
    return x @ params["lm_head"]["w"].T


# -- decode -------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    d_inner, n_heads, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssd": jnp.zeros(
            (cfg.n_layers, batch, n_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cfg: ArchConfig, tokens: jax.Array, cache):
    """tokens: [B, 1] -> (logits [B, 1, V], new cache)."""
    d_inner, n_heads, conv_dim = _dims(cfg)
    x = params["embed"][tokens]                                  # [B,1,d]

    def body(carry, scanned):
        lp, conv_st, ssd_st = scanned
        xx = carry
        u = L.rms_norm(lp["norm"], xx)[:, 0]                     # [B,d]
        zxbcdt = L.linear(lp["in_proj"], u)
        z = zxbcdt[..., :d_inner]
        xbc = zxbcdt[..., d_inner : d_inner + conv_dim]
        dt_raw = zxbcdt[..., d_inner + conv_dim :]
        # conv ring state: window = [conv_st, xbc]
        win = jnp.concatenate([conv_st, xbc[:, None, :]], axis=1)  # [B,K,conv]
        conv_out = jnp.einsum("bkc,kc->bc", win, lp["conv_w"]) + lp["conv_b"]
        xbc_t = jax.nn.silu(conv_out)
        new_conv = win[:, 1:, :]
        xs = xbc_t[..., :d_inner]
        b_in = xbc_t[..., d_inner : d_inner + cfg.ssm_state]
        c_in = xbc_t[..., d_inner + cfg.ssm_state :]
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"])  # [B,H]
        a = -jnp.exp(lp["a_log"])
        xh = xs.reshape(-1, n_heads, cfg.ssm_head_dim).astype(jnp.float32)
        decay = jnp.exp(dt * a)                                  # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, b_in.astype(jnp.float32))
        new_ssd = decay[..., None, None] * ssd_st + upd
        y = jnp.einsum("bhpn,bn->bhp", new_ssd, c_in.astype(jnp.float32))
        y = y + lp["d_skip"][None, :, None] * xh
        y = y.reshape(-1, d_inner).astype(xx.dtype)
        y = L.rms_norm(lp["gated_norm"], y * jax.nn.silu(z))
        out = L.linear(lp["out_proj"], y)[:, None, :]
        return xx + out, (new_conv, new_ssd)

    x, (new_conv, new_ssd) = scan_layers(
        body, x, (params["layers"], cache["conv"], cache["ssd"])
    )
    x = L.rms_norm(params["final_norm"], x)
    logits = x @ params["lm_head"]["w"].T
    return logits, {"conv": new_conv, "ssd": new_ssd, "pos": cache["pos"] + 1}
