"""Architecture configuration for the assigned model pool.

One ``ArchConfig`` instance per architecture lives in src/repro/configs/;
``reduced()`` derives the CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None       # default d_model // n_heads
    # flags
    qkv_bias: bool = False               # qwen2
    qk_norm: bool = False                # qwen3
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    # hybrid (recurrentgemma): layer pattern unit, e.g. ("rglru","rglru","attn")
    block_pattern: Tuple[str, ...] = ()
    attn_window: int = 0                 # sliding-window size (0 = global)
    lru_width: int = 0
    # enc-dec (seamless)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # modality frontend stub: inputs are precomputed embeddings of this dim
    embed_inputs: bool = False
    # paper technique applicability (DESIGN.md §4)
    delta_applicable: bool = False
    # long_500k support (sub-quadratic sequence mixing)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_groups(self) -> int:
        return self.n_heads // self.n_kv_heads

    def validate(self) -> "ArchConfig":
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, self.name
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0, self.name
        if self.family == "ssm":
            assert self.ssm_state > 0, self.name
        if self.family == "hybrid":
            assert self.block_pattern, self.name
        if self.family == "audio":
            assert self.n_enc_layers and self.n_dec_layers, self.name
        return self

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests: small widths, few
        layers/experts, small vocab — structure preserved."""
        def shrink_pattern(p):
            return p[: min(len(p), 3)] if p else p

        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 * max(len(self.block_pattern), 1)),
            d_model=128 if self.hd <= 128 else 256,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=max(1, min(2, self.n_kv_heads)),
            head_dim=64 if (self.head_dim or 0) else None,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            lru_width=128 if self.lru_width else 0,
            attn_window=min(self.attn_window, 16) if self.attn_window else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_dec_layers=min(self.n_dec_layers, 2),
            block_pattern=self.block_pattern,
        )


# ---------------------------------------------------------------------------
# Input-shape cells (assigned): every LM arch gets all four; decode shapes
# lower serve_step; long_500k only for sub-quadratic archs.

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def shape_applicable(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped). Per assignment: long_500k needs
    sub-quadratic attention; pure full-attention archs skip it."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: O(S^2) at 524k out of scope (assignment rule)"
    return True, ""
