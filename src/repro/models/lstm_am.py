"""LSTM acoustic model — the paper's own network family (Sec. V-B).

"LSTM layers are followed by a Fully-Connected Layer having the same
number of units with each LSTM layer and a final logit layer."  Trained
with CTC; supports the pretrain (plain LSTM + CBTD) and retrain
(DeltaLSTM, alpha=1) phases, INT8/INT16 fake-quant, and exposes delta
statistics for the hardware model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import (
    apply_cbtd,
    delta_lstm_layer,
    fake_quant_act_ste,
    fake_quant_ste,
    init_lstm_params,
    lstm_layer,
    stacked_weight_matrix,
    QuantConfig,
)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LSTMAMConfig:
    input_dim: int = 123
    hidden_dim: int = 1024
    n_layers: int = 2
    n_classes: int = 41          # CTC vocab (blank + phonemes)
    delta: bool = False          # DeltaLSTM (retrain phase) vs LSTM (pretrain)
    theta: float = 0.0           # delta threshold
    quant: QuantConfig = QuantConfig(enabled=False)

    @property
    def name(self) -> str:
        kind = "DeltaLSTM" if self.delta else "LSTM"
        return f"{kind}-{self.n_layers}L-{self.hidden_dim}H-UNI"


def init_params(key: jax.Array, cfg: LSTMAMConfig, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    d = cfg.input_dim
    for i in range(cfg.n_layers):
        layers.append(init_lstm_params(keys[i], d, cfg.hidden_dim, dtype))
        d = cfg.hidden_dim
    bound = 1.0 / jnp.sqrt(cfg.hidden_dim)
    fcl = {
        "w": jax.random.uniform(
            keys[-2], (cfg.hidden_dim, cfg.hidden_dim), dtype, -bound, bound
        ),
        "b": jnp.zeros((cfg.hidden_dim,), dtype),
    }
    logit = {
        "w": jax.random.uniform(
            keys[-1], (cfg.n_classes, cfg.hidden_dim), dtype, -bound, bound
        ),
        "b": jnp.zeros((cfg.n_classes,), dtype),
    }
    return {"lstm": layers, "fcl": fcl, "logit": logit}


def n_params(params: Params) -> int:
    return sum(l.size for l in jax.tree.leaves(params))


def _maybe_quant_params(params: Params, cfg: LSTMAMConfig) -> Params:
    if not cfg.quant.enabled:
        return params

    def q(leaf):
        if leaf.ndim == 2:
            return fake_quant_ste(leaf, cfg.quant.weight_bits)
        return leaf

    return jax.tree.map(q, params)


def _maybe_quant_act(x: jax.Array, cfg: LSTMAMConfig) -> jax.Array:
    if not cfg.quant.enabled:
        return x
    return fake_quant_act_ste(x, cfg.quant.act_bits, cfg.quant.act_frac_bits)


def forward(
    params: Params, cfg: LSTMAMConfig, feats: jax.Array,
    collect_aux: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """feats: [B, T, D] -> logits [B, T, n_classes]; aux carries per-layer
    delta occupancy (for sparsity stats / hwsim) when collect_aux."""
    params = _maybe_quant_params(params, cfg)
    x = feats
    aux: Dict[str, Any] = {"layers": []}

    for li, lp in enumerate(params["lstm"]):
        x = _maybe_quant_act(x, cfg)
        if cfg.delta:
            def run(seq, lp=lp):
                return delta_lstm_layer(lp, seq, cfg.theta)
            hs, _, layer_aux = jax.vmap(run)(x)
            if collect_aux:
                aux["layers"].append(
                    {"nnz_dx": layer_aux["nnz_dx"], "nnz_dh": layer_aux["nnz_dh"],
                     "dx_masks": layer_aux["dx_masks"],
                     "dh_masks": layer_aux["dh_masks"]}
                )
        else:
            hs = jax.vmap(lambda seq, lp=lp: lstm_layer(lp, seq))(x)
        x = hs

    x = _maybe_quant_act(x, cfg)
    x = jax.nn.relu(x @ params["fcl"]["w"].T + params["fcl"]["b"])
    x = _maybe_quant_act(x, cfg)
    logits = x @ params["logit"]["w"].T + params["logit"]["b"]
    return logits, aux


def cbtd_prune_stacks(params: Params, gamma: float, m: int) -> Params:
    """CBTD-prune every LSTM layer's stacked [4H, D+H] matrix (the exact
    matrix the serving engines CBCSC-pack) and split it back into
    w_x / w_h.  Returns new params; fcl/logit pass through untouched.
    Used by benchmarks/examples/tests that need a servable (column-
    balanced) model without running the full pretrain/retrain loop."""
    out = dict(params)
    layers = []
    for lp in params["lstm"]:
        w = apply_cbtd(stacked_weight_matrix(lp), gamma=gamma, m=m)
        d = lp["w_x"].shape[1]
        layers.append({**lp, "w_x": w[:, :d], "w_h": w[:, d:]})
    out["lstm"] = layers
    return out


def lstm_weight_layout() -> Dict[str, Any]:
    """CBTD layout: prune the recurrent stacks + FCL (paper Sec. V-C:
    'The CBTD was also applied to the FCL'), never the logit layer."""
    from repro.core.cbtd import CBTDConfig

    return {"w_x": CBTDConfig(), "w_h": CBTDConfig(), "fcl/w": CBTDConfig()}
