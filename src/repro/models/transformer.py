"""Decoder-only transformer LM (dense + MoE) — covers qwen2/qwen3/
granite-34b/internlm2/pixtral-backbone/granite-moe/olmoe.

Layers are stacked along a leading L axis and executed with
``jax.lax.scan`` (small HLO, fast multi-arch dry-run compiles) with an
optional remat policy for training.  Decode steps scan over (layer params,
layer KV cache) pairs and emit the updated stacked cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models.scan import scan_layers

Params = Dict[str, Any]


def init_layer(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": L.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            cfg.qkv_bias, cfg.qk_norm, dtype,
        ),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = L.init_moe(k2, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype)
    else:
        p["mlp"] = L.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ke, kl, kh = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "layers": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_linear(kh, cfg.d_model, cfg.vocab, False, dtype)
    return params


def _layer_fwd(lp: Params, x: jax.Array, cfg: ArchConfig, q_chunk: int) -> jax.Array:
    h = L.attention_forward(
        lp["attn"], L.rms_norm(lp["attn_norm"], x),
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, hd=cfg.hd,
        causal=True, window=cfg.attn_window, q_chunk=q_chunk,
    )
    x = x + h
    y = L.rms_norm(lp["mlp_norm"], x)
    if cfg.family == "moe":
        h2 = L.moe_forward(
            lp["moe"], y, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor
        )
    else:
        h2 = L.swiglu(lp["mlp"], y)
    from repro.distributed import hints
    # sequence-shard the residual checkpoint: the scan stores one carry per
    # layer for backward — at 88 layers x [B,4k,6k] that is the difference
    # between 200 GiB and 13 GiB per device (Megatron-style SP).
    return hints.constrain(x + h2, "batch", "model", None)


def head_weight(params: Params, cfg: ArchConfig) -> jax.Array:
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]["w"]


def forward_hidden(
    params: Params,
    cfg: ArchConfig,
    tokens: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
    *,
    q_chunk: int = 0,
    remat: bool = False,
) -> jax.Array:
    """Full-sequence forward -> final hidden states [B, S, d]."""
    x = params["embed"][tokens] if inputs_embeds is None else inputs_embeds

    def body(carry, lp):
        return _layer_fwd(lp, carry, cfg, q_chunk), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = scan_layers(body, x, params["layers"])
    return L.rms_norm(params["final_norm"], x)


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
    *,
    q_chunk: int = 0,
    remat: bool = False,
) -> jax.Array:
    """Full-sequence forward -> logits [B, S, V]."""
    x = forward_hidden(params, cfg, tokens, inputs_embeds,
                       q_chunk=q_chunk, remat=remat)
    from repro.distributed import hints
    return hints.constrain(x @ head_weight(params, cfg).T, "batch", None, "model")


def init_cache(cfg: ArchConfig, batch: int, s_cache: int, dtype=jnp.float32):
    """Stacked KV cache [L, B, S, Hkv, hd] x2 + position scalar."""
    kv = {
        "k": jnp.zeros((cfg.n_layers, batch, s_cache, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, s_cache, cfg.n_kv_heads, cfg.hd), dtype),
    }
    return {"kv": kv, "pos": jnp.zeros((), jnp.int32)}


def decode_step(
    params: Params,
    cfg: ArchConfig,
    tokens: Optional[jax.Array],           # [B, 1] (or None with embeds)
    cache,
    inputs_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Any]:
    """One token step -> (logits [B, 1, V], new cache)."""
    pos = cache["pos"]
    x = params["embed"][tokens] if inputs_embeds is None else inputs_embeds

    def body(carry, scanned):
        lp, kc = scanned
        x = carry
        h, kc_new = L.attention_decode_step(
            lp["attn"], L.rms_norm(lp["attn_norm"], x), kc, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, hd=cfg.hd,
            window=cfg.attn_window,
        )
        x = x + h
        y = L.rms_norm(lp["mlp_norm"], x)
        if cfg.family == "moe":
            h2 = L.moe_forward(lp["moe"], y, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor)
        else:
            h2 = L.swiglu(lp["mlp"], y)
        return x + h2, kc_new

    x, new_kv = scan_layers(body, x, (params["layers"], cache["kv"]))
    x = L.rms_norm(params["final_norm"], x)
    head_w = params["embed"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = x @ head_w.T
    return logits, {"kv": new_kv, "pos": pos + 1}


def ce_loss(logits: jax.Array, targets: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def chunked_ce_loss(x: jax.Array, head_w: jax.Array, targets: jax.Array,
                    chunk: int = 512) -> jax.Array:
    """CE over a vocab head WITHOUT materialising [B, S, V] logits: scan
    over sequence chunks, recomputing each chunk's logits in the backward
    pass (checkpointed body).  Memory: O(B * chunk * V / tp) fp32.

    The full-logit path peaked at ~4.7 GiB/device on a 152k vocab (see
    EXPERIMENTS.md §Perf) — this is the fix."""
    from repro.distributed import hints
    from repro.models.scan import scan_layers

    b, s, d = x.shape
    if s % chunk or s == chunk:
        logits = hints.constrain(x @ head_w.T, "batch", None, "model")
        return ce_loss(logits, targets)
    nc = s // chunk
    xs = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, nc, chunk).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(acc, inp):
        xc, tc = inp
        logits = hints.constrain(xc @ head_w.T, "batch", None, "model")
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - tgt), None

    total, _ = scan_layers(body, jnp.zeros((), jnp.float32), (xs, ts))
    return total / (b * s)
