"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): RG-LRU recurrent
blocks + local (sliding-window, MQA) attention in a 2:1 pattern.

This is the assigned architecture closest to the paper's domain — a gated
linear recurrence whose input/output projections take both CBTD pruning
and DeltaLinear temporal sparsity (DESIGN.md §4).

Training evaluates the RG-LRU with ``jax.lax.associative_scan`` (log-depth
parallel linear recurrence — the TPU-native answer to "the temporal
dependency creates a critical path", paper Sec. I).  Decode is O(1) state,
so the arch runs ``long_500k``.

Layer pattern: ("rglru", "rglru", "attn") repeated; the remainder layers
(38 = 12*3 + 2) are appended as unstacked blocks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models.scan import scan_layers

Params = Dict[str, Any]

LRU_C = 8.0  # Griffin's fixed exponent scale


def _lru_width(cfg: ArchConfig) -> int:
    return cfg.lru_width or cfg.d_model


# -- RG-LRU core ---------------------------------------------------------------

def init_rglru(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    w = _lru_width(cfg)
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # Lambda raw-init so a = exp(-c*softplus(L)) lands in [0.9, 0.999]
    u = jax.random.uniform(k1, (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / LRU_C))  # inverse softplus
    return {
        "in_x": L.init_linear(k2, cfg.d_model, w, False, dtype),
        "in_y": L.init_linear(k3, cfg.d_model, w, False, dtype),
        "conv_w": jax.random.normal(k4, (4, w), dtype) * 0.2,
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": L.init_linear(k5, w, w, True, dtype),
        "gate_i": L.init_linear(k6, w, w, True, dtype),
        "lambda_raw": lam,
        "out": L.init_linear(k1, w, cfg.d_model, False, dtype),
    }


def _lru_coeffs(p: Params, x: jax.Array):
    """x: [..., W] -> (a, b) of the recurrence h = a*h_prev + b."""
    r = jax.nn.sigmoid(L.linear(p["gate_a"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(L.linear(p["gate_i"], x).astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(p["lambda_raw"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i * x.astype(jnp.float32))
    return a, b


def rglru_scan(p: Params, x: jax.Array,
               h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Parallel linear recurrence over [B, S, W] -> (h [B,S,W], h_last)."""
    a, b = _lru_coeffs(p, x)
    if h0 is not None:
        # fold the carried state into the first step's offset
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_block(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Full Griffin recurrent block over [B, S, d]."""
    from repro.models.mamba2 import causal_conv

    xb = L.linear(p["in_x"], x)
    yb = jax.nn.gelu(L.linear(p["in_y"], x))
    xb = causal_conv(xb, p["conv_w"], p["conv_b"])
    h, _ = rglru_scan(p, xb)
    return L.linear(p["out"], h * yb)


def rglru_decode(p: Params, cfg: ArchConfig, x: jax.Array, state):
    """x: [B, 1, d]; state: {conv: [B,3,W], h: [B,W]}."""
    xb = L.linear(p["in_x"], x[:, 0])
    yb = jax.nn.gelu(L.linear(p["in_y"], x[:, 0]))
    win = jnp.concatenate([state["conv"], xb[:, None]], axis=1)   # [B,4,W]
    xc = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    a, b = _lru_coeffs(p, xc)
    h = a * state["h"].astype(jnp.float32) + b
    out = L.linear(p["out"], (h.astype(x.dtype) * yb))[:, None]
    return out, {"conv": win[:, 1:], "h": h}


# -- block assembly --------------------------------------------------------------

def init_block(key, cfg: ArchConfig, kind: str, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"mix_norm": L.init_rmsnorm(cfg.d_model, dtype),
         "mlp_norm": L.init_rmsnorm(cfg.d_model, dtype),
         "mlp": L.init_swiglu(k2, cfg.d_model, cfg.d_ff, dtype)}
    if kind == "attn":
        p["attn"] = L.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            False, False, dtype,
        )
    else:
        p["rglru"] = init_rglru(k1, cfg, dtype)
    return p


def block_forward(bp: Params, cfg: ArchConfig, kind: str, x: jax.Array,
                  q_chunk: int = 0) -> jax.Array:
    y = L.rms_norm(bp["mix_norm"], x)
    if kind == "attn":
        h = L.attention_forward(
            bp["attn"], y, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            hd=cfg.hd, causal=True, window=cfg.attn_window, q_chunk=q_chunk,
            rope_base=1e4,
        )
    else:
        h = rglru_block(bp["rglru"], cfg, y)
    x = x + h
    from repro.distributed import hints
    x = x + L.swiglu(bp["mlp"], L.rms_norm(bp["mlp_norm"], x))
    return hints.constrain(x, "batch", "model", None)


def _layout(cfg: ArchConfig):
    pat = cfg.block_pattern
    n_super = cfg.n_layers // len(pat)
    rest = tuple(pat[i] for i in range(cfg.n_layers - n_super * len(pat)))
    return pat, n_super, rest


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    pat, n_super, rest = _layout(cfg)
    ke, kl, kr, kh = jax.random.split(key, 4)
    super_keys = jax.random.split(kl, n_super)

    def init_super(k):
        ks = jax.random.split(k, len(pat))
        return {f"b{i}_{kind}": init_block(ks[i], cfg, kind, dtype)
                for i, kind in enumerate(pat)}

    params = {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "supers": jax.vmap(init_super)(super_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        "lm_head": L.init_linear(kh, cfg.d_model, cfg.vocab, False, dtype),
    }
    rest_keys = jax.random.split(kr, max(len(rest), 1))
    params["rest"] = [init_block(rest_keys[i], cfg, kind, dtype)
                      for i, kind in enumerate(rest)]
    return params


def forward_hidden(params: Params, cfg: ArchConfig, tokens: jax.Array,
                   *, q_chunk: int = 0, remat: bool = False) -> jax.Array:
    pat, n_super, rest = _layout(cfg)
    x = params["embed"][tokens]

    def body(carry, sp):
        x = carry
        for i, kind in enumerate(pat):
            x = block_forward(sp[f"b{i}_{kind}"], cfg, kind, x, q_chunk)
        return x, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = scan_layers(body, x, params["supers"])
    for bp, kind in zip(params["rest"], rest):
        x = block_forward(bp, cfg, kind, x, q_chunk)
    return L.rms_norm(params["final_norm"], x)


def forward(params: Params, cfg: ArchConfig, tokens: jax.Array,
            *, q_chunk: int = 0, remat: bool = False) -> jax.Array:
    x = forward_hidden(params, cfg, tokens, q_chunk=q_chunk, remat=remat)
    return x @ params["lm_head"]["w"].T


# -- decode ----------------------------------------------------------------------

def _block_cache(cfg: ArchConfig, kind: str, batch: int, dtype):
    w = _lru_width(cfg)
    if kind == "attn":
        cache_len = cfg.attn_window or 2048
        return L.init_kv_cache(batch, cache_len, cfg.n_kv_heads, cfg.hd, dtype)
    return {"conv": jnp.zeros((batch, 3, w), dtype),
            "h": jnp.zeros((batch, w), jnp.float32)}


def init_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    pat, n_super, rest = _layout(cfg)

    def one(_):
        return {f"b{i}_{kind}": _block_cache(cfg, kind, batch, dtype)
                for i, kind in enumerate(pat)}

    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_super,) + a.shape), one(0)
    )
    return {
        "supers": stacked,
        "rest": [_block_cache(cfg, kind, batch, dtype) for kind in rest],
        "pos": jnp.zeros((), jnp.int32),
    }


def _block_decode(bp, cfg, kind, x, bc, pos):
    y = L.rms_norm(bp["mix_norm"], x)
    if kind == "attn":
        h, bc = L.attention_decode_step(
            bp["attn"], y, bc, pos, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, hd=cfg.hd,
            window=cfg.attn_window or 2048, rope_base=1e4,
        )
    else:
        h, bc = rglru_decode(bp["rglru"], cfg, y, bc)
    x = x + h
    x = x + L.swiglu(bp["mlp"], L.rms_norm(bp["mlp_norm"], x))
    return x, bc


def decode_step(params: Params, cfg: ArchConfig, tokens: jax.Array, cache):
    pat, n_super, rest = _layout(cfg)
    pos = cache["pos"]
    x = params["embed"][tokens]

    def body(carry, scanned):
        sp, sc = scanned
        x = carry
        new_sc = {}
        for i, kind in enumerate(pat):
            name = f"b{i}_{kind}"
            x, new_sc[name] = _block_decode(sp[name], cfg, kind, x, sc[name], pos)
        return x, new_sc

    x, new_supers = scan_layers(body, x, (params["supers"], cache["supers"]))
    new_rest = []
    for bp, bc, kind in zip(params["rest"], cache["rest"], rest):
        x, nbc = _block_decode(bp, cfg, kind, x, bc, pos)
        new_rest.append(nbc)
    x = L.rms_norm(params["final_norm"], x)
    logits = x @ params["lm_head"]["w"].T
    return logits, {"supers": new_supers, "rest": new_rest, "pos": pos + 1}
