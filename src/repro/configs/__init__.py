"""Architecture registry: ``--arch <id>`` ids -> ArchConfig."""
from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.granite_moe_1b_a400m import CONFIG as granite_moe_1b_a400m
from repro.configs.internlm2_20b import CONFIG as internlm2_20b
from repro.configs.mamba2_130m import CONFIG as mamba2_130m
from repro.configs.olmoe_1b_7b import CONFIG as olmoe_1b_7b
from repro.configs.pixtral_12b import CONFIG as pixtral_12b
from repro.configs.qwen2_0_5b import CONFIG as qwen2_0_5b
from repro.configs.qwen3_1_7b import CONFIG as qwen3_1_7b
from repro.configs.recurrentgemma_9b import CONFIG as recurrentgemma_9b
from repro.configs.seamless_m4t_medium import CONFIG as seamless_m4t_medium

REGISTRY = {
    c.name: c
    for c in [
        qwen2_0_5b,
        qwen3_1_7b,
        granite_34b,
        internlm2_20b,
        mamba2_130m,
        pixtral_12b,
        granite_moe_1b_a400m,
        olmoe_1b_7b,
        seamless_m4t_medium,
        recurrentgemma_9b,
    ]
}


def get_arch(name: str):
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
