"""The paper's own acoustic-model configs (Table II) — DeltaLSTM + CBTD.

These are not part of the assigned pool; they are the faithful-reproduction
networks used by the accuracy benchmarks and the hardware model."""
from repro.models.lstm_am import LSTMAMConfig

# Table II rows (TIMIT): the networks Spartus supports in hardware
LSTM_3L_512H = LSTMAMConfig(input_dim=123, hidden_dim=512, n_layers=3, n_classes=41)
LSTM_2L_768H = LSTMAMConfig(input_dim=123, hidden_dim=768, n_layers=2, n_classes=41)
LSTM_2L_1024H = LSTMAMConfig(input_dim=123, hidden_dim=1024, n_layers=2, n_classes=41)
# the hardware test network: top layer of the biggest AM (Sec. VI-C)
DELTA_LSTM_2L_1024H = LSTMAMConfig(
    input_dim=123, hidden_dim=1024, n_layers=2, n_classes=41,
    delta=True, theta=0.3,
)
