"""seamless-m4t-medium [audio] — 12L enc + 12L dec, d=1024 16H (kv=16)
ff=4096 vocab=256206; enc-dec multimodal, frontend STUB provides frame
embeddings [arXiv:2308.11596; hf].  Best-fit arch for the paper's delta
technique: speech frames are temporally smooth."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, n_enc_layers=12, n_dec_layers=12, embed_inputs=True,
    delta_applicable=True,
).validate()
