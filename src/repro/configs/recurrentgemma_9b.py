"""recurrentgemma-9b [hybrid] — 38L d=4096 16H (MQA kv=1) ff=12288
vocab=256000; RG-LRU + local attention 1:2 (pattern R,R,A)
[arXiv:2402.19427; unverified].  Gated linear recurrence: the closest
assigned analogue of the paper's target workload."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab=256000, head_dim=256, block_pattern=("rglru", "rglru", "attn"),
    attn_window=2048, lru_width=4096,
    delta_applicable=True, subquadratic=True,
).validate()
