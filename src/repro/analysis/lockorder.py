"""Runtime lock-order recorder: the dynamic half of the concurrency
analyzer (static half: `repro.analysis.concurrency`).

The serving stack holds a small family of locks — ``SessionPool._state_lock``
guarding the donated device state, the metrics-registry lock shared by
every counter/gauge/histogram, the time-series and tracer ring locks, the
checkpoint manager's commit lock.  Each is individually correct; what no
single call site can see is the *order* they nest in across threads.  Two
threads that ever acquire the same two locks in opposite orders can
deadlock — a class of bug that survives any number of green test runs
until the interleaving finally lands.  This module makes the test suite
itself the detector:

* :func:`make_lock` is the factory the serving modules create their locks
  through.  With no recorder installed it returns a plain
  ``threading.Lock`` — identical cost to today, nothing imported at lock
  time, production untouched.  With a recorder installed (the chaos CI
  job and the concurrency stress test export ``SPARTUS_LOCK_ORDER=1``;
  ``tests/conftest.py`` installs one for the whole session) it returns an
  :class:`InstrumentedLock` that reports every acquire/release.
* :class:`LockOrderRecorder` keeps, per thread, the stack of locks
  currently held, and builds the directed *acquisition-order graph*: an
  edge ``A -> B`` for every acquire of ``B`` while ``A`` is held, keyed
  by lock **name** (every ``SessionPool._state_lock`` instance is one
  node — the ordering discipline is per role, not per object).
  ``cycles()`` runs a DFS over that graph; a cycle is a potential
  deadlock even if no run ever hung.  The recorder also aggregates
  per-name **hold times** (count / total / max seconds) so a lock held
  across a blocking device fetch shows up as a number, not a hunch —
  ``slow_holds`` lists every hold longer than ``slow_hold_s`` with the
  thread that did it.  The static companion rule (``await-under-lock``
  in `repro.analysis.concurrency`) catches the async-driver variant of
  the same mistake at lint time.
* Re-acquiring a lock object the same thread already holds (guaranteed
  self-deadlock for non-reentrant locks) is recorded as a violation
  *before* the acquire blocks, so the report names the culprit even when
  the test then times out.

The recorder never holds its own mutex while acquiring an instrumented
lock, so instrumentation cannot itself deadlock; stdlib-only, no jax.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

__all__ = [
    "InstrumentedLock",
    "LockOrderRecorder",
    "current",
    "install",
    "make_lock",
    "uninstall",
]


class LockOrderRecorder:
    """Cross-thread lock acquisition-order graph + hold-time aggregator.

    Thread-safe; one instance is typically installed process-wide via
    :func:`install` and fed by every :class:`InstrumentedLock`.
    """

    def __init__(self, slow_hold_s: float = 1.0):
        self.slow_hold_s = float(slow_hold_s)
        self._mu = threading.Lock()
        self._tls = threading.local()
        # acquisition-order edges, (held_name, acquired_name) -> count:
        self._edges: Dict[Tuple[str, str], int] = {}
        # per-name hold stats: name -> [n_holds, total_s, max_s]:
        self._holds: Dict[str, List[float]] = {}
        self._slow: List[Tuple[str, float, int]] = []  # (name, s, thread id)
        self._violations: List[str] = []

    # -- instrumentation feed (called by InstrumentedLock) -------------------

    def _stack(self) -> List[Tuple[str, int, float]]:
        """This thread's held-lock stack: (name, lock id, t_acquired)."""
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def note_acquire(self, name: str, lock_id: int) -> None:
        """About to block on ``(name, lock_id)``: record order edges from
        every lock this thread already holds (intent, not success — the
        deadlock happens at intent time)."""
        stack = self._stack()
        if any(lid == lock_id for _, lid, _ in stack):
            with self._mu:
                self._violations.append(
                    f"re-acquire of held lock {name!r} on thread "
                    f"{threading.get_ident()}: guaranteed self-deadlock "
                    f"(threading.Lock is not reentrant)")
        if not stack:
            return
        with self._mu:
            for held_name, _, _ in stack:
                if held_name != name:
                    key = (held_name, name)
                    self._edges[key] = self._edges.get(key, 0) + 1

    def note_acquired(self, name: str, lock_id: int) -> None:
        self._stack().append((name, lock_id, time.perf_counter()))

    def note_release(self, name: str, lock_id: int) -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i][1] == lock_id:
                _, _, t0 = stack.pop(i)
                dt = time.perf_counter() - t0
                with self._mu:
                    h = self._holds.setdefault(name, [0, 0.0, 0.0])
                    h[0] += 1
                    h[1] += dt
                    h[2] = max(h[2], dt)
                    if dt >= self.slow_hold_s:
                        self._slow.append((name, dt, threading.get_ident()))
                return
        with self._mu:
            self._violations.append(
                f"release of {name!r} not held by thread "
                f"{threading.get_ident()}")

    # -- analysis ------------------------------------------------------------

    def edges(self) -> Dict[Tuple[str, str], int]:
        with self._mu:
            return dict(self._edges)

    def violations(self) -> List[str]:
        with self._mu:
            return list(self._violations)

    def cycles(self) -> List[List[str]]:
        """Cycles in the acquisition-order graph (each as the name path
        ``[a, b, ..., a]``) — every one is a potential deadlock."""
        graph: Dict[str, List[str]] = {}
        for (a, b) in self.edges():
            graph.setdefault(a, []).append(b)
            graph.setdefault(b, [])
        out: List[List[str]] = []
        color: Dict[str, int] = {}          # 0 absent / 1 on path / 2 done
        path: List[str] = []

        def dfs(n: str) -> None:
            color[n] = 1
            path.append(n)
            for m in graph[n]:
                c = color.get(m, 0)
                if c == 1:
                    out.append(path[path.index(m):] + [m])
                elif c == 0:
                    dfs(m)
            path.pop()
            color[n] = 2

        for n in sorted(graph):
            if color.get(n, 0) == 0:
                dfs(n)
        return out

    def assert_acyclic(self) -> None:
        cyc = self.cycles()
        if cyc:
            pretty = "; ".join(" -> ".join(c) for c in cyc)
            raise AssertionError(
                f"lock-order cycles (potential deadlocks): {pretty}")
        bad = self.violations()
        if bad:
            raise AssertionError("lock discipline violations: "
                                 + "; ".join(bad))

    def hold_times(self) -> Dict[str, Dict[str, float]]:
        with self._mu:
            return {name: {"count": int(h[0]), "total_s": h[1],
                           "max_s": h[2]}
                    for name, h in sorted(self._holds.items())}

    def slow_holds(self) -> List[Tuple[str, float, int]]:
        with self._mu:
            return list(self._slow)

    def report(self) -> Dict[str, object]:
        """JSON-ready summary (the chaos CI job uploads this artifact)."""
        return {
            "edges": [{"held": a, "acquired": b, "count": n}
                      for (a, b), n in sorted(self.edges().items())],
            "cycles": self.cycles(),
            "violations": self.violations(),
            "hold_times": self.hold_times(),
            "slow_holds": [{"name": n, "seconds": s, "thread": t}
                           for n, s, t in self.slow_holds()],
        }


class InstrumentedLock:
    """Drop-in ``threading.Lock`` that reports to a `LockOrderRecorder`.

    The recorder is resolved per acquire (the installed one by default),
    so locks created before a recorder swap keep reporting to the live
    instance.  Supports the full Lock protocol used in this repo:
    ``with``, ``acquire(blocking=, timeout=)``, ``release``, ``locked``.
    """

    __slots__ = ("name", "_lock", "_rec")

    def __init__(self, name: str,
                 recorder: Optional[LockOrderRecorder] = None):
        self.name = name
        self._lock = threading.Lock()
        self._rec = recorder

    def _recorder(self) -> Optional[LockOrderRecorder]:
        return self._rec if self._rec is not None else current()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        rec = self._recorder()
        if rec is not None:
            rec.note_acquire(self.name, id(self))
        ok = self._lock.acquire(blocking, timeout)
        if ok and rec is not None:
            rec.note_acquired(self.name, id(self))
        return ok

    def release(self) -> None:
        rec = self._recorder()
        if rec is not None:
            rec.note_release(self.name, id(self))
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


_installed: Optional[LockOrderRecorder] = None


def install(recorder: LockOrderRecorder) -> None:
    """Make ``recorder`` the process-wide recorder new instrumented locks
    report to, and the one :func:`make_lock` instruments for."""
    global _installed
    _installed = recorder


def uninstall() -> None:
    global _installed
    _installed = None


def current() -> Optional[LockOrderRecorder]:
    return _installed


def make_lock(name: str):
    """The serving modules' lock factory.

    No recorder installed (production, plain test runs): a bare
    ``threading.Lock`` — zero added cost, chosen once at creation.  With
    a recorder installed (chaos job, stress tests): an
    :class:`InstrumentedLock` named ``name``, feeding the
    acquisition-order graph.  Name by role (``"SessionPool._state_lock"``),
    not by instance — ordering discipline is a property of the role.
    """
    if _installed is None:
        return threading.Lock()
    return InstrumentedLock(name)
