"""Shared HLO-text inspection helpers.

Every hot-path pin in this repo ultimately asserts something about the
optimized HLO that XLA compiled for a jitted function: that the sharded
chunk step contains no collectives, that observability folds stay on the
device (no outfeeds or host callbacks), that donated buffers actually
alias, that the pre-transposed weight mirrors are not re-transposed at
run time.  Before this module existed each test grew its own ad-hoc
string grep; the scanners here are the single source of truth so the
contract checker (``repro.analysis.contracts``) and the test suite agree
byte-for-byte on what counts as a violation.

All helpers operate on the *optimized* HLO text, i.e. the string
returned by ``jitted.lower(*args).compile().as_text()``.  Ops that XLA
fuses are still visible inside fusion bodies, so the op histogram counts
them too.
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Iterable, List, Sequence, Tuple

# Tokens that indicate cross-device communication.  Matches the pin
# introduced for the sharded serving path (PR 5): GSPMD regressions show
# up as one of these op names in the optimized module.
COLLECTIVE_TOKENS: Tuple[str, ...] = (
    "all-reduce",
    "all-gather",
    "collective-permute",
    "all-to-all",
    "reduce-scatter",
)

# Tokens that indicate a device->host (or host->device) transfer inside
# the compiled step.  Matches the observability pin (PR 6): telemetry
# must fold on device and only cross the boundary at chunk edges.
HOST_TRANSFER_TOKENS: Tuple[str, ...] = (
    "outfeed",
    "infeed",
    "xla_python_cpu_callback",
    "host_callback",
    "SendToHost",
    "RecvFromHost",
)

# Optimized HLO instruction lines look like
#   ``%name = f32[4,32]{1,0} op-name(%a, %b), ...`` or
#   ``ROOT %name = (f32[...]) op-name(...)``.
# The op name is the token immediately before the open paren after the
# shape.  This matches instructions inside fusion computations too.
_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|[a-z0-9]+\[[^\]]*\])(?:\{[^}]*\})?\**\s+"
    r"([a-z][a-z0-9\-]*(?:\.[0-9]+)?)\("
)

# ``input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}, may-alias) }``
# on the HloModule header line records which outputs alias which inputs —
# the compile-time footprint of ``donate_argnums``.  The body nests
# braces, so it is extracted by brace counting, not regex.
_ALIAS_KEY = "input_output_alias={"
_ALIAS_ENTRY_RE = re.compile(r"\{[0-9, ]*\}:\s*\(")


def matching_lines(hlo_text: str, tokens: Sequence[str]) -> List[str]:
    """Lines of ``hlo_text`` containing any of ``tokens`` (substring match)."""
    return [
        line
        for line in hlo_text.splitlines()
        if any(tok in line for tok in tokens)
    ]


def collective_lines(hlo_text: str) -> List[str]:
    """HLO lines mentioning a cross-device collective."""
    return matching_lines(hlo_text, COLLECTIVE_TOKENS)


def count_collectives(hlo_text: str) -> int:
    """Number of HLO lines mentioning a collective (the PR-5 pin)."""
    return len(collective_lines(hlo_text))


def host_transfer_lines(hlo_text: str) -> List[str]:
    """HLO lines mentioning a host transfer or host callback (the PR-6 pin)."""
    return matching_lines(hlo_text, HOST_TRANSFER_TOKENS)


def op_histogram(hlo_text: str) -> Counter:
    """Histogram of op names across the module, including fusion bodies.

    Versioned op names (``fusion.1``) are folded onto their base name.
    """
    counts: Counter = Counter()
    for m in _OP_RE.finditer(hlo_text):
        name = m.group(1).split(".")[0]
        counts[name] += 1
    return counts


def count_ops(hlo_text: str, op: str) -> int:
    """Occurrences of one op family (base name, fusion bodies included)."""
    return op_histogram(hlo_text).get(op, 0)


def dtype_violation_lines(hlo_text: str, max_dtype: str = "float32") -> List[str]:
    """Lines whose result dtype exceeds ``max_dtype``.

    Only the f32 ceiling is meaningful for this repo (weights, states and
    logits are all float32; int32 bookkeeping is always allowed).  A wider
    ceiling disables the check.
    """
    if max_dtype in ("float64", "f64", None):
        return []
    # x64 leaks show up as f64 compute or s64 index math on the hot path.
    return matching_lines(hlo_text, ("f64[", "c128["))


def alias_count(hlo_text: str) -> int:
    """Number of input/output alias entries on the HloModule header.

    Each entry corresponds to one donated leaf that XLA agreed to reuse
    for an output buffer.  Donation that silently failed (shape/dtype
    mismatch, or a leaf not reachable from an output) simply has no
    entry, so comparing this count against the number of donated leaves
    catches dropped donations at compile time.  Note the *aliased-input*
    runtime failure (one buffer bound to two donated params) is not
    visible here — the runtime probe in ``contracts.check_case`` covers it.
    """
    i = hlo_text.find(_ALIAS_KEY)
    if i < 0:
        return 0
    j = i + len(_ALIAS_KEY)
    depth = 1
    while j < len(hlo_text) and depth:
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
        j += 1
    return len(_ALIAS_ENTRY_RE.findall(hlo_text[i + len(_ALIAS_KEY):j - 1]))


def compiled_text(jitted, *args, **kwargs) -> str:
    """Optimized HLO for ``jitted`` lowered at ``args``/``kwargs``."""
    return jitted.lower(*args, **kwargs).compile().as_text()


def assert_no_tokens(hlo_text: str, tokens: Iterable[str], what: str) -> None:
    """Raise AssertionError with offending lines if any token appears."""
    hits = matching_lines(hlo_text, tuple(tokens))
    if hits:
        raise AssertionError(
            f"{what}: found {len(hits)} offending HLO line(s):\n"
            + "\n".join(hits[:8])
        )
