"""Compile-time contracts for the serving hot paths.

A *contract* is a set of invariants a jitted function must satisfy in
its compiled form: no collectives, no host transfers, donation actually
honoured, float32 ceiling, per-op budgets.  Functions declare their
contract with the :func:`hotpath_contract` decorator; a
:class:`ContractCase` (see ``repro.analysis.cases``) supplies
representative arguments so the checker can lower, compile and inspect
the real HLO.  ``check_case`` then asserts every clause against the
optimized module text and — for donation — against an actual execution,
because the "same buffer donated twice" failure mode (the
``init_telemetry`` aliasing bug from PR 2) is only detectable at run
time: the compile-time alias map still lists every donated leaf as
``may-alias`` even when two params share one buffer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax

from . import hlo


@dataclasses.dataclass(frozen=True)
class HotpathContract:
    """Declared invariants for one hot-path function.

    Attributes:
      name: registry key; also how cases refer back to the contract.
      no_collectives: compiled module must contain no cross-device
        communication ops (``hlo.COLLECTIVE_TOKENS``).
      no_host_transfers: compiled module must contain no outfeed/infeed/
        host-callback ops (``hlo.HOST_TRANSFER_TOKENS``).
      donates: names of the logical arguments expected to be donated.
        Purely documentary for the static pass; the case supplies the
        concrete donated-leaf count to compare against the alias map.
      max_dtype: widest floating dtype permitted in the compiled module.
      forbid_ops: op families that must not appear at all (e.g.
        ``("transpose",)`` for paths that consume pre-transposed mirrors).
      op_budget: per-op-family ceilings, e.g. at most one
        ``dynamic-update-slice`` for a banked-row write.
    """

    name: str
    no_collectives: bool = True
    no_host_transfers: bool = True
    donates: Tuple[str, ...] = ()
    max_dtype: str = "float32"
    forbid_ops: Tuple[str, ...] = ()
    op_budget: Mapping[str, int] = dataclasses.field(default_factory=dict)


# Global registry: contract name -> HotpathContract.  Decorating a
# function registers it here; cases look contracts up by name so the
# checker works even for bound methods whose jitted wrapper is created
# per-instance (BatchedSpartusEngine jits in __init__).
_REGISTRY: Dict[str, HotpathContract] = {}


def hotpath_contract(
    name: str,
    *,
    no_collectives: bool = True,
    no_host_transfers: bool = True,
    donates: Sequence[str] = (),
    max_dtype: str = "float32",
    forbid_ops: Sequence[str] = (),
    op_budget: Optional[Mapping[str, int]] = None,
) -> Callable[[Any], Any]:
    """Declare and register a contract; returns the function unchanged.

    Stacks on top of ``jax.jit``-wrapped callables (PjitFunction accepts
    attribute assignment) and on plain methods that get jitted later.
    Re-registering the same name with identical clauses is a no-op;
    conflicting re-registration raises, so two modules cannot silently
    fight over one contract.
    """
    contract = HotpathContract(
        name=name,
        no_collectives=no_collectives,
        no_host_transfers=no_host_transfers,
        donates=tuple(donates),
        max_dtype=max_dtype,
        forbid_ops=tuple(forbid_ops),
        op_budget=dict(op_budget or {}),
    )
    existing = _REGISTRY.get(name)
    if existing is not None and existing != contract:
        raise ValueError(
            f"hotpath_contract {name!r} already registered with different "
            f"clauses: {existing} vs {contract}"
        )
    _REGISTRY[name] = contract

    def deco(fn: Any) -> Any:
        try:
            fn.__hotpath_contract__ = contract
        except (AttributeError, TypeError):  # exotic callables: registry only
            pass
        return fn

    return deco


def get_contract(name: str) -> HotpathContract:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no hotpath contract named {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


def registered_contracts() -> Dict[str, HotpathContract]:
    return dict(_REGISTRY)


@dataclasses.dataclass
class Violation:
    contract: str
    clause: str
    message: str

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        return f"[{self.contract}] {self.clause}: {self.message}"


@dataclasses.dataclass
class ContractReport:
    """Result of checking one case against its contract."""

    case: str
    contract: str
    violations: List[Violation]
    op_histogram: Dict[str, int]
    alias_entries: int
    donated_leaves: int

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        status = "OK" if self.ok else f"FAIL ({len(self.violations)})"
        return f"{self.case:<40s} {status}"

    def to_dict(self) -> Dict[str, Any]:
        return {
            "case": self.case,
            "contract": self.contract,
            "ok": self.ok,
            "violations": [dataclasses.asdict(v) for v in self.violations],
            "op_histogram": dict(self.op_histogram),
            "alias_entries": self.alias_entries,
            "donated_leaves": self.donated_leaves,
        }


def check_hlo(
    contract: HotpathContract,
    hlo_text: str,
    *,
    donated_leaves: int = 0,
) -> List[Violation]:
    """Run every static clause of ``contract`` against optimized HLO text."""
    out: List[Violation] = []

    def add(clause: str, message: str) -> None:
        out.append(Violation(contract.name, clause, message))

    if contract.no_collectives:
        hits = hlo.collective_lines(hlo_text)
        if hits:
            add(
                "no_collectives",
                f"{len(hits)} collective op line(s), e.g. {hits[0].strip()!r}",
            )
    if contract.no_host_transfers:
        hits = hlo.host_transfer_lines(hlo_text)
        if hits:
            add(
                "no_host_transfers",
                f"{len(hits)} host-transfer line(s), e.g. {hits[0].strip()!r}",
            )
    dtype_hits = hlo.dtype_violation_lines(hlo_text, contract.max_dtype)
    if dtype_hits:
        add(
            "max_dtype",
            f"{len(dtype_hits)} line(s) exceed {contract.max_dtype}, "
            f"e.g. {dtype_hits[0].strip()!r}",
        )

    histogram = hlo.op_histogram(hlo_text)
    for op in contract.forbid_ops:
        n = histogram.get(op, 0)
        if n:
            add("forbid_ops", f"forbidden op {op!r} appears {n} time(s)")
    for op, budget in contract.op_budget.items():
        n = histogram.get(op, 0)
        if n > budget:
            add("op_budget", f"op {op!r} appears {n} time(s), budget {budget}")

    if donated_leaves:
        entries = hlo.alias_count(hlo_text)
        if entries < donated_leaves:
            add(
                "donation",
                f"only {entries}/{donated_leaves} donated leaves aliased in "
                "the compiled module (donation dropped at compile time)",
            )
    return out


def _donated_leaves_deleted(leaves: Sequence[Any]) -> Tuple[int, int]:
    """(deleted, total) across donated argument leaves after execution."""
    deleted = 0
    total = 0
    for leaf in leaves:
        if isinstance(leaf, jax.Array):
            total += 1
            if leaf.is_deleted():
                deleted += 1
    return deleted, total


def run_donation_probe(
    contract_name: str,
    fn: Callable[..., Any],
    args: Tuple[Any, ...],
    kwargs: Mapping[str, Any],
    donated_args: Sequence[Any],
) -> List[Violation]:
    """Execute ``fn`` once and verify donation really happened.

    Catches the runtime-only failure modes the alias map cannot show:

    * one buffer bound into two donated params -> XLA raises
      ``Attempt to donate the same buffer twice in Execute()``;
    * donation silently rejected -> donated input leaves survive
      (``is_deleted()`` stays False) and the step double-buffers.

    ``args`` must be fresh (not shared with a live pool): a successful
    probe consumes them.
    """
    out: List[Violation] = []
    try:
        result = fn(*args, **kwargs)
        jax.block_until_ready(result)
    except Exception as e:
        # The aliased-input failure surfaces as ValueError or
        # XlaRuntimeError depending on the dispatch path; either way the
        # message names donation ("Attempt to donate the same buffer
        # twice in Execute()").  Anything else is a genuine error.
        if "donat" not in str(e).lower():
            raise
        out.append(
            Violation(
                contract_name,
                "donation",
                f"execution with donated buffers failed: {e}",
            )
        )
        return out
    leaves = jax.tree_util.tree_leaves(list(donated_args))
    deleted, total = _donated_leaves_deleted(leaves)
    if deleted < total:
        out.append(
            Violation(
                contract_name,
                "donation",
                f"only {deleted}/{total} donated input leaves were consumed; "
                "donation was rejected at run time",
            )
        )
    return out


def check_case(case: "ContractCase") -> ContractReport:  # noqa: F821
    """Lower, compile and check one registered case end to end."""
    contract = get_contract(case.contract)
    override = getattr(case, "op_budget_override", None)
    if override:
        contract = dataclasses.replace(
            contract, op_budget={**contract.op_budget, **override})
    built = case.build()
    text = hlo.compiled_text(built.fn, *built.args, **built.kwargs)
    donated_leaves = built.donated_leaf_count()
    violations = check_hlo(contract, text, donated_leaves=donated_leaves)
    if donated_leaves and case.run_donation_probe and not violations:
        # Fresh arguments: the probe consumes donated buffers.
        probe = case.build()
        violations.extend(
            run_donation_probe(
                contract.name,
                probe.fn,
                probe.args,
                probe.kwargs,
                probe.donated_args(),
            )
        )
    return ContractReport(
        case=case.name,
        contract=contract.name,
        violations=violations,
        op_histogram=dict(hlo.op_histogram(text)),
        alias_entries=hlo.alias_count(text),
        donated_leaves=donated_leaves,
    )


def check_cases(cases: Sequence["ContractCase"]) -> List[ContractReport]:  # noqa: F821
    return [check_case(c) for c in cases]
