"""Representative lowering cases for the hot-path contracts.

A :class:`ContractCase` binds a registered contract name to a recipe
that builds a jitted callable plus concrete arguments — the same shapes,
engine configuration and pool wiring the serving tests use (hidden=32,
gamma=0.75, m=4, a 4-slot pool, 4-frame chunks) — so the checker
inspects the HLO that actually ships, not a toy.  ``build_cases()``
returns every case runnable on the current device topology; the sharded
``step_chunk`` case appears only when the interpreter was started with
enough emulated devices (``XLA_FLAGS=--xla_force_host_platform_device_count=4``,
as the CI lint job and the sharded subprocess tests do).

The pool-chunk lowering helper here is also the shared replacement for
the ad-hoc ``_lower_chunk_hlo`` helpers that used to live in
``tests/test_observability.py`` and the sharded subprocess script.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import hlo

# Test-scale model constants, matching tests/test_observability.py and
# tests/test_sharded_serving.py so case HLO is the HLO those suites pin.
INPUT_DIM = 20
HIDDEN = 32
CLASSES = 11
GAMMA = 0.75
M = 4
THETA = 0.05
LENS = (5, 9, 3, 12, 1, 7, 8, 2)


@dataclasses.dataclass
class BuiltCase:
    """A jitted callable plus concrete arguments, ready to lower."""

    fn: Any
    args: Tuple[Any, ...]
    kwargs: Dict[str, Any]
    donate_argnums: Tuple[int, ...] = ()

    def donated_args(self) -> List[Any]:
        return [self.args[i] for i in self.donate_argnums]

    def donated_leaf_count(self) -> int:
        return len(jax.tree_util.tree_leaves(self.donated_args()))


@dataclasses.dataclass
class ContractCase:
    """One (contract, representative arguments) pair for the checker.

    ``build`` must return fresh arguments on every call: the donation
    probe executes the function once, consuming the donated buffers.
    ``op_budget_override`` tightens/relaxes the contract's op budgets for
    this case only (e.g. the scatter-route chunk legitimately contains
    the top-k sort that the dense-mirror route must not).
    """

    name: str
    contract: str
    build: Callable[[], BuiltCase]
    run_donation_probe: bool = True
    min_devices: int = 1
    op_budget_override: Mapping[str, int] = dataclasses.field(
        default_factory=dict)


# -- engines (cached: packing is the expensive part) --------------------------


@functools.lru_cache(maxsize=None)
def _engine(spmv_path: str = "auto", use_pallas: bool = False,
            quant: bool = False):
    from repro.core.quantization import QuantConfig
    from repro.models import lstm_am
    from repro.serving import BatchedSpartusEngine, EngineConfig

    cfg = lstm_am.LSTMAMConfig(input_dim=INPUT_DIM, hidden_dim=HIDDEN,
                               n_layers=2, n_classes=CLASSES)
    params = lstm_am.cbtd_prune_stacks(
        lstm_am.init_params(jax.random.key(0), cfg), gamma=GAMMA, m=M)
    ecfg = EngineConfig(theta=THETA, gamma=GAMMA, m=M, capacity_frac=1.0,
                        use_pallas=use_pallas, spmv_path=spmv_path,
                        quant=QuantConfig() if quant else None)
    return BatchedSpartusEngine(params, cfg, ecfg)


def _feats(n: int = 4) -> List[np.ndarray]:
    return [np.asarray(
        jax.random.normal(jax.random.key(800 + i), (t, INPUT_DIM)),
        np.float32) for i, t in enumerate(LENS[:n])]


# -- the pool-chunk recipe (shared with the test suites) ----------------------


def built_pool_chunk(
    engine: Any,
    feats: Sequence[np.ndarray],
    *,
    capacity: int = 4,
    max_frames: int = 16,
    chunk_frames: int = 4,
    n_devices: Optional[int] = None,
    observability: Any = None,
) -> BuiltCase:
    """Admit ``feats`` into a fresh SessionPool and stage the chunk step
    exactly as a serving run would, returning it ready to lower."""
    from repro.serving import StreamRequest
    from repro.serving.scheduler import SessionPool

    kwargs: Dict[str, Any] = {}
    if n_devices is not None:
        kwargs["n_devices"] = n_devices
    if observability is not None:
        kwargs["observability"] = observability
    pool = SessionPool(engine, capacity=capacity, max_frames=max_frames,
                       chunk_frames=chunk_frames, **kwargs)
    for i in range(capacity):
        pool.admit(StreamRequest(100 + i, 0, feats[i % len(feats)]), 0)
    pool._reap_cancelled()
    active, reset = pool._masks()
    pool._flush_uploads()
    return BuiltCase(
        fn=engine._step_chunk,
        args=(pool.state, pool._frames, pool._lengths, pool._dev1d(active),
              pool._dev1d(reset), pool._out),
        kwargs={"n_frames": chunk_frames},
        donate_argnums=(0, 5),
    )


def lower_pool_chunk(
    engine: Any,
    feats: Sequence[np.ndarray],
    observability: Any = None,
    *,
    capacity: int = 4,
    max_frames: int = 16,
    chunk_frames: int = 4,
    n_devices: Optional[int] = None,
) -> str:
    """Optimized HLO text of the pool's compiled chunk step.

    This is the shared form of the ``_lower_chunk_hlo`` helper the
    observability tests and the sharded-serving subprocess pin both use.
    """
    built = built_pool_chunk(
        engine, feats, capacity=capacity, max_frames=max_frames,
        chunk_frames=chunk_frames, n_devices=n_devices,
        observability=observability)
    return hlo.compiled_text(built.fn, *built.args, **built.kwargs)


# -- per-contract case builders ----------------------------------------------


def _built_step_frames() -> BuiltCase:
    engine = _engine()
    state = engine.init_state(4)
    frames = jax.random.normal(jax.random.key(3), (4, 8, INPUT_DIM),
                               jnp.float32)
    active = jnp.ones((4,), bool)
    reset = jnp.zeros((4,), bool)
    return BuiltCase(fn=engine._step_frames,
                     args=(state, frames, active, reset),
                     kwargs={}, donate_argnums=(0,))


def _built_step_chunk(spmv_path: str, quant: bool = False) -> BuiltCase:
    return built_pool_chunk(_engine(spmv_path, quant=quant), _feats())


def _built_step_chunk_sharded() -> BuiltCase:
    return built_pool_chunk(_engine(), _feats(8), capacity=8, n_devices=4)


def _built_step_chunk_restored() -> BuiltCase:
    """The chunk step as staged by a pool REBUILT from a checkpoint — the
    watchdog-recovery / preemption-resume path (serving/checkpoint.py).

    Restore is host-side assembly plus the standard upload wave, so the
    dispatch a restored pool stages must be the very same compiled
    ``_step_chunk`` with the same shapes, donation and op budgets as a
    fresh pool's — zero ops added by having been through a checkpoint."""
    from repro.serving import StreamRequest
    from repro.serving import checkpoint as ckptlib
    from repro.serving.scheduler import SessionPool

    engine = _engine()
    feats = _feats()
    pool = SessionPool(engine, capacity=4, max_frames=16, chunk_frames=4)
    for i in range(4):
        pool.admit(StreamRequest(100 + i, 0, feats[i]), 0)
    pool.step_chunk(0)                      # mid-flight recurrent state
    ckpt = ckptlib.snapshot_pool(pool)
    pool2 = SessionPool(engine, capacity=4, max_frames=16, chunk_frames=4)
    ckptlib.restore_into(pool2, ckpt)
    pool2._reap_cancelled()
    active, reset = pool2._masks()
    pool2._flush_uploads()
    return BuiltCase(
        fn=engine._step_chunk,
        args=(pool2.state, pool2._frames, pool2._lengths,
              pool2._dev1d(active), pool2._dev1d(reset), pool2._out),
        kwargs={"n_frames": 4},
        donate_argnums=(0, 5),
    )


def _spmv_args(spmv_path: str, quant: bool = False) -> Tuple[Any, ...]:
    layer = _engine(spmv_path, quant=quant).layers[0]
    k = layer.capacity
    idx = jnp.tile(jnp.arange(k, dtype=jnp.int32) %
                   (layer.input_dim + layer.hidden_dim), (4, 1))
    vals = jax.random.normal(jax.random.key(5), (4, k), jnp.float32)
    return layer, idx, vals


def _built_spmv_scatter(use_pallas: bool, quant: bool = False) -> BuiltCase:
    from repro.kernels import ops

    layer, idx, vals = _spmv_args("scatter", quant=quant)
    kwargs: Dict[str, Any] = {"s": layer.enc.s, "use_pallas": use_pallas}
    if quant:
        kwargs["scale"] = layer.scale   # int8 payload + epilogue dequant
    return BuiltCase(
        fn=ops.stsp_spmv_batch,
        args=(layer.enc.val, layer.enc.lidx, idx, vals),
        kwargs=kwargs,
        donate_argnums=(),
    )


def _built_spmv_dense(quant: bool = False) -> BuiltCase:
    from repro.kernels import ops

    layer, _, _ = _spmv_args("dense", quant=quant)
    delta = jax.random.normal(jax.random.key(7),
                              (4, layer.w_dense_t.shape[0]), jnp.float32)
    kwargs: Dict[str, Any] = {"capacity": layer.capacity}
    if quant:
        kwargs["scale"] = layer.scale
    return BuiltCase(
        fn=ops.delta_spmv_dense_topk_batch,
        args=(layer.w_dense_t, delta),
        kwargs=kwargs,
        donate_argnums=(),
    )


def _built_fold_totals() -> BuiltCase:
    engine = _engine()
    return BuiltCase(fn=engine._tel_totals,
                     args=(engine.init_state(4).telemetry,),
                     kwargs={}, donate_argnums=())


def _built_bank_rows() -> BuiltCase:
    from repro.kernels import ops

    buf = jnp.zeros((4, 16, CLASSES), jnp.float32)
    rows = jax.random.normal(jax.random.key(9), (4, 4, CLASSES), jnp.float32)
    start = jnp.asarray([0, 4, 8, 2], jnp.int32)
    return BuiltCase(fn=jax.jit(ops.bank_rows), args=(buf, rows, start),
                     kwargs={}, donate_argnums=())


def _built_gather_rows() -> BuiltCase:
    from repro.kernels import ops

    buf = jax.random.normal(jax.random.key(11), (4, 16, CLASSES), jnp.float32)
    start = jnp.asarray([0, 4, 8, 2], jnp.int32)
    return BuiltCase(fn=jax.jit(ops.gather_rows, static_argnames=("n",)),
                     args=(buf, start), kwargs={"n": 4}, donate_argnums=())


def _built_gather_frames() -> BuiltCase:
    from repro.kernels import ops

    frames = jax.random.normal(jax.random.key(13), (4, 8, INPUT_DIM),
                               jnp.float32)
    cursor = jnp.asarray([0, 3, 7, 2], jnp.int32)
    return BuiltCase(fn=jax.jit(ops.gather_frames), args=(frames, cursor),
                     kwargs={}, donate_argnums=())


def build_cases(*, include_sharded: Optional[bool] = None) -> List[ContractCase]:
    """Every contract case runnable on the current device topology.

    Importing the annotated modules registers the contracts themselves,
    so do that before any lookup.
    """
    from repro.kernels import ops  # noqa: F401  (registers contracts)
    from repro.serving import batched_engine, telemetry  # noqa: F401

    if include_sharded is None:
        include_sharded = jax.device_count() >= 4
    cases = [
        ContractCase("step_frames/unsharded", "step_frames",
                     _built_step_frames),
        ContractCase("step_chunk/dense-mirror", "step_chunk",
                     lambda: _built_step_chunk("auto"),
                     op_budget_override={"sort": 0}),
        ContractCase("step_chunk/scatter", "step_chunk",
                     lambda: _built_step_chunk("scatter")),
        ContractCase("step_chunk/post-restore", "step_chunk",
                     _built_step_chunk_restored,
                     op_budget_override={"sort": 0}),
        ContractCase("stsp_spmv_batch/xla-scatter", "stsp_spmv_batch",
                     lambda: _built_spmv_scatter(False)),
        ContractCase("stsp_spmv_batch/pallas", "stsp_spmv_batch",
                     lambda: _built_spmv_scatter(True)),
        ContractCase("stsp_spmv_batch/dense-mirror", "delta_spmv_dense_topk",
                     _built_spmv_dense),
        # quantized builds of the same hot paths: int8 weight payloads with
        # the scale-epilogue dequant must honour every fp32 clause —
        # donation, zero collectives, op budgets (docs/quantization.md):
        ContractCase("step_chunk/quant-int8", "step_chunk",
                     lambda: _built_step_chunk("auto", quant=True),
                     op_budget_override={"sort": 0}),
        ContractCase("stsp_spmv_batch/quant-scatter", "stsp_spmv_batch",
                     lambda: _built_spmv_scatter(False, quant=True)),
        ContractCase("stsp_spmv_batch/quant-dense-mirror",
                     "delta_spmv_dense_topk",
                     lambda: _built_spmv_dense(quant=True)),
        ContractCase("fold_totals", "fold_totals", _built_fold_totals),
        ContractCase("bank_rows", "bank_rows", _built_bank_rows),
        ContractCase("gather_rows", "gather_rows", _built_gather_rows),
        ContractCase("gather_frames", "gather_frames", _built_gather_frames),
    ]
    if include_sharded:
        cases.append(
            ContractCase("step_chunk/sharded-4dev", "step_chunk",
                         _built_step_chunk_sharded, min_devices=4))
    return cases
