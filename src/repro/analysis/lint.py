"""Repo-specific AST lint rules.

Each rule encodes a bug this repo has already paid for; the docstring of
every rule names the incident.  The pass is deliberately shallow — plain
``ast`` walks, no type inference — because each rule targets one
syntactic shape with a known safe alternative.  False positives are
silenced in place with a pragma comment on the offending line (or the
line above)::

    x = buf.at[i].set(v)  # lint: allow(eager-scatter) staged upload, outside jit

Run via ``python -m tools.lint --ast`` or the ``tests/test_contracts.py``
suite; both lint every ``.py`` file under ``src/`` and ``tools/``.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

_PRAGMA_RE = re.compile(r"lint:\s*allow\(([a-z0-9\-,\s]+)\)")

# Attribute roots that mark a call as "array construction" for the
# aliased-donation rule: one buffer built once and bound into several
# donated fields rejects donation at run time.
_ALLOC_FNS = {"zeros", "ones", "full", "empty", "zeros_like", "ones_like",
              "full_like", "empty_like"}

# Calls that force a device->host sync when applied to device values.
_BLOCKING_ATTRS = {"block_until_ready", "device_get", "asarray", "item"}

_WALLCLOCK_ATTRS = {"time", "perf_counter", "monotonic", "process_time"}


@dataclasses.dataclass
class LintFinding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class Rule:
    name: str
    doc: str
    applies_to: Callable[[str], bool]
    check: Callable[[ast.AST, str], List["_RawHit"]]


@dataclasses.dataclass
class _RawHit:
    line: int
    message: str


def _attr_name(node: ast.AST) -> Optional[str]:
    """Trailing attribute/function name of a call target, if any."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_jit_decorated(fn: ast.AST) -> bool:
    """True if any decorator mentions ``jit`` (covers ``@jax.jit``,
    ``@functools.partial(jax.jit, ...)`` and bare ``@jit``)."""
    for deco in getattr(fn, "decorator_list", ()):
        for node in ast.walk(deco):
            name = _attr_name(node)
            if name == "jit":
                return True
    return False


def _has_contract_decorator(fn: ast.AST) -> bool:
    for deco in getattr(fn, "decorator_list", ()):
        for node in ast.walk(deco):
            if _attr_name(node) == "hotpath_contract":
                return True
    return False


def _enclosing_functions(tree: ast.AST) -> Dict[ast.AST, Optional[ast.AST]]:
    """Map every node to its innermost enclosing function def (or None)."""
    parent: Dict[ast.AST, Optional[ast.AST]] = {}

    def visit(node: ast.AST, fn: Optional[ast.AST]) -> None:
        parent[node] = fn
        inner = node if isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)) else fn
        for child in ast.iter_child_nodes(node):
            visit(child, inner)

    visit(tree, None)
    return parent


# -- rule: iota-gather --------------------------------------------------------


def _check_iota_gather(tree: ast.AST, src: str) -> List[_RawHit]:
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Subscript):
            continue
        # `.at[...]` updates are the scatter API, not a gather.
        if isinstance(node.value, ast.Attribute) and node.value.attr == "at":
            continue
        sl = node.slice
        elts = sl.elts if isinstance(sl, ast.Tuple) else [sl]
        for e in elts:
            if isinstance(e, ast.Call) and _attr_name(e.func) == "arange":
                hits.append(_RawHit(
                    node.lineno,
                    "batch-iota advanced indexing (`x[arange(B), i]`); use "
                    "`jnp.take_along_axis` — the iota form made GSPMD "
                    "insert an all-gather + all-reduce per scan iteration "
                    "on the sharded pool (see ops.gather_frames)"))
                break
    return hits


# -- rule: eager-scatter ------------------------------------------------------


def _check_eager_scatter(tree: ast.AST, src: str) -> List[_RawHit]:
    hits = []
    enclosing = _enclosing_functions(tree)
    for node in ast.walk(tree):
        # shape: <expr>.at[...].set(...) / .add(...) / ...
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("set", "add", "mul", "min", "max",
                                       "divide", "power")
                and isinstance(node.func.value, ast.Subscript)
                and isinstance(node.func.value.value, ast.Attribute)
                and node.func.value.value.attr == "at"):
            continue
        fn = enclosing.get(node)
        while isinstance(fn, ast.Lambda):
            fn = enclosing.get(fn)
        if fn is not None and (_is_jit_decorated(fn)
                               or _has_contract_decorator(fn)):
            continue
        hits.append(_RawHit(
            node.lineno,
            f"`.at[].{node.func.attr}` in a function without a jit "
            "decorator: eager functional updates copy the whole buffer "
            "per call on the serving host path; move it under jit or "
            "mark the staging intent with a pragma"))
    return hits


# -- rule: aliased-donation ---------------------------------------------------


def _check_aliased_donation(tree: ast.AST, src: str) -> List[_RawHit]:
    """One array literal bound into multiple args of one constructor call.

    The init_telemetry bug: ``z = jnp.zeros(...)`` passed as all three
    TelemetryState fields made XLA reject donation of the whole state at
    run time ("attempt to donate the same buffer twice")."""
    hits = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        alloc_vars: Set[str] = set()
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)
                    and _attr_name(node.value.func) in _ALLOC_FNS):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        alloc_vars.add(tgt.id)
        if not alloc_vars:
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            uses: Dict[str, int] = {}
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name) and arg.id in alloc_vars:
                    uses[arg.id] = uses.get(arg.id, 0) + 1
            for var, n in uses.items():
                if n >= 2:
                    hits.append(_RawHit(
                        node.lineno,
                        f"array buffer {var!r} bound into {n} fields of one "
                        "call: a pytree whose leaves share a buffer rejects "
                        "donation at run time (the init_telemetry bug); "
                        "allocate one buffer per field"))
    return hits


# -- rule: blocking-in-driver -------------------------------------------------


def _check_blocking_in_driver(tree: ast.AST, src: str) -> List[_RawHit]:
    """Sync points inside async driver coroutines.

    The async front-end overlaps host scheduling with device compute;
    one ``block_until_ready``/``np.asarray``/``float(device_val)`` in a
    coroutine serialises the whole event loop against the device."""
    hits = []
    enclosing = _enclosing_functions(tree)

    def innermost_def(node: ast.AST) -> Optional[ast.AST]:
        fn = enclosing.get(node)
        while isinstance(fn, ast.Lambda):
            fn = enclosing.get(fn)
        return fn

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = innermost_def(node)
        if not isinstance(fn, ast.AsyncFunctionDef):
            continue
        name = _attr_name(node.func)
        if name in _BLOCKING_ATTRS:
            hits.append(_RawHit(
                node.lineno,
                f"`{name}` inside coroutine `{fn.name}`: host-syncs the "
                "event loop against the device; dispatch instead and fetch "
                "via the boundary snapshot path (or run in an executor)"))
        elif (isinstance(node.func, ast.Name) and node.func.id == "float"
              and node.args
              and isinstance(node.args[0], (ast.Subscript, ast.Attribute,
                                            ast.Call))):
            hits.append(_RawHit(
                node.lineno,
                f"`float(...)` on a computed value inside coroutine "
                f"`{fn.name}`: if the operand is a device array this is a "
                "hidden blocking transfer; fetch at chunk boundaries"))
    return hits


# -- rule: wallclock-in-jit ---------------------------------------------------


def _check_wallclock_in_jit(tree: ast.AST, src: str) -> List[_RawHit]:
    """``time.time()`` (and friends) reachable from traced code.

    Wall-clock reads inside a traced function execute once at trace time
    and bake a constant into the compiled step — timing must live on the
    host side of the dispatch boundary (see serving/observability.py)."""
    fns: Dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fns.setdefault(node.name, node)

    def wallclock_hits(fn: ast.AST) -> List[_RawHit]:
        out = []
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _WALLCLOCK_ATTRS
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id in ("time", "datetime")):
                out.append(_RawHit(
                    node.lineno,
                    f"`time.{node.func.attr}()` reachable from traced code "
                    "(baked in as a trace-time constant); time on the host "
                    "side of the dispatch boundary instead"))
        return out

    def callees(fn: ast.AST) -> Iterable[str]:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = _attr_name(node.func)
                if name in fns:
                    yield name

    hits = []
    roots = [fn for fn in fns.values()
             if _is_jit_decorated(fn) or _has_contract_decorator(fn)]
    seen: Set[str] = set()
    frontier = list(roots)
    while frontier:
        fn = frontier.pop()
        if fn.name in seen:
            continue
        seen.add(fn.name)
        hits.extend(wallclock_hits(fn))
        frontier.extend(fns[c] for c in callees(fn) if c not in seen)
    return hits


def _under(*parts: str) -> Callable[[str], bool]:
    def pred(path: str) -> bool:
        p = path.replace("\\", "/")
        return any(part in p for part in parts)
    return pred


RULES: List[Rule] = [
    Rule("iota-gather", _check_iota_gather.__doc__ or "",
         _under("src/", "tools/"), _check_iota_gather),
    Rule("eager-scatter", _check_eager_scatter.__doc__ or "",
         _under("src/repro/serving/"), _check_eager_scatter),
    Rule("aliased-donation", _check_aliased_donation.__doc__ or "",
         _under("src/", "tools/"), _check_aliased_donation),
    Rule("blocking-in-driver", _check_blocking_in_driver.__doc__ or "",
         _under("src/repro/serving/async_server.py",
                "src/repro/serving/scheduler.py"),
         _check_blocking_in_driver),
    Rule("wallclock-in-jit", _check_wallclock_in_jit.__doc__ or "",
         _under("src/", "tools/"), _check_wallclock_in_jit),
]

RULE_NAMES = tuple(r.name for r in RULES)


def _allowed_rules(src_lines: Sequence[str], line: int) -> Set[str]:
    """Pragma rules in force at 1-indexed ``line`` (same line or above)."""
    allowed: Set[str] = set()
    for ln in (line, line - 1):
        if 1 <= ln <= len(src_lines):
            m = _PRAGMA_RE.search(src_lines[ln - 1])
            if m:
                allowed.update(s.strip() for s in m.group(1).split(","))
    return allowed


def lint_source(src: str, path: str,
                rules: Optional[Sequence[Rule]] = None) -> List[LintFinding]:
    """Lint one source string as if it lived at ``path``."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [LintFinding(path, e.lineno or 0, "syntax",
                            f"unparseable: {e.msg}")]
    src_lines = src.splitlines()
    findings = []
    for rule in (RULES if rules is None else rules):
        if not rule.applies_to(path):
            continue
        for hit in rule.check(tree, src):
            if rule.name in _allowed_rules(src_lines, hit.line):
                continue
            findings.append(LintFinding(path, hit.line, rule.name,
                                        hit.message))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_paths(paths: Iterable[Path],
               root: Optional[Path] = None) -> List[LintFinding]:
    findings = []
    for p in paths:
        rel = str(p.relative_to(root)) if root else str(p)
        findings.extend(lint_source(p.read_text(), rel))
    return findings


def repo_files(root: Path) -> List[Path]:
    """The files the repo lints: every .py under src/ and tools/."""
    out: List[Path] = []
    for sub in ("src", "tools"):
        base = root / sub
        if base.is_dir():
            out.extend(sorted(base.rglob("*.py")))
    return out


def lint_repo(root: Path) -> List[LintFinding]:
    return lint_paths(repo_files(root), root=root)
