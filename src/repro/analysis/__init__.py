"""Static analysis for the serving hot paths.

Two layers (see docs/contracts.md):

* ``contracts``/``cases``/``hlo`` — compile-time contract checking: each
  hot-path function declares its invariants with ``@hotpath_contract``;
  ``ContractCase``s lower it under representative shapes and the checker
  asserts the optimized HLO (no collectives, no host transfers, donation
  honoured, f32 ceiling, op budgets).
* ``lint`` — repo-specific AST rules encoding bugs already paid for
  (iota-gather, eager-scatter, aliased-donation, blocking-in-driver,
  wallclock-in-jit).
* ``concurrency``/``lockorder`` — the concurrency analyzer
  (docs/concurrency.md): a static guarded-by/lockset pass over the
  ``_guarded_by_`` class tables plus the await-under-lock rule, and a
  runtime lock-order recorder (acquisition-graph cycle = potential
  deadlock, per-lock hold times) the chaos job and the stress tests
  install via ``lockorder.install``.

CLI: ``python -m tools.lint --contracts --ast --concurrency``.
"""
from .contracts import (  # noqa: F401
    ContractReport,
    HotpathContract,
    Violation,
    check_case,
    check_cases,
    check_hlo,
    get_contract,
    hotpath_contract,
    registered_contracts,
    run_donation_probe,
)
from .lint import (  # noqa: F401
    LintFinding,
    RULES,
    RULE_NAMES,
    lint_repo,
    lint_source,
)
from .concurrency import (  # noqa: F401
    CONCURRENCY_RULE_NAMES,
    check_repo as check_concurrency_repo,
    check_source as check_concurrency_source,
)
from .lockorder import (  # noqa: F401
    InstrumentedLock,
    LockOrderRecorder,
    make_lock,
)
from . import hlo  # noqa: F401

__all__ = [
    "ContractReport",
    "HotpathContract",
    "Violation",
    "check_case",
    "check_cases",
    "check_hlo",
    "get_contract",
    "hotpath_contract",
    "registered_contracts",
    "run_donation_probe",
    "LintFinding",
    "RULES",
    "RULE_NAMES",
    "lint_repo",
    "lint_source",
    "CONCURRENCY_RULE_NAMES",
    "check_concurrency_repo",
    "check_concurrency_source",
    "InstrumentedLock",
    "LockOrderRecorder",
    "make_lock",
    "hlo",
]
