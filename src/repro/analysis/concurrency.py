"""Static guarded-by/lockset checking: the compile-time half of the
concurrency analyzer (runtime half: `repro.analysis.lockorder`).

Two of the last four PRs shipped fixes for real cross-thread races in the
serving stack — the ``measured_sparsity`` donated-buffer fetch (an
offloaded tick donates ``SessionPool.state`` out from under an admin
scrape) and the metrics-registry torn reads.  Both had the same shape:
a field whose lock discipline lived in a comment, and one reader that
never got the memo.  This pass makes the discipline a declaration the
linter enforces:

* a class states its guarded fields ONCE, in a class-body table::

      class SessionPool:
          _guarded_by_ = {"state": "_state_lock", "_out": "_state_lock"}

* the analyzer walks every method of the class and tracks the *lock
  context* of each ``self.<field>`` read/write: lexically inside a
  ``with self.<lock>:`` block (multi-item withs count), or inside a
  helper method whose every intra-class call site holds the lock
  (resolved ONE call hop deep, the same shallow resolution the
  wallclock-in-jit rule uses — deliberate: a chain the analyzer cannot
  follow is a chain a reviewer cannot follow either);
* ``__init__`` is exempt (the object is not shared until construction
  returns);
* audited exceptions are silenced in place with the shared pragma
  (`repro.analysis.lint` syntax)::

      n = len(self._pending)  # lint: allow(guarded-by) driver-thread-only

A second rule, **await-under-lock**, flags an ``await`` lexically inside
a ``with self.<...lock...>:`` block of an ``async def`` in ``serving/``:
parking a coroutine while holding a lock the tick worker needs stalls
the whole pool for the await's duration (and inverts lock/loop ordering
— the dynamic recorder measures the same hazard as hold times).

Like `repro.analysis.lint`, this is a deliberately shallow ``ast`` walk
— no aliasing, no cross-class tracking (``checkpoint.py`` taking
``pool._state_lock`` around ``pool.state`` reads is audited by the
concurrency stress test, not this pass).  CLI:
``python -m tools.lint --concurrency``.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional, Tuple

from .lint import LintFinding, _RawHit, _allowed_rules, _under, repo_files

__all__ = [
    "CONCURRENCY_RULE_NAMES",
    "GUARD_TABLE_NAME",
    "check_repo",
    "check_source",
]

#: the class-body declaration the guarded-by pass keys on.
GUARD_TABLE_NAME = "_guarded_by_"

CONCURRENCY_RULE_NAMES = ("guarded-by", "await-under-lock")

#: methods whose body runs before/after the object is shared.
_EXEMPT_METHODS = frozenset({"__init__", "__del__"})


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.<attr>`` -> attr name, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _guard_table(cls: ast.ClassDef) -> Tuple[Optional[Dict[str, str]],
                                             List[_RawHit]]:
    """Parse the class's ``_guarded_by_`` literal; (None, []) if absent."""
    for stmt in cls.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target]
        if not any(isinstance(t, ast.Name) and t.id == GUARD_TABLE_NAME
                   for t in targets):
            continue
        try:
            table = ast.literal_eval(stmt.value)
        except (ValueError, SyntaxError):
            table = None
        if (not isinstance(table, dict)
                or not all(isinstance(k, str) and isinstance(v, str)
                           for k, v in table.items())):
            return None, [_RawHit(
                stmt.lineno,
                f"class {cls.name}: {GUARD_TABLE_NAME} must be a literal "
                "{field: lock_attr} dict of strings (the analyzer reads "
                "it with ast.literal_eval)")]
        return table, []
    return None, []


class _AccessCollector:
    """Walk one method, tracking the set of self-locks lexically held."""

    def __init__(self, locks: FrozenSet[str]):
        self.locks = locks
        # (node, field, held, is_write) for self.<field> accesses:
        self.accesses: List[Tuple[ast.AST, str, FrozenSet[str], bool]] = []
        # (node, held) for every intra-class self.<meth>() call site:
        self.calls: List[Tuple[str, FrozenSet[str]]] = []
        # await nodes with >= 1 self-lock held:
        self.awaits_under_lock: List[Tuple[ast.AST, FrozenSet[str]]] = []

    def visit(self, node: ast.AST, held: FrozenSet[str]) -> None:
        if isinstance(node, (ast.With, ast.AsyncWith)):
            acquired = set()
            for item in node.items:
                name = _self_attr(item.context_expr)
                if name in self.locks:
                    acquired.add(name)
                self.visit(item.context_expr, held)
            inner = held | frozenset(acquired)
            for child in node.body:
                self.visit(child, inner)
            return
        attr = _self_attr(node)
        if attr is not None:
            self.accesses.append(
                (node, attr, held,
                 isinstance(node.ctx, (ast.Store, ast.Del))))
        if (isinstance(node, ast.Call)
                and (callee := _self_attr(node.func)) is not None):
            self.calls.append((callee, held))
        if isinstance(node, ast.Await):
            if held:
                self.awaits_under_lock.append((node, held))
        for child in ast.iter_child_nodes(node):
            self.visit(child, held)


def _check_guarded_by(tree: ast.AST, src: str) -> List[_RawHit]:
    hits: List[_RawHit] = []
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        table, bad = _guard_table(cls)
        hits.extend(bad)
        if not table:
            continue
        locks = frozenset(table.values())
        methods = [m for m in cls.body
                   if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))]
        per_method: Dict[str, _AccessCollector] = {}
        for m in methods:
            col = _AccessCollector(locks)
            for stmt in m.body:
                col.visit(stmt, frozenset())
            per_method[m.name] = col
        # one-hop call-site resolution: locks held at EVERY intra-class
        # call site of each method (None = never called intra-class).
        callsite_locks: Dict[str, Optional[FrozenSet[str]]] = {}
        for col in per_method.values():
            for callee, held in col.calls:
                if callee in per_method:
                    prev = callsite_locks.get(callee)
                    callsite_locks[callee] = (held if prev is None
                                              else prev & held)
        for m in methods:
            if m.name in _EXEMPT_METHODS:
                continue
            inherited = callsite_locks.get(m.name) or frozenset()
            for node, field, held, is_write in per_method[m.name].accesses:
                lock = table.get(field)
                if lock is None or lock in held or lock in inherited:
                    continue
                hits.append(_RawHit(
                    node.lineno,
                    f"{'write to' if is_write else 'read of'} "
                    f"`self.{field}` in {cls.name}.{m.name} without "
                    f"holding `self.{lock}` ({GUARD_TABLE_NAME} declares "
                    f"{field!r} guarded by {lock!r}); wrap it in `with "
                    f"self.{lock}:` — or, for an audited single-thread "
                    f"access, annotate `# lint: allow(guarded-by)`"))
    return hits


def _check_await_under_lock(tree: ast.AST, src: str) -> List[_RawHit]:
    hits: List[_RawHit] = []
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.AsyncFunctionDef):
            continue
        locky = frozenset(
            attr for node in ast.walk(fn)
            if isinstance(node, (ast.With, ast.AsyncWith))
            for item in node.items
            if (attr := _self_attr(item.context_expr)) is not None
            and "lock" in attr.lower())
        if not locky:
            continue
        col = _AccessCollector(locky)
        for stmt in fn.body:
            col.visit(stmt, frozenset())
        for node, held in col.awaits_under_lock:
            hits.append(_RawHit(
                node.lineno,
                f"`await` inside `with self.{sorted(held)[0]}:` in "
                f"coroutine `{fn.name}`: parking the event loop while "
                "holding a lock the tick worker contends stalls every "
                "pool thread for the await's duration; release the lock "
                "before awaiting (copy what you need out first)"))
    return hits


_GUARDED_APPLIES = _under("src/", "tools/")
_AWAIT_APPLIES = _under("src/repro/serving/")

_CHECKS = (
    ("guarded-by", _GUARDED_APPLIES, _check_guarded_by),
    ("await-under-lock", _AWAIT_APPLIES, _check_await_under_lock),
)


def check_source(src: str, path: str) -> List[LintFinding]:
    """Run the concurrency rules over one source string at ``path``."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [LintFinding(path, e.lineno or 0, "syntax",
                            f"unparseable: {e.msg}")]
    src_lines = src.splitlines()
    findings: List[LintFinding] = []
    for name, applies, check in _CHECKS:
        if not applies(path):
            continue
        for hit in check(tree, src):
            if name in _allowed_rules(src_lines, hit.line):
                continue
            findings.append(LintFinding(path, hit.line, name, hit.message))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def check_paths(paths, root: Optional[Path] = None) -> List[LintFinding]:
    findings: List[LintFinding] = []
    for p in paths:
        rel = str(p.relative_to(root)) if root else str(p)
        findings.extend(check_source(p.read_text(), rel))
    return findings


def check_repo(root: Path) -> List[LintFinding]:
    """Concurrency rules over every .py under src/ and tools/ (same file
    set as the AST lint layer)."""
    return check_paths(repo_files(root), root=root)
