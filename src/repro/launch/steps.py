"""Distributed train/serve step builders — the functions the dry-run
lowers and the launchers execute.

``q_chunk`` auto-selects for long sequences so 32k prefill never builds an
[S, S] score tile; training always uses per-layer remat (scan-over-layers
checkpointing) — the standard memory policy at these shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ArchConfig, ShapeCell
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def pick_q_chunk(seq_len: int) -> int:
    if seq_len >= 32768:
        return 512
    if seq_len >= 4096:
        return 1024
    return 0


import os


def pick_microbatches(cfg: ArchConfig, cell) -> int:
    """Gradient-accumulation factor: bound per-device activation memory.

    Base heuristic: one microbatch per ~2 GiB of (layers x B x S x d) bf16
    checkpoint volume at 256-way sharding.  Family factors account for
    state that the residual-checkpoint estimate misses: fp32 recurrence
    coefficients under associative_scan (hybrid), encoder+decoder dual
    stacks with cross-attention (audio), dispatch buffers (moe) —
    calibrated against measured compile peaks (EXPERIMENTS.md §Dry-run)."""
    if os.environ.get("REPRO_MICROBATCHES"):
        return int(os.environ["REPRO_MICROBATCHES"])
    # audio: encoder activations + cross-attention scores all scale with
    # the (huge) frame sequence — measured 25.9 GiB at n_mb=1, 6.2 at 8
    factor = {"hybrid": 4.0, "audio": 64.0, "moe": 16.0}.get(cfg.family, 1.0)
    ckpt_bytes = (2 * cfg.n_layers * cell.global_batch * cell.seq_len
                  * cfg.d_model * factor)
    per_dev = ckpt_bytes / 256
    n_mb = 1
    while per_dev / n_mb > 2 * 1024**3 and n_mb < cell.global_batch:
        n_mb *= 2
    return n_mb


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig, seq_len: int,
                    remat: bool = True, microbatches: int = 1):
    q_chunk = pick_q_chunk(seq_len)

    def loss_fn(params, batch):
        return api.train_loss(params, cfg, batch, q_chunk=q_chunk, remat=remat)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def split(leaf):
                b = leaf.shape[0]
                return leaf.reshape((microbatches, b // microbatches)
                                    + leaf.shape[1:])
            mbs = jax.tree.map(split, batch)

            def mb_body(acc, mb):
                loss_acc, g_acc = acc
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(
                mb_body, (jnp.zeros((), jnp.float32), zeros), mbs
            )
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics}

    return train_step


def _is_weight(leaf) -> bool:
    return (hasattr(leaf, "ndim") and leaf.ndim >= 2
            and jnp.issubdtype(leaf.dtype, jnp.floating))


def quantize_params_abstract(params_abs):
    """Abstract int8 serving tree: {'q': int8 weights (+passthrough),
    'scales': per-weight scalar}.  Mirrors serving/engine.py's int8 export
    for the dense TPU path (perf variant int8_weights)."""
    q = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.int8) if _is_weight(l) else l,
        params_abs,
    )
    scales = jax.tree.map(
        lambda l: (jax.ShapeDtypeStruct((), jnp.float32) if _is_weight(l)
                   else jax.ShapeDtypeStruct((0,), jnp.float32)),
        params_abs,
    )
    return {"q": q, "scales": scales}


def dequantize_params(pq, dtype=jnp.bfloat16):
    def one(q, s):
        if q.dtype == jnp.int8:
            return q.astype(dtype) * s.astype(dtype)
        return q

    return jax.tree.map(one, pq["q"], pq["scales"])


def make_serve_step(cfg: ArchConfig):
    from repro import perf

    if perf.current().int8_weights:
        def serve_step(pq, cache, inputs):
            params = dequantize_params(pq)
            logits, cache = api.serve_step(params, cfg, inputs, cache)
            return logits, cache
    else:
        def serve_step(params, cache, inputs):
            logits, cache = api.serve_step(params, cfg, inputs, cache)
            return logits, cache

    return serve_step


def make_prefill_step(cfg: ArchConfig, seq_len: int):
    q_chunk = pick_q_chunk(seq_len)

    def prefill_step(params, inputs):
        return api.prefill(params, cfg, inputs, q_chunk=q_chunk)

    return prefill_step


# -- abstract state builders (dry-run: no allocation) --------------------------


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.key(0), dtype)
    )


def abstract_opt_state(params_abs):
    return jax.eval_shape(adamw_init, params_abs)


def abstract_cache(cfg: ArchConfig, cell: ShapeCell, dtype=jnp.bfloat16):
    s_cache = cell.seq_len if cell.kind == "decode" else cell.seq_len
    return jax.eval_shape(
        lambda: api.init_cache(cfg, cell.global_batch, s_cache, dtype)
    )


def n_params_of(tree_abs) -> int:
    return sum(int(jnp.prod(jnp.array(l.shape))) if l.shape else 1
               for l in jax.tree.leaves(tree_abs))
