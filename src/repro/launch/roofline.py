"""Roofline-term derivation from a compiled dry-run artifact.

Hardware constants (TPU v5e-class, per assignment):
    197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI

Terms (per device — the compiled SPMD module IS the per-device program):
    compute    = HLO_FLOPs / peak_FLOPs
    memory     = HLO_bytes / HBM_bw
    collective = collective_bytes / link_bw

``collective_bytes`` is parsed from the *post-partitioning* HLO
(``compiled.as_text()``): per instruction we take the result-shape bytes
and apply a ring-model multiplier with the replica-group size n:
    all-gather        r * (n-1)/n       (r = full gathered result)
    reduce-scatter    r * (n-1)         (r = the shard each device keeps)
    all-reduce        2r * (n-1)/n      (RS + AG)
    all-to-all        r * (n-1)/n
    collective-permute r
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Tuple

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[G,n]<=[N]: G groups of size n
        return int(m.group(2))
    return default


def collective_bytes(hlo_text: str, n_devices: int) -> Tuple[float, Dict[str, float]]:
    """Per-device ICI bytes, total + per-collective-kind breakdown."""
    per_kind: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes = m.group(1) or m.group(2)
        kind = m.group(3)
        r = _shape_bytes(shapes)
        n = max(_group_size(line, n_devices), 1)
        if n == 1:
            continue
        if kind == "all-gather":
            b = r * (n - 1) / n
        elif kind == "reduce-scatter":
            b = r * (n - 1)
        elif kind == "all-reduce":
            b = 2 * r * (n - 1) / n
        elif kind == "all-to-all":
            b = r * (n - 1) / n
        else:  # collective-permute
            b = r
        per_kind[kind] = per_kind.get(kind, 0.0) + b
    return sum(per_kind.values()), per_kind


@dataclasses.dataclass
class Roofline:
    flops: float               # per-device HLO flops
    hbm_bytes: float           # per-device bytes accessed
    coll_bytes: float          # per-device ICI bytes
    coll_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: float   # 6ND-style useful flops (whole job)
    useful_ratio: float        # model_flops / (hlo flops * chips)
    n_devices: int

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, n_devices: int, model_flops_total: float) -> Roofline:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    coll, breakdown = collective_bytes(compiled.as_text(), n_devices)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops_total / max(flops * n_devices, 1.0)
    return Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=coll, coll_breakdown=breakdown,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck, model_flops_total=model_flops_total,
        useful_ratio=useful, n_devices=n_devices,
    )


def model_flops(cfg, cell, n_params_nonembed: int) -> float:
    """6ND for training, 2ND for single forward (prefill; the vocab head
    runs on the last position only), 2N*B per decoded token.  MoE uses
    active params (top_k/n_experts of expert weights)."""
    n = n_params_nonembed
    head = 0 if cfg.family == "audio" else cfg.vocab * cfg.d_model
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        if cfg.family == "audio":
            tokens = cell.global_batch * (cell.seq_len + cell.seq_len // 8)
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        body = 2.0 * (n - head) * cell.global_batch * cell.seq_len
        return body + 2.0 * head * cell.global_batch
    # decode: one token per sequence
    return 2.0 * n * cell.global_batch


def active_params(cfg, params_abs) -> int:
    """Matmul-active parameter count: excludes embeddings; scales expert
    weights by top_k/n_experts; counts the lm_head."""
    import jax

    total = 0
    flat = jax.tree_util.tree_flatten_with_path(params_abs)[0]
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        size = 1
        for s in leaf.shape:
            size *= s
        if name.endswith("embed") and not cfg.tie_embeddings:
            continue
        if name.endswith("embed") and cfg.tie_embeddings:
            pass  # used as the head matmul
        if "moe/" in name and ("gate" in name or "up" in name or "down" in name):
            size = size * cfg.top_k // max(cfg.n_experts, 1)
        total += size
    return total
