import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lower one (arch x shape) cell under a named
PerfVariant and record the roofline delta vs baseline.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch granite-34b --shape train_4k --variant fsdp_sp

Artifacts: experiments/perf/{arch}__{shape}__{variant}.json
"""
import argparse
import json

from repro import perf
from repro.launch import dryrun

VARIANTS = {
    "baseline": perf.PerfVariant(name="baseline"),
    # decode: gather-free attention over the seq-sharded KV cache
    "gathered_kv": perf.PerfVariant(name="gathered_kv",
                                    seq_sharded_decode=False),
    # train: drop TP, 2-axis FSDP + sequence parallelism
    "fsdp_sp": perf.PerfVariant(name="fsdp_sp", fsdp_sp=True),
    # train: fsdp_sp with more microbatches (activation/collective trade)
    "fsdp_sp_mb8": perf.PerfVariant(name="fsdp_sp_mb8", fsdp_sp=True,
                                    microbatches=8),
    # train: same 256 chips, wider data axis (halves activation AR bytes)
    "tp8": perf.PerfVariant(name="tp8",
                            mesh_override=((32, 8), ("data", "model"))),
    "tp4": perf.PerfVariant(name="tp4",
                            mesh_override=((64, 4), ("data", "model"))),
    # pure DP + 256-way FSDP: no TP activation all-reduces at all; per-layer
    # full weight gathers instead (napkin: ~200-340 GB/device/step -> ~5-7 s)
    "tp1": perf.PerfVariant(name="tp1",
                            mesh_override=((256, 1), ("data", "model"))),
    # serving quantization
    "int8_weights": perf.PerfVariant(name="int8_weights", int8_weights=True),
}

OUT = os.path.join(os.path.dirname(__file__), "../../../experiments/perf")


def run(arch: str, shape: str, variant_name: str, multi_pod: bool = False):
    v = VARIANTS[variant_name]
    if v.microbatches:
        os.environ["REPRO_MICROBATCHES"] = str(v.microbatches)
    else:
        os.environ.pop("REPRO_MICROBATCHES", None)
    with perf.variant(v):
        rec = dryrun.run_cell(arch, shape, multi_pod, out_dir=os.path.abspath(OUT))
    rec["variant"] = variant_name
    path = os.path.join(os.path.abspath(OUT),
                        f"{arch}__{shape}__{variant_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    args = ap.parse_args()
    rec = run(args.arch, args.shape, args.variant)
    if "roofline" in rec:
        r = rec["roofline"]
        print(f"{args.variant}: compute={r['compute_s']:.3f}s "
              f"mem={r['memory_s']:.3f}s coll={r['collective_s']:.3f}s "
              f"-> {r['bottleneck']}")


if __name__ == "__main__":
    main()
