"""Render the dry-run JSON artifacts into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.summarize [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dir_: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(records: List[Dict], mesh: str) -> str:
    rows = [
        "| arch | shape | kind | status | peak GiB | fits | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "ok":
            m = r["memory"]
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['kind']} | ok | "
                f"{fmt_bytes(m['peak_bytes'])} | "
                f"{'Y' if m['fits_16gb'] else 'NO'} | {r.get('compile_s','')} |"
            )
        else:
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['status']} "
                f"| - | - | - |"
            )
    return "\n".join(rows)


def roofline_table(records: List[Dict]) -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | bottleneck |"
        " useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r["mesh"] != "single" or "roofline" not in r:
            continue
        rf = r["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / dom if dom > 0 else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"{rf['bottleneck']} | {rf['useful_ratio']:.2f} | {frac:.3f} |"
        )
    return "\n".join(rows)


def pick_hillclimb(records: List[Dict]):
    """The three §Perf targets: worst roofline fraction, most collective-
    bound, most representative of the paper's technique (recurrent-state
    serving at scale)."""
    cands = [r for r in records if r["mesh"] == "single" and "roofline" in r]

    def frac(r):
        rf = r["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        return rf["compute_s"] / dom if dom else 0.0

    def coll_ratio(r):
        rf = r["roofline"]
        return rf["collective_s"] / max(rf["compute_s"], 1e-12)

    worst = min(cands, key=frac)
    coll = max(cands, key=coll_ratio)
    return worst, coll


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## Dry-run (single-pod 16x16)\n")
    print(dryrun_table(recs, "single"))
    print("\n## Dry-run (multi-pod 2x16x16)\n")
    print(dryrun_table(recs, "multi"))
    print("\n## Roofline (single-pod, probe-extrapolated)\n")
    print(roofline_table(recs))
    try:
        worst, coll = pick_hillclimb(recs)
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']}")
        print(f"most collective-bound:   {coll['arch']}/{coll['shape']}")
    except ValueError:
        pass


if __name__ == "__main__":
    main()
