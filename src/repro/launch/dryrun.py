import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, prove memory fit, and extract roofline
terms.  MUST be run as its own process (the XLA_FLAGS line above has to
execute before jax initialises — do not import this module from tests).

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k

Methodology note (measured, see EXPERIMENTS.md §Dry-run): XLA's
``cost_analysis`` counts a while-loop body ONCE, so the scanned layer
stack undercounts flops/bytes by ~n_layers.  Each cell therefore runs
  1. the PRODUCTION compile (scan-over-layers): proves sharding coherence
     + per-device memory fit (memory_analysis is per-device);
  2. two reduced-depth UNROLLED cost probes (1 and 2 layer-stacks):
     exact per-layer flops/bytes/collective-bytes by finite difference,
     extrapolated to full depth for the roofline terms.

Artifacts: experiments/dryrun/{arch}__{shape}__{mesh}.json
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, get_arch
from repro.distributed.sharding import batch_specs, cache_specs, param_specs
from repro.launch.mesh import compat_make_mesh, make_production_mesh, mesh_context
from repro.launch import roofline as RL
from repro.launch.steps import (
    abstract_cache, abstract_opt_state, abstract_params, make_prefill_step,
    make_serve_step, make_train_step, n_params_of,
)
from repro.models import api, scan
from repro.models.config import SHAPES, shape_applicable
from repro.training.optimizer import AdamWConfig

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")

HBM_LIMIT_BYTES = 16 * 1024**3  # v5e HBM per chip


def _shardings(mesh, spec_tree):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def _probe_cfgs(cfg) -> Tuple[object, object, int, int, int]:
    """(cfg1, cfg2, L1, L2, L_full) reduced-depth same-width configs."""
    if cfg.family == "audio":
        c1 = dataclasses.replace(cfg, n_enc_layers=1, n_dec_layers=1, n_layers=2)
        c2 = dataclasses.replace(cfg, n_enc_layers=2, n_dec_layers=2, n_layers=4)
        return c1, c2, 2, 4, cfg.n_enc_layers + cfg.n_dec_layers
    if cfg.family == "hybrid":
        u = len(cfg.block_pattern)
        c1 = dataclasses.replace(cfg, n_layers=u)
        c2 = dataclasses.replace(cfg, n_layers=2 * u)
        return c1, c2, u, 2 * u, cfg.n_layers
    c1 = dataclasses.replace(cfg, n_layers=1)
    c2 = dataclasses.replace(cfg, n_layers=2)
    return c1, c2, 1, 2, cfg.n_layers


def _lower_cell(cfg, cell, mesh, *, donate: bool = True):
    """Build + lower the cell's step (abstract args, current scan mode)."""
    params_abs = abstract_params(cfg, jnp.bfloat16)
    p_sh = _shardings(mesh, param_specs(params_abs, mesh, cfg))
    if cell.kind == "train":
        opt_abs = abstract_opt_state(params_abs)
        o_sh = _shardings(mesh, param_specs(opt_abs, mesh, cfg))
        batch_abs = api.input_specs(cfg, cell)
        b_sh = _shardings(mesh, batch_specs(batch_abs, mesh))
        from repro.launch.steps import pick_microbatches
        step = make_train_step(cfg, AdamWConfig(), cell.seq_len,
                               microbatches=pick_microbatches(cfg, cell))
        return jax.jit(
            step, in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1) if donate else (),
        ).lower(params_abs, opt_abs, batch_abs), params_abs
    if cell.kind == "prefill":
        in_abs = api.input_specs(cfg, cell)["inputs"]
        in_sh = _shardings(mesh, batch_specs({"x": in_abs}, mesh))["x"]
        step = make_prefill_step(cfg, cell.seq_len)
        return jax.jit(
            step, in_shardings=(p_sh, in_sh), out_shardings=None
        ).lower(params_abs, in_abs), params_abs
    # decode
    from repro import perf
    from repro.launch.steps import quantize_params_abstract

    cache_abs = abstract_cache(cfg, cell, jnp.bfloat16)
    c_sh = _shardings(mesh, cache_specs(cache_abs, mesh))
    in_abs = api.input_specs(cfg, cell)["inputs"]
    in_sh = _shardings(mesh, batch_specs({"x": in_abs}, mesh))["x"]
    step = make_serve_step(cfg)
    arg0 = params_abs
    a0_sh = p_sh
    if perf.current().int8_weights:
        arg0 = quantize_params_abstract(params_abs)
        a0_sh = {"q": _shardings(mesh, param_specs(arg0["q"], mesh, cfg)),
                 "scales": _shardings(mesh, param_specs(arg0["scales"], mesh, cfg))}
    return jax.jit(
        step, in_shardings=(a0_sh, c_sh, in_sh),
        out_shardings=(None, c_sh), donate_argnums=(1,) if donate else (),
    ).lower(arg0, cache_abs, in_abs), params_abs


def _probe_costs(cfg, cell, mesh, n_dev: int):
    """Unrolled finite-difference probe -> extrapolated per-device
    (flops, hbm_bytes, coll_bytes, coll_breakdown)."""
    c1, c2, l1, l2, l_full = _probe_cfgs(cfg)
    vals = []
    for c in (c1, c2):
        with scan.unrolled():
            lowered, _ = _lower_cell(c, cell, mesh, donate=False)
        compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        coll, breakdown = RL.collective_bytes(compiled.as_text(), n_dev)
        vals.append((float(ca.get("flops", 0.0)),
                     float(ca.get("bytes accessed", 0.0)), coll, breakdown))
    (f1, b1, c1b, bd1), (f2, b2, c2b, bd2) = vals
    per_layer = ((f2 - f1) / (l2 - l1), (b2 - b1) / (l2 - l1),
                 (c2b - c1b) / (l2 - l1))
    extra = l_full - l1
    flops = f1 + per_layer[0] * extra
    hbm = b1 + per_layer[1] * extra
    coll = c1b + per_layer[2] * extra
    kinds = set(bd1) | set(bd2)
    breakdown = {
        k: bd1.get(k, 0.0)
        + (bd2.get(k, 0.0) - bd1.get(k, 0.0)) / (l2 - l1) * extra
        for k in kinds
    }
    return flops, hbm, coll, breakdown


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, verbose: bool = True,
             probe: bool = True) -> dict:
    cfg = get_arch(arch_name)
    cell = {c.name: c for c in SHAPES}[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    record = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "kind": cell.kind, "status": "?",
    }

    ok, reason = shape_applicable(cfg, cell)
    if not ok:
        record.update(status="skipped", reason=reason)
        _emit(record, out_dir, verbose)
        return record

    t0 = time.time()
    from repro import perf
    mo = perf.current().mesh_override
    if mo is not None:
        mesh = compat_make_mesh(mo[0], mo[1])
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size

    with mesh_context(mesh):
        # 1. production compile: sharding + memory proof
        lowered, params_abs = _lower_cell(cfg, cell, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        record["n_params"] = n_params_of(params_abs)

        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        peak = (mem["argument_bytes"] + mem["temp_bytes"]
                + max(mem["output_bytes"] - mem["alias_bytes"], 0))
        mem["peak_bytes"] = int(peak)
        mem["fits_16gb"] = bool(peak < HBM_LIMIT_BYTES)

        # raw (scan-once) costs, kept for reference
        ca = compiled.cost_analysis() or {}
        raw_coll, _ = RL.collective_bytes(compiled.as_text(), n_dev)
        record["raw_scanned_costs"] = {
            "flops": float(ca.get("flops", 0.0)),
            "hbm_bytes": float(ca.get("bytes accessed", 0.0)),
            "coll_bytes": raw_coll,
        }

        # 2. cost probe (unrolled finite difference)
        if probe:
            flops, hbm, coll, breakdown = _probe_costs(cfg, cell, mesh, n_dev)
            n_active = RL.active_params(cfg, params_abs)
            mf = RL.model_flops(cfg, cell, n_active)
            roof = RL.Roofline(
                flops=flops, hbm_bytes=hbm, coll_bytes=coll,
                coll_breakdown=breakdown,
                compute_s=flops / RL.PEAK_FLOPS,
                memory_s=hbm / RL.HBM_BW,
                collective_s=coll / RL.ICI_BW,
                bottleneck="", model_flops_total=mf,
                useful_ratio=mf / max(flops * n_dev, 1.0), n_devices=n_dev,
            )
            terms = {"compute": roof.compute_s, "memory": roof.memory_s,
                     "collective": roof.collective_s}
            roof.bottleneck = max(terms, key=terms.get)
            record["n_active_params"] = n_active
            record["roofline"] = roof.to_dict()

    record.update(status="ok", lower_s=round(t_lower, 1),
                  compile_s=round(t_compile, 1), memory=mem)
    _emit(record, out_dir, verbose)
    return record


def _emit(record: dict, out_dir: Optional[str], verbose: bool):
    out_dir = out_dir or os.path.abspath(OUT_DIR)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    )
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if not verbose:
        return
    if record["status"] == "ok":
        m = record["memory"]
        msg = (f"[dryrun] {record['arch']:24s} {record['shape']:12s} "
               f"{record['mesh']:6s} OK  peak={m['peak_bytes']/2**30:7.2f}GiB"
               f"{'' if m['fits_16gb'] else ' OVER'}")
        if "roofline" in record:
            r = record["roofline"]
            msg += (f" compute={r['compute_s']*1e3:9.2f}ms"
                    f" mem={r['memory_s']*1e3:9.2f}ms"
                    f" coll={r['collective_s']*1e3:9.2f}ms"
                    f" -> {r['bottleneck']}  useful={r['useful_ratio']:.2f}")
        print(msg, flush=True)
    else:
        print(f"[dryrun] {record['arch']:24s} {record['shape']:12s} "
              f"{record['mesh']:6s} {record['status'].upper()}: "
              f"{record.get('reason','')}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the unrolled cost probe (multi-pod pass only "
                         "needs the compile+memory proof)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(REGISTRY)
    shapes = [args.shape] if args.shape else [c.name for c in SHAPES]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, mp, args.out,
                                   probe=not args.no_probe and not mp)
                    if rec["status"] not in ("ok", "skipped"):
                        failures.append((arch, shape, mp))
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mp))
                    _emit({"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "kind": "?", "status": "error",
                           "reason": repr(e)[:500]}, args.out, True)
    if failures:
        print(f"FAILURES: {failures}", flush=True)
        raise SystemExit(1)
    print("dry-run complete: all cells OK", flush=True)


if __name__ == "__main__":
    main()
