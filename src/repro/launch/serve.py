"""Serving launcher: batched decode for any --arch, or the paper's
streaming Spartus engine for the LSTM AM (batch-1, the continuous-batching
session pool with --pool N, or the asyncio streaming front-end with
--async).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 4 --steps 32
    PYTHONPATH=src python -m repro.launch.serve --spartus --theta 0.2
    PYTHONPATH=src python -m repro.launch.serve --spartus --pool 8 --requests 24
    PYTHONPATH=src python -m repro.launch.serve --spartus --pool 8 --quant \
        --requests 24        # int8 weights + Q8.8 activations end-to-end
    PYTHONPATH=src python -m repro.launch.serve --spartus --pool 8 \
        --chunk-frames 32    # chunked device tick loop (1 dispatch / 32 frames)
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --spartus --pool 8 \
        --chunk-frames 32 --devices 4   # slot-sharded pool (2 slots/device)
    PYTHONPATH=src python -m repro.launch.serve --spartus --async --pool 8 \
        --clients 8          # TCP/JSON-lines streaming server + demo clients
    PYTHONPATH=src python -m repro.launch.serve --spartus --async --pool 8 \
        --clients 0 --port 8765   # serve forever on localhost:8765

The --async mode exposes the `AsyncSpartusServer` over a localhost
TCP socket speaking newline-delimited JSON (one object per line):

    client -> {"op": "open",   "id": 0}        # optional "token": "..."
    server -> {"event": "open_ok", "id": 0}
    client -> {"op": "frames", "id": 0, "frames": [[...], ...]}   # [n, D]
    client -> {"op": "close",  "id": 0}        # end of utterance
    client -> {"op": "cancel", "id": 0}        # abandon mid-utterance
    server -> {"event": "partial", "id": 0, "t0": 0, "logits": [[...], ...]}
    server -> {"event": "done", "id": 0, "n_frames": 40,
               "latency_ms": ..., "ttfl_ms": ..., "queue_wait_ms": ...}
    server -> {"event": "cancelled", "id": 0}
    server -> {"event": "error", "id": 0, "code": "...",
               "retriable": false, "message": "..."}

`id` is chosen by the client and scopes to its connection; multiple
streams may be multiplexed over one connection.  Partial logits arrive
per chunk as they are produced (`target_chunk_ms` paces the boundaries);
`done` closes the stream with its latency breakdown.

Every error carries a stable ``code`` and a ``retriable`` flag
(serving/faults.py; catalog in docs/robustness.md) — malformed traffic
(``bad_json`` / ``unknown_op`` / ``no_such_stream`` / ``duplicate_id`` /
``bad_request``) answers in-band and only ever fails the offending
stream; the connection and every other stream stay up.  The one
transport-level violation is a line over ``MAX_LINE_BYTES`` (framing is
lost at that point): the server answers ``line_too_long`` and closes
THAT connection.  Retriable errors (``shed`` under --overload shed,
``timeout`` under --idle-timeout, ``retriable_internal`` after a
watchdog recovery) are retried by the demo client with seeded
full-jitter backoff; ``"token"`` on open makes the retry idempotent
(re-opening a live token returns the same stream instead of
double-admitting).

**Admin surface** (--async): `--admin-port P` opens a second localhost
listener speaking the same JSON-lines convention, read-only, for
operators scraping the live pool (docs/observability.md):

    client -> {"cmd": "healthz"}
    server -> {"ok": true, "uptime_s": ..., "connected": ..., "capacity": ...}
    client -> {"cmd": "stats"}
    server -> {"stats": { ... ServeStats.to_dict() ... }}
    client -> {"cmd": "metrics"}
    server -> {"metrics": {name: {...}}, "prometheus": "<text exposition>"}
    client -> {"cmd": "timeseries", "last": 64}
    server -> {"timeseries": [{...per-chunk sample...}], "n_dropped": 0}

Unknown commands answer ``{"error": "..."}`` in-band; the connection
stays up.  `--stats-interval S` additionally logs a one-line pool-health
summary every S seconds, and `--trace PATH` records the driver's phase
spans (admission-wave upload, dispatch, snapshot D2H fetch, delivery
pump, pacing idle) to a Chrome trace-event JSON on shutdown — load it in
Perfetto or chrome://tracing.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import api
from repro.serving.faults import Backoff, ProtocolError, error_payload

#: JSON-lines framing bound: one message may not exceed this many bytes.
#: Past it the stream's framing is unrecoverable (we cannot know where the
#: runaway line ends a message), so the server answers ``line_too_long``
#: and closes that one connection.
MAX_LINE_BYTES = 1 << 20


def serve_arch(args):
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init_params(cfg, jax.random.key(0))
    cache = api.init_cache(cfg, args.batch, args.ctx)
    step = jax.jit(lambda p, c, t: api.serve_step(p, cfg, t, c))

    if cfg.family == "vlm":
        inputs = jax.random.normal(jax.random.key(1),
                                   (args.batch, 1, cfg.d_model))
    else:
        inputs = jnp.zeros((args.batch, 1), jnp.int32)

    logits, cache = step(params, cache, inputs)  # compile
    jax.block_until_ready(logits)
    t0 = time.time()
    toks = inputs
    for i in range(args.steps):
        logits, cache = step(params, cache, toks)
        if cfg.family != "vlm":
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = (time.time() - t0) / args.steps
    print(f"[serve] {cfg.name}: {args.steps} steps batch={args.batch} "
          f"-> {dt*1e3:.2f} ms/token ({args.batch/dt:.1f} tok/s)")


def serve_spartus(args):
    import numpy as np

    from repro.core.quantization import QuantConfig
    from repro.data.speech import SpeechConfig, SpeechDataset
    from repro.models import lstm_am
    from repro.serving import (
        BatchedSpartusEngine, EngineConfig, SpartusEngine, StreamRequest,
        serve_requests,
    )
    from repro.training.trainer import TrainConfig, pretrain_retrain
    from repro.training.optimizer import AdamWConfig

    cfg = TrainConfig(
        model=lstm_am.LSTMAMConfig(input_dim=123, hidden_dim=args.hidden,
                                   n_layers=2, n_classes=41),
        data=SpeechConfig(max_frames=64),
        opt=AdamWConfig(lr=3e-3), batch_size=8, steps_per_epoch=15,
        cbtd_gamma=args.gamma, cbtd_m=8, cbtd_delta_alpha=0.5,
    )
    print("[serve] training a small CBTD+DeltaLSTM AM first ...")
    pre, post, rcfg = pretrain_retrain(cfg, 2, 1, theta=args.theta)
    quant = QuantConfig() if args.quant else None
    if quant is not None:
        print("[serve] quantized serving: int8 weights, Q8.8 activations")
    ecfg = EngineConfig(theta=args.theta, gamma=args.gamma, m=8, quant=quant)
    from repro.hwsim import spartus_model as hw

    if args.pool > 0:
        engine = BatchedSpartusEngine(post.params, rcfg.model, ecfg)
        n_req = max(args.requests, 1)
        data = SpeechDataset(cfg.data, n_req)
        feats, n_frames, *_ = next(data)
        reqs = [
            StreamRequest(
                req_id=i, arrival_step=2 * i,
                feats=np.asarray(feats[i, :max(int(n_frames[i]), 8)],
                                 np.float32))
            for i in range(n_req)
        ]
        n_devices = args.devices if args.devices > 0 else None
        if n_devices:
            print(f"[serve] sharding the pool's {args.pool} slots over "
                  f"{n_devices} device(s) (slot-dimension data "
                  f"parallelism; {len(jax.devices())} visible)")
        results, stats = serve_requests(engine, reqs, capacity=args.pool,
                                        chunk_frames=args.chunk_frames,
                                        n_devices=n_devices)
        mode = (f"chunked x{args.chunk_frames}" if args.chunk_frames
                else "per-frame")
        print(f"[serve] pool({args.pool}, {mode}): {stats.n_requests} "
              f"sessions / {stats.total_frames} frames in {stats.wall_s:.2f}s "
              f"-> {stats.frames_per_s:.0f} frames/s, latency "
              f"p50 {stats.p50_latency_s*1e3:.0f} ms / "
              f"p95 {stats.p95_latency_s*1e3:.0f} ms")
        print(f"[serve] dispatch economy: {stats.n_dispatches} dispatches "
              f"({stats.dispatches_per_frame:.3f}/frame), host overlap "
              f"{stats.host_overlap_frac:.0%}")
        sp = stats.sparsity
        print(f"[serve] temporal sparsity {sp['temporal_sparsity']:.1%}, "
              f"weight sparsity {engine.weight_sparsity():.1%} "
              f"(pack overflow {engine.pack_overflow_count()} clipped), "
              f"overflow {sp['capacity_overflow_rate']:.1%}")
        rep = hw.evaluate_from_telemetry(hw.SPARTUS, hw.TEST_LAYER,
                                         args.gamma, sp)
        print(f"[serve] modelled Spartus latency at this sparsity: "
              f"{rep.latency_us:.2f} us "
              f"({rep.batch1_throughput_gops:.0f} GOp/s effective)")
        return

    engine = SpartusEngine(post.params, rcfg.model, ecfg)
    feats, *_ = next(SpeechDataset(cfg.data, 1))
    t0 = time.time()
    logits = engine.run_utterance(feats[0])
    dt = time.time() - t0
    sp = engine.measured_sparsity()
    print(f"[serve] streamed {feats.shape[1]} frames in {dt:.2f}s; "
          f"temporal sparsity {sp['temporal_sparsity']:.1%}, "
          f"weight sparsity {engine.weight_sparsity():.1%} "
          f"(pack overflow {engine.pack_overflow_count()} clipped), "
          f"overflow {sp['capacity_overflow_rate']:.1%}")
    rep = hw.evaluate_from_telemetry(hw.SPARTUS, hw.TEST_LAYER, args.gamma, sp)
    print(f"[serve] modelled Spartus latency for the paper's test layer at "
          f"this sparsity: {rep.latency_us:.2f} us "
          f"({rep.batch1_throughput_gops:.0f} GOp/s effective)")


def stats_line(server) -> str:
    """One-line live pool-health summary (the --stats-interval log line;
    also what an operator's dashboard would tail).  Prefers the live
    observability counters when attached — `ServeStats.total_frames` only
    counts COMPLETED requests, so mid-utterance progress would read 0."""
    import time as _time

    pool = server.pool
    stats = server.stats()
    obs = server.obs
    frames = (int(obs.c_frames.value) if obs is not None
              else stats.total_frames)
    up = (_time.perf_counter() - server._t_start
          if server._t_start is not None else 0.0)
    rate = frames / up if up > 0 else 0.0
    return (f"[stats] occ {pool.n_active}/{server.capacity} "
            f"conn {server.n_connected} "
            f"frames {frames} ({rate:.0f}/s) "
            f"dispatches {stats.n_dispatches} "
            f"overlap {stats.host_overlap_frac:.0%} "
            f"lagging {len(server._lagging)}")


async def start_admin_server(server, observability, host: str = "127.0.0.1",
                             port: int = 0):
    """Open the read-only admin listener over an `AsyncSpartusServer`:
    newline-delimited JSON commands ``healthz`` / ``stats`` / ``metrics``
    / ``timeseries`` (see the module docstring for the reply schemas).

    Importable on its own (tools/obs_smoke.py, tests) — returns the
    ``asyncio.Server``; close it like any other.  Localhost by default:
    this surface is for operators on the box, not the public protocol."""
    import asyncio
    import json
    import time as _time

    t_started = _time.time()

    def reply(msg):
        if not isinstance(msg, dict):
            raise ValueError("admin commands are JSON objects")
        cmd = msg.get("cmd")
        if cmd == "healthz":
            return {"ok": True, "uptime_s": _time.time() - t_started,
                    "connected": server.n_connected,
                    "capacity": server.capacity}
        if cmd == "stats":
            return {"stats": server.stats().to_dict()}
        if cmd == "metrics":
            return {"metrics": observability.registry.snapshot(),
                    "prometheus": observability.registry.render_prometheus()}
        if cmd == "timeseries":
            last = msg.get("last")
            ts = observability.timeseries
            return {"timeseries": ts.snapshot(
                        last=int(last) if last is not None else None),
                    "n_appended": ts.n_appended, "n_dropped": ts.n_dropped}
        raise ValueError(f"unknown admin command {cmd!r}")

    async def handle(reader, writer):
        try:
            while line := await reader.readline():
                try:
                    out = reply(json.loads(line))
                except Exception as e:   # bad command answers in-band
                    out = {"error": str(e)}
                writer.write((json.dumps(out) + "\n").encode())
                await writer.drain()
        except ConnectionError:
            pass
        finally:
            writer.close()

    return await asyncio.start_server(handle, host, port)


def jline(writer, obj):
    """Write one JSON-lines message (module-level: the protocol tests and
    the demo client share it with the connection handler)."""
    writer.write((json.dumps(obj) + "\n").encode())


async def handle_conn(server, reader, writer):
    """One JSON-lines client connection over an `AsyncSpartusServer`.

    Module-level so the protocol fuzz tests (tests/test_faults.py) can
    drive it against in-memory stream pairs.  Malformed traffic — bad
    JSON, unknown ops, frames before open, duplicate opens, invalid
    payloads — answers with a typed in-band ``error`` event (codes from
    serving/faults.py) and fails at most the offending stream; every
    other stream on the connection, and every other connection, is
    untouched.  The single transport-level failure is an over-long line
    (``MAX_LINE_BYTES``): framing is unrecoverable, so the handler
    answers ``line_too_long`` and closes this one connection."""
    handles = {}
    pumps = []

    async def pump_out(cid, handle):
        try:
            async for p in handle:
                jline(writer, {"event": "partial", "id": cid,
                               "t0": p.t0, "logits": p.rows.tolist()})
                await writer.drain()
            r = await handle.result()
            jline(writer, {
                "event": "done", "id": cid,
                "n_frames": int(r.logits.shape[0]),
                "latency_ms": r.wall_latency_s * 1e3,
                "ttfl_ms": r.ttfl_s * 1e3,
                "queue_wait_ms": r.queue_wait_s * 1e3})
            await writer.drain()
        except asyncio.CancelledError:
            try:
                jline(writer, {"event": "cancelled", "id": cid})
                await writer.drain()
            except (ConnectionError, RuntimeError):
                pass             # connection already gone
            raise
        except Exception as e:   # reaped / lost-in-recovery: typed + in-band
            try:
                jline(writer, {"event": "error", "id": cid,
                               **error_payload(e)})
                await writer.drain()
            except (ConnectionError, RuntimeError):
                pass

    try:
        while True:
            try:
                line = await reader.readline()
            except ValueError:   # reader limit: the line never terminated
                jline(writer, {"event": "error", "id": None,
                               **error_payload(ProtocolError(
                                   "line_too_long",
                                   f"message exceeds {MAX_LINE_BYTES} "
                                   f"bytes; closing connection"))})
                await writer.drain()
                break
            if not line:
                break
            msg = None           # stays None if this line fails to parse
            try:
                try:
                    msg = json.loads(line)
                except Exception:
                    raise ProtocolError("bad_json",
                                        "line is not valid JSON") from None
                if not isinstance(msg, dict) or "op" not in msg:
                    raise ProtocolError(
                        "bad_json", "message must be an object with an 'op'")
                op, cid = msg["op"], msg.get("id", 0)
                if op == "open":
                    if cid in handles:
                        raise ProtocolError(
                            "duplicate_id",
                            f"stream {cid} is already open on this "
                            f"connection")
                    handles[cid] = await server.stream(
                        want_partials=True, token=msg.get("token"))
                    pumps.append(asyncio.create_task(
                        pump_out(cid, handles[cid])))
                    jline(writer, {"event": "open_ok", "id": cid})
                    await writer.drain()
                elif op in ("frames", "close", "cancel"):
                    if cid not in handles:
                        raise ProtocolError(
                            "no_such_stream",
                            f"stream {cid} is not open on this connection "
                            f"(send 'open' first)")
                    if op == "frames":
                        if "frames" not in msg:
                            raise ProtocolError(
                                "bad_json",
                                "'frames' op requires a 'frames' field")
                        await handles[cid].send(
                            np.asarray(msg["frames"], np.float32))
                    elif op == "close":
                        handles[cid].close()
                    else:
                        handles[cid].cancel()
                else:
                    raise ProtocolError("unknown_op", f"unknown op {op!r}")
            except asyncio.CancelledError:
                raise
            except Exception as e:  # typed, in-band; connection stays up
                jline(writer, {"event": "error",
                               "id": msg.get("id") if isinstance(msg, dict)
                               else None, **error_payload(e)})
                await writer.drain()
    finally:
        for cid, h in handles.items():
            h.cancel()           # connection gone: abandon open streams
        for t in pumps:
            t.cancel()
        # retrieve the pumps' outcomes BEFORE closing the transport so
        # a cancelled pump's last write never lands on a closed writer
        # (and no "exception was never retrieved" warnings are logged):
        await asyncio.gather(*pumps, return_exceptions=True)
        writer.close()


async def demo_client(port, cid, feats, *, max_attempts=6, seed=None):
    """Stream one utterance over TCP, retrying retriable errors.

    The client half of the robustness story: it opens with an idempotent
    token (a retry after a dropped ``open_ok`` cannot double-admit), and
    on a retriable error (``shed``, ``timeout``, ``retriable_internal``)
    it backs off with seeded full-jitter delays — honouring the server's
    ``retry_after_ms`` hint when present — and resends the utterance."""
    backoff = Backoff(seed=cid if seed is None else seed)
    token = f"demo-{cid}"
    last = None
    for attempt in range(max_attempts):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        jline(writer, {"op": "open", "id": cid, "token": token})
        await writer.drain()
        msg = json.loads(await reader.readline())
        if msg.get("event") == "error":
            writer.close()
            last = msg
            if not msg.get("retriable"):
                raise RuntimeError(f"server error: {msg}")
            await asyncio.sleep(max(msg.get("retry_after_ms", 0.0) / 1e3,
                                    backoff.delay(attempt)))
            continue
        assert msg.get("event") == "open_ok", msg
        for j in range(0, len(feats), 8):       # stream in 8-frame slices
            jline(writer, {"op": "frames", "id": cid,
                           "frames": feats[j:j + 8].tolist()})
            await writer.drain()
            await asyncio.sleep(0.005)
        jline(writer, {"op": "close", "id": cid})
        await writer.drain()
        rows, done, retry = [], None, False
        while line := await reader.readline():
            msg = json.loads(line)
            if msg["event"] == "partial":
                rows.append(np.asarray(msg["logits"], np.float32))
            elif msg["event"] == "done":
                done = msg
                break
            elif msg["event"] == "error" and msg.get("retriable"):
                last, retry = msg, True
                break
            else:
                raise RuntimeError(f"server error: {msg}")
        writer.close()
        if retry:
            await asyncio.sleep(backoff.delay(attempt))
            continue
        return cid, np.concatenate(rows), done
    raise RuntimeError(
        f"client {cid}: gave up after {max_attempts} attempts ({last})")


def serve_spartus_async(args):
    """--async: the asyncio streaming front-end behind a localhost
    TCP/JSON-lines protocol (see the module docstring), plus optional
    in-process demo clients that stream utterances and print latency.

    Uses an untrained CBTD-pruned model (the protocol/latency demo does
    not need trained weights; run --pool mode for the trained pipeline)."""
    from repro.core.quantization import QuantConfig
    from repro.data.speech import SpeechConfig, SpeechDataset
    from repro.models import lstm_am
    from repro.serving import AsyncSpartusServer, BatchedSpartusEngine, \
        EngineConfig, PoolObservability, Tracer

    data_cfg = SpeechConfig(max_frames=64)
    cfg = lstm_am.LSTMAMConfig(input_dim=data_cfg.feat_dim,
                               hidden_dim=args.hidden, n_layers=2,
                               n_classes=data_cfg.vocab)
    params = lstm_am.cbtd_prune_stacks(
        lstm_am.init_params(jax.random.key(0), cfg),
        gamma=args.gamma, m=8)
    engine = BatchedSpartusEngine(
        params, cfg, EngineConfig(theta=args.theta, gamma=args.gamma, m=8,
                                  quant=QuantConfig() if args.quant
                                  else None))
    capacity = max(args.pool, 1)
    chunk = args.chunk_frames or 8

    async def run():
        obs = PoolObservability(tracer=Tracer(enabled=bool(args.trace)))
        server = AsyncSpartusServer(
            engine, capacity, chunk_frames=chunk,
            target_chunk_ms=args.target_chunk_ms, max_frames=64,
            max_pending=4 * capacity,
            n_devices=args.devices if args.devices > 0 else None,
            observability=obs,
            overload_policy=args.overload,
            idle_timeout_s=args.idle_timeout or None,
            watchdog=True)

        async def log_stats():
            while True:
                await asyncio.sleep(args.stats_interval)
                print(stats_line(server))

        admin = None
        logger = None
        async with server:
            tcp = await asyncio.start_server(
                lambda r, w: handle_conn(server, r, w),
                "127.0.0.1", args.port, limit=MAX_LINE_BYTES)
            port = tcp.sockets[0].getsockname()[1]
            mode = (f"{args.target_chunk_ms:.0f} ms/chunk paced"
                    if args.target_chunk_ms else "free-run")
            print(f"[serve] async Spartus server on 127.0.0.1:{port} "
                  f"(capacity {capacity}, {chunk}-frame chunks, {mode})")
            try:
                if args.admin_port >= 0:
                    admin = await start_admin_server(server, obs,
                                                     port=args.admin_port)
                    aport = admin.sockets[0].getsockname()[1]
                    print(f"[serve] admin endpoint on 127.0.0.1:{aport} "
                          f"(healthz / stats / metrics / timeseries)")
                if args.stats_interval > 0:
                    logger = asyncio.create_task(log_stats())
                await run_clients(server, tcp, port)
            finally:
                if logger is not None:
                    logger.cancel()
                if admin is not None:
                    admin.close()
                    await admin.wait_closed()
                if args.trace:
                    obs.tracer.dump(args.trace)
                    print(f"[serve] wrote {obs.tracer.n_events} trace events "
                          f"to {args.trace} (load in Perfetto / "
                          f"chrome://tracing)")

    async def run_clients(server, tcp, port):
        if args.clients <= 0:
            print("[serve] serving forever (ctrl-c to stop) ...")
            async with tcp:
                await tcp.serve_forever()
            return
        n = args.clients
        data = SpeechDataset(data_cfg, n)
        feats, n_frames, *_ = next(data)
        utts = [np.asarray(feats[i, :max(int(n_frames[i]), 8)],
                           np.float32) for i in range(n)]
        out = await asyncio.gather(
            *[demo_client(port, i, utts[i]) for i in range(n)])
        tcp.close()
        await tcp.wait_closed()
        for cid, streamed, done in out:
            assert streamed.shape[0] == utts[cid].shape[0]
        stats = server.stats()
        print(f"[serve] {n} concurrent TCP clients served "
              f"{stats.total_frames} frames; per-client latency "
              f"p50 {stats.p50_latency_s*1e3:.0f} ms / "
              f"p95 {stats.p95_latency_s*1e3:.0f} ms, "
              f"first logit p50 {stats.p50_ttfl_s*1e3:.0f} ms, "
              f"queue wait p95 {stats.p95_queue_wait_s*1e3:.0f} ms")
        print(f"[serve] dispatch economy: {stats.n_dispatches} dispatches "
              f"({stats.dispatches_per_frame:.3f}/frame)")

    asyncio.run(run())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--spartus", action="store_true")
    ap.add_argument("--theta", type=float, default=0.2)
    ap.add_argument("--gamma", type=float, default=0.75)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--quant", action="store_true",
                    help="--spartus modes: serve with int8 CBCSC weight "
                         "payloads and Q8.8 delta thresholds "
                         "(docs/quantization.md)")
    ap.add_argument("--pool", type=int, default=0,
                    help="session-pool capacity (0 = batch-1 engine)")
    ap.add_argument("--requests", type=int, default=16,
                    help="number of streaming requests for --pool mode")
    ap.add_argument("--chunk-frames", type=int, default=0,
                    help="--pool mode: frames advanced per device dispatch "
                         "(0 = per-frame ticks; --async defaults to 8)")
    ap.add_argument("--devices", type=int, default=0,
                    help="--pool/--async: shard the pool's slot dimension "
                         "over N devices (0 = single-device; emulate with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="asyncio streaming front-end over localhost "
                         "TCP/JSON-lines (requires --spartus)")
    ap.add_argument("--port", type=int, default=0,
                    help="--async: TCP port (0 = ephemeral, printed)")
    ap.add_argument("--clients", type=int, default=8,
                    help="--async: in-process demo clients to run "
                         "(0 = serve forever)")
    ap.add_argument("--target-chunk-ms", type=float, default=0.0,
                    help="--async: wall-clock pacing per chunk boundary "
                         "(0 = free-run)")
    ap.add_argument("--admin-port", type=int, default=-1,
                    help="--async: open the read-only localhost admin "
                         "endpoint (healthz/stats/metrics/timeseries JSON "
                         "lines) on this port (0 = ephemeral, printed; "
                         "-1 = off)")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="--async: log a one-line pool-health summary "
                         "every S seconds (0 = off)")
    ap.add_argument("--trace", default="",
                    help="--async: record driver-phase spans and write a "
                         "Chrome trace-event JSON here on shutdown "
                         "(Perfetto / chrome://tracing)")
    ap.add_argument("--idle-timeout", type=float, default=0.0,
                    help="--async: reap sessions whose client is silent "
                         "for S seconds (typed retriable 'timeout' error; "
                         "0 = never)")
    ap.add_argument("--overload", choices=("wait", "shed"), default="wait",
                    help="--async: admission policy when max_pending "
                         "saturates — 'wait' queues the caller, 'shed' "
                         "answers a retriable typed error with a "
                         "retry_after_ms hint")
    args = ap.parse_args()
    if args.async_mode:
        if not args.spartus:
            ap.error("--async requires --spartus")
        serve_spartus_async(args)
    elif args.spartus:
        serve_spartus(args)
    else:
        serve_arch(args)


if __name__ == "__main__":
    main()
