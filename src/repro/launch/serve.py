"""Serving launcher: batched decode for any --arch, or the paper's
streaming Spartus engine for the LSTM AM (batch-1, or the
continuous-batching session pool with --pool N).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 4 --steps 32
    PYTHONPATH=src python -m repro.launch.serve --spartus --theta 0.2
    PYTHONPATH=src python -m repro.launch.serve --spartus --pool 8 --requests 24
    PYTHONPATH=src python -m repro.launch.serve --spartus --pool 8 \
        --chunk-frames 32    # chunked device tick loop (1 dispatch / 32 frames)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import api


def serve_arch(args):
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = api.init_params(cfg, jax.random.key(0))
    cache = api.init_cache(cfg, args.batch, args.ctx)
    step = jax.jit(lambda p, c, t: api.serve_step(p, cfg, t, c))

    if cfg.family == "vlm":
        inputs = jax.random.normal(jax.random.key(1),
                                   (args.batch, 1, cfg.d_model))
    else:
        inputs = jnp.zeros((args.batch, 1), jnp.int32)

    logits, cache = step(params, cache, inputs)  # compile
    jax.block_until_ready(logits)
    t0 = time.time()
    toks = inputs
    for i in range(args.steps):
        logits, cache = step(params, cache, toks)
        if cfg.family != "vlm":
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = (time.time() - t0) / args.steps
    print(f"[serve] {cfg.name}: {args.steps} steps batch={args.batch} "
          f"-> {dt*1e3:.2f} ms/token ({args.batch/dt:.1f} tok/s)")


def serve_spartus(args):
    import numpy as np

    from repro.data.speech import SpeechConfig, SpeechDataset
    from repro.models import lstm_am
    from repro.serving import (
        BatchedSpartusEngine, EngineConfig, SpartusEngine, StreamRequest,
        serve_requests,
    )
    from repro.training.trainer import TrainConfig, pretrain_retrain
    from repro.training.optimizer import AdamWConfig

    cfg = TrainConfig(
        model=lstm_am.LSTMAMConfig(input_dim=123, hidden_dim=args.hidden,
                                   n_layers=2, n_classes=41),
        data=SpeechConfig(max_frames=64),
        opt=AdamWConfig(lr=3e-3), batch_size=8, steps_per_epoch=15,
        cbtd_gamma=args.gamma, cbtd_m=8, cbtd_delta_alpha=0.5,
    )
    print("[serve] training a small CBTD+DeltaLSTM AM first ...")
    pre, post, rcfg = pretrain_retrain(cfg, 2, 1, theta=args.theta)
    ecfg = EngineConfig(theta=args.theta, gamma=args.gamma, m=8)
    from repro.hwsim import spartus_model as hw

    if args.pool > 0:
        engine = BatchedSpartusEngine(post.params, rcfg.model, ecfg)
        n_req = max(args.requests, 1)
        data = SpeechDataset(cfg.data, n_req)
        feats, n_frames, *_ = next(data)
        reqs = [
            StreamRequest(
                req_id=i, arrival_step=2 * i,
                feats=np.asarray(feats[i, :max(int(n_frames[i]), 8)],
                                 np.float32))
            for i in range(n_req)
        ]
        results, stats = serve_requests(engine, reqs, capacity=args.pool,
                                        chunk_frames=args.chunk_frames)
        mode = (f"chunked x{args.chunk_frames}" if args.chunk_frames
                else "per-frame")
        print(f"[serve] pool({args.pool}, {mode}): {stats.n_requests} "
              f"sessions / {stats.total_frames} frames in {stats.wall_s:.2f}s "
              f"-> {stats.frames_per_s:.0f} frames/s, latency "
              f"p50 {stats.p50_latency_s*1e3:.0f} ms / "
              f"p95 {stats.p95_latency_s*1e3:.0f} ms")
        print(f"[serve] dispatch economy: {stats.n_dispatches} dispatches "
              f"({stats.dispatches_per_frame:.3f}/frame), host overlap "
              f"{stats.host_overlap_frac:.0%}")
        sp = stats.sparsity
        print(f"[serve] temporal sparsity {sp['temporal_sparsity']:.1%}, "
              f"weight sparsity {engine.weight_sparsity():.1%} "
              f"(pack overflow {engine.pack_overflow_count()} clipped), "
              f"overflow {sp['capacity_overflow_rate']:.1%}")
        rep = hw.evaluate_from_telemetry(hw.SPARTUS, hw.TEST_LAYER,
                                         args.gamma, sp)
        print(f"[serve] modelled Spartus latency at this sparsity: "
              f"{rep.latency_us:.2f} us "
              f"({rep.batch1_throughput_gops:.0f} GOp/s effective)")
        return

    engine = SpartusEngine(post.params, rcfg.model, ecfg)
    feats, *_ = next(SpeechDataset(cfg.data, 1))
    t0 = time.time()
    logits = engine.run_utterance(feats[0])
    dt = time.time() - t0
    sp = engine.measured_sparsity()
    print(f"[serve] streamed {feats.shape[1]} frames in {dt:.2f}s; "
          f"temporal sparsity {sp['temporal_sparsity']:.1%}, "
          f"weight sparsity {engine.weight_sparsity():.1%} "
          f"(pack overflow {engine.pack_overflow_count()} clipped), "
          f"overflow {sp['capacity_overflow_rate']:.1%}")
    rep = hw.evaluate_from_telemetry(hw.SPARTUS, hw.TEST_LAYER, args.gamma, sp)
    print(f"[serve] modelled Spartus latency for the paper's test layer at "
          f"this sparsity: {rep.latency_us:.2f} us "
          f"({rep.batch1_throughput_gops:.0f} GOp/s effective)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--spartus", action="store_true")
    ap.add_argument("--theta", type=float, default=0.2)
    ap.add_argument("--gamma", type=float, default=0.75)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--pool", type=int, default=0,
                    help="session-pool capacity (0 = batch-1 engine)")
    ap.add_argument("--requests", type=int, default=16,
                    help="number of streaming requests for --pool mode")
    ap.add_argument("--chunk-frames", type=int, default=0,
                    help="--pool mode: frames advanced per device dispatch "
                         "(0 = per-frame ticks)")
    args = ap.parse_args()
    if args.spartus:
        serve_spartus(args)
    else:
        serve_arch(args)


if __name__ == "__main__":
    main()
