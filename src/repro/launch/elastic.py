"""Elastic scaling: re-shard a checkpoint onto a different device count.

Checkpoints store full (host) arrays keyed by tree path, so elasticity is
a pure re-layout problem: build the new mesh from whatever devices exist,
recompute PartitionSpecs with the same rules (they degrade gracefully —
any non-divisible dim falls back to replication), and device_put.

Straggler/failure policy at the job level (launch/train.py):
  * deterministic (process, step)->data mapping means a restarted/rescaled
    job replays the exact stream — no sample loss, no duplication;
  * checkpoint cadence bounds lost work; COMMIT markers make partial
    writes invisible;
  * on shrink, the global batch is preserved by raising per-host batch
    (grad-accumulation) so optimization hyperparameters stay valid.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax

from repro.distributed.sharding import param_specs
from repro.launch.mesh import compat_make_mesh


def best_mesh_for(n_devices: int):
    """Largest (data, model) grid <= n_devices with model <= 16 (TP island
    bounded by ICI domain) and data maximal."""
    model = min(16, n_devices)
    while n_devices % model:
        model //= 2
    data = n_devices // model
    return compat_make_mesh((data, model), ("data", "model"))


def reshard(tree, mesh, cfg=None):
    """device_put a host pytree onto ``mesh`` with the standard rules."""
    from jax.sharding import NamedSharding

    specs = param_specs(tree, mesh, cfg)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    return jax.device_put(tree, shardings)


def rescale_batch(global_batch: int, old_hosts: int, new_hosts: int,
                  per_host: int) -> Tuple[int, int]:
    """(new per-host batch, grad-accum factor) preserving the global batch."""
    assert global_batch == old_hosts * per_host
    new_per_host = math.ceil(global_batch / new_hosts)
    accum = 1
    while new_per_host > 2 * per_host:
        new_per_host = math.ceil(new_per_host / 2)
        accum *= 2
    return new_per_host, accum
