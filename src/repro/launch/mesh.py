"""Production mesh builders.

A FUNCTION (not module-level constant) so importing this module never
touches jax device state.  Shapes follow the assignment:
  single-pod: (16, 16)        -> ("data", "model")      = 256 chips
  multi-pod:  (2, 16, 16)     -> ("pod", "data", "model") = 512 chips

``data_axes()`` returns the axes a global batch shards over (pod folds
into data parallelism); ``model_axis()`` the tensor-parallel axis.
"""
from __future__ import annotations

from typing import Tuple

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across the 0.4 -> 0.5+ API change: newer releases take
    (and want) ``axis_types``; 0.4.x has neither the kwarg nor AxisType."""
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def mesh_context(mesh):
    """Context manager activating ``mesh``: jax.set_mesh on 0.5+, the Mesh
    object itself (a context manager) on 0.4.x."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh over host devices (tests; needs XLA_FLAGS device count)."""
    return compat_make_mesh((n_data, n_model), ("data", "model"))


def make_data_mesh(n_data: int = 1):
    """1-D ``("data",)`` mesh over the first ``n_data`` local devices —
    the serving pool's slot-dimension data parallelism (each device owns
    a contiguous block of pool slots; no model axis, the CBCSC weights
    replicate).  Raises with a clear message when the host exposes fewer
    devices (CI emulates them with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    avail = len(jax.devices())
    if n_data < 1:
        raise ValueError(f"n_data must be >= 1, got {n_data}")
    if n_data > avail:
        raise ValueError(
            f"requested a {n_data}-device data mesh but only {avail} "
            f"device(s) are visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_data} (before "
            f"importing jax) to emulate host devices")
    return compat_make_mesh((n_data,), ("data",))


def data_axes(mesh) -> Tuple[str, ...]:
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def model_axis(mesh) -> str:
    return "model"


def axis_size(mesh, *names: str) -> int:
    out = 1
    for n in names:
        if n in mesh.axis_names:
            out *= mesh.shape[n]
    return out
