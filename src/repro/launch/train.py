"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/run1

Single-host it runs on local devices (CPU included); multi-host it
expects ``jax.distributed.initialize`` env (TPU pods) and builds the mesh
over all devices.  Fault tolerance: resumes from the latest committed
checkpoint (params, optimizer, data position); preemption mid-step costs
at most ``--ckpt-every`` steps.

The paper's technique is first-class: ``--cbtd-gamma`` prunes every
linear with CBTD inside the jitted step (Alg. 2), and the LM data stream
is the synthetic pipeline (offline substitute).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_arch
from repro.core import alpha_at, cbtd_prune_tree
from repro.data.lm import LMConfig, LMDataset
from repro.distributed.sharding import param_specs
from repro.launch.elastic import best_mesh_for
from repro.launch.mesh import mesh_context
from repro.launch.steps import make_train_step
from repro.models import api
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import AdamWConfig, adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--cbtd-gamma", type=float, default=None)
    ap.add_argument("--cbtd-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if jax.process_count() > 1:  # multi-host: initialize was done by env
        pass

    mesh = best_mesh_for(len(jax.devices()))
    print(f"[train] arch={cfg.name} mesh={dict(mesh.shape)} "
          f"devices={len(jax.devices())}")

    key = jax.random.key(0)
    params = api.init_params(cfg, key, jnp.float32)
    opt_state = adamw_init(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          schedule="cosine", total_steps=args.steps)

    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, cfg))
    o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(opt_state, mesh, cfg))
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    data = LMDataset(LMConfig(vocab=cfg.vocab, seq_len=args.seq),
                     args.batch, jax.process_index())

    step0 = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep_last=2)
        (params, opt_state), meta, ck = mgr.restore_latest((params, opt_state))
        if ck is not None:
            step0 = int(meta["step"])
            data.load_state_dict({"step": meta["data_step"]})
            params = jax.device_put(params, p_sh)
            opt_state = jax.device_put(opt_state, o_sh)
            print(f"[train] resumed from step {step0}")

    train_step = make_train_step(cfg, opt_cfg, args.seq,
                                 microbatches=args.microbatches)
    layout = api.cbtd_layout(cfg) if args.cbtd_gamma else None
    if layout:
        layout = {k: dataclasses.replace(v, gamma=args.cbtd_gamma)
                  for k, v in layout.items()}

    @jax.jit
    def prune(params, alpha):
        return cbtd_prune_tree(params, layout, alpha)

    jit_step = jax.jit(train_step, in_shardings=(p_sh, o_sh, None),
                       out_shardings=(p_sh, o_sh, None),
                       donate_argnums=(0, 1))

    with mesh_context(mesh):
        t0 = time.time()
        for step in range(step0, args.steps):
            tokens, targets = next(data)
            batch = (
                api.make_train_batch(cfg, jax.random.fold_in(key, step),
                                     args.batch, args.seq)
                if cfg.family in ("vlm", "audio")
                else {"tokens": tokens, "targets": targets}
            )
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if layout and (step + 1) % args.cbtd_every == 0:
                alpha = alpha_at(step // args.cbtd_every, 0.2)
                params = prune(params, alpha)
            if (step + 1) % args.log_every == 0:
                print(f"step {step+1:5d} loss {float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/args.log_every:.2f}s/step)")
                t0 = time.time()
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, (params, opt_state),
                         {"step": step + 1, "data_step": data.step})
        if mgr:
            mgr.save(args.steps, (params, opt_state),
                     {"step": args.steps, "data_step": data.step})
            mgr.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
