"""Pallas TPU kernel: fused LSTM gate pointwise math (the Spartus HPE,
Fig. 8 — sigmoid/tanh units + pointwise multiply-add after the adder
trees).

Input is the delta-memory tensor DM [4, H] (gate order i, g, f, o per
eq. 8) and the cell state c [H]; outputs are (h, c').  One VMEM tile of
every gate row is resident per grid step, so the whole cell update is a
single VPU pass with no HBM round-trips between gates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK = 512  # elements of H per grid step (4 sublane rows x 128)


def _lstm_pointwise_kernel(dm_ref, c_ref, h_ref, c_out_ref):
    i = jax.nn.sigmoid(dm_ref[0, :])
    g = jnp.tanh(dm_ref[1, :])
    f = jax.nn.sigmoid(dm_ref[2, :])
    o = jax.nn.sigmoid(dm_ref[3, :])
    c_new = f * c_ref[...] + i * g
    h_ref[...] = o * jnp.tanh(c_new)
    c_out_ref[...] = c_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def lstm_pointwise_pallas(dm: jax.Array, c: jax.Array, *, interpret: bool = True):
    """dm: [4, H], c: [H], H % 512 == 0 -> (h [H], c' [H])."""
    h_dim = c.shape[0]
    assert dm.shape == (4, h_dim)
    assert h_dim % BLOCK == 0, f"H={h_dim} must be padded to {BLOCK}"
    n_blocks = h_dim // BLOCK

    h, c_new = pl.pallas_call(
        _lstm_pointwise_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((4, BLOCK), lambda b: (0, b)),
            pl.BlockSpec((BLOCK,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda b: (b,)),
            pl.BlockSpec((BLOCK,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h_dim,), dm.dtype),
            jax.ShapeDtypeStruct((h_dim,), dm.dtype),
        ],
        interpret=interpret,
    )(dm, c)
    return h, c_new
