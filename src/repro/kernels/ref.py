"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical spec; kernels must match to float
tolerance across the shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantization import quantize_act


def delta_encode_ref(
    x: jax.Array, x_hat: jax.Array, theta: float,
    act_bits: Optional[int] = None, act_frac_bits: int = 8,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Eqs. (4)-(5): (delta, new_x_hat, nnz). x, x_hat: [F].

    With ``act_bits`` set, the threshold comparison runs on the Qm.n
    activation grid: x and theta are snapped to the grid first, and the
    updated reference state stores the *quantized* x — so x_hat stays
    on-grid by induction and every delta is an exact difference of grid
    points (what the fixed-point DPE hardware compares).
    """
    if act_bits is not None:
        x = quantize_act(x, act_bits, act_frac_bits)
        theta = quantize_act(jnp.asarray(theta, x.dtype), act_bits,
                             act_frac_bits)
    raw = x - x_hat
    fired = jnp.abs(raw) > theta
    delta = jnp.where(fired, raw, jnp.zeros_like(raw))
    new_x_hat = jnp.where(fired, x, x_hat)
    return delta, new_x_hat, jnp.sum(fired.astype(jnp.int32))


def lstm_pointwise_ref(
    dm: jax.Array, c: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """HPE post-MxV math (Sec. IV-D): dm [4, H] (i,g,f,o), c [H] -> (h, c')."""
    i = jax.nn.sigmoid(dm[0])
    g = jnp.tanh(dm[1])
    f = jax.nn.sigmoid(dm[2])
    o = jax.nn.sigmoid(dm[3])
    c_new = f * c + i * g
    h = o * jnp.tanh(c_new)
    return h, c_new


def stsp_spmv_ref(
    val: jax.Array,      # [Q, M, BLEN] CBCSC values (0-padded)
    lidx: jax.Array,     # [Q, M, BLEN] local indices
    idx: jax.Array,      # [K] active column ids (padded entries arbitrary)
    ds_vals: jax.Array,  # [K] delta values (0.0 for padding)
    s: int,              # subcolumn length H/M
) -> jax.Array:
    """y[H] = sum_k ds_vals[k] * column(idx[k]), column scattered from
    CBCSC: row r = lidx*M + pe.  The spec of the Spartus MAC arrays."""
    q, m, blen = val.shape
    v = val[idx]                                   # [K, M, BLEN]
    li = lidx[idx].astype(jnp.int32)               # [K, M, BLEN] (lidx may
    #                                                be int8-packed)
    onehot = li[..., None] == jnp.arange(s, dtype=li.dtype)   # [K,M,BLEN,S]
    contrib = jnp.einsum(
        "kmb,kmbs->ksm", v.astype(jnp.float32) * ds_vals[:, None, None],
        onehot.astype(jnp.float32),
    )                                              # [K, S, M]
    return jnp.sum(contrib, axis=0).reshape(s * m)  # row r = s*M + m


def stsp_spmv_scatter_ref(
    val: jax.Array,      # [Q, M, BLEN] CBCSC values (0-padded)
    lidx: jax.Array,     # [Q, M, BLEN] local indices
    idx: jax.Array,      # [K] active column ids (padded entries arbitrary)
    ds_vals: jax.Array,  # [K] delta values (0.0 for padding)
    s: int,              # subcolumn length H/M
) -> jax.Array:
    """Scatter-add formulation of ``stsp_spmv_ref`` — the oracle of the
    batched Pallas scatter kernel and the XLA serving path.  Each fetched
    (value, lidx) pair lands at global row r = lidx*M + pe via one
    scatter-add, O(1) per nonzero instead of the one-hot's O(S).  Must be
    numerically identical (same fp32 adds, different order) to the one-hot
    spec above."""
    q, m, blen = val.shape
    v = val[idx].astype(jnp.float32) * ds_vals[:, None, None].astype(jnp.float32)
    pe = jnp.arange(m, dtype=jnp.int32)[None, :, None]        # [1, M, 1]
    # int32 row math: an int8-packed lidx would overflow at lidx*m
    rows = lidx[idx].astype(jnp.int32) * m + pe                # [K, M, BLEN]
    return jnp.zeros((s * m,), jnp.float32).at[rows.reshape(-1)].add(
        v.reshape(-1))
