"""jit'd public wrappers for the Pallas kernels + XLA fallback paths.

``use_pallas`` selects the Pallas implementation (interpret=True on CPU,
compiled on TPU); the default XLA path implements identical math with
gather/einsum and is what the dry-run lowers (TPU Pallas cannot compile on
the CPU backend — DESIGN.md §6).

Also hosts ``select_active_columns`` — the fixed-capacity NZI list builder
(the static-shape translation of the Spartus DPE's NZV/NZI streams).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.delta_encode import delta_encode_pallas
from repro.kernels.lstm_pointwise import lstm_pointwise_pallas
from repro.kernels.stsp_spmv import (
    stsp_spmv_pallas,
    stsp_spmv_scatter_batch_pallas,
)
from repro.kernels import ref as _ref

PAD_ALIGN = 1024  # delta_encode tile: 8 sublanes x 128 lanes


def _pad_to(x: jax.Array, align: int) -> Tuple[jax.Array, int]:
    f = x.shape[0]
    pad = (-f) % align
    if pad:
        x = jnp.pad(x, (0, pad))
    return x, f


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def delta_encode(
    x: jax.Array, x_hat: jax.Array, theta,
    *, use_pallas: bool = False, interpret: bool = True,
):
    """Eqs. (4)-(5). x, x_hat: [F] any length (padded internally).
    Returns (delta [F], new_x_hat [F], nnz scalar int32)."""
    if not use_pallas:
        return _ref.delta_encode_ref(x, x_hat, theta)
    xp, f = _pad_to(x, PAD_ALIGN)
    xhp, _ = _pad_to(x_hat, PAD_ALIGN)
    delta, new_xh, nnz = delta_encode_pallas(xp, xhp, theta, interpret=interpret)
    return delta[:f], new_xh[:f], jnp.sum(nnz)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def lstm_pointwise(
    dm: jax.Array, c: jax.Array, *, use_pallas: bool = False, interpret: bool = True
):
    """HPE gate math. dm: [4, H], c: [H] -> (h, c')."""
    if not use_pallas:
        return _ref.lstm_pointwise_ref(dm, c)
    h_dim = c.shape[0]
    pad = (-h_dim) % 512
    if pad:
        dm = jnp.pad(dm, ((0, 0), (0, pad)))
        c = jnp.pad(c, (0, pad))
    h, c_new = lstm_pointwise_pallas(dm, c, interpret=interpret)
    return h[:h_dim], c_new[:h_dim]


@functools.partial(jax.jit, static_argnames=("capacity",))
def select_active_columns(
    delta: jax.Array, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Build the fixed-capacity NZI/NZV lists from a (sparse) delta vector.

    Deterministic policy: if more than ``capacity`` deltas fired, keep the
    largest |delta| (drop-smallest overflow, DESIGN.md §9); padding slots
    get idx=0, val=0.  Returns (idx [K] int32, vals [K], n_dropped)."""
    mag = jnp.abs(delta)
    fired = delta != 0
    neg = jnp.where(fired, -mag, 1.0)            # actives first, by magnitude
    order = jnp.argsort(neg)[:capacity]
    valid = fired[order]
    idx = jnp.where(valid, order, 0).astype(jnp.int32)
    vals = jnp.where(valid, delta[order], 0).astype(delta.dtype)
    n_dropped = jnp.maximum(jnp.sum(fired.astype(jnp.int32)) - capacity, 0)
    return idx, vals, n_dropped


def stsp_spmv_xla(
    val: jax.Array, lidx: jax.Array, idx: jax.Array, ds_vals: jax.Array, s: int
) -> jax.Array:
    """XLA gather+scatter-add path (identical math to the Pallas kernel).

    Historically this decompressed CBCSC with an S-wide one-hot einsum —
    O(S) work per stored nonzero, which cratered the batched pool at large
    subcolumn lengths (hidden>=256 / m=16).  The scatter-add formulation
    (``ref.stsp_spmv_scatter_ref``) touches each fetched (value, lidx) pair
    exactly once."""
    return _ref.stsp_spmv_scatter_ref(val, lidx, idx, ds_vals, s)


@functools.partial(jax.jit, static_argnames=("s", "use_pallas", "interpret"))
def stsp_spmv(
    val: jax.Array,
    lidx: jax.Array,
    idx: jax.Array,
    ds_vals: jax.Array,
    *,
    s: int,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """y [H] = sum_k ds_vals[k] * W_cbcsc[:, idx[k]]  (fp32)."""
    if not use_pallas:
        return stsp_spmv_xla(val, lidx, idx, ds_vals, s)
    return stsp_spmv_pallas(val, lidx, idx, ds_vals, s=s, interpret=interpret)


# -- batched (slot-dimension) entry points ---------------------------------
#
# The serving scheduler advances a whole pool of independent streaming
# sessions per frame (serving/batched_engine.py).  These wrappers vmap the
# scalar-session kernels over a leading slot dimension B so one jitted call
# covers the entire pool; weights broadcast (in_axes=None), per-slot state
# maps.  Numerics per row are identical to the unbatched calls (vmap only
# changes the iteration structure), which is what makes the batched engine
# bit-comparable to `SpartusEngine`.


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def delta_encode_batch(
    x: jax.Array, x_hat: jax.Array, theta,
    *, use_pallas: bool = False, interpret: bool = True,
):
    """Batched eqs. (4)-(5).  x, x_hat: [B, F] -> (delta [B, F],
    new_x_hat [B, F], nnz [B] int32)."""
    fn = functools.partial(delta_encode, use_pallas=use_pallas,
                           interpret=interpret)
    return jax.vmap(fn, in_axes=(0, 0, None))(x, x_hat, theta)


@functools.partial(jax.jit, static_argnames=("capacity",))
def select_active_columns_batch(
    delta: jax.Array, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched NZI/NZV list builder.  delta: [B, F] ->
    (idx [B, K] int32, vals [B, K], n_dropped [B])."""
    fn = functools.partial(select_active_columns, capacity=capacity)
    return jax.vmap(fn)(delta)


def spmv_use_dense_gather(s: int, gamma: float) -> bool:
    """Path heuristic for the batched SpMV: the CBCSC scatter path does
    BLEN ~= S*(1-gamma) adds per (PE, active column), the dense-gather path
    does S multiply-adds but on the MXU with no index traffic.  Once
    ``S*(1-gamma) >= 1`` the scatter path has no arithmetic advantage left
    per lane, so large-S models route to the dense mirror and never touch
    the O(S)-per-nonzero decompression that caused the hidden>=256 / m=16
    performance cliff."""
    return s * (1.0 - gamma) >= 1.0


@functools.partial(jax.jit, static_argnames=("s", "use_pallas", "interpret"))
def stsp_spmv_batch(
    val: jax.Array,
    lidx: jax.Array,
    idx: jax.Array,
    ds_vals: jax.Array,
    *,
    s: int,
    use_pallas: bool = False,
    interpret: bool = True,
    w_dense: jax.Array | None = None,
) -> jax.Array:
    """Batched STSP SpMxSpV: shared CBCSC weights, per-slot active lists.
    idx, ds_vals: [B, K] -> y [B, H].

    Three implementations, selected at pack time (serving/engine.py applies
    ``spmv_use_dense_gather``):
      * ``w_dense`` given — dense-gather fallback: one [B, K] panel gather
        from the pack-time dense mirror + an MXU matmul (no CBCSC decode in
        the hot loop at all);
      * ``use_pallas`` — single batched Pallas scatter kernel over grid
        (B, K) (one pallas_call for the whole pool, not a vmap of B calls);
      * otherwise — vmap of the XLA scatter-add path.
    """
    if w_dense is not None:
        return delta_spmv_dense_gather_batch(w_dense, idx, ds_vals)
    if use_pallas:
        return stsp_spmv_scatter_batch_pallas(val, lidx, idx, ds_vals, s=s,
                                              interpret=interpret)
    fn = functools.partial(stsp_spmv, s=s, use_pallas=False,
                           interpret=interpret)
    return jax.vmap(fn, in_axes=(None, None, 0, 0))(val, lidx, idx, ds_vals)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def lstm_pointwise_batch(
    dm: jax.Array, c: jax.Array, *, use_pallas: bool = False, interpret: bool = True
):
    """Batched HPE gate math.  dm: [B, 4, H], c: [B, H] -> (h, c') [B, H]."""
    fn = functools.partial(lstm_pointwise, use_pallas=use_pallas,
                           interpret=interpret)
    return jax.vmap(fn)(dm, c)


def delta_spmv_dense_gather(
    w: jax.Array, idx: jax.Array, ds_vals: jax.Array
) -> jax.Array:
    """Temporal-sparsity-only path: gather dense columns of w [H, Q] by the
    active index list and run one [H, K] x [K] MXU matmul.  Used when the
    weights are not CBCSC-packed (e.g. unpruned baselines) and as the
    batch-1 leg of the large-S dense mirror path (spmv_use_dense_gather)."""
    panel = jnp.take(w, idx, axis=1)             # [H, K]
    return panel @ ds_vals


def delta_spmv_dense_gather_batch(
    w: jax.Array, idx: jax.Array, ds_vals: jax.Array
) -> jax.Array:
    """Batched dense-mirror SpMV: w [H, Q], idx/ds_vals [B, K] -> y [B, H].

    The [B, K] active lists are scattered back to a dense [B, Q] delta
    slab (one cheap gather-free scatter-add; duplicate indices accumulate,
    padding slots carry 0.0) and contracted against the mirror in a single
    [B, Q] x [Q, H] MXU matmul.  Unlike a per-slot [B, K, H] column-panel
    gather — whose weight traffic grows with B — the GEMM reads the mirror
    ONCE per tick regardless of pool size, which is exactly the
    weight-fetch amortisation continuous batching exists for.  Exploits
    temporal sparsity only; spatial sparsity is already priced into the
    pack-time mirror's zeros."""
    b, k = idx.shape
    slot = jnp.arange(b, dtype=idx.dtype)[:, None]
    ds_dense = jnp.zeros((b, w.shape[1]), jnp.float32).at[
        jnp.broadcast_to(slot, (b, k)), idx
    ].add(ds_vals.astype(jnp.float32))
    return ds_dense @ w.T.astype(jnp.float32)
