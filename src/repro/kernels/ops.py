"""jit'd public wrappers for the Pallas kernels + XLA fallback paths.

``use_pallas`` selects the Pallas implementation (interpret=True on CPU,
compiled on TPU); the default XLA path implements identical math with
gather/einsum and is what the dry-run lowers (TPU Pallas cannot compile on
the CPU backend — DESIGN.md §6).

Also hosts ``select_active_columns`` — the fixed-capacity NZI list builder
(the static-shape translation of the Spartus DPE's NZV/NZI streams).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.analysis.contracts import hotpath_contract
from repro.kernels.delta_encode import delta_encode_pallas
from repro.kernels.lstm_pointwise import lstm_pointwise_pallas
from repro.kernels.stsp_spmv import (
    stsp_spmv_pallas,
    stsp_spmv_scatter_batch_pallas,
)
from repro.kernels import ref as _ref

PAD_ALIGN = 1024  # delta_encode tile: 8 sublanes x 128 lanes


def _pad_to(x: jax.Array, align: int) -> Tuple[jax.Array, int]:
    f = x.shape[0]
    pad = (-f) % align
    if pad:
        x = jnp.pad(x, (0, pad))
    return x, f


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "act_bits", "act_frac_bits"))
def delta_encode(
    x: jax.Array, x_hat: jax.Array, theta,
    *, use_pallas: bool = False, interpret: bool = True,
    act_bits: int | None = None, act_frac_bits: int = 8,
):
    """Eqs. (4)-(5). x, x_hat: [F] any length (padded internally).
    Returns (delta [F], new_x_hat [F], nnz scalar int32).

    ``act_bits`` (static) quantizes the threshold comparison to the Qm.n
    activation grid (Q8.8 by default): x and theta are snapped to the
    grid and the reference state stores the quantized x, so temporal
    sparsity is computed on the same values the fixed-point arithmetic
    sees.  None (default) keeps the fp32 comparison bit-identical to
    before."""
    if not use_pallas:
        return _ref.delta_encode_ref(x, x_hat, theta, act_bits, act_frac_bits)
    xp, f = _pad_to(x, PAD_ALIGN)
    xhp, _ = _pad_to(x_hat, PAD_ALIGN)
    delta, new_xh, nnz = delta_encode_pallas(
        xp, xhp, theta, interpret=interpret,
        act_bits=act_bits, act_frac_bits=act_frac_bits)
    return delta[:f], new_xh[:f], jnp.sum(nnz)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def lstm_pointwise(
    dm: jax.Array, c: jax.Array, *, use_pallas: bool = False, interpret: bool = True
):
    """HPE gate math. dm: [4, H], c: [H] -> (h, c')."""
    if not use_pallas:
        return _ref.lstm_pointwise_ref(dm, c)
    h_dim = c.shape[0]
    pad = (-h_dim) % 512
    if pad:
        dm = jnp.pad(dm, ((0, 0), (0, pad)))
        c = jnp.pad(c, (0, pad))
    h, c_new = lstm_pointwise_pallas(dm, c, interpret=interpret)
    return h[:h_dim], c_new[:h_dim]


@functools.partial(jax.jit, static_argnames=("capacity",))
def select_active_columns(
    delta: jax.Array, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Build the fixed-capacity NZI/NZV lists from a (sparse) delta vector.

    Deterministic policy: if more than ``capacity`` deltas fired, keep the
    largest |delta| (drop-smallest overflow, DESIGN.md §9); padding slots
    get idx=0, val=0.  Returns (idx [K] int32, vals [K], n_dropped).

    Implemented with ``lax.top_k`` on the magnitudes (un-fired slots
    masked to -1): ~5x faster than the full argsort it replaces, and
    bit-identical — top_k orders descending and breaks ties toward the
    lower index, exactly like the old stable ascending argsort of the
    negated magnitudes (the per-frame serving hot path spent more time in
    this sort than in the SpMV itself)."""
    idx, vals, n_dropped = _select_active_columns_batch(delta[None], capacity)
    return idx[0], vals[0], n_dropped[0]


def _select_active_columns_batch(
    delta: jax.Array, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched NZI/NZV core shared by the scalar and _batch wrappers.
    delta [B, F] -> (idx [B, K] int32, vals [B, K], n_dropped [B])."""
    k = min(capacity, delta.shape[-1])
    mag = jnp.abs(delta)
    fired = delta != 0
    masked = jnp.where(fired, mag, -1.0)         # fired mags are > 0
    top_mag, top_idx = jax.lax.top_k(masked, k)
    valid = top_mag > 0
    idx = jnp.where(valid, top_idx, 0).astype(jnp.int32)
    vals = jnp.where(valid, jnp.take_along_axis(delta, top_idx, axis=-1),
                     0).astype(delta.dtype)
    n_dropped = jnp.maximum(
        jnp.sum(fired.astype(jnp.int32), axis=-1) - capacity, 0)
    return idx, vals, n_dropped


def stsp_spmv_xla(
    val: jax.Array, lidx: jax.Array, idx: jax.Array, ds_vals: jax.Array, s: int
) -> jax.Array:
    """XLA gather+scatter-add path (identical math to the Pallas kernel).

    Historically this decompressed CBCSC with an S-wide one-hot einsum —
    O(S) work per stored nonzero, which cratered the batched pool at large
    subcolumn lengths (hidden>=256 / m=16).  The scatter-add formulation
    (``ref.stsp_spmv_scatter_ref``) touches each fetched (value, lidx) pair
    exactly once."""
    return _ref.stsp_spmv_scatter_ref(val, lidx, idx, ds_vals, s)


@functools.partial(jax.jit, static_argnames=("s", "use_pallas", "interpret"))
def stsp_spmv(
    val: jax.Array,
    lidx: jax.Array,
    idx: jax.Array,
    ds_vals: jax.Array,
    *,
    s: int,
    use_pallas: bool = False,
    interpret: bool = True,
    scale: jax.Array | None = None,
) -> jax.Array:
    """y [H] = sum_k ds_vals[k] * W_cbcsc[:, idx[k]]  (fp32).

    ``scale`` dequantizes int8 payloads in the epilogue: the kernels cast
    ``val`` to fp32 internally, so y*scale with a power-of-two per-tensor
    scale is exactly the fp32 result on pre-scaled weights (the multiply
    is exact and commutes with the adds)."""
    if not use_pallas:
        y = stsp_spmv_xla(val, lidx, idx, ds_vals, s)
    else:
        y = stsp_spmv_pallas(val, lidx, idx, ds_vals, s=s, interpret=interpret)
    if scale is not None:
        y = y * scale
    return y


# -- batched (slot-dimension) entry points ---------------------------------
#
# The serving scheduler advances a whole pool of independent streaming
# sessions per frame (serving/batched_engine.py).  These wrappers vmap the
# scalar-session kernels over a leading slot dimension B so one jitted call
# covers the entire pool; weights broadcast (in_axes=None), per-slot state
# maps.  Numerics per row are identical to the unbatched calls (vmap only
# changes the iteration structure), which is what makes the batched engine
# bit-comparable to `SpartusEngine`.


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "act_bits", "act_frac_bits"))
def delta_encode_batch(
    x: jax.Array, x_hat: jax.Array, theta,
    *, use_pallas: bool = False, interpret: bool = True,
    act_bits: int | None = None, act_frac_bits: int = 8,
):
    """Batched eqs. (4)-(5).  x, x_hat: [B, F] -> (delta [B, F],
    new_x_hat [B, F], nnz [B] int32).  ``act_bits`` as in delta_encode."""
    fn = functools.partial(delta_encode, use_pallas=use_pallas,
                           interpret=interpret, act_bits=act_bits,
                           act_frac_bits=act_frac_bits)
    return jax.vmap(fn, in_axes=(0, 0, None))(x, x_hat, theta)


@functools.partial(jax.jit, static_argnames=("capacity",))
def select_active_columns_batch(
    delta: jax.Array, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched NZI/NZV list builder.  delta: [B, F] ->
    (idx [B, K] int32, vals [B, K], n_dropped [B]).  Runs the batched
    top_k directly (not a vmap of the scalar op) so the one sort covers
    the whole pool."""
    return _select_active_columns_batch(delta, capacity)


def spmv_use_dense_gather(s: int, gamma: float) -> bool:
    """Path heuristic for the batched SpMV: the CBCSC scatter path does
    BLEN ~= S*(1-gamma) adds per (PE, active column), the dense-gather path
    does S multiply-adds but on the MXU with no index traffic.  Once
    ``S*(1-gamma) >= 1`` the scatter path has no arithmetic advantage left
    per lane, so large-S models route to the dense mirror and never touch
    the O(S)-per-nonzero decompression that caused the hidden>=256 / m=16
    performance cliff."""
    return s * (1.0 - gamma) >= 1.0


@hotpath_contract("stsp_spmv_batch")
@functools.partial(jax.jit, static_argnames=("s", "use_pallas", "interpret"))
def stsp_spmv_batch(
    val: jax.Array,
    lidx: jax.Array,
    idx: jax.Array,
    ds_vals: jax.Array,
    *,
    s: int,
    use_pallas: bool = False,
    interpret: bool = True,
    w_dense: jax.Array | None = None,
    scale: jax.Array | None = None,
) -> jax.Array:
    """Batched STSP SpMxSpV: shared CBCSC weights, per-slot active lists.
    idx, ds_vals: [B, K] -> y [B, H].

    Three implementations, selected at pack time (serving/engine.py applies
    ``spmv_use_dense_gather``):
      * ``w_dense`` given — dense-gather fallback: one [B, K] panel gather
        from the pack-time dense mirror + an MXU matmul (no CBCSC decode in
        the hot loop at all);
      * ``use_pallas`` — single batched Pallas scatter kernel over grid
        (B, K) (one pallas_call for the whole pool, not a vmap of B calls);
      * otherwise — vmap of the XLA scatter-add path.

    ``scale`` dequantizes int8 payloads (CBCSC val or dense mirror) in the
    epilogue — one fp32 multiply on the [B, H] result, exact for the
    power-of-two per-tensor scales the pack emits, so weight memory stays
    int8 at rest on every route.
    """
    if w_dense is not None:
        y = delta_spmv_dense_gather_batch(w_dense, idx, ds_vals)
    elif use_pallas:
        y = stsp_spmv_scatter_batch_pallas(val, lidx, idx, ds_vals, s=s,
                                           interpret=interpret)
    else:
        fn = functools.partial(stsp_spmv, s=s, use_pallas=False,
                               interpret=interpret)
        y = jax.vmap(fn, in_axes=(None, None, 0, 0))(val, lidx, idx, ds_vals)
    if scale is not None:
        y = y * scale
    return y


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def lstm_pointwise_batch(
    dm: jax.Array, c: jax.Array, *, use_pallas: bool = False, interpret: bool = True
):
    """Batched HPE gate math.  dm: [B, 4, H], c: [B, H] -> (h, c') [B, H]."""
    fn = functools.partial(lstm_pointwise, use_pallas=use_pallas,
                           interpret=interpret)
    return jax.vmap(fn)(dm, c)


@hotpath_contract("gather_frames", op_budget={"gather": 1})
def gather_frames(frames: jax.Array, cursor: jax.Array) -> jax.Array:
    """Gather each slot's current frame from its device-resident buffer.

    frames [B, T_buf, D], cursor [B] int32 -> x [B, D].  The cursor is
    clamped to the buffer (slots whose cursor ran past their utterance are
    masked inactive by the caller, so the clamped garbage row is never
    consumed).  Deliberately not jit-wrapped: it is traced inline by the
    serving step/chunk functions — including inside `jax.lax.scan`, where
    the chunked tick loop (batched_engine.step_chunk) calls it once per
    scan iteration with the carried cursor.

    Implemented as ``take_along_axis`` over the time axis (batch dims
    aligned) rather than ``frames[arange(B), cursor]``: identical rows,
    but the aligned-batch form partitions cleanly when the slot dimension
    is sharded across devices — GSPMD keeps the gather local per shard,
    where the iota-indexed form inserted an all-gather of the indices
    plus an all-reduce of the result on EVERY scan iteration (measured on
    the emulated-device mesh; the sharded pool's zero-communication
    steady state depends on this)."""
    t_buf = frames.shape[1]
    idx = jnp.minimum(cursor, t_buf - 1).astype(jnp.int32)[:, None, None]
    return jnp.take_along_axis(frames, idx, axis=1)[:, 0]


@hotpath_contract("bank_rows", forbid_ops=("scatter",),
                  op_budget={"dynamic-update-slice": 1})
def bank_rows(
    buf: jax.Array, rows: jax.Array, start: jax.Array
) -> jax.Array:
    """Bank one chunk's stacked logits into the per-slot output buffers.

    buf [B, T_pad, C], rows [N, B, C] (a lax.scan's stacked per-iteration
    outputs), start [B] int32 -> updated buf, where slot b's rows land at
    ``buf[b, start[b] : start[b]+N]``.  One vmapped dynamic_update_slice
    per chunk — far cheaper on CPU than a scatter per scan iteration.
    The caller guarantees ``start[b] + N <= T_pad`` (the serving pool pads
    the buffer's time axis by chunk_frames), so the slice never clamps;
    rows written past a session's utterance length are scratch that no
    reader ever consumes (retirement fetches ``[:n_frames]``)."""
    per_slot = jnp.swapaxes(rows, 0, 1)          # [B, N, C]

    def one(buf_b, rows_b, start_b):
        return jax.lax.dynamic_update_slice(buf_b, rows_b, (start_b, 0))

    return jax.vmap(one)(buf, per_slot, start)


@hotpath_contract("gather_rows",
                  forbid_ops=("scatter", "dynamic-update-slice"))
def gather_rows(buf: jax.Array, start: jax.Array, n: int) -> jax.Array:
    """Inverse of ``bank_rows``: slice each slot's last-banked chunk back out.

    buf [B, T_pad, C], start [B] int32, static n -> rows [B, n, C], where
    row b is ``buf[b, start[b] : start[b]+n]``.  One vmapped dynamic_slice
    — the partial-logits streaming path (`SessionPool.stream_partials`)
    uses it to snapshot ONLY the chunk's rows for every live slot, so a
    streamed chunk costs a [B, n, C] copy + fetch instead of re-copying
    the whole [B, T_pad, C] output buffer.  The caller guarantees
    ``start[b] + n <= T_pad`` (the serving pool pads the buffer's time
    axis by chunk_frames), so the slice never clamps."""
    def one(buf_b, st):
        return jax.lax.dynamic_slice(buf_b, (st, 0), (n, buf_b.shape[-1]))

    return jax.vmap(one)(buf, start)


def delta_spmv_dense_gather(
    w: jax.Array, idx: jax.Array, ds_vals: jax.Array
) -> jax.Array:
    """Temporal-sparsity-only path: gather dense columns of w [H, Q] by the
    active index list and run one [H, K] x [K] MXU matmul.  Used when the
    weights are not CBCSC-packed (e.g. unpruned baselines) and as the
    batch-1 leg of the large-S dense mirror path (spmv_use_dense_gather)."""
    panel = jnp.take(w, idx, axis=1)             # [H, K]
    return panel @ ds_vals


@hotpath_contract("delta_spmv_dense_topk", forbid_ops=("transpose",),
                  op_budget={"dot": 1, "sort": 1})
@functools.partial(jax.jit, static_argnames=("capacity",))
def delta_spmv_dense_topk_batch(
    wt: jax.Array, delta: jax.Array, capacity: int,
    scale: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused capacity enforcement + dense-mirror SpMV: wt [Q, H]
    (pre-transposed mirror), delta [B, Q] -> (y [B, H], n_dropped [B]).

    The dense-mirror path never consumes the NZI/NZV *lists* — only the
    dense delta slab with the over-capacity tail zeroed.  So instead of
    top_k -> gather -> scatter-back-to-dense (the scatter dominated the
    serving step at hidden=128), enforce capacity directly in the dense
    domain: keep a column iff its |delta| beats the K-th largest, with
    boundary ties broken toward the lower index via a cumulative tie
    rank.  That reproduces ``select_active_columns_batch`` +
    ``delta_spmv_dense_gather_batch`` BIT-EXACTLY (same kept set, same
    GEMM contraction).  Two more CPU-motivated savings:

      * the clip runs under a ``lax.cond`` on "did ANY row overflow" —
        at serving sparsity the NZI capacity almost never binds, so the
        steady state pays one reduction instead of a top_k + cumsum
        (whose XLA CPU lowering costs more than the GEMM itself);
      * the mirror is stored pre-transposed [Q, H]: XLA does not hoist
        the transpose of `w.T` out of the per-tick dot on CPU, which
        made the un-transposed GEMM ~3x slower.

    ``capacity >= Q`` (nothing can ever drop) skips the cond too.

    ``scale`` dequantizes an int8 mirror in the GEMM epilogue (y*scale,
    exact for power-of-two per-tensor scales): the mirror stays int8 at
    rest and is only widened inside the GEMM fusion."""
    b, q = delta.shape
    k = min(capacity, q)
    fired = delta != 0
    n_fired = jnp.sum(fired.astype(jnp.int32), axis=-1)
    n_dropped = jnp.maximum(n_fired - capacity, 0)

    def clip(d):
        mag = jnp.abs(d)
        masked = jnp.where(d != 0, mag, -1.0)
        top_mag, _ = jax.lax.top_k(masked, k)
        thresh = top_mag[:, -1:]                  # K-th largest (or -1)
        above = (d != 0) & (mag > thresh)
        ties = (d != 0) & (mag == thresh)
        n_above = jnp.sum(above.astype(jnp.int32), axis=-1, keepdims=True)
        tie_rank = jnp.cumsum(ties.astype(jnp.int32), axis=-1)
        keep = above | (ties & (tie_rank <= k - n_above))
        return jnp.where(keep, d, 0.0)

    if k >= q:
        ds_dense = delta                          # un-fired entries are 0
    else:
        ds_dense = jax.lax.cond(
            jnp.any(n_dropped > 0), clip, lambda d: d, delta)
    y = ds_dense.astype(jnp.float32) @ wt.astype(jnp.float32)
    if scale is not None:
        y = y * scale
    return y, n_dropped


def delta_spmv_dense_gather_batch(
    w: jax.Array, idx: jax.Array, ds_vals: jax.Array
) -> jax.Array:
    """Batched dense-mirror SpMV: w [H, Q], idx/ds_vals [B, K] -> y [B, H].

    The [B, K] active lists are scattered back to a dense [B, Q] delta
    slab (one cheap gather-free scatter-add; duplicate indices accumulate,
    padding slots carry 0.0) and contracted against the mirror in a single
    [B, Q] x [Q, H] MXU matmul.  Unlike a per-slot [B, K, H] column-panel
    gather — whose weight traffic grows with B — the GEMM reads the mirror
    ONCE per tick regardless of pool size, which is exactly the
    weight-fetch amortisation continuous batching exists for.  Exploits
    temporal sparsity only; spatial sparsity is already priced into the
    pack-time mirror's zeros."""
    b, k = idx.shape
    slot = jnp.arange(b, dtype=idx.dtype)[:, None]
    ds_dense = jnp.zeros((b, w.shape[1]), jnp.float32).at[
        jnp.broadcast_to(slot, (b, k)), idx
    ].add(ds_vals.astype(jnp.float32))
    return ds_dense @ w.T.astype(jnp.float32)
