"""Pallas TPU kernels for the Spartus compute hot-spots.

delta_encode    — DPE: thresholded delta + reference update (Fig. 6)
stsp_spmv       — MAC arrays: spatio-temporal sparse MxV over CBCSC (Fig. 2/9)
lstm_pointwise  — HPE: fused gate nonlinearities + cell update (Fig. 8)

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper (with XLA
fallback) in ops.py.  See tests/test_kernels.py for the shape/dtype sweeps.
"""
from repro.kernels import ops, ref
from repro.kernels.delta_encode import delta_encode_pallas
from repro.kernels.lstm_pointwise import lstm_pointwise_pallas
from repro.kernels.stsp_spmv import (
    stsp_spmv_pallas,
    stsp_spmv_scatter_batch_pallas,
)
