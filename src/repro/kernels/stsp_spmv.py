"""Pallas TPU kernel: spatio-temporal sparse matrix-vector product — the
heart of the Spartus accelerator (Fig. 2/4/9), adapted for TPU.

Semantics (one DeltaLSTM/DeltaLinear step):

    y[H] = sum_{k < K} ds_vals[k] * W[:, idx[k]]

where W is stored in CBCSC (core/cbcsc.py): ``val/lidx [Q, M, BLEN]``,
row r = lidx*M + pe.  Only the K *active* columns (nonzero deltas) are
touched — temporal sparsity — and only BLEN nonzeros per subcolumn are
stored/fetched — spatial sparsity.

TPU adaptation of the FPGA dataflow (DESIGN.md §2):
  * NZI list -> scalar-prefetched index vector: the grid's DMA engine
    fetches exactly the CBCSC slabs of active columns from HBM
    (``index_map`` reads ``idx_ref[k]``) — this is the "CTRL generates
    physical WMEM addresses from NZIs" step of Sec. IV-A;
  * per-PE LUTRAM scatter -> S-wide one-hot contraction in VMEM: each PE's
    BLEN (value, lidx) pairs expand to its S-length subcolumn on the VPU;
    with S = 8..32 this costs S*(1-gamma) multiplies per dense-equivalent
    element (< 1 at the paper's gamma) and stays sublane-aligned;
  * MAC-array partial sums -> an [S, M] fp32 VMEM accumulator, revisited
    across the K grid steps ("arbitrary" dimension semantics) and written
    once at k = K-1.

Workload balance: CBCSC guarantees every "PE" (lane) sees exactly BLEN
pairs per active column — the same argument as the paper's Sec. III-C,
with the memory-interface arbitration replaced by a fixed-shape DMA.

The XLA fallback (ops.stsp_spmv_xla) implements the identical math with
gather + einsum for non-TPU backends and for batched serving.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stsp_kernel(idx_ref, ds_ref, val_ref, lidx_ref, y_ref, *, s: int, k_total: int):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    val = val_ref[0]                       # [M, BLEN] this column's slab
    lidx = lidx_ref[0]                     # [M, BLEN]
    ds = ds_ref[0]                         # scalar delta value

    # one-hot expand each PE's subcolumn: [M, BLEN, S] -> contribution [S, M]
    onehot = (lidx[..., None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, s), 2))
    contrib = jnp.einsum(
        "mb,mbs->sm",
        val.astype(jnp.float32),
        onehot.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    y_ref[...] += ds.astype(jnp.float32) * contrib


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def stsp_spmv_pallas(
    val: jax.Array,      # [Q, M, BLEN]
    lidx: jax.Array,     # [Q, M, BLEN] int32
    idx: jax.Array,      # [K] int32 active columns (pad: any valid id)
    ds_vals: jax.Array,  # [K] float (pad: 0.0)
    *,
    s: int,
    interpret: bool = True,
) -> jax.Array:
    """Returns y [H] = [S*M] in fp32.  K is static (capacity-padded)."""
    q, m, blen = val.shape
    k_total = idx.shape[0]

    kernel = functools.partial(_stsp_kernel, s=s, k_total=k_total)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k_total,),
        in_specs=[
            pl.BlockSpec((1,), lambda k, idx_ref: (k,)),               # ds_vals
            pl.BlockSpec((1, m, blen), lambda k, idx_ref: (idx_ref[k], 0, 0)),
            pl.BlockSpec((1, m, blen), lambda k, idx_ref: (idx_ref[k], 0, 0)),
        ],
        out_specs=pl.BlockSpec((s, m), lambda k, idx_ref: (0, 0)),
    )
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, m), jnp.float32),
        interpret=interpret,
        compiler_params=(
            pltpu.CompilerParams(dimension_semantics=("arbitrary",))
            if not interpret
            else None
        ),
    )(idx, ds_vals, val, lidx)
    return y.reshape(s * m)
