"""Pallas TPU kernel: spatio-temporal sparse matrix-vector product — the
heart of the Spartus accelerator (Fig. 2/4/9), adapted for TPU.

Semantics (one DeltaLSTM/DeltaLinear step):

    y[H] = sum_{k < K} ds_vals[k] * W[:, idx[k]]

where W is stored in CBCSC (core/cbcsc.py): ``val/lidx [Q, M, BLEN]``,
row r = lidx*M + pe.  Only the K *active* columns (nonzero deltas) are
touched — temporal sparsity — and only BLEN nonzeros per subcolumn are
stored/fetched — spatial sparsity.

TPU adaptation of the FPGA dataflow (DESIGN.md §2):
  * NZI list -> scalar-prefetched index vector: the grid's DMA engine
    fetches exactly the CBCSC slabs of active columns from HBM
    (``index_map`` reads ``idx_ref[k]``) — this is the "CTRL generates
    physical WMEM addresses from NZIs" step of Sec. IV-A;
  * per-PE LUTRAM scatter -> S-wide one-hot contraction in VMEM: each PE's
    BLEN (value, lidx) pairs expand to its S-length subcolumn on the VPU;
    with S = 8..32 this costs S*(1-gamma) multiplies per dense-equivalent
    element (< 1 at the paper's gamma) and stays sublane-aligned;
  * MAC-array partial sums -> an [S, M] fp32 VMEM accumulator, revisited
    across the K grid steps ("arbitrary" dimension semantics) and written
    once at k = K-1.

Workload balance: CBCSC guarantees every "PE" (lane) sees exactly BLEN
pairs per active column — the same argument as the paper's Sec. III-C,
with the memory-interface arbitration replaced by a fixed-shape DMA.

The XLA fallback (ops.stsp_spmv_xla) implements the identical math with
gather + scatter-add for non-TPU backends; batched serving uses either
``stsp_spmv_scatter_batch_pallas`` below (one pallas_call over grid (B, K),
scatter-add into each slot's [S, M] accumulator) or the pack-time dense
mirror (ops.delta_spmv_dense_gather_batch) when S*(1-gamma) >= 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names it TPUCompilerParams; 0.5+ renamed to CompilerParams.
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or getattr(
    pltpu, "CompilerParams")


def _stsp_kernel(idx_ref, ds_ref, val_ref, lidx_ref, y_ref, *, s: int, k_total: int):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    val = val_ref[0]                       # [M, BLEN] this column's slab
    lidx = lidx_ref[0]                     # [M, BLEN]
    ds = ds_ref[0]                         # scalar delta value

    # one-hot expand each PE's subcolumn: [M, BLEN, S] -> contribution [S, M]
    onehot = (lidx[..., None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, s), 2))
    contrib = jnp.einsum(
        "mb,mbs->sm",
        val.astype(jnp.float32),
        onehot.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    y_ref[...] += ds.astype(jnp.float32) * contrib


def _stsp_scatter_batch_kernel(idx_ref, ds_ref, val_ref, lidx_ref, y_ref, *, s: int):
    """Batched scatter variant: one (slot, active-column) pair per grid step.

    Instead of expanding each PE's BLEN (value, lidx) pairs into an S-wide
    one-hot and contracting (O(S) work per nonzero), the accumulator tile is
    indexed *directly* with ``lidx`` — a scatter-add into the [S, M] VMEM
    block, O(1) per nonzero.  This is the literal per-PE LUTRAM write of the
    FPGA MAC array (Sec. IV-A) rather than its one-hot algebraic encoding.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    val = val_ref[0]                       # [M, BLEN] this column's slab
    lidx = lidx_ref[0]                     # [M, BLEN]
    ds = ds_ref[0, 0]                      # scalar delta value
    m, blen = val.shape
    pe = jax.lax.broadcasted_iota(jnp.int32, (m, blen), 0)
    contrib = (
        jnp.zeros((s, m), jnp.float32)
        .at[lidx, pe]
        .add(ds.astype(jnp.float32) * val.astype(jnp.float32))
    )
    y_ref[0] += contrib


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def stsp_spmv_scatter_batch_pallas(
    val: jax.Array,      # [Q, M, BLEN]
    lidx: jax.Array,     # [Q, M, BLEN] int32
    idx: jax.Array,      # [B, K] int32 active columns per slot (pad: any id)
    ds_vals: jax.Array,  # [B, K] float (pad: 0.0)
    *,
    s: int,
    interpret: bool = True,
) -> jax.Array:
    """Batched STSP SpMxSpV: y [B, H] = sum_k ds[b, k] * W[:, idx[b, k]].

    One pallas_call for the whole pool: grid (B, K), slots parallel, the K
    active columns of each slot revisiting that slot's [S, M] accumulator
    ("arbitrary" semantics).  The scalar-prefetched [B, K] NZI table steers
    the DMA so only active columns' CBCSC slabs are fetched from HBM —
    the weight-fetch economy of the paper's NZI dataflow, kept intact under
    batching (no one-hot materialisation, no [K, M, BLEN, S] temporaries).
    """
    q, m, blen = val.shape
    b, k_total = idx.shape

    kernel = functools.partial(_stsp_scatter_batch_kernel, s=s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, k_total),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, kk, idx_ref: (bb, kk)),   # ds_vals
            pl.BlockSpec((1, m, blen),
                         lambda bb, kk, idx_ref: (idx_ref[bb, kk], 0, 0)),
            pl.BlockSpec((1, m, blen),
                         lambda bb, kk, idx_ref: (idx_ref[bb, kk], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, m), lambda bb, kk, idx_ref: (bb, 0, 0)),
    )
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, s, m), jnp.float32),
        interpret=interpret,
        compiler_params=(
            _CompilerParams(dimension_semantics=("parallel", "arbitrary"))
            if not interpret
            else None
        ),
    )(idx, ds_vals, val, lidx)
    return y.reshape(b, s * m)


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def stsp_spmv_pallas(
    val: jax.Array,      # [Q, M, BLEN]
    lidx: jax.Array,     # [Q, M, BLEN] int32
    idx: jax.Array,      # [K] int32 active columns (pad: any valid id)
    ds_vals: jax.Array,  # [K] float (pad: 0.0)
    *,
    s: int,
    interpret: bool = True,
) -> jax.Array:
    """Returns y [H] = [S*M] in fp32.  K is static (capacity-padded)."""
    q, m, blen = val.shape
    k_total = idx.shape[0]

    kernel = functools.partial(_stsp_kernel, s=s, k_total=k_total)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k_total,),
        in_specs=[
            pl.BlockSpec((1,), lambda k, idx_ref: (k,)),               # ds_vals
            pl.BlockSpec((1, m, blen), lambda k, idx_ref: (idx_ref[k], 0, 0)),
            pl.BlockSpec((1, m, blen), lambda k, idx_ref: (idx_ref[k], 0, 0)),
        ],
        out_specs=pl.BlockSpec((s, m), lambda k, idx_ref: (0, 0)),
    )
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, m), jnp.float32),
        interpret=interpret,
        compiler_params=(
            _CompilerParams(dimension_semantics=("arbitrary",))
            if not interpret
            else None
        ),
    )(idx, ds_vals, val, lidx)
    return y.reshape(s * m)
