"""Pallas TPU kernel: fused delta encoding (the Spartus DPE, Fig. 6).

Computes eqs. (4)-(5) in one pass over the state vector: thresholded delta,
reference-state update, and per-block nonzero counts (the NZV occupancy
used for capacity selection and balance-ratio statistics).

TPU mapping: the state vector is viewed as [R, 128] (lane-aligned); the
grid walks row-blocks of 8 sublanes, so each step owns one (8, 128) VMEM
tile — the elementwise threshold/select runs entirely on the VPU.  The
per-block count is a scalar write to SMEM-resident output.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 8
LANES = 128


def _delta_encode_kernel(
    theta_ref, x_ref, xh_ref, delta_ref, xh_out_ref, nnz_ref,
    *, act_bits: Optional[int] = None, act_frac_bits: int = 8,
):
    x = x_ref[...]
    xh = xh_ref[...]
    if act_bits is not None:
        # Snap the incoming state to the Qm.n grid in-register (the DPE's
        # fixed-point view); xh is already on-grid by induction because
        # xh_out below stores the quantized x.  Saturating clip, matching
        # core.quantization.quantize_act (wrapper pre-quantizes theta).
        scale = 2.0 ** (-act_frac_bits)
        qmax = 2.0 ** (act_bits - 1) - 1
        x = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax) * scale
    raw = x - xh
    fired = jnp.abs(raw) > theta_ref[0]
    delta_ref[...] = jnp.where(fired, raw, jnp.zeros_like(raw))
    xh_out_ref[...] = jnp.where(fired, x, xh)
    nnz_ref[0] = jnp.sum(fired.astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("interpret", "act_bits", "act_frac_bits"))
def delta_encode_pallas(
    x: jax.Array, x_hat: jax.Array, theta: jax.Array, *,
    interpret: bool = True,
    act_bits: Optional[int] = None, act_frac_bits: int = 8,
):
    """x, x_hat: [F] with F % (8*128) == 0 (callers pad; see ops.py).

    Returns (delta [F], new_x_hat [F], nnz_per_block [F/1024] int32).
    With ``act_bits`` the threshold comparison runs on the Qm.n grid
    (see ops.delta_encode); theta is snapped here, x inside the kernel.
    """
    f = x.shape[0]
    assert f % (BLOCK_ROWS * LANES) == 0, f"F={f} must be padded to 1024"
    rows = f // LANES
    n_blocks = rows // BLOCK_ROWS
    x2 = x.reshape(rows, LANES)
    xh2 = x_hat.reshape(rows, LANES)
    theta_arr = jnp.asarray(theta, x.dtype).reshape(1)
    if act_bits is not None:
        from repro.core.quantization import quantize_act
        theta_arr = quantize_act(theta_arr, act_bits, act_frac_bits)

    delta, new_xh, nnz = pl.pallas_call(
        functools.partial(_delta_encode_kernel, act_bits=act_bits,
                          act_frac_bits=act_frac_bits),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (0,)),                     # theta
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda b: (b, 0)),    # x
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda b: (b, 0)),    # x_hat
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda b: (b, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), x.dtype),
            jax.ShapeDtypeStruct((rows, LANES), x.dtype),
            jax.ShapeDtypeStruct((n_blocks,), jnp.int32),
        ],
        interpret=interpret,
    )(theta_arr, x2, xh2)
    return delta.reshape(f), new_xh.reshape(f), nnz
