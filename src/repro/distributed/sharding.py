"""Partition rules: DP x FSDP x TP (x pod) PartitionSpecs for every arch.

Strategy (DESIGN.md §5):
  * batch dims shard over ("pod","data") when divisible;
  * TP: attention heads / ffn / experts / vocab shard over "model";
  * FSDP: the non-TP dim of every large matrix shards over "data"
    (XLA all-gathers per layer inside the scan = standard FSDP re-gather);
  * any dim not divisible by its axis size falls back to replication —
    rules never produce invalid shardings (this is what makes one rule
    table serve 10 architectures).

Rules are name-substring keyed, most-specific-first; each value is a
callable (shape, mesh) -> PartitionSpec so divisibility is checked against
the actual leaf.
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, data_axes
from repro.models.config import ArchConfig


def _div(dim: int, mesh, *axes: str):
    """Return the axis group if it divides dim, else None (replicate)."""
    if not axes:
        return None
    size = axis_size(mesh, *axes)
    if size > 1 and dim % size == 0:
        return axes if len(axes) > 1 else axes[0]
    return None


def _spec_matmul(shape, mesh, tp_dim: int, fsdp_dim: int,
                 fsdp: bool = True, tp: bool = True) -> P:
    """Spec for a (possibly layer/expert-stacked) matrix: put "model" on
    ``tp_dim`` (negative index from the end), "data" on ``fsdp_dim``."""
    nd = len(shape)
    spec = [None] * nd
    if tp:
        ax = _div(shape[nd + tp_dim], mesh, "model")
        if ax:
            spec[nd + tp_dim] = ax
    if fsdp:
        fs = _div(shape[nd + fsdp_dim], mesh, "data")
        if fs:
            spec[nd + fsdp_dim] = fs
    return P(*spec)


# name-pattern -> (tp_dim, fsdp_dim) on the trailing two axes of the leaf.
# weights are [out, in]:  column-parallel => tp on -2, row-parallel => tp on -1.
_MATRIX_RULES = [
    # attention: q/k/v column-parallel (heads), o row-parallel
    (r"attn/(q|k|v)/w$", (-2, -1)),
    (r"attn/o/w$", (-1, -2)),
    (r"(self|cross)_attn/(q|k|v)/w$", (-2, -1)),
    (r"(self|cross)_attn/o/w$", (-1, -2)),
    # MLP: gate/up column-parallel (ffn), down row-parallel
    (r"mlp/(gate|up)/w$", (-2, -1)),
    (r"mlp/down/w$", (-1, -2)),
    # lstm AM
    (r"w_x$", (-2, -1)),
    (r"w_h$", (-2, -1)),
    (r"fcl/w$", (-2, -1)),
    # rglru block
    (r"rglru/(in_x|in_y)/w$", (-2, -1)),
    (r"rglru/(gate_a|gate_i)/w$", (-2, -1)),
    (r"rglru/out/w$", (-1, -2)),
    # mamba2
    (r"in_proj/w$", (-2, -1)),
    (r"out_proj/w$", (-1, -2)),
    # heads / embeddings: vocab-parallel
    (r"lm_head/w$", (-2, -1)),
    (r"logit/w$", (-2, -1)),
]

# MoE experts: [.., E, ff, d] / [.., E, d, ff] — expert-parallel over model,
# FSDP over the trailing input dim.
_MOE_RULES = [
    (r"moe/(gate|up)$", ("model", None, "data")),
    (r"moe/down$", ("model", None, "data")),
    (r"moe/router/w$", None),
]


def _heads_shardable(name: str, cfg: Optional[ArchConfig], mesh) -> bool:
    """Attention projections may TP-shard only if the *head count* divides
    the model-axis size — otherwise the [B,S,H,hd] activation view cannot
    stay head-aligned and XLA reshards every layer (measured: 100x temp
    blow-up on qwen2's 14-head attention at model=16)."""
    if cfg is None:
        return True
    tp = axis_size(mesh, "model")
    if tp <= 1:
        return True
    if re.search(r"attn/(q|o)/", name):
        return cfg.n_heads % tp == 0
    if re.search(r"attn/(k|v)/", name):
        return cfg.n_kv_heads % tp == 0
    return True


def param_spec(name: str, shape: Tuple[int, ...], mesh,
               cfg: Optional[ArchConfig] = None) -> P:
    from repro.perf import current

    if len(shape) < 2:
        return P(*([None] * len(shape)))

    if current().fsdp_sp and len(shape) >= 2:
        # §Perf variant: no TP — weights shard over BOTH axes (2-D FSDP)
        # and are all-gathered per layer; activations stay seq-sharded.
        nd = len(shape)
        spec = [None] * nd
        if _div(shape[-1], mesh, "data"):
            spec[-1] = "data"
        if _div(shape[-2], mesh, "model"):
            spec[-2] = "model"
        return P(*spec)
    for pat, dims in _MOE_RULES:
        if re.search(pat, name):
            if dims is None:
                return P()
            nd = len(shape)
            spec = [None] * nd
            e_ax = nd - 3
            if _div(shape[e_ax], mesh, "model"):
                spec[e_ax] = "model"
            if dims[2] and _div(shape[nd - 1], mesh, "data"):
                spec[nd - 1] = "data"
            return P(*spec)
    for pat, (tp_dim, fsdp_dim) in _MATRIX_RULES:
        if re.search(pat, name):
            if "attn/" in pat and not _heads_shardable(name, cfg, mesh):
                # FSDP-only fallback: shard the input dim over "data"
                return _spec_matmul(shape, mesh, tp_dim, fsdp_dim,
                                    fsdp=True, tp=False)
            return _spec_matmul(shape, mesh, tp_dim, fsdp_dim)
    if re.search(r"embed$", name):
        # vocab gather stays local; FSDP over the feature dim only
        nd = len(shape)
        spec = [None] * nd
        if _div(shape[-1], mesh, "data"):
            spec[-1] = "data"
        return P(*spec)
    # rglru per-channel params [.., W]
    if re.search(r"(lambda_raw|conv_w|conv_b)$", name) and shape:
        spec = [None] * len(shape)
        if _div(shape[-1], mesh, "model"):
            spec[-1] = "model"
        return P(*spec)
    return P()  # norms, biases, scalars: replicate


def _leaf_name(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def param_specs(params, mesh, cfg: Optional[ArchConfig] = None):
    """PartitionSpec pytree for a parameter (or Adam m/v) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(_leaf_name(path), leaf.shape, mesh, cfg),
        params,
    )


def param_shardings(params, mesh, cfg: Optional[ArchConfig] = None):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, cfg))


# -- batch / cache ------------------------------------------------------------


def batch_spec(shape: Tuple[int, ...], mesh) -> P:
    """Shard dim0 (global batch) over (pod, data) when divisible."""
    return slot_spec(shape, mesh, dim=0)


def slot_spec(shape: Tuple[int, ...], mesh, dim: int = 0) -> P:
    """Slot/batch-dimension data parallelism: shard ``dim`` over the
    (pod, data) axes when divisible, else replicate — the same
    never-invalid rule the training specs follow.  The serving pool uses
    this for every per-slot slab in `PoolState` (layer state and cursors
    on dim 0, the `[L, B]` telemetry accumulators on dim 1, frame/logits
    buffers on dim 0), so one rule keeps a whole pool consistently
    slot-sharded or consistently replicated."""
    dp = data_axes(mesh)
    ax = _div(shape[dim], mesh, *dp)
    spec = [None] * len(shape)
    if ax:
        spec[dim] = ax
    return P(*spec)


def batch_specs(batch_tree, mesh):
    return jax.tree.map(lambda l: batch_spec(l.shape, mesh), batch_tree)


def cache_spec(name: str, shape: Tuple[int, ...], mesh) -> P:
    """KV/state caches: [L, B, ...] — batch over (pod,data) on dim1, heads
    over model where divisible.  Scalars (pos) replicate."""
    if len(shape) == 0:
        return P()
    dp = data_axes(mesh)
    spec = [None] * len(shape)
    if len(shape) >= 2:
        ax = _div(shape[1], mesh, *dp)
        if ax:
            spec[1] = ax
    # kv caches [L, B, S, H, hd]: try heads; ssd [L,B,H,P,N]: try heads;
    # rglru h [n,B,W] / conv [n,B,K,W]: try trailing width.
    if re.search(r"/(k|v)$", name) and len(shape) == 5:
        if _div(shape[3], mesh, "model"):
            spec[3] = "model"
        elif _div(shape[2], mesh, "model"):
            # MQA/GQA with too few kv heads for the model axis: shard the
            # cache SEQUENCE dim instead (32k decode caches at kv=1 would
            # otherwise replicate 11.8 GiB/device over the model axis)
            spec[2] = "model"
    elif re.search(r"ssd$", name) and len(shape) == 5:
        if _div(shape[2], mesh, "model"):
            spec[2] = "model"
    elif len(shape) >= 3 and re.search(r"(h|conv)$", name):
        if _div(shape[-1], mesh, "model"):
            spec[-1] = "model"
    return P(*spec)


def cache_specs(cache_tree, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(
            _leaf_name(path), getattr(leaf, "shape", ()), mesh
        ),
        cache_tree,
    )


def to_shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
