"""Gradient compression for cross-pod reduction (DESIGN.md §5).

Error-feedback int8 compression: quantize (gradient + residual) to int8
per-tensor before the cross-pod all-reduce, keep the quantization error
as local residual for the next step (Seide et al. / EF-SGD family —
unbiased over time, convergence-safe for the slow cross-pod link).

Also: top-k sparsification with error feedback (the spatio-temporal idea
applied to the *optimizer's* communication: only large deltas travel —
the paper's Sec. I memory-access argument, one level up).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_int8_compress(grads, residual):
    """(grads+residual) -> (int8 payload, scales, new residual)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_r = x - q.astype(jnp.float32) * scale
        return q, scale, new_r

    flat, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    qs, scales, rs = zip(*[one(g, r) for g, r in zip(flat, flat_r)])
    return (treedef.unflatten(list(qs)), treedef.unflatten(list(scales)),
            treedef.unflatten(list(rs)))


def ef_int8_decompress(payload, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, payload, scales
    )


def init_residual(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_topk_compress(grads, residual, frac: float = 0.01):
    """Keep the largest-|.| ``frac`` of each tensor (delta-style temporal
    sparsity on the gradient stream); the rest accumulates locally."""
    def one(g, r):
        x = (g.astype(jnp.float32) + r).reshape(-1)
        k = max(int(x.size * frac), 1)
        mag = jnp.abs(x)
        thresh = jnp.sort(mag)[-k]
        mask = mag >= thresh
        sent = jnp.where(mask, x, 0.0)
        return sent.reshape(g.shape), (x - sent).reshape(g.shape)

    flat, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    sent, rs = zip(*[one(g, r) for g, r in zip(flat, flat_r)])
    return treedef.unflatten(list(sent)), treedef.unflatten(list(rs))


def compressed_psum(grads, residual, axis_name: str, mode: str = "int8"):
    """all-reduce over ``axis_name`` with error-feedback compression.
    Used under shard_map for the cross-pod reduction (the intra-pod
    reduction stays full-precision — ICI is fast, DCI is not)."""
    if mode == "int8":
        q, scales, residual = ef_int8_compress(grads, residual)
        # ints sum exactly; scales are tiny and travel fp32
        summed = jax.tree.map(
            lambda t: jax.lax.psum(t.astype(jnp.int32), axis_name), q
        )
        s_sum = jax.tree.map(lambda s: jax.lax.pmean(s, axis_name), scales)
        out = jax.tree.map(
            lambda t, s: t.astype(jnp.float32) * s, summed, s_sum
        )
    elif mode == "topk":
        sent, residual = ef_topk_compress(grads, residual)
        out = jax.tree.map(lambda t: jax.lax.psum(t, axis_name), sent)
    else:
        out = jax.tree.map(lambda t: jax.lax.psum(t, axis_name), grads)
    return out, residual
