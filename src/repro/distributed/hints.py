"""Optional sharding annotations inside model code.

Model code calls ``constrain(x, "batch", "model", None, ...)`` at layout-
critical points (MoE dispatch buffers, vocab-parallel logits).  Outside a
mesh context this is a no-op, so CPU unit tests and single-device examples
never see sharding machinery.  Inside jit-with-mesh, unknown axis names
are dropped (single-pod meshes have no "pod") and non-divisible dims fall
back to replication — annotations are always valid.

"batch" is a virtual axis name resolving to ("pod", "data").
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _current_axes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if mesh is None or not mesh.axis_names:
        return None
    return mesh


def shard_attn(q: jax.Array, k: jax.Array, v: jax.Array):
    """Layout for an attention block with expanded heads.

    If the head count divides the model axis -> tensor-parallel heads
    (q/k/v all head-sharded).  Otherwise -> sequence-parallel queries
    (q rows sharded over "model", k/v replicated): every device computes
    its own query rows against the full KV, which partitions both the
    O(S^2) score memory and the flops even for awkward head counts
    (e.g. qwen2's 14 heads on a 16-wide model axis).

    Under the ``fsdp_sp`` perf variant, sequence parallelism is forced for
    every arch: weights are FSDP-gathered per layer instead of TP-sharded,
    which removes the per-layer activation all-reduces (EXPERIMENTS.md
    §Perf, granite-34b train)."""
    from repro.perf import current

    mesh = _current_axes()
    if mesh is None or "model" not in mesh.axis_names:
        return q, k, v
    tp = mesh.shape["model"]
    h = q.shape[2]
    force_sp = current().fsdp_sp
    if tp > 1 and h % tp == 0 and not force_sp:
        q = constrain(q, "batch", None, "model", None)
        k = constrain(k, "batch", None, "model", None)
        v = constrain(v, "batch", None, "model", None)
    elif tp > 1 and q.shape[1] % tp == 0:
        q = constrain(q, "batch", "model", None, None)
    return q, k, v


def shard_attn_decode(q: jax.Array, ke: jax.Array, ve: jax.Array,
                      n_kv_heads: int):
    """Decode-step layout: keep the KV cache's own sharding local.

    Head-shardable caches -> head TP (q too).  Otherwise the cache is
    SEQUENCE-sharded (sharding.cache_spec) and gathering ~1 GiB/layer of
    KV per decoded token would dominate the step (measured: 96 GB/step on
    internlm2 decode_32k).  Constraining the expanded K/V to stay
    seq-sharded makes XLA compute per-shard partial attention and combine
    with tiny [B,H] reductions — a distributed flash-decode."""
    from repro.perf import current

    mesh = _current_axes()
    if mesh is None or "model" not in mesh.axis_names:
        return q, ke, ve
    tp = mesh.shape["model"]
    h = q.shape[2]
    s = ke.shape[1]
    # the layout must follow the CACHE: only head-shard when the stored
    # kv heads themselves shard (else XLA re-gathers the cache per step)
    if tp > 1 and n_kv_heads % tp == 0 and h % tp == 0:
        q = constrain(q, "batch", None, "model", None)
        ke = constrain(ke, "batch", None, "model", None)
        ve = constrain(ve, "batch", None, "model", None)
    elif tp > 1 and s % tp == 0 and current().seq_sharded_decode:
        ke = constrain(ke, "batch", "model", None, None)
        ve = constrain(ve, "batch", "model", None, None)
    return q, ke, ve


def constrain(x: jax.Array, *axes) -> jax.Array:
    mesh = _current_axes()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    spec = []
    for dim, ax in enumerate(axes):
        if ax == "batch":
            group = tuple(a for a in ("pod", "data") if a in names)
            size = 1
            for a in group:
                size *= mesh.shape[a]
            if group and size > 1 and x.shape[dim] % size == 0:
                spec.append(group if len(group) > 1 else group[0])
            else:
                spec.append(None)
        elif ax in names and mesh.shape[ax] > 1 and x.shape[dim] % mesh.shape[ax] == 0:
            spec.append(ax)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
