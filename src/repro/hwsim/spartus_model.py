"""Cycle-approximate Spartus performance model (Sec. IV/VI-C, Tables IV/V/VI).

The FPGA cannot run here, so hardware latency/throughput are *modelled*
and driven by the real sparsity statistics measured from the JAX nets
(DESIGN.md §2 "what does not transfer").  The model:

    cycles/step = max_n(WL_t^n) * BLEN + OVH
      WL_t^n : nonzero delta count routed to MAC array n at step t
               (measured masks -> exact; or analytic (1-ts)/N/BR)
      BLEN   : nonzeros per subcolumn = ceil(4H/M * (1-gamma))  [spatial]
      OVH    : pipeline fill + IPU encode + HPE activation overhead
               (calibrated once against Table IV, default 126 cycles)

Validation against the paper (tests/test_hwsim.py):
  * eq. (9) peak:           204.8 GOp/s (Spartus), 1.0 GOp/s (Edge)
  * dense baseline latency: ~46 us for the 123->1024 DeltaLSTM layer
  * Table IV ladder:        +CBTD ~3.3 us, +Delta(0.1) ~1.6 us,
                            +Delta(0.3) ~1.0 us  -> ~9.4 TOp/s effective
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SpartusHW:
    name: str = "Spartus"
    n_arrays: int = 8          # N MAC arrays
    pes_per_array: int = 64    # M PEs per array
    f_clk_hz: float = 200e6
    overhead_cycles: float = 126.0  # calibrated vs Table IV
    # Edge-Spartus fetches weights from DDR3L: bandwidth-bound extra term
    offchip_bytes_per_cycle: float = 0.0  # 0 = on-chip BRAM (big Spartus)

    @property
    def n_macs(self) -> int:
        return self.n_arrays * self.pes_per_array

    def peak_ops(self) -> float:
        """Eq. (9): nu_peak = 2 * f * K."""
        return 2.0 * self.f_clk_hz * self.n_macs


SPARTUS = SpartusHW()
EDGE_SPARTUS = SpartusHW(
    name="Edge-Spartus", n_arrays=1, pes_per_array=4, f_clk_hz=125e6,
    overhead_cycles=126.0,
    # 72-bit @ DDR3L-ish effective rate relative to PL clock (Sec. VII-B)
    offchip_bytes_per_cycle=9.0,
)


@dataclasses.dataclass(frozen=True)
class LayerDims:
    input_dim: int
    hidden_dim: int

    @property
    def n_cols(self) -> int:          # columns of the stacked matrix (eq. 8)
        return self.input_dim + self.hidden_dim

    @property
    def col_height(self) -> int:
        return 4 * self.hidden_dim

    @property
    def dense_macs(self) -> int:
        return self.col_height * self.n_cols

    @property
    def dense_ops(self) -> int:
        return 2 * self.dense_macs


# paper's hardware test network: top of the 2L-1024H AM fed by 123-dim
# features (#Parameters = 4.7 M in Table V = 4*1024*(1024+123))
TEST_LAYER = LayerDims(input_dim=123, hidden_dim=1024)


def blen(hw: SpartusHW, dims: LayerDims, gamma: float) -> int:
    s = dims.col_height // hw.pes_per_array
    return int(np.ceil(s * (1.0 - gamma)))


def step_cycles_from_masks(
    hw: SpartusHW, dims: LayerDims, gamma: float, delta_masks: np.ndarray,
) -> np.ndarray:
    """Exact trace-driven cycles per step.  delta_masks: [T, F] bool of the
    concatenated delta state vector (True = nonzero -> column fetched)."""
    t, f = delta_masks.shape
    pad = (-f) % hw.n_arrays
    if pad:
        delta_masks = np.pad(delta_masks, ((0, 0), (0, pad)))
    wl = delta_masks.reshape(t, hw.n_arrays, -1).sum(-1)        # [T, N]
    max_wl = wl.max(axis=1)
    b = blen(hw, dims, gamma)
    cycles = max_wl * b + hw.overhead_cycles
    if hw.offchip_bytes_per_cycle > 0:
        # weight fetch: VAL(1B)+LIDX(~1.25B) per nonzero, per active column
        bytes_step = wl.sum(axis=1) * b * hw.pes_per_array * 2.25
        cycles = np.maximum(cycles, bytes_step / hw.offchip_bytes_per_cycle)
    return cycles


def step_cycles_analytic(
    hw: SpartusHW, dims: LayerDims, gamma: float, temporal_sparsity: float,
    balance_ratio: float = 1.0,
) -> float:
    """Expected cycles per step from summary statistics (used where no
    trace is available): max workload ~ mean/(BR)."""
    active = (1.0 - temporal_sparsity) * dims.n_cols
    max_wl = active / hw.n_arrays / max(balance_ratio, 1e-6)
    b = blen(hw, dims, gamma)
    cycles = max_wl * b + hw.overhead_cycles
    if hw.offchip_bytes_per_cycle > 0:
        bytes_step = active * b * hw.pes_per_array * 2.25
        cycles = max(cycles, bytes_step / hw.offchip_bytes_per_cycle)
    return float(cycles)


@dataclasses.dataclass
class HWReport:
    name: str
    latency_us: float
    batch1_throughput_gops: float   # effective: dense ops / latency
    peak_gops: float
    speedup_vs_peak: float          # effective / peak ("Speedup" in Table V)
    kfps: float

    def to_dict(self):
        return dataclasses.asdict(self)


def evaluate(
    hw: SpartusHW, dims: LayerDims, gamma: float,
    temporal_sparsity: float = 0.0, balance_ratio: float = 1.0,
    delta_masks: Optional[np.ndarray] = None,
) -> HWReport:
    """Model one DeltaLSTM layer (the paper's batch-1 benchmark)."""
    if delta_masks is not None:
        cycles = float(np.mean(step_cycles_from_masks(hw, dims, gamma,
                                                      delta_masks)))
    else:
        cycles = step_cycles_analytic(hw, dims, gamma, temporal_sparsity,
                                      balance_ratio)
    lat_s = cycles / hw.f_clk_hz
    eff = dims.dense_ops / lat_s
    peak = hw.peak_ops()
    return HWReport(
        name=hw.name,
        latency_us=lat_s * 1e6,
        batch1_throughput_gops=eff / 1e9,
        peak_gops=peak / 1e9,
        speedup_vs_peak=eff / peak,
        kfps=1.0 / lat_s / 1e3,
    )


def evaluate_from_telemetry(
    hw: SpartusHW, dims: LayerDims, gamma: float,
    sparsity: Dict[str, float], balance_ratio: float = 0.75,
) -> HWReport:
    """Model a layer from an *aggregated* telemetry summary — the dict
    produced by the serving engines' ``measured_sparsity()`` (device-side
    accumulators, one host fetch), replacing the old per-step-dict flow.
    Uses ``temporal_sparsity`` and, when present, ``balance_ratio``."""
    return evaluate(
        hw, dims, gamma,
        temporal_sparsity=sparsity.get("temporal_sparsity", 0.0),
        balance_ratio=sparsity.get("balance_ratio", balance_ratio),
    )


def dense_baseline(hw: SpartusHW, dims: LayerDims) -> HWReport:
    """'No Opt.' row of Table IV: dense MxV on the MAC arrays."""
    cycles = dims.dense_macs / hw.n_macs + hw.overhead_cycles
    if hw.offchip_bytes_per_cycle > 0:
        cycles = max(cycles, dims.dense_macs * 1.0 / hw.offchip_bytes_per_cycle)
    lat_s = cycles / hw.f_clk_hz
    return HWReport(
        name=hw.name + " (dense)",
        latency_us=lat_s * 1e6,
        batch1_throughput_gops=dims.dense_ops / lat_s / 1e9,
        peak_gops=hw.peak_ops() / 1e9,
        speedup_vs_peak=(dims.dense_ops / lat_s) / hw.peak_ops(),
        kfps=1.0 / lat_s / 1e3,
    )


def table4_ladder(
    hw: SpartusHW = SPARTUS,
    dims: LayerDims = TEST_LAYER,
    gamma: float = 0.9375,
    ts_by_theta: Optional[Dict[float, float]] = None,
    br_by_theta: Optional[Dict[float, float]] = None,
) -> Dict[str, HWReport]:
    """Reproduce Table IV: No Opt -> +CBTD -> +DeltaLSTM(0.1/0.3).
    Default sparsities are the paper's measured values; callers pass our
    own measured values for the trace-driven reproduction."""
    ts = ts_by_theta or {0.1: 0.7422, 0.3: 0.9060}
    br = br_by_theta or {0.1: 0.80, 0.3: 0.73}
    out = {"no_opt": dense_baseline(hw, dims)}
    out["cbtd"] = evaluate(hw, dims, gamma, temporal_sparsity=0.0,
                           balance_ratio=1.0)
    for theta, t in sorted(ts.items()):
        out[f"delta_{theta}"] = evaluate(hw, dims, gamma, t,
                                         br.get(theta, 0.75))
    return out


# -- Table V / VI constants (prior accelerators, from the paper) --------------

PRIOR_ACCELERATORS = {
    "ESE":       dict(eff_gops=78.6,   power_w=41.0, latency_us=82.7, platform="XCKU060"),
    "DeltaRNN":  dict(eff_gops=1198.0, power_w=7.3,  latency_us=None, platform="XC7Z100"),
    "C-LSTM":    dict(eff_gops=714.3,  power_w=23.0, latency_us=9.1,  platform="XC7VX690T"),
    "E-RNN":     dict(eff_gops=783.1,  power_w=25.0, latency_us=8.3,  platform="XC7VX690T"),
    "BBS":       dict(eff_gops=2432.8, power_w=19.1, latency_us=2.4,  platform="GX1150"),
    "E-LSTM":    dict(eff_gops=403.3,  power_w=15.9, latency_us=23.9, platform="SX660"),
    "EdgeDRNN":  dict(eff_gops=20.2,   power_w=2.3,  latency_us=536.0, platform="XC7Z007S"),
}

SPARTUS_WALL_POWER_W = 8.4       # Table V
EDGE_SPARTUS_WALL_POWER_W = 2.3  # Table VI


def comparison_table(our: HWReport, power_w: float) -> Dict[str, Dict]:
    """Table V-style comparison: ratios of our modelled effective
    throughput / power efficiency to each prior accelerator."""
    ours_eff = our.batch1_throughput_gops
    ours_effW = ours_eff / power_w
    rows = {}
    for name, d in PRIOR_ACCELERATORS.items():
        rows[name] = {
            "eff_gops": d["eff_gops"],
            "throughput_ratio": ours_eff / d["eff_gops"],
            "power_eff_ratio": ours_effW / (d["eff_gops"] / d["power_w"]),
        }
    rows["ours"] = {"eff_gops": ours_eff, "throughput_ratio": 1.0,
                    "power_eff_ratio": 1.0,
                    "power_eff_gopsw": ours_effW}
    return rows
