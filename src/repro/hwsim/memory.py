"""Off-chip DRAM access-energy model (Sec. VII-C, Table VII, Fig. 14).

Energy per inference frame = bits fetched from DRAM x energy/bit.
Spatio-temporal sparsity reduces fetched weight bits by
(1-gamma)x(1-temporal_sparsity) plus the CBCSC index overhead — the
paper reports a 91.7x reduction for Edge-Spartus; we reproduce the
figure from our measured sparsities.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# Table VII: DRAM access energy per bit (pJ)
DRAM_ENERGY_PJ_PER_BIT = {
    "DDR3": 20.3,
    "DDR3L": 16.5,   # scaled from DDR3 by supply voltage (paper footnote)
    "GDDR6": 5.5,
    "HBM2": 3.9,
}


@dataclasses.dataclass(frozen=True)
class FetchModel:
    weight_bits: int = 8
    index_bits: int = 10      # Edge-Spartus LIDX
    act_bits: int = 16


def weight_bits_per_frame(
    n_weights: int, gamma: float, temporal_sparsity: float,
    fm: FetchModel = FetchModel(),
) -> float:
    """Bits of weight traffic for one inference frame (batch-1 MxV)."""
    active_cols = 1.0 - temporal_sparsity
    nnz = n_weights * (1.0 - gamma)
    per_nz_bits = fm.weight_bits + (fm.index_bits if gamma > 0 else 0)
    return nnz * active_cols * per_nz_bits


def dense_bits_per_frame(n_weights: int, fm: FetchModel = FetchModel()) -> float:
    return n_weights * fm.weight_bits


def energy_per_frame_uj(bits: float, dram: str) -> float:
    return bits * DRAM_ENERGY_PJ_PER_BIT[dram] * 1e-12 * 1e6


def fig14_table(
    n_weights: int, gamma: float, temporal_sparsity: float,
    fm: FetchModel = FetchModel(),
) -> Dict[str, Dict[str, float]]:
    """Fig. 14: energy/frame for dense vs CBTD vs spatio-temporal, per
    DRAM type; plus the paper's headline reduction factor."""
    rows = {}
    dense = dense_bits_per_frame(n_weights, fm)
    cbtd = weight_bits_per_frame(n_weights, gamma, 0.0, fm)
    st = weight_bits_per_frame(n_weights, gamma, temporal_sparsity, fm)
    for dram in DRAM_ENERGY_PJ_PER_BIT:
        rows[dram] = {
            "dense_uj": energy_per_frame_uj(dense, dram),
            "cbtd_uj": energy_per_frame_uj(cbtd, dram),
            "spatio_temporal_uj": energy_per_frame_uj(st, dram),
        }
    # the paper's 91.7x headline ignores the CBCSC index bits (pure op/
    # traffic-saving factor 1/((1-gamma)(1-ts))); we report both that and
    # the honest figure including LIDX overhead:
    st_no_idx = weight_bits_per_frame(
        n_weights, gamma, temporal_sparsity,
        FetchModel(fm.weight_bits, 0, fm.act_bits))
    rows["reduction"] = {
        "dense_over_st_with_index": dense / max(st, 1e-9),
        "dense_over_st": dense / max(st_no_idx, 1e-9),
    }
    return rows
