"""Synthetic LM token pipeline for the assigned transformer architectures.

Zipf-distributed unigrams mixed with a first-order Markov back-off so the
streams are learnable (loss decreases measurably within a few hundred
steps) while requiring no disk.  Deterministic in (seed, process, step) —
same sharding contract as data/speech.py.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LMConfig:
    vocab: int = 32000
    seq_len: int = 1024
    zipf_a: float = 1.1
    markov_states: int = 256   # size of the hidden bigram table
    seed: int = 0


def _zipf_logits(cfg: LMConfig) -> jax.Array:
    ranks = jnp.arange(1, cfg.vocab + 1, dtype=jnp.float32)
    return -cfg.zipf_a * jnp.log(ranks)


def _bigram_table(cfg: LMConfig) -> jax.Array:
    """[markov_states, vocab] logits; tokens hash into markov states."""
    key = jax.random.key(cfg.seed + 7)
    return jax.random.gumbel(key, (cfg.markov_states, cfg.vocab)) * 2.0


def sample_tokens(key: jax.Array, cfg: LMConfig, batch: int) -> jax.Array:
    """[B, S+1] token streams (callers slice input/target views)."""
    base = _zipf_logits(cfg)
    table = _bigram_table(cfg)

    def sample_one(k):
        k0, kseq = jax.random.split(k)
        first = jax.random.categorical(k0, base)
        keys = jax.random.split(kseq, cfg.seq_len)

        def step(prev, kk):
            state = prev % cfg.markov_states
            logits = base + table[state]
            tok = jax.random.categorical(kk, logits)
            return tok, tok

        _, toks = jax.lax.scan(step, first, keys)
        return jnp.concatenate([first[None], toks])

    return jax.vmap(sample_one)(jax.random.split(key, batch)).astype(jnp.int32)


class LMDataset:
    """Sharded iterator yielding (tokens [B,S], targets [B,S])."""

    def __init__(self, cfg: LMConfig, batch_per_host: int,
                 process_index: int = 0, start_step: int = 0):
        self.cfg = cfg
        self.batch = batch_per_host
        self.process_index = process_index
        self.step = start_step
        self._root = jax.random.key(cfg.seed + 11)
        self._make = jax.jit(lambda k: sample_tokens(k, cfg, batch_per_host))

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        key = jax.random.fold_in(
            jax.random.fold_in(self._root, self.process_index), self.step
        )
        self.step += 1
        stream = self._make(key)
        return stream[:, :-1], stream[:, 1:]

    def state_dict(self):
        return {"step": self.step}

    def load_state_dict(self, state):
        self.step = int(state["step"])
