"""Synthetic speech-feature pipeline (offline stand-in for TIMIT/Librispeech).

TIMIT/Librispeech are not available offline, so we synthesise sequences
with the *statistical properties the paper's mechanism depends on*:

  * piecewise-stationary "phoneme" segments (geometric durations),
  * slowly-varying (Ornstein-Uhlenbeck) intra-segment feature dynamics —
    this temporal smoothness is exactly what gives delta networks their
    sparsity (Fig. 13a), and its time-constant ``tau`` is a config knob so
    the Theta -> sparsity curve can be swept,
  * 123-dim features mirroring TIMIT's: 41 static (40 Mel-like + energy)
    plus first and second temporal derivatives (Sec. V-B),
  * CTC phoneme targets = the segment class sequence.

Everything is jit-able and deterministic in the dataset key, so any host
in a multi-pod job can materialise its own shard without I/O.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SpeechConfig:
    n_classes: int = 40          # phoneme inventory (excl. blank)
    n_static: int = 41           # 40 Mel-like + energy
    avg_segment: int = 8         # mean phoneme duration (frames)
    tau: float = 0.9             # OU smoothness (higher = smoother = sparser deltas)
    noise: float = 0.15          # observation noise
    max_frames: int = 128
    seed: int = 0

    @property
    def feat_dim(self) -> int:   # static + delta + delta-delta
        return 3 * self.n_static

    @property
    def vocab(self) -> int:      # CTC classes: blank(0) + phonemes
        return self.n_classes + 1


def class_means(cfg: SpeechConfig) -> jax.Array:
    """Fixed per-class target vectors (the dataset's 'formant' table)."""
    key = jax.random.key(cfg.seed)
    return jax.random.normal(key, (cfg.n_classes, cfg.n_static)) * 1.5


def _derivatives(x: jax.Array) -> jax.Array:
    """First/second temporal derivative features, concatenated. x: [T, F]."""
    d1 = jnp.diff(x, axis=0, prepend=x[:1])
    d2 = jnp.diff(d1, axis=0, prepend=d1[:1])
    return jnp.concatenate([x, d1, d2], axis=-1)


def synth_utterance(
    key: jax.Array, cfg: SpeechConfig, means: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One utterance: (features [T, 3F], n_frames, labels [T], n_labels).

    labels is padded to T (upper bound on segment count); blank id is 0 so
    phoneme classes are shifted to 1..n_classes.
    """
    t = cfg.max_frames
    k_seg, k_cls, k_len, k_ou = jax.random.split(key, 4)

    # segment boundaries: bernoulli changes, forced at t=0
    change = jax.random.bernoulli(k_seg, 1.0 / cfg.avg_segment, (t,))
    change = change.at[0].set(True)
    seg_id = jnp.cumsum(change.astype(jnp.int32)) - 1            # [T] 0..n_seg-1
    seg_class = jax.random.randint(k_cls, (t,), 0, cfg.n_classes)  # per segment
    frame_class = seg_class[seg_id]                               # [T]

    # utterance length: uniform in [T/2, T]
    n_frames = jax.random.randint(k_len, (), t // 2, t + 1)

    # OU trajectory toward the active class mean
    target = means[frame_class]                                   # [T, F]
    eps = jax.random.normal(k_ou, (t, cfg.n_static)) * cfg.noise

    def step(x, inp):
        mu, e = inp
        x = cfg.tau * x + (1.0 - cfg.tau) * mu + e * jnp.sqrt(1 - cfg.tau**2)
        return x, x

    _, traj = jax.lax.scan(step, target[0], (target, eps))
    feats = _derivatives(traj)                                    # [T, 3F]
    mask = (jnp.arange(t) < n_frames)[:, None]
    feats = feats * mask

    # labels: class of each segment that starts within n_frames
    starts = change & (jnp.arange(t) < n_frames)
    n_labels = jnp.sum(starts.astype(jnp.int32))
    # gather segment classes in order: seg s starts at the s-th True in
    # `starts`; seg_class at a start frame = frame_class there.
    order = jnp.argsort(~starts, stable=True)                     # starts first
    labels = jnp.where(jnp.arange(t) < n_labels, frame_class[order] + 1, 0)
    return feats, n_frames, labels.astype(jnp.int32), n_labels


def make_batch(key: jax.Array, cfg: SpeechConfig, batch: int, means: jax.Array):
    """(feats [B,T,3F], feat_lens [B], labels [B,T], label_lens [B])."""
    keys = jax.random.split(key, batch)
    return jax.vmap(synth_utterance, in_axes=(0, None, None))(keys, cfg, means)


class SpeechDataset:
    """Sharded, stateful iterator.  Each (process, step) pair maps to a
    unique fold of the dataset key, so (a) restarts resume exactly from the
    checkpointed step and (b) every host in a multi-pod job reads disjoint
    data with no communication."""

    def __init__(self, cfg: SpeechConfig, batch_per_host: int,
                 process_index: int = 0, start_step: int = 0):
        self.cfg = cfg
        self.batch = batch_per_host
        self.process_index = process_index
        self.step = start_step
        self.means = class_means(cfg)
        self._root = jax.random.key(cfg.seed + 1)
        self._make = jax.jit(
            lambda k: make_batch(k, cfg, batch_per_host, self.means)
        )

    def _key(self, step: int) -> jax.Array:
        return jax.random.fold_in(
            jax.random.fold_in(self._root, self.process_index), step
        )

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        out = self._make(self._key(self.step))
        self.step += 1
        return out

    def state_dict(self):
        return {"step": self.step}

    def load_state_dict(self, state):
        self.step = int(state["step"])
