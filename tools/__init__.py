"""Repo tooling: ``python -m tools.lint``, docs checker, smoke scripts."""
