"""Documentation checker: executable snippets + intra-repo links.

Keeps the docs honest as the code moves:

* every fenced ```python block in ``docs/*.md`` and ``README.md`` is
  compiled and **executed** (with ``src/`` importable), so a renamed
  function or changed signature breaks CI instead of silently rotting in
  prose.  A block preceded (within two lines) by an HTML comment
  containing ``doccheck: skip`` is exempt — use it for illustrative
  fragments that are not self-contained;
* every relative markdown link ``[text](path)`` / ``[text](path#anchor)``
  must resolve to an existing file, and same-file ``#anchor`` links to an
  existing heading (GitHub slug rules, simplified).

Exit code 0 = clean.  Run directly::

    PYTHONPATH=src python tools/check_docs.py

or via the tier-1 suite (tests/test_docs.py imports `check_repo`).
"""
from __future__ import annotations

import re
import sys
import traceback
from pathlib import Path
from typing import List, Tuple

FENCE_RE = re.compile(r"^```(\w*)\s*$")
# [text](target) — skip images ![..](..) and external/absolute schemes:
LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
SKIP_MARK = "doccheck: skip"


def doc_files(root: Path) -> List[Path]:
    docs = sorted((root / "docs").glob("*.md")) if (root / "docs").is_dir() \
        else []
    readme = root / "README.md"
    return ([readme] if readme.is_file() else []) + docs


def extract_python_blocks(text: str) -> List[Tuple[int, str]]:
    """(start_line, source) for each executable ```python block."""
    lines = text.splitlines()
    blocks: List[Tuple[int, str]] = []
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m and m.group(1) == "python":
            skip = any(SKIP_MARK in lines[j]
                       for j in range(max(0, i - 2), i))
            body: List[str] = []
            i += 1
            start = i + 1          # 1-based first body line
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            if not skip:
                blocks.append((start, "\n".join(body)))
        i += 1
    return blocks


def extract_links(text: str) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    in_fence = False
    for n, line in enumerate(text.splitlines(), 1):
        if line.startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            out.append((n, m.group(1)))
    return out


def heading_slugs(text: str) -> set:
    """GitHub-style slugs of every markdown heading (simplified: lower-
    case, alphanumerics and hyphens, spaces -> hyphens)."""
    slugs = set()
    in_fence = False
    for line in text.splitlines():
        if line.startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence or not line.startswith("#"):
            continue
        title = line.lstrip("#").strip()
        slug = re.sub(r"[^\w\- ]", "", title.lower()).replace(" ", "-")
        slugs.add(slug)
    return slugs


def check_links(path: Path, root: Path) -> List[str]:
    text = path.read_text()
    problems = []
    for line, target in extract_links(text):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        file_part, _, anchor = target.partition("#")
        if file_part:
            dest = (path.parent / file_part).resolve()
            if not dest.exists():
                problems.append(f"{path.relative_to(root)}:{line}: broken "
                                f"link target {target!r}")
                continue
            dest_text = dest.read_text() if dest.suffix == ".md" else ""
        else:
            dest_text = text
        if anchor and dest_text:
            if anchor.lower() not in heading_slugs(dest_text):
                problems.append(f"{path.relative_to(root)}:{line}: broken "
                                f"anchor {target!r}")
    return problems


def check_snippets(path: Path, root: Path) -> List[str]:
    problems = []
    for start, src in extract_python_blocks(path.read_text()):
        where = f"{path.relative_to(root)}:{start}"
        try:
            code = compile(src, f"<{where}>", "exec")
        except SyntaxError as e:
            problems.append(f"{where}: snippet does not compile: {e}")
            continue
        try:
            exec(code, {"__name__": f"doccheck_{path.stem}"})
        except Exception:
            tb = traceback.format_exc(limit=2).strip().splitlines()[-1]
            problems.append(f"{where}: snippet failed to run: {tb}")
    return problems


def check_repo(root: Path) -> List[str]:
    """All documentation problems in the repo (empty list = clean)."""
    src = root / "src"
    if str(src) not in sys.path:
        sys.path.insert(0, str(src))
    problems: List[str] = []
    for path in doc_files(root):
        problems += check_links(path, root)
        problems += check_snippets(path, root)
    return problems


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    problems = check_repo(root)
    files = doc_files(root)
    n_snippets = sum(len(extract_python_blocks(p.read_text()))
                     for p in files)
    n_links = sum(len(extract_links(p.read_text())) for p in files)
    if problems:
        print(f"[docs] {len(problems)} problem(s):")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"[docs] OK: {len(files)} files, {n_snippets} executable "
          f"snippets ran, {n_links} links checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
