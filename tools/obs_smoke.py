"""Observability smoke for CI: a live async pool under client load, the
admin endpoint answering every command, the tracer covering all five
tick-loop phases, and the counters agreeing with the delivered results.

Spins up an in-process `AsyncSpartusServer` (tiny untrained CBTD model —
this exercises plumbing, not accuracy) with observability + tracing
attached, streams concurrent clients through it, queries the admin
listener (``healthz`` / ``stats`` / ``metrics`` / ``timeseries``) while
the pool is serving, and writes the artifacts CI uploads:

* ``<outdir>/trace.json``    — Chrome trace (load it in Perfetto)
* ``<outdir>/metrics.json``  — final registry snapshot + time series

Exit code 0 = every check passed.  Run directly::

    PYTHONPATH=src python tools/obs_smoke.py --outdir /tmp/obs
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys

import numpy as np

REQUIRED_PHASES = {"admission_upload", "dispatch", "snapshot_fetch",
                   "delivery_pump", "pacing_idle"}
ADMIN_COMMANDS = ("healthz", "stats", "metrics", "timeseries")


def _fail(msg: str) -> None:
    print(f"[obs-smoke] FAIL: {msg}")
    sys.exit(1)


async def _query(reader, writer, msg):
    writer.write((json.dumps(msg) + "\n").encode())
    await writer.drain()
    return json.loads(await reader.readline())


async def _client(server, feats, block=3):
    handle = await server.stream(want_partials=True)
    for j in range(0, len(feats), block):
        await handle.send(feats[j:j + block])
        await asyncio.sleep(0)
    handle.close()
    async for _ in handle:
        pass
    return await handle.result()


async def _run(args):
    import jax

    from repro.launch.serve import start_admin_server
    from repro.models import lstm_am
    from repro.serving import (AsyncSpartusServer, BatchedSpartusEngine,
                               EngineConfig, PoolObservability, Tracer)

    cfg = lstm_am.LSTMAMConfig(input_dim=20, hidden_dim=args.hidden,
                               n_layers=2, n_classes=11)
    params = lstm_am.cbtd_prune_stacks(
        lstm_am.init_params(jax.random.key(0), cfg), gamma=0.75, m=4)
    engine = BatchedSpartusEngine(
        params, cfg, EngineConfig(theta=0.05, gamma=0.75, m=4))
    rng = np.random.default_rng(0)
    feats = [rng.standard_normal((t, 20)).astype(np.float32)
             for t in (12, 7, 19, 4, 15, 9, 11, 6)[:args.clients]]

    obs = PoolObservability(tracer=Tracer(enabled=True))
    replies = {}
    async with AsyncSpartusServer(engine, capacity=args.capacity,
                                  chunk_frames=4,
                                  observability=obs) as server:
        admin = await start_admin_server(server, obs, port=0)
        port = admin.sockets[0].getsockname()[1]
        print(f"[obs-smoke] admin listening on 127.0.0.1:{port}")
        tasks = [asyncio.ensure_future(_client(server, f)) for f in feats]
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        # first sweep races the load on purpose — the endpoint must answer
        # mid-serve; the post-load sweep is what we assert counters on:
        for cmd in ADMIN_COMMANDS:
            replies[f"live_{cmd}"] = await _query(reader, writer,
                                                  {"cmd": cmd})
        results = await asyncio.gather(*tasks)
        for cmd in ADMIN_COMMANDS:
            replies[cmd] = await _query(reader, writer, {"cmd": cmd})
        replies["bad"] = await _query(reader, writer, {"cmd": "bogus"})
        writer.close()
        admin.close()
        await admin.wait_closed()
    return obs, replies, results


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--outdir", default="obs_smoke_out")
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=3)
    ap.add_argument("--clients", type=int, default=6)
    args = ap.parse_args()

    obs, replies, results = asyncio.run(_run(args))

    for cmd in ADMIN_COMMANDS:
        for key in (f"live_{cmd}", cmd):
            if "error" in replies[key]:
                _fail(f"admin {key!r} answered error: {replies[key]}")
    if replies["healthz"].get("ok") is not True:
        _fail(f"healthz not ok: {replies['healthz']}")
    if "error" not in replies["bad"]:
        _fail("unknown command did not answer in-band error")

    if len(results) != args.clients:
        _fail(f"{len(results)}/{args.clients} clients finished")
    snap = replies["metrics"]["metrics"]
    n_done = snap["spartus_completed_total"]["value"]
    if n_done != args.clients:
        _fail(f"completed counter {n_done} != {args.clients} clients")
    if snap["spartus_dispatches_total"]["value"] <= 0:
        _fail("no dispatches counted")
    if not replies["timeseries"]["timeseries"]:
        _fail("empty time series after a served load")
    if "# TYPE spartus_frames_total counter" not in \
            replies["metrics"]["prometheus"]:
        _fail("prometheus exposition missing the frames counter")

    trace = json.loads(obs.tracer.to_json())
    names = {e["name"] for e in trace["traceEvents"]}
    if not REQUIRED_PHASES <= names:
        _fail(f"trace missing phases: {sorted(REQUIRED_PHASES - names)}")

    os.makedirs(args.outdir, exist_ok=True)
    trace_path = os.path.join(args.outdir, "trace.json")
    obs.tracer.dump(trace_path)
    metrics_path = os.path.join(args.outdir, "metrics.json")
    with open(metrics_path, "w") as f:
        json.dump({"metrics": snap,
                   "prometheus": replies["metrics"]["prometheus"],
                   "timeseries": obs.timeseries.snapshot()}, f, indent=2)
    print(f"[obs-smoke] {len(results)} clients served, "
          f"{int(snap['spartus_frames_total']['value'])} frames, "
          f"{len(trace['traceEvents'])} trace events "
          f"({', '.join(sorted(names))})")
    print(f"[obs-smoke] wrote {trace_path} and {metrics_path}")
    print("[obs-smoke] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
