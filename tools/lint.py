"""Repo lint driver: AST rules + hot-path contracts + concurrency rules.

Usage (from the repo root)::

    python -m tools.lint --ast --contracts --concurrency [--report out.json]

``--ast`` runs the repo-specific AST rules (repro.analysis.lint) over
every ``.py`` file under ``src/`` and ``tools/``.  ``--contracts``
lowers and compiles every registered hot-path contract case
(repro.analysis.cases) and checks the optimized HLO.  ``--concurrency``
runs the static guarded-by/lockset pass and the await-under-lock rule
(repro.analysis.concurrency) over the same file set.  With no flag,
all layers run.  Exit status is non-zero on any violation; ``--report``
writes a JSON artifact with every finding and per-case op histograms
(the CI lint job uploads it).

By default the process re-execs itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the *sharded*
``step_chunk`` case — the zero-collectives pin — is checked too; set
``SPARTUS_LINT_NO_FORCE_DEVICES=1`` to skip that (e.g. on real
multi-device hardware).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def _ensure_devices() -> None:
    """Arrange for >= 4 (emulated) devices before jax initialises."""
    if os.environ.get("SPARTUS_LINT_NO_FORCE_DEVICES"):
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        flags + " --xla_force_host_platform_device_count=4").strip()


def _run_ast() -> list:
    from repro.analysis import lint

    return lint.lint_repo(REPO_ROOT)


def _run_contracts() -> list:
    from repro.analysis import cases, contracts

    return contracts.check_cases(cases.build_cases())


def _run_concurrency() -> list:
    from repro.analysis import concurrency

    return concurrency.check_repo(REPO_ROOT)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="tools.lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument("--ast", action="store_true",
                        help="run the repo-specific AST rules")
    parser.add_argument("--contracts", action="store_true",
                        help="compile and check the hot-path contracts")
    parser.add_argument("--concurrency", action="store_true",
                        help="run the guarded-by/await-under-lock rules")
    parser.add_argument("--report", type=Path, default=None,
                        help="write a JSON report artifact")
    args = parser.parse_args(argv)
    any_flag = args.ast or args.contracts or args.concurrency
    run_ast = args.ast or not any_flag
    run_contracts = args.contracts or not any_flag
    run_concurrency = args.concurrency or not any_flag

    failed = False
    report: dict = {}

    if run_ast:
        findings = _run_ast()
        report["ast"] = [vars(f) for f in findings]
        if findings:
            failed = True
            print(f"AST lint: {len(findings)} finding(s)")
            for f in findings:
                print(f"  {f}")
        else:
            print("AST lint: clean")

    if run_concurrency:
        findings = _run_concurrency()
        report["concurrency"] = [vars(f) for f in findings]
        if findings:
            failed = True
            print(f"concurrency lint: {len(findings)} finding(s)")
            for f in findings:
                print(f"  {f}")
        else:
            print("concurrency lint: clean")

    if run_contracts:
        reports = _run_contracts()
        report["contracts"] = [r.to_dict() for r in reports]
        n_bad = sum(not r.ok for r in reports)
        import jax

        print(f"contracts: {len(reports)} case(s) on {jax.device_count()} "
              f"device(s), {n_bad} failing")
        for r in reports:
            print(f"  {r.summary()}")
            for v in r.violations:
                print(f"      {v}")
        if n_bad:
            failed = True

    if args.report is not None:
        args.report.parent.mkdir(parents=True, exist_ok=True)
        args.report.write_text(json.dumps(report, indent=2))
        print(f"report written to {args.report}")

    return 1 if failed else 0


if __name__ == "__main__":
    _ensure_devices()
    src = REPO_ROOT / "src"
    if str(src) not in sys.path:
        sys.path.insert(0, str(src))
    sys.exit(main())
