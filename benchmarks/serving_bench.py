"""Load-generator benchmark for the continuous-batching serving subsystem.

Compares aggregate throughput (frames/s) and per-request latency (p50/p95)
of the `SessionPool` scheduler at several batch capacities against the
baseline of running the same requests *sequentially* through the batch-1
`SpartusEngine`, and verifies that the pooled per-request logits are
identical (atol 1e-5) to the batch-1 engine's.

    PYTHONPATH=src python benchmarks/serving_bench.py
    PYTHONPATH=src python benchmarks/serving_bench.py --check   # CI gate:
        fail unless capacity-16 aggregate frames/s >= 4x sequential

Runs on CPU: the batch-1 engine pays ~8 XLA dispatches + 3 host syncs per
(frame, layer) while the pool amortises one dispatch + one logits fetch
across all slots per tick — the speedup below is that dispatch economy,
before any accelerator parallelism.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lstm_am
from repro.serving import (
    BatchedSpartusEngine, EngineConfig, SpartusEngine, StreamRequest,
    serve_requests,
)


def build_model(hidden: int, n_layers: int, input_dim: int, n_classes: int,
                gamma: float, m: int, seed: int = 0):
    cfg = lstm_am.LSTMAMConfig(input_dim=input_dim, hidden_dim=hidden,
                               n_layers=n_layers, n_classes=n_classes)
    params = lstm_am.init_params(jax.random.key(seed), cfg)
    return lstm_am.cbtd_prune_stacks(params, gamma=gamma, m=m), cfg


def make_requests(n: int, frames: int, input_dim: int,
                  arrival_stride: int = 0) -> List[StreamRequest]:
    return [
        StreamRequest(
            req_id=i, arrival_step=i * arrival_stride,
            feats=np.asarray(
                jax.random.normal(jax.random.key(100 + i), (frames, input_dim)),
                np.float32))
        for i in range(n)
    ]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--input-dim", type=int, default=40)
    ap.add_argument("--classes", type=int, default=41)
    ap.add_argument("--frames", type=int, default=40)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--capacities", default="1,4,16")
    ap.add_argument("--theta", type=float, default=0.05)
    ap.add_argument("--gamma", type=float, default=0.9375)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--capacity-frac", type=float, default=0.5)
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless capacity-16 (or max capacity) hits "
                         ">=4x sequential frames/s with matching logits")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    params, cfg = build_model(args.hidden, args.layers, args.input_dim,
                              args.classes, args.gamma, args.m)
    ecfg = EngineConfig(theta=args.theta, gamma=args.gamma, m=args.m,
                        capacity_frac=args.capacity_frac)
    e1 = SpartusEngine(params, cfg, ecfg)
    eb = BatchedSpartusEngine(params, cfg, ecfg)
    reqs = make_requests(args.requests, args.frames, args.input_dim)
    total_frames = args.requests * args.frames

    # -- sequential batch-1 baseline ----------------------------------------
    warm = jnp.asarray(reqs[0].feats[:2])
    e1.run_utterance(warm)  # compile
    e1.telemetry.clear()
    t0 = time.perf_counter()
    seq_logits = [np.asarray(e1.run_utterance(jnp.asarray(r.feats)))
                  for r in reqs]
    t_seq = time.perf_counter() - t0
    seq_fps = total_frames / t_seq
    report = {"sequential": {"frames_per_s": seq_fps, "wall_s": t_seq}}
    print(f"[bench] sequential batch-1: {args.requests} x {args.frames} "
          f"frames in {t_seq:.2f}s -> {seq_fps:.0f} frames/s")

    # -- pooled, per capacity ------------------------------------------------
    caps = [int(c) for c in args.capacities.split(",")]
    parity_ok = True
    for cap in caps:
        # warm-up compiles the step for this capacity outside the timing:
        serve_requests(eb, [StreamRequest(0, 0, reqs[0].feats[:2])], cap)
        results, stats = serve_requests(eb, reqs, capacity=cap)
        for r in results:
            if not np.allclose(r.logits, seq_logits[r.req_id], atol=1e-5):
                parity_ok = False
                print(f"[bench] PARITY FAIL req {r.req_id} at capacity {cap}")
        speedup = stats.frames_per_s / seq_fps
        report[f"capacity_{cap}"] = dict(stats.to_dict(), speedup=speedup)
        print(f"[bench] capacity {cap:3d}: {stats.frames_per_s:8.0f} frames/s "
              f"({speedup:4.1f}x)  p50 {stats.p50_latency_s*1e3:7.1f} ms  "
              f"p95 {stats.p95_latency_s*1e3:7.1f} ms")

    if args.json:
        print(json.dumps(report, indent=2))

    if args.check:
        cap = max(caps)
        speedup = report[f"capacity_{cap}"]["speedup"]
        ok = parity_ok and speedup >= 4.0
        print(f"[bench] check: parity={'ok' if parity_ok else 'FAIL'} "
              f"speedup@{cap}={speedup:.1f}x -> {'PASS' if ok else 'FAIL'}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
