"""Load-generator benchmark for the continuous-batching serving subsystem.

Compares aggregate throughput (frames/s) and per-request latency (p50/p95)
of the `SessionPool` scheduler at several batch capacities against the
baseline of running the same requests *sequentially* through the batch-1
`SpartusEngine`, and verifies that the pooled per-request logits are
identical (atol 1e-5) to the batch-1 engine's.

    PYTHONPATH=src python benchmarks/serving_bench.py
    PYTHONPATH=src python benchmarks/serving_bench.py --chunk-frames 32
    PYTHONPATH=src python benchmarks/serving_bench.py --async-load  # open-loop
        Poisson-arrival load generator against the asyncio front-end:
        latency (p50/p95/p99), time-to-first-logit and queue wait vs
        offered load, plus sustained throughput vs the synchronous
        chunked pool at the same chunk size
    PYTHONPATH=src python benchmarks/serving_bench.py --check   # CI gate:
        fail unless capacity-16 aggregate frames/s >= 4x sequential
    PYTHONPATH=src python benchmarks/serving_bench.py --sweep   # slow CI gate:
        hidden in {128, 512} at m=16 / capacity 16 plus a forced-scatter
        leg, emits BENCH_serving.json, fails if the pool ever drops below
        the batch-1 engine (the crossover that regressed before the
        scatter/dense-gather SpMV paths); also runs the chunked tick loop
        at chunk_frames in {1, 8, 32} vs the per-frame pool at hidden=128
        and fails if chunk_frames=32 is slower than per-frame (the
        dispatch-amortisation gate), and the async open-loop leg, failing
        if the async front-end's sustained (saturated) throughput drops
        below ASYNC_FLOOR x the synchronous chunked pool, and the
        quantized leg (int8 weights + Q8.8 delta thresholds): parity vs
        the quantized batch-1 engine, max-abs logit divergence vs the
        fp32 pool under QUANT_DIVERGENCE_BOUND, and the 4x int8 weight-
        payload shrink (QUANT_PAYLOAD_FLOOR)
    PYTHONPATH=src python benchmarks/serving_bench.py --quant  # that
        quantized leg alone, at the CLI's model config

Runs on CPU: the batch-1 engine pays ~8 XLA dispatches + 3 host syncs per
(frame, layer) while the pool amortises one dispatch + one logits fetch
across all slots per tick — the speedup below is that dispatch economy,
before any accelerator parallelism.  The chunked tick loop compounds it:
one lax.scan dispatch advances all slots up to C frames and logits leave
the device once per session (at retirement), so the per-tick Python /
dispatch / fetch overhead is amortised C-fold on top.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lstm_am
from repro.serving import (
    AsyncSpartusServer, BatchedSpartusEngine, EngineConfig,
    PoolObservability, SpartusEngine, StreamRequest, serve_requests,
)

#: BENCH_serving.json schema version.  Stamped on the report and on every
#: top-level row by `_write_report`, which refuses to mix versions —
#: downstream consumers (CI artifact diffing, dashboards) can trust that
#: one file means one schema.  v2 added the observability rows
#: (`obs_overhead`) and the per-row stamp itself.  v3 added the quantized
#: leg (`quant_*` rows: divergence vs fp32, weight-payload ratio,
#: bytes-per-slot and the equal-bytes capacity) and `bytes_per_slot`
#: inside every ServeStats dict.
SCHEMA_VERSION = 3


def _write_report(path: str, report: dict) -> None:
    """Stamp the schema version on the report and every row, then write.

    Refuses to *silently mix* schemas: a row already carrying a different
    ``schema_version`` (say, merged in from an older BENCH_serving.json)
    raises instead of producing a file that is half old shape, half new."""
    stamped = {}
    top = report.get("schema_version", SCHEMA_VERSION)
    if top != SCHEMA_VERSION:
        raise ValueError(
            f"refusing to mix schemas: report carries schema_version={top}, "
            f"writer is {SCHEMA_VERSION}")
    for key, row in report.items():
        if isinstance(row, dict):
            v = row.get("schema_version", SCHEMA_VERSION)
            if v != SCHEMA_VERSION:
                raise ValueError(
                    f"refusing to mix schemas: row {key!r} carries "
                    f"schema_version={v}, writer is {SCHEMA_VERSION}")
            row = dict(row, schema_version=SCHEMA_VERSION)
        stamped[key] = row
    stamped["schema_version"] = SCHEMA_VERSION
    with open(path, "w") as f:
        json.dump(stamped, f, indent=2)
    print(f"[bench] wrote {path} (schema v{SCHEMA_VERSION})")


def build_model(hidden: int, n_layers: int, input_dim: int, n_classes: int,
                gamma: float, m: int, seed: int = 0):
    cfg = lstm_am.LSTMAMConfig(input_dim=input_dim, hidden_dim=hidden,
                               n_layers=n_layers, n_classes=n_classes)
    params = lstm_am.init_params(jax.random.key(seed), cfg)
    return lstm_am.cbtd_prune_stacks(params, gamma=gamma, m=m), cfg


def make_requests(n: int, frames: int, input_dim: int,
                  arrival_stride: int = 0) -> List[StreamRequest]:
    return [
        StreamRequest(
            req_id=i, arrival_step=i * arrival_stride,
            feats=np.asarray(
                jax.random.normal(jax.random.key(100 + i), (frames, input_dim)),
                np.float32))
        for i in range(n)
    ]


def bench_config(hidden: int, layers: int, input_dim: int, classes: int,
                 frames: int, n_requests: int, caps: List[int], theta: float,
                 gamma: float, m: int, capacity_frac: float,
                 spmv_path: str = "auto", chunk_frames: int = 0):
    """One model configuration: sequential batch-1 baseline + the pool at
    each capacity, with per-request logits parity checked against the
    batch-1 engine.  Returns (report dict, parity_ok)."""
    params, cfg = build_model(hidden, layers, input_dim, classes, gamma, m)
    ecfg = EngineConfig(theta=theta, gamma=gamma, m=m,
                        capacity_frac=capacity_frac, spmv_path=spmv_path)
    e1 = SpartusEngine(params, cfg, ecfg)
    eb = BatchedSpartusEngine(params, cfg, ecfg)
    reqs = make_requests(n_requests, frames, input_dim)
    total_frames = n_requests * frames

    # -- sequential batch-1 baseline ----------------------------------------
    warm = jnp.asarray(reqs[0].feats[:2])
    e1.run_utterance(warm)  # compile
    e1.telemetry.clear()
    t0 = time.perf_counter()
    seq_logits = [np.asarray(e1.run_utterance(jnp.asarray(r.feats)))
                  for r in reqs]
    t_seq = time.perf_counter() - t0
    seq_fps = total_frames / t_seq
    report = {"hidden": hidden, "m": m, "spmv_path": spmv_path,
              "chunk_frames": chunk_frames,
              "sequential": {"frames_per_s": seq_fps, "wall_s": t_seq}}
    print(f"[bench] hidden={hidden} ({spmv_path}) sequential batch-1: "
          f"{n_requests} x {frames} frames in {t_seq:.2f}s -> "
          f"{seq_fps:.0f} frames/s")

    # -- pooled, per capacity ------------------------------------------------
    parity_ok = True
    for cap in caps:
        # warm-up compiles the step for this capacity outside the timing;
        # full-length feats so the warm-up hits the same frame-buffer bucket
        # as the timed run (a [:2] slice would bucket differently past 64
        # frames and hide a recompile inside the timing), and a full
        # admission wave so the batched-upload variant is compiled too:
        serve_requests(eb, [StreamRequest(i, 0, reqs[0].feats)
                            for i in range(cap)], cap,
                       chunk_frames=chunk_frames)
        results, stats = serve_requests(eb, reqs, capacity=cap,
                                        chunk_frames=chunk_frames)
        for r in results:
            if not np.allclose(r.logits, seq_logits[r.req_id], atol=1e-5):
                parity_ok = False
                print(f"[bench] PARITY FAIL req {r.req_id} at capacity {cap}")
        speedup = stats.frames_per_s / seq_fps
        report[f"capacity_{cap}"] = dict(stats.to_dict(), speedup=speedup)
        print(f"[bench] capacity {cap:3d}: {stats.frames_per_s:8.0f} frames/s "
              f"({speedup:4.1f}x)  p50 {stats.p50_latency_s*1e3:7.1f} ms  "
              f"p95 {stats.p95_latency_s*1e3:7.1f} ms")
    return report, parity_ok


def bench_chunked(hidden: int, layers: int, input_dim: int, classes: int,
                  frames: int, n_requests: int, cap: int, theta: float,
                  gamma: float, m: int, capacity_frac: float,
                  chunk_grid=(1, 8, 32)):
    """Chunked tick loop vs the per-frame pool at one capacity: same
    requests, logits parity pinned against the per-frame results, speedup
    and dispatch amortisation reported per chunk_frames.  Returns
    (report dict, parity_ok)."""
    params, cfg = build_model(hidden, layers, input_dim, classes, gamma, m)
    ecfg = EngineConfig(theta=theta, gamma=gamma, m=m,
                        capacity_frac=capacity_frac)
    eb = BatchedSpartusEngine(params, cfg, ecfg)
    reqs = make_requests(n_requests, frames, input_dim)

    def warm(chunk):
        # full admission wave at full length: compiles the step, the
        # batched upload and the retirement snapshot for the timed shapes
        serve_requests(eb, [StreamRequest(i, 0, reqs[0].feats)
                            for i in range(cap)], cap, chunk_frames=chunk)

    warm(0)
    base_results, base = serve_requests(eb, reqs, capacity=cap)
    report = {"hidden": hidden, "m": m, "capacity": cap,
              "per_frame": base.to_dict()}
    print(f"[bench] hidden={hidden} capacity={cap} per-frame pool: "
          f"{base.frames_per_s:8.0f} frames/s  "
          f"({base.dispatches_per_frame:.3f} dispatches/frame)")

    parity_ok = True
    for chunk in chunk_grid:
        warm(chunk)
        results, stats = serve_requests(eb, reqs, capacity=cap,
                                        chunk_frames=chunk)
        for r in results:
            if not np.allclose(r.logits, base_results[r.req_id].logits,
                               atol=1e-5):
                parity_ok = False
                print(f"[bench] PARITY FAIL req {r.req_id} at "
                      f"chunk_frames {chunk}")
        speedup = stats.frames_per_s / base.frames_per_s
        report[f"chunk_{chunk}"] = dict(stats.to_dict(),
                                        speedup_vs_per_frame=speedup)
        print(f"[bench] chunk_frames {chunk:3d}: {stats.frames_per_s:8.0f} "
              f"frames/s ({speedup:4.1f}x per-frame)  "
              f"{stats.dispatches_per_frame:.3f} dispatches/frame  "
              f"host overlap {stats.host_overlap_frac:.0%}")
    return report, parity_ok


def bench_obs_overhead(hidden: int, layers: int, input_dim: int,
                       classes: int, frames: int, n_requests: int, cap: int,
                       theta: float, gamma: float, m: int,
                       capacity_frac: float, chunk: int, repeats: int = 5):
    """Observability-overhead leg: the same chunked workload with live
    metrics + time-series folding enabled vs fully disabled.

    The fold happens at chunk boundaries only, on host values the pool
    already computed, so the expected cost is a few dict/lock operations
    per boundary — the gate (enabled >= OBS_FLOOR x disabled) pins that
    the observability layer never grows a hot-path cost.

    A sub-3% effect needs signal discipline on a shared runner whose
    speed drifts ~10% over seconds: the workload is floored (each timed
    run covers at least OBS_MIN_FRAMES total frames, whatever
    --frames/--requests say), the two sides run INTERLEAVED off/on
    pairs so each pair shares one drift regime, and the gate takes the
    BEST pair ratio — a systematic observability cost slows the on-side
    of every pair, while drift hits pairs at random, so max-over-pairs
    rejects the former and forgives the latter.  Returns
    (report dict with an ``obs_overhead`` shape, gate_ok)."""
    frames = max(frames, OBS_MIN_FRAMES // max(n_requests, 1), 1)
    if n_requests * frames < OBS_MIN_FRAMES:
        n_requests = -(-OBS_MIN_FRAMES // frames)
    params, cfg = build_model(hidden, layers, input_dim, classes, gamma, m)
    ecfg = EngineConfig(theta=theta, gamma=gamma, m=m,
                        capacity_frac=capacity_frac)
    eb = BatchedSpartusEngine(params, cfg, ecfg)
    reqs = make_requests(n_requests, frames, input_dim)
    # warm: compiles the step/upload/snapshot AND the telemetry-totals
    # reduction the enabled side dispatches per boundary
    serve_requests(eb, [StreamRequest(i, 0, reqs[0].feats)
                        for i in range(cap)], cap, chunk_frames=chunk,
                   observability=PoolObservability())

    off = on = obs = None
    pair_ratios = []
    for _ in range(repeats):
        _, s_off = serve_requests(eb, reqs, capacity=cap, chunk_frames=chunk)
        if off is None or s_off.frames_per_s > off.frames_per_s:
            off = s_off
        o = PoolObservability()
        _, s_on = serve_requests(eb, reqs, capacity=cap, chunk_frames=chunk,
                                 observability=o)
        if on is None or s_on.frames_per_s > on.frames_per_s:
            on, obs = s_on, o
        if s_off.frames_per_s:
            pair_ratios.append(s_on.frames_per_s / s_off.frames_per_s)
    ratio = max(pair_ratios) if pair_ratios else 0.0
    snap = obs.registry.snapshot()
    row = {
        "hidden": hidden, "m": m, "capacity": cap, "chunk_frames": chunk,
        "repeats": repeats, "n_requests": n_requests, "frames": frames,
        "disabled_frames_per_s": off.frames_per_s,
        "enabled_frames_per_s": on.frames_per_s,
        "pair_ratios": [round(r, 4) for r in pair_ratios],
        "ratio": ratio,
        "n_timeseries_samples": len(obs.timeseries),
        "dispatches_counted": snap["spartus_dispatches_total"]["value"],
        "frames_counted": snap["spartus_frames_total"]["value"],
    }
    ok = ratio >= OBS_FLOOR
    print(f"[bench] obs overhead hidden={hidden} chunk={chunk} "
          f"({n_requests}x{frames} frames, {repeats} interleaved pairs): "
          f"enabled {on.frames_per_s:8.0f} / disabled "
          f"{off.frames_per_s:8.0f} frames/s, best pair ratio "
          f"{ratio:.3f}x (floor {OBS_FLOOR}) -> {'PASS' if ok else 'FAIL'}")
    return row, ok


def bench_async_load(hidden: int, layers: int, input_dim: int, classes: int,
                     frames: int, n_requests: int, cap: int, theta: float,
                     gamma: float, m: int, capacity_frac: float, chunk: int,
                     loads=(0.5, 1.0, 2.0)):
    """Open-loop Poisson-arrival load generator against the asyncio
    front-end (`AsyncSpartusServer`), at offered loads relative to the
    synchronous chunked pool's saturated throughput.

    Open-loop means arrivals are scheduled by the wall clock, independent
    of completions (the admission queue is unbounded), so past saturation
    the latency percentiles grow while sustained throughput plateaus at
    the server's capacity — the classic latency-vs-offered-load curve.
    Each load row records achieved frames/s, p50/p95/p99 latency,
    time-to-first-logit and queue wait.

    The ``saturated`` row is the curve's limit point — every arrival at
    t=0 — which is exactly the workload the synchronous chunked drain
    loop (`serve_requests`) measures, so the report's
    ``throughput_ratio`` = saturated async frames/s / sync chunked
    frames/s isolates the front-end's event-loop overhead (~0.9x on a
    2-core CPU box; the finite-load rows are additionally depressed by
    chunk under-fill while staggered sessions wait for boundaries, which
    is a property of chunked scheduling itself, not of the async front
    end).  Per-request logits are parity-checked against the synchronous
    results at every load.  Returns (report dict, parity_ok)."""
    params, cfg = build_model(hidden, layers, input_dim, classes, gamma, m)
    ecfg = EngineConfig(theta=theta, gamma=gamma, m=m,
                        capacity_frac=capacity_frac)
    eb = BatchedSpartusEngine(params, cfg, ecfg)
    reqs = make_requests(n_requests, frames, input_dim)
    total_frames = n_requests * frames

    # -- synchronous chunked baseline (same chunk size) ----------------------
    serve_requests(eb, [StreamRequest(i, 0, reqs[0].feats)
                        for i in range(cap)], cap, chunk_frames=chunk)  # warm
    base_results, base = serve_requests(eb, reqs, capacity=cap,
                                        chunk_frames=chunk)
    sync_fps = base.frames_per_s
    print(f"[bench] hidden={hidden} capacity={cap} chunk={chunk} sync "
          f"chunked pool: {sync_fps:8.0f} frames/s")

    async def run_async(arrivals):
        async with AsyncSpartusServer(
                eb, cap, chunk_frames=chunk, max_frames=frames,
                offload_ticks=False) as srv:
            t0 = time.perf_counter()

            async def client(i):
                await asyncio.sleep(arrivals[i])
                return await srv.submit(reqs[i].feats)

            results = await asyncio.gather(
                *[client(i) for i in range(n_requests)])
            wall = time.perf_counter() - t0
        return results, wall

    # warm the async-only shapes outside the timed runs.  One all-at-once
    # pass is NOT enough: staggered arrivals hit small pow2 admission-wave
    # upload buckets that an aligned pass never compiles (a stray ~100 ms
    # compile mid-leg wrecks a 100 ms measurement), so every bucket is
    # compiled deterministically here, and each load leg below also runs
    # once unmeasured with the SAME arrival schedule before its timed pass.
    from repro.serving import SessionPool
    wpool = SessionPool(eb, cap, max_frames=frames, chunk_frames=chunk)
    rid = 0
    r = 1
    while r <= cap:
        for _ in range(r):
            wpool.admit(StreamRequest(10 ** 6 + rid, 0, reqs[0].feats), 0)
            rid += 1
        wpool.step_chunk(now=0)
        wpool.drain(now=0)
        r *= 2
    asyncio.run(run_async([0.0] * n_requests))

    report = {"hidden": hidden, "m": m, "capacity": cap,
              "chunk_frames": chunk, "n_requests": n_requests,
              "frames_per_request": frames,
              "sync_chunked": base.to_dict()}
    parity_ok = True

    def one_leg(label, mult, arrivals):
        nonlocal parity_ok
        asyncio.run(run_async(arrivals))            # compile-warm pass
        results, wall = asyncio.run(run_async(arrivals))
        for rr in results:
            if not np.allclose(rr.logits, base_results[rr.req_id].logits,
                               atol=1e-5):
                parity_ok = False
                print(f"[bench] ASYNC PARITY FAIL req {rr.req_id} at "
                      f"load {label}")
        achieved = total_frames / wall
        lat = np.array([rr.wall_latency_s for rr in results])
        ttfl = np.array([rr.ttfl_s for rr in results])
        qw = np.array([rr.queue_wait_s for rr in results])
        row = {
            "offered_x": mult,
            "offered_frames_per_s": (mult * sync_fps
                                     if np.isfinite(mult) else None),
            "achieved_frames_per_s": achieved,
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "p99_latency_s": float(np.percentile(lat, 99)),
            "p50_ttfl_s": float(np.percentile(ttfl, 50)),
            "p95_queue_wait_s": float(np.percentile(qw, 95)),
        }
        print(f"[bench] async load {label:>5}: {achieved:8.0f} frames/s  "
              f"p50 {row['p50_latency_s']*1e3:7.1f} ms  "
              f"p99 {row['p99_latency_s']*1e3:7.1f} ms  "
              f"queue p95 {row['p95_queue_wait_s']*1e3:7.1f} ms")
        return row

    rng = np.random.default_rng(0)
    for mult in loads:
        # Poisson process: exponential inter-arrival gaps at a mean rate
        # of offered_fps / frames utterances per second.
        gaps = rng.exponential(frames / (mult * sync_fps), n_requests)
        arrivals = np.cumsum(gaps) - gaps[0]
        report[f"load_{mult}"] = one_leg(f"{mult:.1f}x", mult,
                                         list(arrivals))
    sat = one_leg("sat", float("inf"), [0.0] * n_requests)
    report["saturated"] = sat
    sustained = sat["achieved_frames_per_s"]
    report["sustained_frames_per_s"] = sustained
    report["throughput_ratio"] = sustained / sync_fps if sync_fps else 0.0
    print(f"[bench] async saturated throughput: {sustained:.0f} frames/s = "
          f"{report['throughput_ratio']:.2f}x the sync chunked pool")
    return report, parity_ok


def bench_sharded(layers: int, input_dim: int, classes: int, frames: int,
                  theta: float, gamma: float, capacity_frac: float,
                  hidden: int, cap: int, chunk: int, grid=(1, 2, 4)):
    """Slot-sharded pool scaling: the same request burst through
    ``SessionPool(n_devices=n)`` for each n in ``grid``, logits pinned
    against the shard_1 run at 1e-5, frames/s per row.

    Runs on emulated host devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``): the SPMD
    partitioning, placement and admission paths are identical to real
    multi-device, and because the sharded steady state contains zero
    cross-device communication, per-device wall time shrinks with the
    shard count — bounded by physical cores, since the emulated devices
    share them.  Rows a machine cannot host (n > visible devices) are
    recorded as skipped.  Returns (report dict, parity_ok,
    shard4_speedup or None)."""
    params, cfg = build_model(hidden, layers, input_dim, classes, gamma, m=16)
    ecfg = EngineConfig(theta=theta, gamma=gamma, m=16,
                        capacity_frac=capacity_frac)
    eb = BatchedSpartusEngine(params, cfg, ecfg)
    reqs = make_requests(cap, frames, input_dim)   # one request per slot
    report = {"hidden": hidden, "m": 16, "capacity": cap,
              "chunk_frames": chunk, "n_cpus": os.cpu_count(),
              "n_devices_visible": jax.device_count()}
    parity_ok = True
    base_results = None
    fps = {}
    for n_dev in grid:
        if n_dev > jax.device_count():
            print(f"[bench] shard_{n_dev}: skipped "
                  f"({jax.device_count()} device(s) visible; set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count=8)")
            report[f"shard_{n_dev}"] = {
                "skipped": f"needs {n_dev} devices, "
                           f"{jax.device_count()} visible"}
            continue
        # warm: compiles the sharded step/upload/snapshot for this mesh
        serve_requests(eb, [StreamRequest(i, 0, reqs[0].feats)
                            for i in range(cap)], cap, chunk_frames=chunk,
                       n_devices=n_dev)
        results, stats = serve_requests(eb, reqs, capacity=cap,
                                        chunk_frames=chunk, n_devices=n_dev)
        if base_results is None:
            base_results = results
        for r in results:
            if not np.allclose(r.logits, base_results[r.req_id].logits,
                               atol=1e-5):
                parity_ok = False
                print(f"[bench] SHARD PARITY FAIL req {r.req_id} at "
                      f"n_devices {n_dev}")
        fps[n_dev] = stats.frames_per_s
        speedup = stats.frames_per_s / fps[min(fps)]
        report[f"shard_{n_dev}"] = dict(stats.to_dict(), n_devices=n_dev,
                                        speedup_vs_shard_1=speedup)
        print(f"[bench] shard_{n_dev}: {stats.frames_per_s:8.0f} frames/s "
              f"({speedup:4.2f}x shard_1)")
    shard4 = (fps[4] / fps[1]) if (1 in fps and 4 in fps) else None
    report["shard4_speedup"] = shard4
    return report, parity_ok, shard4


def bench_quant(hidden: int, layers: int, input_dim: int, classes: int,
                frames: int, n_requests: int, cap: int, theta: float,
                gamma: float, m: int, capacity_frac: float, chunk: int):
    """Quantized-serving leg: the same pooled workload with int8 CBCSC
    weight payloads + Q8.8 delta thresholds vs the fp32 pool.

    Three gates ride on one pair of runs (docs/quantization.md):

    - **parity**: the quantized pool must match the quantized batch-1
      engine (the scale-epilogue dequant is the same arithmetic in both,
      so pooling may not perturb quantized logits any more than fp32);
    - **divergence**: max-abs logit difference between the quantized and
      fp32 pools stays under ``QUANT_DIVERGENCE_BOUND`` — the only
      quant-mode divergence source is the Q8.8 activation snap in the
      delta threshold (measured ~5e-4 at this config; the bound leaves
      two orders of headroom so model-seed drift cannot flake CI);
    - **memory**: the int8 weight *payload* (CBCSC values + 8-bit LIDX +
      the dense mirrors) must shrink by at least ``QUANT_PAYLOAD_FLOOR``
      (exactly 4.0x by construction; total weight bytes shrink less
      because the fp32 head, biases and valid masks do not quantize).

    The report also prices the saving as capacity: ``equal_bytes_
    capacity`` is the slot count the quantized pool could host in the
    fp32 pool's device-byte budget (weight saving divided by the
    per-slot state cost).  Returns (report dict, gate_ok)."""
    from repro.core.quantization import QuantConfig

    params, cfg = build_model(hidden, layers, input_dim, classes, gamma, m)
    ecfg_f = EngineConfig(theta=theta, gamma=gamma, m=m,
                          capacity_frac=capacity_frac)
    ecfg_q = EngineConfig(theta=theta, gamma=gamma, m=m,
                          capacity_frac=capacity_frac, quant=QuantConfig())
    eb_f = BatchedSpartusEngine(params, cfg, ecfg_f)
    eb_q = BatchedSpartusEngine(params, cfg, ecfg_q)
    e1_q = SpartusEngine(params, cfg, ecfg_q)
    reqs = make_requests(n_requests, frames, input_dim)

    for eb in (eb_f, eb_q):     # warm: full admission wave, full length
        serve_requests(eb, [StreamRequest(i, 0, reqs[0].feats)
                            for i in range(cap)], cap, chunk_frames=chunk)
    f_results, f_stats = serve_requests(eb_f, reqs, capacity=cap,
                                        chunk_frames=chunk)
    q_results, q_stats = serve_requests(eb_q, reqs, capacity=cap,
                                        chunk_frames=chunk)

    # parity: quantized pool vs the quantized batch-1 oracle
    e1_q.run_utterance(jnp.asarray(reqs[0].feats[:2]))  # compile
    parity_ok = True
    for r in q_results:
        ref = np.asarray(e1_q.run_utterance(jnp.asarray(reqs[r.req_id].feats)))
        if not np.allclose(r.logits, ref, atol=1e-5):
            parity_ok = False
            print(f"[bench] QUANT PARITY FAIL req {r.req_id}")

    # divergence: quantized pool vs the fp32 pool, same requests
    f_by_id = {r.req_id: r for r in f_results}
    divergence = max(
        float(np.max(np.abs(np.asarray(r.logits, np.float32)
                            - np.asarray(f_by_id[r.req_id].logits,
                                         np.float32))))
        for r in q_results)

    w_f, w_q = eb_f.weight_bytes(), eb_q.weight_bytes()
    p_f, p_q = eb_f.weight_payload_bytes(), eb_q.weight_payload_bytes()
    payload_ratio = p_f / p_q if p_q else 0.0
    total_ratio = w_f / w_q if w_q else 0.0
    # price the weight saving as extra capacity at the fp32 byte budget:
    state_per_slot = q_stats.bytes_per_slot - w_q / cap
    equal_bytes_cap = (int(cap + (w_f - w_q) / state_per_slot)
                       if state_per_slot > 0 else cap)

    row = {
        "hidden": hidden, "m": m, "capacity": cap, "chunk_frames": chunk,
        "fp32_frames_per_s": f_stats.frames_per_s,
        "quant_frames_per_s": q_stats.frames_per_s,
        "fp32_bytes_per_slot": f_stats.bytes_per_slot,
        "quant_bytes_per_slot": q_stats.bytes_per_slot,
        "fp32_weight_bytes": w_f, "quant_weight_bytes": w_q,
        "fp32_weight_payload_bytes": p_f, "quant_weight_payload_bytes": p_q,
        "weight_payload_ratio": payload_ratio,
        "weight_total_ratio": total_ratio,
        "equal_bytes_capacity": equal_bytes_cap,
        "max_abs_logit_divergence": divergence,
        "divergence_bound": QUANT_DIVERGENCE_BOUND,
    }
    diverged = divergence > QUANT_DIVERGENCE_BOUND
    shrunk = payload_ratio >= QUANT_PAYLOAD_FLOOR
    ok = parity_ok and not diverged and shrunk
    print(f"[bench] quant hidden={hidden} cap={cap} chunk={chunk}: "
          f"{q_stats.frames_per_s:8.0f} frames/s "
          f"(fp32 {f_stats.frames_per_s:8.0f}), divergence "
          f"{divergence:.2e} (bound {QUANT_DIVERGENCE_BOUND}), payload "
          f"{payload_ratio:.2f}x / total {total_ratio:.2f}x smaller, "
          f"slot {q_stats.bytes_per_slot/1e3:.0f} kB vs "
          f"{f_stats.bytes_per_slot/1e3:.0f} kB "
          f"(equal-bytes capacity {equal_bytes_cap}) -> "
          f"{'PASS' if ok else 'FAIL'}")
    return row, ok


# sweep legs: (hidden, spmv_path).  The auto legs pin the dense-mirror route
# (every gated config has S*(1-gamma) >= 1); the forced-scatter leg pins the
# scatter kernels, which auto would otherwise never exercise here.
SWEEP_LEGS = ((128, "auto"), (512, "auto"), (128, "scatter"))
SWEEP_CAP = 16
# chunked-vs-per-frame leg: hidden for the chunked tick-loop gate and the
# chunk_frames grid recorded in BENCH_serving.json.  The gate requires the
# largest chunk to be at least as fast as the per-frame pool; measured CPU
# speedup at hidden=128 / capacity 16 is >= 3x (dispatch amortisation).
SWEEP_CHUNK_HIDDEN = 128
SWEEP_CHUNK_GRID = (1, 8, 32)
# async open-loop leg: offered-load multipliers (x the sync chunked pool's
# throughput) for the latency-vs-load rows, and the CI floor on the
# saturated-throughput ratio.  The async front-end runs the identical
# chunked dispatch loop, so the saturated ratio measures pure event-loop
# overhead (client-task wakeups, admission pumping between chunks):
# ~0.85-0.9x at hidden=128 / ~10 ms chunks on a 2-core CPU box, and
# closer to 1x as per-chunk device time grows.  The floor is set low
# enough that shared-runner noise cannot flake the job:
ASYNC_LOADS = (0.5, 1.0, 2.0)
# raised 0.75 -> 0.85 with the batched-wakeup driver (dirty-set pump, one
# delivery pass per boundary, no per-send event-loop pokes): measured
# 0.93-1.0x on the 2-core dev box at hidden=128 / 32-frame chunks.
ASYNC_FLOOR = 0.85
# sharded leg: slot-dimension data parallelism at the big-model config
# (hidden=512, a 64-slot pool, 32-frame chunks), shard_{1,2,4} rows.  The
# scaling gate — shard_4 >= SHARD_FLOOR x shard_1 — is enforced on the
# emulated-device CI run (the multi-device job), where >= 4 cores back
# the 4 emulated devices; on smaller hosts the rows are still written
# but the gate only warns, since emulated devices cannot scale past the
# physical core count.
SHARD_HIDDEN = 512
SHARD_CAP = 64
SHARD_CHUNK = 32
SHARD_GRID = (1, 2, 4)
SHARD_FLOOR = 2.0
SHARD_MIN_CPUS = 4
# observability-overhead leg: live metrics + time-series folding may cost
# at most 3% of chunked throughput (measured ~30-40us per chunk boundary:
# the fold is a few dict/lock ops, and the incremental-sparsity totals
# ride the existing one-boundary-later fetch cadence).  Shared-runner
# noise swamps a sub-3% effect on short runs, so the leg floors the
# workload (OBS_MIN_FRAMES total frames per timed run) and interleaves
# best-of-N off/on pairs:
OBS_FLOOR = 0.97
OBS_MIN_FRAMES = 16384
# quantized leg: max-abs logit divergence of the int8/Q8.8 pool vs the
# fp32 pool (sole source: the Q8.8 activation snap in the delta
# threshold; measured ~5e-4 at hidden=128 / m=16 / gamma=0.9375 — the
# bound leaves ~100x headroom), and the floor on the weight-payload
# shrink (CBCSC values + LIDX + dense mirrors quantize 4.0x exactly;
# 3.5 tolerates any future payload bookkeeping change):
QUANT_DIVERGENCE_BOUND = 0.05
QUANT_PAYLOAD_FLOOR = 3.5


def _sharded_gate(shard4, parity_ok) -> bool:
    """PASS/FAIL for the sharded leg: parity always gates; the 2x scaling
    floor gates only where the hardware can express it (>= SHARD_MIN_CPUS
    physical cores behind >= 4 emulated devices)."""
    if not parity_ok:
        return False
    if shard4 is None:
        return True                       # leg skipped: too few devices
    if (os.cpu_count() or 1) < SHARD_MIN_CPUS:
        if shard4 < SHARD_FLOOR:
            print(f"[bench] sharded scaling {shard4:.2f}x below the "
                  f"{SHARD_FLOOR}x floor, NOT gating: only "
                  f"{os.cpu_count()} physical core(s) behind the emulated "
                  f"devices")
        return True
    return shard4 >= SHARD_FLOOR


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--input-dim", type=int, default=40)
    ap.add_argument("--classes", type=int, default=41)
    ap.add_argument("--frames", type=int, default=40)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--capacities", default="1,4,16")
    ap.add_argument("--theta", type=float, default=0.05)
    ap.add_argument("--gamma", type=float, default=0.9375)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--capacity-frac", type=float, default=0.5)
    ap.add_argument("--chunk-frames", type=int, default=0,
                    help="chunked tick loop: frames advanced per dispatch "
                         "(0 = per-frame path)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless capacity-16 (or max capacity) hits "
                         ">=4x sequential frames/s with matching logits")
    ap.add_argument("--sweep", action="store_true",
                    help="crossover gate: hidden in {128, 512} at m=16, "
                         "capacity 16; exit 1 if the pool is ever slower "
                         "than batch-1 or parity fails")
    ap.add_argument("--async-load", action="store_true",
                    help="open-loop Poisson load generator against the "
                         "asyncio front-end: latency vs offered load plus "
                         "sustained-throughput ratio vs the sync chunked "
                         "pool (exit 1 on parity failure)")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="observability-overhead leg only: chunked "
                         "throughput with live metrics + time-series "
                         "enabled vs disabled, exit 1 if enabled < "
                         f"{OBS_FLOOR}x disabled")
    ap.add_argument("--sharded", action="store_true",
                    help="sharded-pool leg only: shard_{1,2,4} rows at "
                         "hidden=512 / capacity=64 / 32-frame chunks, "
                         "parity-pinned, with the 2x shard_4 scaling gate "
                         "(run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8; the "
                         "multi-device CI job does)")
    ap.add_argument("--quant", action="store_true",
                    help="quantized leg only: int8 weight payloads + Q8.8 "
                         "delta thresholds vs the fp32 pool; exit 1 on "
                         "parity failure, logit divergence > "
                         f"{QUANT_DIVERGENCE_BOUND}, or a weight-payload "
                         f"shrink under {QUANT_PAYLOAD_FLOOR}x")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--emit-json", metavar="PATH", default=None,
                    help="write the report as JSON (--sweep defaults to "
                         "BENCH_serving.json)")
    args = ap.parse_args()

    if args.sweep:
        if args.check or args.sharded or args.quant:
            ap.error("--sweep already includes the other gates; drop "
                     "--check/--sharded/--quant")
        if args.m != ap.get_default("m") or \
                args.capacities != ap.get_default("capacities") or \
                args.chunk_frames != ap.get_default("chunk_frames"):
            ap.error("--sweep fixes m=16, capacity 16 and its own "
                     "chunk_frames grid; drop --m/--capacities/"
                     "--chunk-frames or run without --sweep")
        emit = args.emit_json or "BENCH_serving.json"
        report = {}
        ok = True
        for hidden, path in SWEEP_LEGS:
            rep, parity = bench_config(
                hidden, args.layers, args.input_dim, args.classes,
                args.frames, args.requests, [SWEEP_CAP], args.theta,
                args.gamma, m=16, capacity_frac=args.capacity_frac,
                spmv_path=path)
            speedup = rep[f"capacity_{SWEEP_CAP}"]["speedup"]
            crossed = speedup >= 1.0
            print(f"[bench] sweep hidden={hidden} path={path}: parity="
                  f"{'ok' if parity else 'FAIL'} speedup={speedup:.1f}x -> "
                  f"{'PASS' if (parity and crossed) else 'FAIL'}")
            ok = ok and parity and crossed
            report[f"hidden_{hidden}_{path}"] = dict(
                rep, parity=parity,
                frames_per_s=rep[f"capacity_{SWEEP_CAP}"]["frames_per_s"])
        # chunked tick-loop gate: the biggest chunk must never be slower
        # than the per-frame pool (it measures >= 3x on CPU; the CI floor
        # is 1x so a noisy shared runner cannot flake the job):
        crep, cparity = bench_chunked(
            SWEEP_CHUNK_HIDDEN, args.layers, args.input_dim, args.classes,
            args.frames, args.requests, SWEEP_CAP, args.theta, args.gamma,
            m=16, capacity_frac=args.capacity_frac,
            chunk_grid=SWEEP_CHUNK_GRID)
        cmax = max(SWEEP_CHUNK_GRID)
        cspeed = crep[f"chunk_{cmax}"]["speedup_vs_per_frame"]
        cfast = cspeed >= 1.0
        print(f"[bench] sweep chunked hidden={SWEEP_CHUNK_HIDDEN}: parity="
              f"{'ok' if cparity else 'FAIL'} chunk_{cmax}="
              f"{cspeed:.1f}x per-frame -> "
              f"{'PASS' if (cparity and cfast) else 'FAIL'}")
        ok = ok and cparity and cfast
        report[f"chunked_hidden_{SWEEP_CHUNK_HIDDEN}"] = dict(
            crep, parity=cparity)
        # async open-loop leg: the asyncio front-end must sustain at least
        # ASYNC_FLOOR x the sync chunked pool at the same chunk size
        # (measured ~1x: it runs the identical chunked dispatch loop):
        arep, aparity = bench_async_load(
            SWEEP_CHUNK_HIDDEN, args.layers, args.input_dim, args.classes,
            args.frames, 3 * args.requests, SWEEP_CAP, args.theta,
            args.gamma, m=16, capacity_frac=args.capacity_frac,
            chunk=cmax, loads=ASYNC_LOADS)
        aratio = arep["throughput_ratio"]
        afast = aratio >= ASYNC_FLOOR
        print(f"[bench] sweep async hidden={SWEEP_CHUNK_HIDDEN}: parity="
              f"{'ok' if aparity else 'FAIL'} saturated={aratio:.2f}x sync "
              f"chunked (floor {ASYNC_FLOOR}) -> "
              f"{'PASS' if (aparity and afast) else 'FAIL'}")
        ok = ok and aparity and afast
        report[f"async_hidden_{SWEEP_CHUNK_HIDDEN}_chunk_{cmax}"] = dict(
            arep, parity=aparity)
        # sharded leg: shard_{1,2,4} rows; the 2x gate binds where the
        # host can express it (multi-device CI job), rows always land:
        srep, sparity, shard4 = bench_sharded(
            args.layers, args.input_dim, args.classes, args.frames,
            args.theta, args.gamma, args.capacity_frac,
            hidden=SHARD_HIDDEN, cap=SHARD_CAP, chunk=SHARD_CHUNK,
            grid=SHARD_GRID)
        sgate = _sharded_gate(shard4, sparity)
        print(f"[bench] sweep sharded hidden={SHARD_HIDDEN}: parity="
              f"{'ok' if sparity else 'FAIL'} shard_4="
              f"{'skipped' if shard4 is None else f'{shard4:.2f}x'} "
              f"shard_1 (floor {SHARD_FLOOR}x) -> "
              f"{'PASS' if sgate else 'FAIL'}")
        ok = ok and sgate
        report[f"sharded_hidden_{SHARD_HIDDEN}"] = dict(srep, parity=sparity)
        # observability-overhead leg: live metrics + time-series must stay
        # within OBS_FLOOR of the bare chunked pool (same config as the
        # chunked leg, so the two rows are directly comparable):
        orow, ook = bench_obs_overhead(
            SWEEP_CHUNK_HIDDEN, args.layers, args.input_dim, args.classes,
            args.frames, args.requests, SWEEP_CAP, args.theta, args.gamma,
            m=16, capacity_frac=args.capacity_frac, chunk=cmax)
        ok = ok and ook
        report["obs_overhead"] = orow
        # quantized leg: int8 weights + Q8.8 activations at the chunked
        # config — divergence-gated vs the fp32 pool, payload-ratio gated:
        qrow, qok = bench_quant(
            SWEEP_CHUNK_HIDDEN, args.layers, args.input_dim, args.classes,
            args.frames, args.requests, SWEEP_CAP, args.theta, args.gamma,
            m=16, capacity_frac=args.capacity_frac, chunk=cmax)
        ok = ok and qok
        report[f"quant_hidden_{SWEEP_CHUNK_HIDDEN}"] = qrow
        if args.json:
            print(json.dumps(report, indent=2))
        _write_report(emit, report)
        return 0 if ok else 1

    if args.sharded:
        emit = args.emit_json or "BENCH_serving.json"
        srep, sparity, shard4 = bench_sharded(
            args.layers, args.input_dim, args.classes, args.frames,
            args.theta, args.gamma, args.capacity_frac,
            hidden=SHARD_HIDDEN, cap=SHARD_CAP, chunk=SHARD_CHUNK,
            grid=SHARD_GRID)
        sgate = _sharded_gate(shard4, sparity)
        print(f"[bench] sharded hidden={SHARD_HIDDEN}: parity="
              f"{'ok' if sparity else 'FAIL'} shard_4="
              f"{'skipped' if shard4 is None else f'{shard4:.2f}x'} "
              f"shard_1 (floor {SHARD_FLOOR}x) -> "
              f"{'PASS' if sgate else 'FAIL'}")
        report = {f"sharded_hidden_{SHARD_HIDDEN}": dict(srep,
                                                         parity=sparity)}
        if args.json:
            print(json.dumps(report, indent=2))
        _write_report(emit, report)
        return 0 if sgate else 1

    if args.quant:
        chunk = args.chunk_frames or 32
        cap = max(int(c) for c in args.capacities.split(","))
        row, ok = bench_quant(
            args.hidden, args.layers, args.input_dim, args.classes,
            args.frames, args.requests, cap, args.theta, args.gamma,
            args.m, args.capacity_frac, chunk=chunk)
        report = {f"quant_hidden_{args.hidden}": row}
        if args.json:
            print(json.dumps(report, indent=2))
        if args.emit_json:
            _write_report(args.emit_json, report)
        return 0 if ok else 1

    if args.obs_overhead:
        chunk = args.chunk_frames or 32
        cap = max(int(c) for c in args.capacities.split(","))
        row, ok = bench_obs_overhead(
            args.hidden, args.layers, args.input_dim, args.classes,
            args.frames, args.requests, cap, args.theta, args.gamma,
            args.m, args.capacity_frac, chunk=chunk)
        report = {"obs_overhead": row}
        if args.json:
            print(json.dumps(report, indent=2))
        if args.emit_json:
            _write_report(args.emit_json, report)
        return 0 if ok else 1

    if args.async_load:
        chunk = args.chunk_frames or 32
        report, parity_ok = bench_async_load(
            args.hidden, args.layers, args.input_dim, args.classes,
            args.frames, args.requests, max(
                int(c) for c in args.capacities.split(",")), args.theta,
            args.gamma, args.m, args.capacity_frac, chunk=chunk,
            loads=ASYNC_LOADS)
        if args.json:
            print(json.dumps(report, indent=2))
        if args.emit_json:
            _write_report(args.emit_json, report)
        return 0 if parity_ok else 1

    caps = [int(c) for c in args.capacities.split(",")]
    report, parity_ok = bench_config(
        args.hidden, args.layers, args.input_dim, args.classes, args.frames,
        args.requests, caps, args.theta, args.gamma, args.m,
        args.capacity_frac, chunk_frames=args.chunk_frames)

    if args.json:
        print(json.dumps(report, indent=2))
    if args.emit_json:
        _write_report(args.emit_json, report)

    if args.check:
        cap = max(caps)
        speedup = report[f"capacity_{cap}"]["speedup"]
        ok = parity_ok and speedup >= 4.0
        print(f"[bench] check: parity={'ok' if parity_ok else 'FAIL'} "
              f"speedup@{cap}={speedup:.1f}x -> {'PASS' if ok else 'FAIL'}")
        return 0 if ok else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
