"""Kernel micro-benchmarks: wall time of the XLA fallback path on CPU,
interpret-mode overhead, and the TPU roofline estimate of the stsp_spmv
kernel (bytes-bound at batch-1, DESIGN.md §2 'Batch-1 vs batched')."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apply_cbtd, blen_for, cbcsc_encode
from repro.kernels import ops

HBM_BW = 819e9


def _time(fn, *args, iters=20) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_kernels(quick: bool = True) -> Dict:
    rows = {}
    cases = [(1024, 1147, 64, 0.9375, 128)]
    if not quick:
        cases += [(2048, 4096, 64, 0.9375, 256), (512, 512, 32, 0.9, 64)]
    for h4, q, m, gamma, k in cases:
        w = apply_cbtd(jax.random.normal(jax.random.key(0), (h4 * 4, q)) + 0.01,
                       gamma, m, 1.0)
        enc = cbcsc_encode(w, m, blen=blen_for(h4 * 4, m, gamma))
        idx = jnp.arange(k, dtype=jnp.int32)
        vals = jax.random.normal(jax.random.key(1), (k,))

        t_xla = _time(
            lambda v, li, i, dv: ops.stsp_spmv(v, li, i, dv, s=enc.s),
            enc.val, enc.lidx, idx, vals,
        )
        t_dense = _time(lambda ww, dv: ww @ dv, w,
                        jnp.zeros((q,)).at[idx].set(vals))
        # TPU estimate: the op is HBM-bound at batch-1; bytes = CBCSC slabs
        # of K active columns (int8 val + int8 idx) + output
        sparse_bytes = k * enc.m * enc.blen * 2 + h4 * 4 * 4
        dense_bytes = h4 * 4 * q * 2  # bf16 dense fetch of the whole matrix
        rows[f"stsp_h{h4*4}_q{q}_k{k}"] = {
            "xla_us_cpu": round(t_xla, 1),
            "dense_matvec_us_cpu": round(t_dense, 1),
            "tpu_est_sparse_us": round(sparse_bytes / HBM_BW * 1e6, 3),
            "tpu_est_dense_us": round(dense_bytes / HBM_BW * 1e6, 3),
            "tpu_est_traffic_reduction": round(dense_bytes / sparse_bytes, 1),
        }

    # delta_encode
    x = jax.random.normal(jax.random.key(2), (4096,))
    xh = x + jax.random.normal(jax.random.key(3), (4096,)) * 0.1
    rows["delta_encode_4096"] = {
        "xla_us_cpu": round(_time(
            lambda a, b: ops.delta_encode(a, b, 0.1), x, xh), 1),
        "pallas_interpret_us_cpu": round(_time(
            lambda a, b: ops.delta_encode(a, b, 0.1, use_pallas=True), x, xh), 1),
    }
    return rows
