"""One benchmark per paper table/figure (Sec. VI/VII).

Accuracy-bearing benches train small same-family networks on the
synthetic speech task (TIMIT is offline-unavailable; DESIGN.md §7), so
absolute PERs differ from the paper but every *relative* claim is
checked: the sparsity->accuracy trade-off shape, temporal sparsity vs
theta, balance ratio vs (theta, N), the op-saving ladder, and the
modelled hardware numbers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import balance_ratio, op_saving, tree_weight_sparsity
from repro.data.speech import SpeechConfig, SpeechDataset
from repro.hwsim import memory as hwmem
from repro.hwsim import spartus_model as hw
from repro.models import lstm_am
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import (
    TrainConfig, evaluate_per, measure_delta_stats, train,
)

Q = dict()  # quick-mode cache of trained models


def _base_cfg(gamma=0.94, m=16, hidden=64, frames=64):
    # 10-phoneme task calibrated to be learnable in ~4 epochs on CPU
    # (PER < 0.3), so the accuracy columns carry signal
    return TrainConfig(
        model=lstm_am.LSTMAMConfig(input_dim=123, hidden_dim=hidden,
                                   n_layers=2, n_classes=11),
        data=SpeechConfig(max_frames=frames, n_classes=10, avg_segment=12,
                          tau=0.9),
        opt=AdamWConfig(lr=5e-3),
        batch_size=16,
        steps_per_epoch=60,
        cbtd_gamma=gamma,
        cbtd_m=m,
        cbtd_delta_alpha=0.5,
    )


def _train_pair(gamma: float, theta: float, epochs=(4, 2)):
    """pretrain (LSTM+CBTD) then retrain (DeltaLSTM) — cached."""
    key = (gamma, theta)
    if key in Q:
        return Q[key]
    cfg = _base_cfg(gamma=gamma)
    pre = train(cfg, epochs=epochs[0])
    retrain_cfg = dataclasses.replace(
        cfg,
        model=dataclasses.replace(cfg.model, delta=True, theta=theta),
        cbtd_delta_alpha=1.0,
    )
    post = train(retrain_cfg, epochs=epochs[1], params=pre.params)
    Q[key] = (pre, post, retrain_cfg)
    return Q[key]


def bench_table2_accuracy(quick: bool = True) -> Dict:
    """Table II: accuracy/sparsity/op-saving ladder (relative PERs)."""
    gammas = [0.0, 0.75, 0.94] if quick else [0.0, 0.5, 0.75, 0.9, 0.94, 0.97]
    rows = {}
    ds = SpeechDataset(_base_cfg().data, 16)
    for gamma in gammas:
        cfg = _base_cfg(gamma=gamma if gamma > 0 else None)
        res = train(cfg, epochs=4)
        per = evaluate_per(res.params, cfg, ds, n_batches=2)
        ws = tree_weight_sparsity(
            {"x": [l["w_x"] for l in res.params["lstm"]],
             "h": [l["w_h"] for l in res.params["lstm"]]}
        )
        rows[f"gamma={gamma}"] = {
            "per": round(per, 4), "weight_sparsity": round(ws, 4),
            "op_saving": round(op_saving(ws, 0.0), 1),
            "final_loss": round(res.final_loss, 3),
        }
    # spatio-temporal row (the paper's headline config, scaled down)
    pre, post, rcfg = _train_pair(0.94, 0.2)
    stats = measure_delta_stats(post.params, rcfg, SpeechDataset(rcfg.data, 8))
    ts = np.mean([stats[f"layer{i}"]["temporal_sparsity"] for i in range(2)])
    ws = tree_weight_sparsity(
        {"x": [l["w_x"] for l in post.params["lstm"]],
         "h": [l["w_h"] for l in post.params["lstm"]]}
    )
    per = evaluate_per(post.params, rcfg, ds, n_batches=2)
    rows["spatio_temporal"] = {
        "per": round(per, 4), "weight_sparsity": round(float(ws), 4),
        "temporal_sparsity": round(float(ts), 4),
        "op_saving": round(op_saving(ws, ts), 1),
    }
    return rows


def bench_fig13_sparsity_vs_theta(quick: bool = True) -> Dict:
    """Fig. 13a/b: temporal sparsity of dx/dh and PER vs theta."""
    thetas = [0.05, 0.2, 0.5] if quick else [0.0, 0.05, 0.1, 0.2, 0.3, 0.5]
    rows = {}
    ds = SpeechDataset(_base_cfg().data, 16)
    for theta in thetas:
        pre, post, rcfg = _train_pair(0.94, theta)
        stats = measure_delta_stats(post.params, rcfg,
                                    SpeechDataset(rcfg.data, 8))
        per = evaluate_per(post.params, rcfg, ds, n_batches=2)
        rows[f"theta={theta}"] = {
            "ts_dx_l0": round(stats["layer0"]["temporal_sparsity_dx"], 4),
            "ts_dh_l0": round(stats["layer0"]["temporal_sparsity_dh"], 4),
            "ts_dh_l1": round(stats["layer1"]["temporal_sparsity_dh"], 4),
            "per": round(per, 4),
        }
    # monotonicity check (the paper's qualitative claim)
    ts_list = [rows[f"theta={t}"]["ts_dh_l1"] for t in thetas]
    rows["_monotone"] = bool(all(a <= b + 1e-6 for a, b in zip(ts_list, ts_list[1:])))
    return rows


def bench_fig12_balance_ratio(quick: bool = True) -> Dict:
    """Fig. 12: BR vs theta and #MAC arrays, from measured delta masks."""
    thetas = [0.05, 0.2, 0.5] if quick else [0.05, 0.1, 0.2, 0.3, 0.5]
    ns = [2, 4, 8, 16]
    rows = {}
    for theta in thetas:
        pre, post, rcfg = _train_pair(0.94, theta)
        stats = measure_delta_stats(post.params, rcfg,
                                    SpeechDataset(rcfg.data, 8))
        masks = jnp.concatenate(
            [stats["layer1"]["dx_masks"], stats["layer1"]["dh_masks"]], axis=-1
        )
        rows[f"theta={theta}"] = {
            f"N={n}": round(float(balance_ratio(masks, n)), 4) for n in ns
        }
    # BR decreases with N (paper observation)
    for theta in thetas:
        r = rows[f"theta={theta}"]
        rows.setdefault("_br_decreasing_in_N", True)
        rows["_br_decreasing_in_N"] &= (r["N=2"] >= r["N=16"] - 1e-6)
    return rows


def bench_table4_hw_ladder(quick: bool = True) -> Dict:
    """Table IV + Fig. 13c: the optimization ladder on modelled hardware,
    driven by OUR measured temporal sparsity + balance ratio."""
    pre, post, rcfg = _train_pair(0.94, 0.2)
    stats = measure_delta_stats(post.params, rcfg, SpeechDataset(rcfg.data, 8))
    masks = jnp.concatenate(
        [stats["layer1"]["dx_masks"], stats["layer1"]["dh_masks"]], axis=-1
    )
    ts = float(1.0 - jnp.mean(masks.astype(jnp.float32)))
    br = float(balance_ratio(masks, hw.SPARTUS.n_arrays))

    ladder = hw.table4_ladder(ts_by_theta={0.2: ts}, br_by_theta={0.2: br})
    out = {k: {"latency_us": round(v.latency_us, 2),
               "eff_gops": round(v.batch1_throughput_gops, 1)}
           for k, v in ladder.items()}
    out["measured_ts"] = round(ts, 4)
    out["measured_br_n8"] = round(br, 4)
    out["paper_ladder"] = {k: {"latency_us": round(v.latency_us, 2),
                               "eff_gops": round(v.batch1_throughput_gops, 1)}
                           for k, v in hw.table4_ladder().items()}
    return out


def bench_table5_comparison(quick: bool = True) -> Dict:
    """Tables V/VI: Spartus + Edge-Spartus vs prior accelerators."""
    ladder = hw.table4_ladder()
    spartus = hw.comparison_table(ladder["delta_0.3"],
                                  hw.SPARTUS_WALL_POWER_W)
    edge = hw.evaluate(hw.EDGE_SPARTUS, hw.TEST_LAYER, 0.9375,
                       temporal_sparsity=0.8256, balance_ratio=1.0)
    return {
        "spartus_vs_prior": {k: {kk: round(vv, 2) for kk, vv in v.items()}
                             for k, v in spartus.items()},
        "edge_spartus": {"latency_us": round(edge.latency_us, 1),
                         "eff_gops": round(edge.batch1_throughput_gops, 1)},
    }


def bench_table7_dram_energy(quick: bool = True) -> Dict:
    """Table VII / Fig. 14: DRAM access energy per inference frame."""
    tbl = hwmem.fig14_table(hw.TEST_LAYER.dense_macs, gamma=0.9375,
                            temporal_sparsity=0.8256)
    return {k: ({kk: round(vv, 3) for kk, vv in v.items()}
                if isinstance(v, dict) else v)
            for k, v in tbl.items()}


def bench_deltagru_vs_deltalstm(quick: bool = True) -> Dict:
    """The paper's prior-art algorithm comparison (Sec. VII-A, DeltaRNN):
    DeltaGRU vs DeltaLSTM on the same smooth-signal task — temporal
    sparsity at matched thresholds and the modelled hardware speedup each
    buys.  (The paper's claim: the DN algorithm extends to LSTM with the
    same sparsity behaviour; Table V then compares the accelerators.)"""
    import jax
    from repro.core import (
        delta_gru_layer, delta_lstm_layer, init_gru_params, init_lstm_params,
        summarize_delta_aux,
    )
    from repro.data.speech import SpeechConfig, class_means, synth_utterance

    d, h = 123, 64
    scfg = SpeechConfig(max_frames=96, tau=0.9)
    feats, *_ = synth_utterance(jax.random.key(0), scfg, class_means(scfg))
    lstm_p = init_lstm_params(jax.random.key(1), d, h)
    gru_p = init_gru_params(jax.random.key(2), d, h)

    rows = {}
    for theta in ([0.1, 0.3] if quick else [0.05, 0.1, 0.2, 0.3, 0.5]):
        _, _, aux_l = delta_lstm_layer(lstm_p, feats, theta)
        _, _, aux_g = delta_gru_layer(gru_p, feats, theta)
        ts_l = summarize_delta_aux(aux_l, d, h)["temporal_sparsity"]
        ts_g = summarize_delta_aux(aux_g, d, h)["temporal_sparsity"]
        rep_l = hw.evaluate(hw.SPARTUS, hw.TEST_LAYER, 0.9375, ts_l, 0.75)
        rep_g = hw.evaluate(hw.SPARTUS, hw.TEST_LAYER, 0.9375, ts_g, 0.75)
        rows[f"theta={theta}"] = {
            "ts_deltalstm": round(float(ts_l), 4),
            "ts_deltagru": round(float(ts_g), 4),
            "hw_eff_gops_deltalstm": round(rep_l.batch1_throughput_gops, 1),
            "hw_eff_gops_deltagru": round(rep_g.batch1_throughput_gops, 1),
        }
    return rows
