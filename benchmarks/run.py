"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (derived = JSON payload of
the table's reproduced values) and writes experiments/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

BENCHES = {}


def register(name):
    def deco(fn):
        BENCHES[name] = fn
        return fn
    return deco


def _lazy():
    from benchmarks import kernel_bench, paper_tables

    register("table2_accuracy_vs_sparsity")(paper_tables.bench_table2_accuracy)
    register("fig13_sparsity_vs_theta")(paper_tables.bench_fig13_sparsity_vs_theta)
    register("fig12_balance_ratio")(paper_tables.bench_fig12_balance_ratio)
    register("table4_hw_ladder")(paper_tables.bench_table4_hw_ladder)
    register("table5_6_comparison")(paper_tables.bench_table5_comparison)
    register("table7_fig14_dram_energy")(paper_tables.bench_table7_dram_energy)
    register("deltagru_vs_deltalstm")(paper_tables.bench_deltagru_vs_deltalstm)
    register("kernels_micro")(kernel_bench.bench_kernels)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full sweeps (quick subsets by default)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    _lazy()

    results = {}
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        derived = fn(quick=not args.full)
        us = (time.perf_counter() - t0) * 1e6
        results[name] = {"us_per_call": us, "derived": derived}
        print(f"{name},{us:.0f},{json.dumps(derived, sort_keys=True)}")

    out = os.path.join(os.path.dirname(__file__), "..", "experiments")
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, "bench_results.json"), "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)


if __name__ == "__main__":
    main()
