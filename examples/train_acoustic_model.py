"""End-to-end driver: train a ~100M-parameter LSTM acoustic model with the
paper's full spatio-temporal pipeline for a few hundred steps, with
fault-tolerant checkpointing (kill it mid-run and re-launch: it resumes).

    PYTHONPATH=src python examples/train_acoustic_model.py \
        [--small] [--steps-per-epoch 50] [--ckpt /tmp/spartus_am]

--small uses a 2L-64H model (~100k params, seconds/epoch on CPU); the
default 4L-1024H is the ~100M-parameter configuration (4*1024*2048*4 +
FCL/logit ~ 100M) matching the assignment's end-to-end driver scale.
"""
import argparse
import dataclasses

from repro.data.speech import SpeechConfig, SpeechDataset
from repro.models import lstm_am
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import (
    TrainConfig, evaluate_per, measure_delta_stats, pretrain_retrain, train,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--steps-per-epoch", type=int, default=50)
    ap.add_argument("--pretrain-epochs", type=int, default=4)
    ap.add_argument("--retrain-epochs", type=int, default=2)
    ap.add_argument("--gamma", type=float, default=0.94)
    ap.add_argument("--theta", type=float, default=0.2)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    hidden, layers, m = (64, 2, 8) if args.small else (1024, 4, 64)
    cfg = TrainConfig(
        model=lstm_am.LSTMAMConfig(input_dim=123, hidden_dim=hidden,
                                   n_layers=layers, n_classes=11),
        data=SpeechConfig(max_frames=96, n_classes=10, avg_segment=12,
                          tau=0.9),
        opt=AdamWConfig(lr=2e-3, schedule="cosine",
                        total_steps=args.steps_per_epoch
                        * (args.pretrain_epochs + args.retrain_epochs)),
        batch_size=16,
        steps_per_epoch=args.steps_per_epoch,
        cbtd_gamma=args.gamma,
        cbtd_m=m,
        cbtd_delta_alpha=1.0 / max(args.pretrain_epochs - 1, 1),
        ckpt_dir=args.ckpt,
        ckpt_every=args.steps_per_epoch,
    )
    import jax
    n = lstm_am.n_params(lstm_am.init_params(jax.random.key(0), cfg.model))
    print(f"model: {cfg.model.name}  ({n/1e6:.1f} M params)")

    pre, post, rcfg = pretrain_retrain(
        cfg, args.pretrain_epochs, args.retrain_epochs, theta=args.theta
    )
    per = evaluate_per(post.params, rcfg, SpeechDataset(cfg.data, 16))
    stats = measure_delta_stats(post.params, rcfg, SpeechDataset(rcfg.data, 8))
    print(f"pretrain loss {pre.final_loss:.3f} | retrain loss "
          f"{post.final_loss:.3f} | PER {per:.3f}")
    for li in range(rcfg.model.n_layers):
        s = stats[f"layer{li}"]
        print(f"  layer{li}: temporal sparsity dx {s['temporal_sparsity_dx']:.1%} "
              f"dh {s['temporal_sparsity_dh']:.1%}")


if __name__ == "__main__":
    main()
