"""Quickstart: the paper's full pipeline in miniature, on CPU, in ~2 min.

1. pretrain a small LSTM acoustic model with CBTD structured pruning,
2. retrain it as a DeltaLSTM (temporal sparsity),
3. export to CBCSC and stream an utterance through the Spartus engine,
4. report the measured spatio-temporal sparsity, op savings, and the
   modelled accelerator speedup (Table IV style).

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.core import op_saving, tree_weight_sparsity
from repro.data.speech import SpeechConfig, SpeechDataset
from repro.hwsim import spartus_model as hw
from repro.models import lstm_am
from repro.serving.engine import EngineConfig, SpartusEngine
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import TrainConfig, evaluate_per, pretrain_retrain

GAMMA, THETA, M = 0.75, 0.2, 8

cfg = TrainConfig(
    model=lstm_am.LSTMAMConfig(input_dim=123, hidden_dim=64, n_layers=2,
                               n_classes=11),
    data=SpeechConfig(max_frames=64, n_classes=10, avg_segment=12, tau=0.9),
    opt=AdamWConfig(lr=5e-3),
    batch_size=16,
    steps_per_epoch=60,
    cbtd_gamma=GAMMA,
    cbtd_m=M,
    cbtd_delta_alpha=0.5,
)

print(f"== 1/2: pretrain LSTM+CBTD (gamma={GAMMA}), retrain DeltaLSTM "
      f"(theta={THETA}) ==")
pre, post, retrain_cfg = pretrain_retrain(cfg, pretrain_epochs=3,
                                          retrain_epochs=2, theta=THETA)
ws = tree_weight_sparsity({"x": [l["w_x"] for l in post.params["lstm"]],
                           "h": [l["w_h"] for l in post.params["lstm"]]})
per = evaluate_per(post.params, retrain_cfg, SpeechDataset(cfg.data, 16))
print(f"   pretrain loss {pre.final_loss:.3f} -> retrain loss "
      f"{post.final_loss:.3f}; weight sparsity {ws:.1%}; PER {per:.3f}")

print("== 3: CBCSC export + Spartus streaming engine ==")
engine = SpartusEngine(post.params, retrain_cfg.model,
                       EngineConfig(theta=THETA, gamma=GAMMA, m=M))
feats, *_ = next(SpeechDataset(cfg.data, 1))
logits = engine.run_utterance(feats[0])
sp = engine.measured_sparsity()
print(f"   streamed {logits.shape[0]} frames; temporal sparsity "
      f"{sp['temporal_sparsity']:.1%}; capacity overflow "
      f"{sp['capacity_overflow_rate']:.1%}")

print("== 4: op savings + modelled hardware (Table IV style) ==")
saving = op_saving(ws, sp["temporal_sparsity"])
print(f"   arithmetic op saving: {saving:.1f}x "
      f"(paper at gamma=0.94/theta=0.3: 170x)")
dense = hw.dense_baseline(hw.SPARTUS, hw.TEST_LAYER)
fast = hw.evaluate(hw.SPARTUS, hw.TEST_LAYER, 0.9375,
                   sp["temporal_sparsity"], 0.75)
print(f"   modelled Spartus: dense {dense.latency_us:.1f} us -> "
      f"spatio-temporal {fast.latency_us:.2f} us "
      f"({dense.latency_us / fast.latency_us:.0f}x speedup, "
      f"{fast.batch1_throughput_gops/1e3:.2f} TOp/s effective)")
