"""Minimal continuous-batching streaming server demo.

Builds a small CBTD-pruned DeltaLSTM acoustic model, generates a burst of
staggered streaming requests (a Poisson-ish arrival pattern), serves them
through the `SessionPool` scheduler, and prints per-request latency plus
the aggregated sparsity telemetry feeding the hardware model.

    PYTHONPATH=src python examples/streaming_server.py
"""
from __future__ import annotations

import numpy as np
import jax

from repro.data.speech import SpeechConfig, SpeechDataset
from repro.hwsim import spartus_model as hw
from repro.models import lstm_am
from repro.serving import (
    BatchedSpartusEngine, EngineConfig, StreamRequest, serve_requests,
)

GAMMA, M, THETA = 0.9375, 4, 0.1


def main():
    data_cfg = SpeechConfig(max_frames=48)
    cfg = lstm_am.LSTMAMConfig(input_dim=data_cfg.feat_dim, hidden_dim=64,
                               n_layers=2, n_classes=data_cfg.vocab)
    params = lstm_am.init_params(jax.random.key(0), cfg)
    params = lstm_am.cbtd_prune_stacks(params, gamma=GAMMA, m=M)

    engine = BatchedSpartusEngine(
        params, cfg, EngineConfig(theta=THETA, gamma=GAMMA, m=M))

    # a burst of real (synthetic-speech) utterances, arriving every 4 ticks:
    feats, frame_lens, _, _ = next(SpeechDataset(data_cfg, 12))
    rng = np.random.default_rng(0)
    requests = []
    for i in range(12):
        t = int(frame_lens[i]) if int(frame_lens[i]) > 0 else 16
        requests.append(StreamRequest(
            req_id=i, arrival_step=int(rng.integers(0, 4)) + 4 * i,
            feats=np.asarray(feats[i, :t], np.float32)))

    # chunked tick loop: ONE device dispatch advances all slots up to 8
    # frames, logits are fetched per session at retirement (chunk_frames=0
    # would run the per-frame oracle path instead)
    results, stats = serve_requests(engine, requests, capacity=4,
                                    chunk_frames=8)

    print(f"served {stats.n_requests} sessions / {stats.total_frames} frames "
          f"in {stats.wall_s:.2f}s -> {stats.frames_per_s:.0f} frames/s "
          f"(pool capacity {stats.capacity}, "
          f"{stats.chunk_frames}-frame chunks)")
    print(f"dispatch economy: {stats.n_dispatches} dispatches for "
          f"{stats.total_frames} frames "
          f"({stats.dispatches_per_frame:.3f}/frame), host overlap "
          f"{stats.host_overlap_frac:.0%}")
    print(f"latency p50 {stats.p50_latency_s*1e3:.0f} ms, "
          f"p95 {stats.p95_latency_s*1e3:.0f} ms; "
          f"turnaround p95 {stats.p95_turnaround_steps:.0f} ticks")
    for r in results[:4]:
        print(f"  req {r.req_id}: arrived t={r.arrival_step}, queued "
              f"{r.queue_steps}, served {r.service_steps} frames, "
              f"logits {r.logits.shape}")

    # telemetry: accumulated on device across the whole run, fetched once
    # by serve_requests into stats.sparsity -> drives the hardware model
    sp = stats.sparsity
    print(f"measured temporal sparsity {sp['temporal_sparsity']:.1%}, "
          f"overflow rate {sp['capacity_overflow_rate']:.1%}")
    rep = hw.evaluate_from_telemetry(hw.SPARTUS, hw.TEST_LAYER, GAMMA, sp)
    print(f"modelled Spartus latency at this sparsity: {rep.latency_us:.2f} us"
          f" ({rep.batch1_throughput_gops:.0f} GOp/s effective)")


if __name__ == "__main__":
    main()
