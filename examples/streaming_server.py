"""Streaming serving demo: the synchronous chunked pool, then the asyncio
front-end with concurrent incrementally-fed clients.

Builds a small CBTD-pruned DeltaLSTM acoustic model and serves a burst of
staggered streaming requests two ways:

1. `serve_requests` — the synchronous drain loop (the parity oracle):
   chunked device ticks, logits at retirement.
2. `AsyncSpartusServer` — ten concurrent clients connect, feed their
   utterances a few frames at a time, and receive **partial logits per
   chunk** while the utterance is still in flight.  The streamed rows are
   checked to match the synchronous results at 1e-5.

    PYTHONPATH=src python examples/streaming_server.py
    PYTHONPATH=src python examples/streaming_server.py --clients 12 \
        --target-chunk-ms 20     # wall-clock-paced chunk boundaries
"""
from __future__ import annotations

import argparse
import asyncio

import numpy as np
import jax

from repro.data.speech import SpeechConfig, SpeechDataset
from repro.hwsim import spartus_model as hw
from repro.models import lstm_am
from repro.serving import (
    AsyncSpartusServer, BatchedSpartusEngine, EngineConfig, StreamRequest,
    serve_requests,
)

GAMMA, M, THETA = 0.9375, 4, 0.1


def build(n_requests: int):
    data_cfg = SpeechConfig(max_frames=48)
    cfg = lstm_am.LSTMAMConfig(input_dim=data_cfg.feat_dim, hidden_dim=64,
                               n_layers=2, n_classes=data_cfg.vocab)
    params = lstm_am.init_params(jax.random.key(0), cfg)
    params = lstm_am.cbtd_prune_stacks(params, gamma=GAMMA, m=M)
    engine = BatchedSpartusEngine(
        params, cfg, EngineConfig(theta=THETA, gamma=GAMMA, m=M))

    # real (synthetic-speech) utterances with ragged lengths:
    feats, frame_lens, _, _ = next(SpeechDataset(data_cfg, n_requests))
    utts = []
    for i in range(n_requests):
        t = int(frame_lens[i]) if int(frame_lens[i]) > 0 else 16
        utts.append(np.asarray(feats[i, :t], np.float32))
    return engine, utts


def sync_demo(engine, utts, capacity: int, chunk: int):
    """Chunked drain loop: ONE device dispatch advances all slots up to
    `chunk` frames, logits are fetched per session at retirement."""
    rng = np.random.default_rng(0)
    requests = [
        StreamRequest(req_id=i, arrival_step=int(rng.integers(0, 4)) + 4 * i,
                      feats=u)
        for i, u in enumerate(utts)
    ]
    results, stats = serve_requests(engine, requests, capacity=capacity,
                                    chunk_frames=chunk)

    print(f"[sync]  served {stats.n_requests} sessions / "
          f"{stats.total_frames} frames in {stats.wall_s:.2f}s -> "
          f"{stats.frames_per_s:.0f} frames/s (pool capacity "
          f"{stats.capacity}, {stats.chunk_frames}-frame chunks)")
    print(f"[sync]  dispatch economy: {stats.n_dispatches} dispatches "
          f"({stats.dispatches_per_frame:.3f}/frame), host overlap "
          f"{stats.host_overlap_frac:.0%}")
    print(f"[sync]  latency p50 {stats.p50_latency_s*1e3:.0f} ms, "
          f"p95 {stats.p95_latency_s*1e3:.0f} ms; time-to-first-logit "
          f"p50 {stats.p50_ttfl_s*1e3:.0f} ms (== latency: logits "
          f"surface at retirement)")
    return results, stats


async def one_client(server, i, feats, rng):
    """Connect, drip-feed the utterance (as an audio front-end would),
    and collect partial logits per chunk as they stream back."""
    handle = await server.stream(want_partials=True)
    j = 0
    while j < len(feats):
        n = int(rng.integers(2, 6))
        await handle.send(feats[j:j + n])
        j += n
        await asyncio.sleep(float(rng.random()) * 0.002)
    handle.close()
    partials = [p async for p in handle]       # per-chunk [n, n_classes] rows
    result = await handle.result()
    return i, partials, result


async def async_demo(engine, utts, capacity: int, chunk: int,
                     target_chunk_ms: float):
    async with AsyncSpartusServer(
            engine, capacity, chunk_frames=chunk, max_frames=64,
            target_chunk_ms=target_chunk_ms,
            max_pending=2 * capacity) as server:
        rngs = [np.random.default_rng(100 + i) for i in range(len(utts))]
        out = await asyncio.gather(*[
            one_client(server, i, utts[i], rngs[i])
            for i in range(len(utts))])
        stats = server.stats()
    return out, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=10,
                    help="concurrent streaming clients (>= 8 for the demo)")
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--chunk-frames", type=int, default=8)
    ap.add_argument("--target-chunk-ms", type=float, default=0.0,
                    help="wall-clock pacing per chunk (0 = free-run)")
    args = ap.parse_args()

    engine, utts = build(args.clients)
    sync_results, sync_stats = sync_demo(engine, utts, args.capacity,
                                         args.chunk_frames)

    out, stats = asyncio.run(async_demo(
        engine, utts, args.capacity, args.chunk_frames,
        args.target_chunk_ms))

    # every client's streamed per-chunk rows concatenate to exactly the
    # synchronous drain loop's logits:
    n_blocks = 0
    for i, partials, result in out:
        streamed = np.concatenate([p.rows for p in partials])
        np.testing.assert_allclose(streamed, sync_results[i].logits,
                                   atol=1e-5)
        np.testing.assert_allclose(result.logits, sync_results[i].logits,
                                   atol=1e-5)
        n_blocks += len(partials)
    print(f"[async] {len(out)} concurrent streaming clients served; "
          f"{n_blocks} partial-logit blocks streamed; parity with "
          f"serve_requests at 1e-5: OK")
    print(f"[async] latency p50 {stats.p50_latency_s*1e3:.0f} ms, "
          f"p95 {stats.p95_latency_s*1e3:.0f} ms, "
          f"p99 {stats.p99_latency_s*1e3:.0f} ms")
    print(f"[async] time-to-first-logit p50 {stats.p50_ttfl_s*1e3:.0f} ms, "
          f"queue wait p95 {stats.p95_queue_wait_s*1e3:.0f} ms "
          f"({stats.n_dispatches} dispatches, "
          f"{stats.dispatches_per_frame:.3f}/frame)")

    # telemetry: accumulated on device across the whole run, fetched once
    # -> drives the hardware model
    sp = stats.sparsity
    print(f"measured temporal sparsity {sp['temporal_sparsity']:.1%}, "
          f"overflow rate {sp['capacity_overflow_rate']:.1%}")
    rep = hw.evaluate_from_telemetry(hw.SPARTUS, hw.TEST_LAYER, GAMMA, sp)
    print(f"modelled Spartus latency at this sparsity: {rep.latency_us:.2f} us"
          f" ({rep.batch1_throughput_gops:.0f} GOp/s effective)")


if __name__ == "__main__":
    main()
