"""Beyond-paper example: the delta-network idea applied to a transformer's
decode path (DeltaLinear, eq. 2 generalised — DESIGN.md §4).

Runs a reduced seamless-m4t-style encoder over smooth speech-frame
embeddings and measures how much temporal sparsity DeltaLinear extracts
from the time-distributed projections at several thresholds, versus the
same mechanism on a text-token transformer (where smoothness — and hence
sparsity — is absent).  This reproduces the paper's core claim in the
assigned-architecture setting: delta sparsity is a property of the
*signal*, and speech-like inputs are where it pays.

    PYTHONPATH=src python examples/delta_transformer_decode.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.delta_linear import delta_linear_over_time
from repro.data.speech import SpeechConfig, class_means, synth_utterance
from repro.models import api

THETAS = [0.0, 0.05, 0.1, 0.3]


def smooth_frames(t=96, d=128):
    cfg = SpeechConfig(max_frames=t, n_static=d // 3 + 1, tau=0.95)
    feats, *_ = synth_utterance(jax.random.key(0), cfg, class_means(cfg))
    return feats[:, :d] / (jnp.std(feats[:, :d]) + 1e-6)


def token_embeds(t=96, d=128):
    emb = jax.random.normal(jax.random.key(1), (512, d)) * (1 / jnp.sqrt(d))
    toks = jax.random.randint(jax.random.key(2), (t,), 0, 512)
    x = emb[toks]
    return x / (jnp.std(x) + 1e-6)


def main():
    d, o = 128, 256
    w = jax.random.normal(jax.random.key(3), (o, d)) / jnp.sqrt(d)
    speech = smooth_frames(d=d)
    text = token_embeds(d=d)

    print(f"{'theta':>6} | {'speech ts':>9} | {'text ts':>8} | max |err|")
    for theta in THETAS:
        ys, _, aux_s = delta_linear_over_time(w, speech, theta)
        yt, _, aux_t = delta_linear_over_time(w, text, theta)
        ts_s = 1.0 - float(jnp.mean(aux_s["nnz_dx"])) / d
        ts_t = 1.0 - float(jnp.mean(aux_t["nnz_dx"])) / d
        err = float(jnp.max(jnp.abs(ys - speech @ w.T)))
        print(f"{theta:6.2f} | {ts_s:9.1%} | {ts_t:8.1%} | {err:.3f}")

    print("\nSmooth (speech-like) inputs give high delta sparsity; token "
          "embeddings give ~0 beyond the threshold floor — matching the "
          "paper's premise and DESIGN.md §4 applicability table.")


if __name__ == "__main__":
    main()
