"""Distributed utilities: sharding rules, gradient compression, elastic
re-sharding, multi-device train-step smoke (subprocess with 8 host devices).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (
    ef_int8_compress, ef_int8_decompress, ef_topk_compress, init_residual,
)
from repro.launch.elastic import rescale_batch


def test_ef_int8_roundtrip_error_bounded():
    g = {"w": jax.random.normal(jax.random.key(0), (64, 64))}
    r = init_residual(g)
    q, scales, r2 = ef_int8_compress(g, r)
    out = ef_int8_decompress(q, scales)
    err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
    assert err <= float(scales["w"]) / 2 + 1e-7
    # residual holds exactly the quantization error
    np.testing.assert_allclose(np.asarray(r2["w"]),
                               np.asarray(g["w"] - out["w"]), rtol=1e-5,
                               atol=1e-7)


def test_ef_accumulates_small_signals():
    """A gradient smaller than one quantization step must not be lost:
    error feedback accumulates it until it crosses a step.  The residual
    bounds the total error by half a quantization step at any time."""
    n_steps, signal = 2000, 1e-4
    g = {"w": jnp.concatenate([jnp.full((4,), signal), jnp.ones((1,)) * 10.0])}
    r = init_residual(g)
    total_sent = jnp.zeros((4,))
    for i in range(n_steps):
        q, s, r = ef_int8_compress(g, r)
        total_sent = total_sent + ef_int8_decompress(q, s)["w"][:4]
    step = 10.0 / 127.0
    expect = n_steps * signal
    # EF guarantee: |sent_total - signal_total| <= residual <= step/2
    assert float(jnp.max(jnp.abs(total_sent - expect))) <= step / 2 + 1e-6
    # and without EF, every step would round to zero => nothing sent:
    q0, s0, _ = ef_int8_compress(g, init_residual(g))
    assert float(jnp.max(jnp.abs(ef_int8_decompress(q0, s0)["w"][:4]))) == 0.0


def test_topk_keeps_largest():
    g = {"w": jnp.array([0.1, -5.0, 0.2, 3.0])}
    sent, r = ef_topk_compress(g, init_residual(g), frac=0.5)
    nz = np.asarray(sent["w"] != 0)
    assert list(nz) == [False, True, False, True]
    np.testing.assert_allclose(np.asarray(r["w"]), [0.1, 0, 0.2, 0],
                               atol=1e-7)


def test_rescale_batch_preserves_global():
    per_host, accum = rescale_batch(global_batch=256, old_hosts=32,
                                    new_hosts=16, per_host=8)
    assert per_host * accum * 16 >= 256
    assert accum >= 1


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding
    from repro.configs import get_arch
    from repro.distributed.sharding import batch_specs, param_specs
    from repro.launch.elastic import best_mesh_for, reshard
    from repro.launch.steps import make_train_step
    from repro.models import api
    from repro.training.optimizer import AdamWConfig, adamw_init

    cfg = get_arch("qwen3-1.7b").reduced()
    mesh = best_mesh_for(8)
    params = api.init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, cfg))
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(opt, jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(opt, mesh, cfg)))
    batch = api.make_train_batch(cfg, jax.random.key(1), 8, 32)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3), 32)
    with mesh:
        jstep = jax.jit(step)
        losses = []
        for i in range(4):
            params, opt, m = jstep(params, opt, batch)
            losses.append(float(m["loss"]))
    # elastic: re-shard onto a smaller mesh and keep stepping
    host_params = jax.device_get(params)
    mesh2 = jax.make_mesh((2, 2), ("data", "model"))
    params2 = reshard(host_params, mesh2, cfg)
    with mesh2:
        opt2 = reshard(jax.device_get(opt), mesh2, cfg)
        params2, opt2, m2 = jax.jit(step)(params2, opt2, batch)
    print(json.dumps({"losses": losses, "elastic_loss": float(m2["loss"]),
                      "devices": len(jax.devices())}))
""")


@pytest.mark.slow
def test_multidevice_train_and_elastic_reshard():
    """8 fake host devices in a subprocess: sharded training decreases the
    loss; re-sharding to a 4-device mesh continues training."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_SCRIPT],
        capture_output=True, text=True, timeout=900,
        # JAX_PLATFORMS=cpu: the emulated host devices ARE the cpu
        # platform, and without the pin a box with a TPU plugin installed
        # burns ~8 minutes of metadata-probe timeouts before falling back
        env={"PYTHONPATH": os.path.join(repo_root, "src"),
             "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd=repo_root,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["devices"] == 8
    assert data["losses"][-1] < data["losses"][0]
    assert np.isfinite(data["elastic_loss"])
