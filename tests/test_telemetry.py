"""Edge-case tests for repro.serving.telemetry: float32 accumulator
saturation, the fused-vs-per-layer accumulate equivalence under masking,
empty-sample reductions, and the fold_totals/measured_sparsity contract
the observability layer diffs against."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.telemetry import (
    TelemetryState,
    accumulate,
    accumulate_layers,
    fold_totals,
    init_telemetry,
    measured_sparsity,
    percentile_summary,
)


def _state(nnz, ovf, steps):
    return TelemetryState(
        nnz_sum=jnp.asarray(nnz, jnp.float32),
        overflow_steps=jnp.asarray(ovf, jnp.float32),
        steps=jnp.asarray(steps, jnp.float32),
    )


# -------------------------------------------------- float32 counter bounds

def test_float32_steps_exact_below_2_24():
    """Counts are exact integers up to 2^24 (the documented float32
    contract): one more step from 2^24 - 1 lands exactly on 2^24."""
    big = float(2 ** 24 - 1)
    tel = _state([[0.0]], [[0.0]], [[big]])
    tel = accumulate(tel, 0, jnp.array([0], jnp.int32),
                     jnp.array([0], jnp.int32), jnp.array([True]))
    assert float(tel.steps[0, 0]) == float(2 ** 24)


def test_float32_steps_round_beyond_2_24():
    """Past 2^24 single increments round away (2^24 + 1 is not a
    float32) — the accumulator stays finite and monotone rather than
    wrapping like an int32 would, and the summary ratios stay sane."""
    at_cap = float(2 ** 24)
    tel = _state([[at_cap / 2]], [[0.0]], [[at_cap]])
    tel = accumulate(tel, 0, jnp.array([1], jnp.int32),
                     jnp.array([0], jnp.int32), jnp.array([True]))
    assert float(tel.steps[0, 0]) == at_cap          # +1 rounded away
    summ = measured_sparsity(tel, n_cols=[1])
    assert summ["temporal_sparsity"] == pytest.approx(0.5, abs=1e-6)
    assert np.isfinite(list(summ.values())).all()


# ------------------------------------- fused vs per-layer accumulate paths

def test_accumulate_layers_matches_per_layer_on_masked_slots():
    """accumulate_layers (one [L, B] slab add per step) must fold exactly
    what L accumulate() calls fold — including inactive slots, whose
    columns must not move."""
    L, B = 3, 5
    rng = np.random.default_rng(0)
    nnz = rng.integers(0, 50, (L, B)).astype(np.int32)
    dropped = rng.integers(0, 2, (L, B)).astype(np.int32)
    active = np.array([True, False, True, True, False])

    t_fused = init_telemetry(L, B)
    t_loop = init_telemetry(L, B)
    t_fused = accumulate_layers(t_fused, jnp.asarray(nnz),
                                jnp.asarray(dropped), jnp.asarray(active))
    for layer in range(L):
        t_loop = accumulate(t_loop, layer, jnp.asarray(nnz[layer]),
                            jnp.asarray(dropped[layer]), jnp.asarray(active))
    for a, b in zip(t_fused, t_loop):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # masked slots stayed identically zero:
    np.testing.assert_array_equal(np.asarray(t_fused.steps)[:, ~active],
                                  0.0)


def test_accumulate_layers_all_inactive_is_identity():
    L, B = 2, 3
    tel = init_telemetry(L, B)
    out = accumulate_layers(tel, jnp.ones((L, B), jnp.int32),
                            jnp.ones((L, B), jnp.int32),
                            jnp.zeros((B,), bool))
    for a in out:
        np.testing.assert_array_equal(np.asarray(a), 0.0)


# ------------------------------------------------------ empty-sample paths

def test_percentile_summary_empty_and_singleton():
    empty = percentile_summary([], "latency_s")
    assert empty == {"p50_latency_s": 0.0, "p95_latency_s": 0.0,
                     "p99_latency_s": 0.0}
    one = percentile_summary([0.125], "wait_s")
    assert one == {"p50_wait_s": 0.125, "p95_wait_s": 0.125,
                   "p99_wait_s": 0.125}


def test_measured_sparsity_zero_steps_returns_full_zeroed_keys():
    """Regression: an idle pool (steps.sum() == 0) must return the full
    key set zeroed, not {} — callers index the summary unconditionally,
    matching percentile_summary's empty contract."""
    tel = init_telemetry(2, 4)
    summ = measured_sparsity(tel, n_cols=[8, 8])
    assert summ == {"temporal_sparsity": 0.0,
                    "capacity_overflow_rate": 0.0,
                    "mean_active_columns": 0.0}


# ------------------------------------- fold_totals vs measured_sparsity

def test_fold_totals_matches_measured_sparsity():
    """The jitted [3] reduction the observability layer diffs must carry
    exactly the numbers measured_sparsity reduces host-side."""
    L, B = 2, 3
    rng = np.random.default_rng(1)
    tel = _state(rng.integers(0, 100, (L, B)),
                 rng.integers(0, 5, (L, B)),
                 rng.integers(1, 20, (L, B)))
    cols = [16, 32]
    tot = np.asarray(jax.jit(lambda t: fold_totals(t, cols))(tel),
                     np.float64)
    summ = measured_sparsity(tel, cols)
    steps = tot[2]
    assert summ["temporal_sparsity"] == pytest.approx(1.0 - tot[0] / steps)
    assert summ["capacity_overflow_rate"] == pytest.approx(tot[1] / steps)


def test_fold_totals_zero_state():
    tel = init_telemetry(2, 2)
    tot = np.asarray(fold_totals(tel, [4, 4]))
    np.testing.assert_array_equal(tot, 0.0)
