"""Integration: synthetic data pipeline, trainer (pretrain+retrain),
checkpoint/restart fault tolerance, optimizer."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tree_weight_sparsity
from repro.data.speech import SpeechConfig, SpeechDataset, make_batch, class_means
from repro.data.lm import LMConfig, LMDataset
from repro.models import lstm_am
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, schedule_fn
from repro.training.trainer import (
    TrainConfig,
    evaluate_per,
    measure_delta_stats,
    pretrain_retrain,
    train,
)

SMALL = TrainConfig(
    model=lstm_am.LSTMAMConfig(input_dim=123, hidden_dim=32, n_layers=2,
                               n_classes=41),
    data=SpeechConfig(max_frames=48, n_classes=40),
    opt=AdamWConfig(lr=3e-3),
    batch_size=8,
    steps_per_epoch=10,
    cbtd_gamma=0.75,
    cbtd_m=4,
    cbtd_delta_alpha=0.5,  # reach target sparsity after 2 epochs
)


def test_speech_batch_shapes_and_smoothness():
    cfg = SpeechConfig(max_frames=64)
    feats, feat_lens, labels, label_lens = make_batch(
        jax.random.key(0), cfg, 4, class_means(cfg)
    )
    assert feats.shape == (4, 64, 123)
    assert bool(jnp.all(feat_lens >= 32)) and bool(jnp.all(feat_lens <= 64))
    assert bool(jnp.all(label_lens >= 1))
    assert bool(jnp.all((labels >= 0) & (labels <= cfg.n_classes)))
    # temporal smoothness: one-step delta of static features is much smaller
    # than the feature scale (this is what gives delta sparsity)
    static = feats[..., :41]
    diffs = jnp.abs(jnp.diff(static, axis=1))
    assert float(jnp.mean(diffs)) < 0.5 * float(jnp.std(static))


def test_dataset_determinism_and_sharding():
    cfg = SpeechConfig(max_frames=32)
    a = next(SpeechDataset(cfg, 4, process_index=0))
    b = next(SpeechDataset(cfg, 4, process_index=0))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    c = next(SpeechDataset(cfg, 4, process_index=1))
    assert not np.allclose(np.asarray(a[0]), np.asarray(c[0]))
    # resume mid-stream
    ds = SpeechDataset(cfg, 4)
    next(ds)
    state = ds.state_dict()
    x1 = next(ds)
    ds2 = SpeechDataset(cfg, 4)
    ds2.load_state_dict(state)
    x2 = next(ds2)
    np.testing.assert_array_equal(np.asarray(x1[0]), np.asarray(x2[0]))


def test_lm_dataset():
    ds = LMDataset(LMConfig(vocab=128, seq_len=16), 4)
    tok, tgt = next(ds)
    assert tok.shape == (4, 16) and tgt.shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(tok[:, 1:]), np.asarray(tgt[:, :-1]))
    assert int(jnp.max(tok)) < 128


def test_loss_decreases_and_sparsity_reached():
    res = train(SMALL, epochs=3)
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first, f"loss did not decrease: {first} -> {last}"
    ws = tree_weight_sparsity(
        {"w_x": [l["w_x"] for l in res.params["lstm"]],
         "w_h": [l["w_h"] for l in res.params["lstm"]],
         "fcl": res.params["fcl"]["w"]}
    )
    # gamma=0.75, subcolumn len 32/4=8 -> drop 6/8 = 75%
    assert ws == pytest.approx(0.75, abs=0.01)
    # logit layer untouched
    assert float(jnp.mean(res.params["logit"]["w"] == 0)) < 0.01


def test_pretrain_retrain_pipeline():
    pre, post, retrain_cfg = pretrain_retrain(
        SMALL, pretrain_epochs=2, retrain_epochs=1, theta=0.05
    )
    assert retrain_cfg.model.delta and retrain_cfg.model.theta == 0.05
    assert np.isfinite(post.final_loss)
    # delta stats are measurable on the retrained model
    ds = SpeechDataset(SMALL.data, 4)
    stats = measure_delta_stats(post.params, retrain_cfg, ds, n_batches=1)
    assert 0.0 <= stats["layer0"]["temporal_sparsity"] <= 1.0
    # hidden-state deltas should show some sparsity even at small theta
    assert stats["layer1"]["temporal_sparsity_dh"] > 0.05


def test_per_evaluation_runs():
    res = train(SMALL, epochs=1)
    per = evaluate_per(res.params, SMALL, SpeechDataset(SMALL.data, 8), n_batches=1)
    assert 0.0 <= per <= 1.5  # PER can exceed 1 with insertions


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg = dataclasses.replace(SMALL, ckpt_dir=str(tmp_path / "ck"), ckpt_every=5)
    full = train(cfg, epochs=2, resume=False)
    # simulate preemption: run 1 epoch (10 steps), kill, resume to 2 epochs
    cfg2 = dataclasses.replace(cfg, ckpt_dir=str(tmp_path / "ck2"))
    train(cfg2, epochs=1, resume=False)
    resumed = train(cfg2, epochs=2, resume=True)
    # resumed run continued (step count completes to 20, not restarted at 0)
    assert resumed.steps == 20
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(resumed.params)[0]),
        np.asarray(jax.tree.leaves(full.params)[0]),
        rtol=1e-4, atol=1e-5,
    )


def test_checkpoint_manager_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, process_index=0,
                            async_save=False)
    tree = {"w": jnp.arange(4.0)}
    for s in [1, 2, 3]:
        mgr.save(s, tree)
    assert mgr.all_steps() == [2, 3]  # retention
    # incomplete checkpoint (no COMMIT) is ignored
    os.makedirs(tmp_path / "step_000000009")
    assert mgr.latest_step() == 3
    restored, meta = mgr.restore(3, {"w": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(4.0))


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, clip_norm=None)
    params = {"x": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.05


def test_schedules():
    cfg = AdamWConfig(lr=1.0, schedule="cosine", warmup_steps=10,
                      total_steps=110, min_lr_frac=0.1)
    fn = schedule_fn(cfg)
    assert float(fn(jnp.array(0))) == 0.0
    assert float(fn(jnp.array(10))) == pytest.approx(1.0)
    assert float(fn(jnp.array(110))) == pytest.approx(0.1)
    mid = float(fn(jnp.array(60)))
    assert 0.1 < mid < 1.0
