"""Robustness suite: checkpoint/restore, fault injection, degradation.

Three pillars, pinned to BIT-identical logits (``np.array_equal``, not
allclose) wherever before/after run the SAME compiled program on the
same f32 state — then there is nothing to round.  The two documented
exceptions fall back to the repo's 1e-5 oracle tolerance: capacity-1
pools (a different XLA program than the batch-1 engine) and cross-
shard-count migration (rows straddle two differently-partitioned
programs; the transferred *state* is still checked byte-for-byte):

* **Checkpoint/restore** (serving/checkpoint.py): a pool killed at a
  chunk boundary and restored — same shape, different capacity, or a
  different shard count — finishes every in-flight session with exactly
  the logits of an uninterrupted run.
* **Fault injection** (serving/faults.py): seeded deterministic
  `FaultPlan` s fire at named pool sites; every session that survives a
  fault bit-matches the fault-free run (no cross-session contamination).
* **Graceful degradation** (async_server.py): the driver watchdog
  rebuilds the pool after a crashed tick and resumes the salvageable
  sessions; overload sheds with a typed retriable error; idle sessions
  reap; the JSON-lines transport answers malformed traffic in-band with
  typed codes and never takes down a neighbouring stream.

Run sharded cases under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI chaos
job does).
"""
import asyncio
import json
from collections import deque

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch.serve import MAX_LINE_BYTES, demo_client, handle_conn, jline
from repro.models import lstm_am
from repro.serving import (
    AdmissionShed,
    AsyncSpartusServer,
    Backoff,
    BadRequest,
    BatchedSpartusEngine,
    DriverRecovered,
    EngineConfig,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    InjectedFault,
    PoolObservability,
    ProtocolError,
    ServingError,
    SessionTimeout,
    SpartusEngine,
    StreamRequest,
    error_payload,
)
from repro.serving import checkpoint as ckptlib
from repro.serving.scheduler import SessionPool, validated_frames

INPUT_DIM, HIDDEN, CLASSES = 20, 32, 11
GAMMA, M, THETA = 0.75, 4, 0.05
LENS = [5, 9, 3, 12, 1, 7]
N_DEV = jax.device_count()


@pytest.fixture(scope="module")
def model():
    cfg = lstm_am.LSTMAMConfig(input_dim=INPUT_DIM, hidden_dim=HIDDEN,
                               n_layers=2, n_classes=CLASSES)
    params = lstm_am.init_params(jax.random.key(0), cfg)
    return lstm_am.cbtd_prune_stacks(params, gamma=GAMMA, m=M), cfg


@pytest.fixture(scope="module")
def engines(model):
    params, cfg = model
    ecfg = EngineConfig(theta=THETA, gamma=GAMMA, m=M, capacity_frac=1.0)
    return (SpartusEngine(params, cfg, ecfg),
            BatchedSpartusEngine(params, cfg, ecfg))


def _utterance(key, t):
    return np.asarray(
        jax.random.normal(jax.random.key(key), (t, INPUT_DIM)), np.float32)


@pytest.fixture(scope="module")
def workload(engines):
    e1, _ = engines
    feats = [_utterance(300 + i, t) for i, t in enumerate(LENS)]
    refs = [np.asarray(e1.run_utterance(jnp.asarray(f))) for f in feats]
    return feats, refs


def _reqs(feats):
    return [StreamRequest(100 + i, 0, f) for i, f in enumerate(feats)]


def _drain(pool, pending, *, now=0, collected=None, max_iters=10_000):
    """Drive a pool to completion, retrying ticks that raise injected
    faults (the transient-infrastructure model: state is intact, the
    driver simply tries again).  Returns {req_id: logits}."""
    out = dict(collected or {})
    pending = deque(pending)
    for _ in range(max_iters):
        while pending and pool.n_free and pool.admit(pending[0], now):
            pending.popleft()
        if not (pending or pool.n_active or pool.has_pending):
            break
        try:
            finished, adv = pool.tick(now)
        except InjectedFault:
            continue
        for r in finished:
            out[r.req_id] = r.logits
        now += max(adv, 1)
    else:
        raise AssertionError("pool did not drain")
    for r in pool.flush():
        out[r.req_id] = r.logits
    return out


# -- the harness itself -------------------------------------------------------


def test_fault_plan_deterministic():
    a, b = FaultPlan.seeded(7), FaultPlan.seeded(7)
    assert a == b and len(a.events) == 4
    assert FaultPlan.seeded(8) != a
    plan = FaultPlan(events=(FaultEvent("dispatch", 5),
                             FaultEvent("dispatch", 1),
                             FaultEvent("preempt", 0)))
    assert [e.at for e in plan.events_for("dispatch")] == [1, 5]
    assert plan.with_events(FaultEvent("dispatch", 9)).events[-1].at == 9


def test_fault_injector_fires_once_at_scheduled_invocations():
    inj = FaultInjector(FaultPlan(events=(FaultEvent("dispatch", 2),)))
    inj.fire("dispatch")
    inj.fire("dispatch")
    with pytest.raises(InjectedFault) as ei:
        inj.fire("dispatch")
    assert ei.value.site == "dispatch" and ei.value.invocation == 2
    assert ei.value.retriable and ei.value.code == "injected"
    inj.fire("dispatch")                     # each event fires exactly once
    assert inj.count("dispatch") == 4 and len(inj.fired) == 1


def test_backoff_deterministic_and_bounded():
    a, b = Backoff(seed=3), Backoff(seed=3)
    delays = [a.delay(k) for k in range(8)]
    assert delays == [b.delay(k) for k in range(8)]
    for k, d in enumerate(delays):
        assert 0.0 <= d <= a.ceiling(k) <= a.cap_s
    assert a.ceiling(50) == a.cap_s          # capped, no overflow
    assert Backoff(seed=4).delay(3) != a.delay(3)


def test_error_payload_taxonomy():
    cases = [
        (BadRequest("nope"), "bad_request", False),
        (AdmissionShed(), "shed", True),
        (SessionTimeout("idle"), "timeout", True),
        (DriverRecovered("lost"), "retriable_internal", True),
        (ProtocolError("bad_json", "junk"), "bad_json", False),
        (InjectedFault("dispatch", 3), "injected", True),
        (ValueError("plain"), "bad_request", False),
        (RuntimeError("boom"), "internal", False),
    ]
    for exc, code, retriable in cases:
        p = error_payload(exc)
        assert p["code"] == code and p["retriable"] is retriable
        assert p["message"]
    assert error_payload(AdmissionShed(retry_after_ms=80))[
        "retry_after_ms"] == 80.0
    assert isinstance(BadRequest("x"), ValueError)   # pre-taxonomy callers
    assert isinstance(BadRequest("x"), ServingError)


# -- admission validation -----------------------------------------------------


def test_validated_frames_rejects_garbage():
    good = validated_frames(np.zeros((3, INPUT_DIM), np.float32), 1)
    assert good.dtype == np.float32 and good.shape == (3, INPUT_DIM)
    with pytest.raises(ValueError, match="NaN/Inf"):
        validated_frames(np.full((2, INPUT_DIM), np.nan), 1)
    with pytest.raises(ValueError, match="NaN/Inf"):
        validated_frames(np.full((2, INPUT_DIM), np.inf), 1)
    with pytest.raises(ValueError, match="dtype"):
        validated_frames(np.array([["a"] * INPUT_DIM]), 1)
    with pytest.raises(ValueError, match="feature dim"):
        validated_frames(np.zeros((2, 3), np.float32), 1,
                         input_dim=INPUT_DIM)


def test_rejected_admission_leaves_neighbours_bit_identical(
        engines, workload):
    """A poisoned admission fails ITS request; sessions admitted before
    and after produce exactly the fault-free logits."""
    _, eb = engines
    feats, refs = workload
    pool = SessionPool(eb, 2, max_frames=16, chunk_frames=4)
    assert pool.admit(StreamRequest(100, 0, feats[0]), 0)
    with pytest.raises(ValueError, match="NaN/Inf"):
        pool.admit(StreamRequest(999, 0,
                                 np.full((4, INPUT_DIM), np.nan)), 0)
    with pytest.raises(ValueError, match="dtype"):
        pool.admit(StreamRequest(998, 0, np.array([["x"] * INPUT_DIM])), 0)
    got = _drain(pool, [StreamRequest(101, 0, feats[1])])
    assert np.array_equal(got[100], refs[0])
    assert np.array_equal(got[101], refs[1])
    # incremental path: a bad append also fails cleanly
    pool2 = SessionPool(eb, 2, max_frames=16, chunk_frames=4)
    assert pool2.admit_stream(200, 0, feats=feats[2][:1])
    with pytest.raises(ValueError, match="NaN/Inf"):
        pool2.append_frames(200, np.full((2, INPUT_DIM), np.nan))


# -- checkpoint / restore -----------------------------------------------------


@pytest.mark.parametrize("capacity,chunk", [(2, 4), (4, 8), (3, 0)])
def test_checkpoint_restore_roundtrip_bit_identical(
        engines, workload, tmp_path, capacity, chunk):
    """Kill the pool mid-flight at a chunk boundary, restore from disk
    into a fresh pool, finish: every session's logits are bit-identical
    to the uninterrupted run — over chunked and per-frame modes."""
    _, eb = engines
    feats, refs = workload
    pool = SessionPool(eb, capacity, max_frames=16, chunk_frames=chunk)
    got = {}
    pending = deque(_reqs(feats))
    now = 0
    for _ in range(3):                     # run a few boundaries...
        while pending and pool.n_free and pool.admit(pending[0], now):
            pending.popleft()
        finished, adv = pool.tick(now)
        for r in finished:                 # collect — retirements during
            got[r.req_id] = r.logits       # warm-up are results too
        now += max(adv, 1)
    # ...then "die": checkpoint returns the flushed double-buffer tail
    for r in pool.checkpoint(str(tmp_path / "ckpt")):
        got[r.req_id] = r.logits
    n_live = pool.n_active
    del pool                               # the process is gone
    pool2 = SessionPool(eb, capacity, max_frames=16, chunk_frames=chunk)
    pool2.restore(str(tmp_path / "ckpt"))
    assert pool2.n_active == n_live
    got = _drain(pool2, pending, now=now, collected=got)
    assert sorted(got) == [100 + i for i in range(len(feats))]
    for i in range(len(feats)):
        assert np.array_equal(got[100 + i], refs[i]), f"req {100 + i}"


def test_restore_into_different_capacity(engines, workload, tmp_path):
    """Capacity is placement, not semantics: restoring a 2-slot pool's
    checkpoint into a 5-slot pool continues bit-identically."""
    _, eb = engines
    feats, refs = workload
    pool = SessionPool(eb, 2, max_frames=16, chunk_frames=4)
    pending = deque(_reqs(feats[:4]))
    while pending and pool.n_free and pool.admit(pending[0], 0):
        pending.popleft()
    got = {r.req_id: r.logits for r in pool.tick(0)[0]}
    for r in pool.checkpoint(str(tmp_path / "ck")):
        got[r.req_id] = r.logits
    big = SessionPool(eb, 5, max_frames=16, chunk_frames=4)
    big.restore(str(tmp_path / "ck"))
    got = _drain(big, pending, now=4, collected=got)
    for i in range(4):
        assert np.array_equal(got[100 + i], refs[i])


@pytest.mark.skipif(N_DEV < 4, reason="needs 4 (emulated) devices")
@pytest.mark.parametrize("src_dev,dst_dev", [(None, 4), (4, None), (2, 4)])
def test_restore_across_shard_counts(engines, workload, tmp_path,
                                     src_dev, dst_dev):
    """The migration primitive: a checkpoint written at one shard count
    restores at another.  The state transfer is byte-identical — every
    array the destination pool holds after restore equals the file
    bit-for-bit — but end-to-end logits straddle two differently
    partitioned XLA programs (src's first chunk, dst's rest), so the
    numeric bar is the repo's 1e-5 oracle tolerance, same as
    test_sharded_serving.py."""
    _, eb = engines
    feats, refs = workload
    pool = SessionPool(eb, 4, max_frames=16, chunk_frames=4,
                       n_devices=src_dev)
    pending = deque(_reqs(feats[:4]))
    while pending and pool.n_free and pool.admit(pending[0], 0):
        pending.popleft()
    got = {r.req_id: r.logits for r in pool.tick(0)[0]}
    for r in pool.checkpoint(str(tmp_path / "mig")):
        got[r.req_id] = r.logits
    dst = SessionPool(eb, 4, max_frames=16, chunk_frames=4,
                      n_devices=dst_dev)
    dst.restore(str(tmp_path / "mig"))
    saved = {s.req_id: s for s in
             ckptlib.load_checkpoint(str(tmp_path / "mig")).sessions}
    for snap in ckptlib.snapshot_pool(dst).sessions:
        ref_snap = saved.pop(snap.req_id)
        assert snap.meta["cursor"] == ref_snap.meta["cursor"]
        for key, arr in ref_snap.arrays.items():
            assert np.array_equal(snap.arrays[key], arr), (snap.req_id, key)
    assert not saved
    got = _drain(dst, pending, now=4, collected=got)
    for i in range(4):
        np.testing.assert_allclose(got[100 + i], refs[i], atol=1e-5)


def test_single_session_snapshot_migrates(engines, workload):
    """One session snapshotted out of a busy pool and restored into a
    different pool (different capacity, different neighbours) continues
    bit-identically — per-slot computational independence."""
    _, eb = engines
    feats, refs = workload
    pool = SessionPool(eb, 4, max_frames=16, chunk_frames=4)
    for i in range(4):
        assert pool.admit(StreamRequest(100 + i, 0, feats[i]), 0)
    got = {r.req_id: r.logits for r in pool.tick(0)[0]}
    snap = pool.snapshot_session(101)
    assert snap.req_id == 101
    other = SessionPool(eb, 2, max_frames=16, chunk_frames=4)
    assert other.admit(StreamRequest(500, 0, feats[4]), 0)
    assert other.restore_session(snap)
    got.update(_drain(other, [], now=4))
    assert np.array_equal(got[101], refs[1])
    assert np.array_equal(got[500], refs[4])


def test_restore_guards(engines, model, workload, tmp_path):
    """Engine fingerprint mismatches and non-empty targets are refused
    loudly — a checkpoint is only valid against the weights/config that
    wrote it, and restore never silently merges into live sessions."""
    params, cfg = model
    _, eb = engines
    feats, _ = workload
    pool = SessionPool(eb, 2, max_frames=16, chunk_frames=4)
    assert pool.admit(StreamRequest(100, 0, feats[0]), 0)
    ckpt = pool.snapshot()
    assert ckpt.meta["engine"] == ckptlib.engine_fingerprint(eb)
    # duplicate req_id: single-session restore into a pool that already
    # serves it is refused
    with pytest.raises(ValueError, match="already in the pool"):
        pool.restore_session(pool.snapshot_session(100))
    # non-empty target
    with pytest.raises(ValueError, match="empty pool"):
        ckptlib.restore_into(pool, ckpt)
    # different engine config -> different fingerprint
    other = BatchedSpartusEngine(
        params, cfg, EngineConfig(theta=0.2, gamma=GAMMA, m=M,
                                  capacity_frac=1.0))
    mism = SessionPool(other, 2, max_frames=16, chunk_frames=4)
    with pytest.raises(ValueError, match="fingerprint"):
        ckptlib.restore_into(mism, ckpt)
    # nothing on disk
    with pytest.raises(FileNotFoundError):
        ckptlib.load_checkpoint(str(tmp_path / "nope"))


def test_preemption_cycles(engines, workload, tmp_path):
    """The 'preempt' site end-to-end, twice: kill the pool at a boundary,
    restore from the latest committed checkpoint, keep going.  Two
    preemptions deep, every session is still bit-identical."""
    _, eb = engines
    feats, refs = workload
    path = str(tmp_path / "preempt")
    pool = SessionPool(eb, 3, max_frames=16, chunk_frames=4)
    pending = deque(_reqs(feats))
    got = {}
    now = 0
    for cycle in range(2):
        for _ in range(2):
            while pending and pool.n_free and pool.admit(pending[0], now):
                pending.popleft()
            finished, adv = pool.tick(now)
            for r in finished:
                got[r.req_id] = r.logits
            now += max(adv, 1)
        for r in pool.checkpoint(path):
            got[r.req_id] = r.logits
        del pool                          # preempted
        pool = SessionPool(eb, 3, max_frames=16, chunk_frames=4)
        pool.restore(path)                # latest committed step
    got = _drain(pool, pending, now=now, collected=got)
    for i in range(len(feats)):
        assert np.array_equal(got[100 + i], refs[i]), f"req {100 + i}"


# -- chaos: injected pool faults ----------------------------------------------


@pytest.mark.parametrize("site,ats", [
    ("dispatch", (1, 3)),
    ("admission_upload", (0, 2)),
    ("dispatch", (0,)),
])
def test_pool_fault_retry_bit_identical(engines, workload, site, ats):
    """A plain injected fault at a pool site leaves device state intact
    (it fires BEFORE the dispatch donates); the driver retries the tick
    and every session finishes bit-identical to the fault-free run."""
    _, eb = engines
    feats, refs = workload
    inj = FaultInjector(FaultPlan(
        events=tuple(FaultEvent(site, at) for at in ats)))
    pool = SessionPool(eb, 3, max_frames=16, chunk_frames=4, faults=inj)
    got = _drain(pool, _reqs(feats))
    assert len(inj.fired) == len(ats)
    for i in range(len(feats)):
        assert np.array_equal(got[100 + i], refs[i]), f"req {100 + i}"


# -- chaos: async server degradation ------------------------------------------


@pytest.mark.parametrize("ats,n_devices", [
    ((1,), None),
    ((1, 3), None),
    ((2,), 4),
])
def test_watchdog_recovers_bit_identical(engines, workload, ats, n_devices):
    """The driver watchdog: an injected dispatch crash mid-service is
    absorbed — the pool is rebuilt from snapshots and EVERY session
    completes with exactly the fault-free logits."""
    if n_devices and N_DEV < n_devices:
        pytest.skip("needs emulated devices")
    _, eb = engines
    feats, refs = workload
    inj = FaultInjector(FaultPlan(
        events=tuple(FaultEvent("dispatch", at) for at in ats)))
    obs = PoolObservability()

    async def run():
        async with AsyncSpartusServer(
                eb, 4, chunk_frames=4, max_frames=16, offload_ticks=False,
                watchdog=True, faults=inj, n_devices=n_devices,
                observability=obs) as srv:
            res = await asyncio.gather(
                *[srv.submit(f) for f in feats])
            assert srv.n_recoveries == len(ats)
            return res

    for r in asyncio.run(run()):
        if n_devices:
            # sharded pools are 1e-5 vs the batch-1 oracle (different
            # XLA partitioning); the rebuild itself is same-program.
            np.testing.assert_allclose(r.logits, refs[r.req_id], atol=1e-5)
        else:
            assert np.array_equal(r.logits, refs[r.req_id]), r.req_id
    assert obs.c_recoveries.value == len(ats)
    assert obs.c_salvaged.value > 0 and obs.c_lost.value == 0
    assert obs.registry.counter(
        "spartus_faults_total", labels={"site": "dispatch"}).value == len(ats)


def test_watchdog_poison_fails_only_unsalvageable(engines, workload):
    """A poison fault models a crash AFTER donation: the device state is
    gone, so mid-flight sessions fail — each with a retriable
    `DriverRecovered` — but the server survives and a fresh submission
    afterwards is served bit-identically."""
    _, eb = engines
    feats, refs = workload
    inj = FaultInjector(FaultPlan(
        events=(FaultEvent("dispatch", 1, payload="poison"),)))

    async def run():
        async with AsyncSpartusServer(
                eb, 4, chunk_frames=4, max_frames=16, offload_ticks=False,
                watchdog=True, faults=inj) as srv:
            handles = [await srv.stream(feats[i]) for i in range(4)]
            for h in handles:
                h.close()
            ok = lost = 0
            for h in handles:
                try:
                    r = await h.result()
                    assert np.array_equal(r.logits, refs[r.req_id])
                    ok += 1
                except ServingError as e:
                    assert e.retriable and e.code == "retriable_internal"
                    lost += 1
            assert srv.n_recoveries == 1 and lost >= 1
            # the server is alive: retry one lost utterance, then a new one
            r = await srv.submit(feats[0])
            assert np.array_equal(r.logits, refs[0])
            r = await srv.submit(feats[5])
            assert np.array_equal(r.logits, refs[5])

    asyncio.run(run())


def test_watchdog_disabled_fails_loudly(engines, workload):
    """Without the watchdog the old contract holds: a crashed tick fails
    every connected client with the driver's error."""
    _, eb = engines
    feats, _ = workload
    inj = FaultInjector(FaultPlan(events=(FaultEvent("dispatch", 0),)))

    async def run():
        srv = AsyncSpartusServer(eb, 2, chunk_frames=4, max_frames=16,
                                 offload_ticks=False, faults=inj)
        await srv.start()
        with pytest.raises(InjectedFault):
            await srv.submit(feats[0])
        with pytest.raises(InjectedFault):
            await srv.stop()              # the driver re-raises on join

    asyncio.run(run())


def test_idle_reaper_times_out_silent_sessions(engines, workload):
    """A client that opens and goes silent is reaped after
    ``idle_timeout_s`` with a retriable `SessionTimeout`; a busy
    neighbour is untouched and bit-identical."""
    _, eb = engines
    feats, refs = workload
    obs = PoolObservability()

    async def run():
        async with AsyncSpartusServer(
                eb, 2, chunk_frames=4, max_frames=16, offload_ticks=False,
                idle_timeout_s=0.15, observability=obs) as srv:
            silent = await srv.stream(feats[1][:2])   # never closes
            r = await srv.submit(feats[3])
            assert np.array_equal(r.logits, refs[3])
            with pytest.raises(SessionTimeout):
                await silent.result()

    asyncio.run(run())
    assert obs.c_timeouts.value >= 1


def test_shed_policy_and_idempotent_tokens(engines, workload):
    """Overload with policy='shed': admission past max_pending raises a
    typed retriable `AdmissionShed` with a retry hint instead of
    queueing; a token re-open returns the SAME handle (no double
    admission) while the stream lives, and a backoff retry eventually
    lands."""
    _, eb = engines
    feats, refs = workload
    obs = PoolObservability()

    async def run():
        async with AsyncSpartusServer(
                eb, 1, chunk_frames=4, max_frames=16, offload_ticks=False,
                max_pending=1, overload_policy="shed",
                target_chunk_ms=15.0, observability=obs) as srv:
            h = await srv.stream(feats[0], token="tok")
            assert (await srv.stream(token="tok")) is h   # idempotent
            shed = None
            others = []
            try:
                for i in range(8):
                    others.append(await srv.stream(feats[1][:3]))
            except AdmissionShed as e:
                shed = e
            assert shed is not None and shed.retriable
            assert shed.code == "shed" and shed.retry_after_ms >= 15.0
            h.close()
            for o in others:
                o.close()
            r = await h.result()
            # capacity-1 compiles a different program than the batch-1
            # oracle: oracle parity is 1e-5, like the serving suite pins
            np.testing.assert_allclose(r.logits, refs[0], atol=1e-5)
            for o in others:
                await o.result()
            # the slot freed: a backoff retry now succeeds
            bo = Backoff(seed=1)
            for attempt in range(6):
                try:
                    h2 = await srv.stream(feats[2], token="tok2")
                    break
                except AdmissionShed:
                    await asyncio.sleep(bo.delay(attempt))
            else:
                raise AssertionError("retry never admitted")
            h2.close()
            r2 = await h2.result()
            np.testing.assert_allclose(r2.logits, refs[2], atol=1e-5)
            # settled stream released its token: a re-open is a NEW stream
            h3 = await srv.stream(feats[0], token="tok")
            assert h3 is not h
            h3.close()
            await h3.result()
    asyncio.run(run())
    assert obs.c_shed.value >= 1


def test_async_bad_request_is_typed_and_isolated(engines, workload):
    """Malformed payloads at the async boundary raise `BadRequest`
    (typed, non-retriable) in the offending call; the pool and its other
    sessions never see them."""
    _, eb = engines
    feats, refs = workload
    obs = PoolObservability()

    async def run():
        async with AsyncSpartusServer(
                eb, 2, chunk_frames=4, max_frames=16, offload_ticks=False,
                observability=obs) as srv:
            with pytest.raises(BadRequest, match="NaN/Inf"):
                await srv.stream(np.full((3, INPUT_DIM), np.nan))
            with pytest.raises(BadRequest, match="dtype"):
                await srv.stream(np.array([["z"] * INPUT_DIM]))
            with pytest.raises(BadRequest, match="feature dim"):
                await srv.stream(np.zeros((2, 7), np.float32))
            h = await srv.stream(feats[0][:2])
            with pytest.raises(BadRequest, match="NaN/Inf"):
                await h.send(np.full((1, INPUT_DIM), -np.inf))
            await h.send(feats[0][2:])
            h.close()
            r = await h.result()
            # capacity-1 compiles a different program than the batch-1
            # oracle: oracle parity is 1e-5, like the serving suite pins
            np.testing.assert_allclose(r.logits, refs[0], atol=1e-5)

    asyncio.run(run())
    assert obs.c_bad_requests.value == 4


# -- the JSON-lines transport under fuzzed traffic ----------------------------


async def _jsonl_roundtrip(reader, writer, obj):
    jline(writer, obj)
    await writer.drain()
    return json.loads(await reader.readline())


def test_protocol_hardening_fuzz(engines, workload):
    """Malformed JSON-lines traffic answers typed in-band errors without
    killing the connection; an oversized line closes only ITS connection;
    a well-behaved stream on another connection is bit-identical
    throughout the abuse."""
    _, eb = engines
    feats, refs = workload

    async def run():
        async with AsyncSpartusServer(
                eb, 2, chunk_frames=4, max_frames=16,
                offload_ticks=False) as srv:
            tcp = await asyncio.start_server(
                lambda r, w: handle_conn(srv, r, w), "127.0.0.1", 0,
                limit=MAX_LINE_BYTES)
            port = tcp.sockets[0].getsockname()[1]
            good = asyncio.create_task(demo_client(port, 7, feats[0]))

            r, w = await asyncio.open_connection("127.0.0.1", port)
            corpus = [
                (b"this is not json\n", "bad_json"),
                (b"[1, 2, 3]\n", "bad_json"),
                (b'{"no_op": true}\n', "bad_json"),
                (b'{"op": "detonate", "id": 1}\n', "unknown_op"),
                (b'{"op": "frames", "id": 1, "frames": [[0.0]]}\n',
                 "no_such_stream"),
                (b'{"op": "close", "id": 1}\n', "no_such_stream"),
                (b'{"op": "frames"}\n', "no_such_stream"),
            ]
            for raw, code in corpus:
                w.write(raw)
                await w.drain()
                msg = json.loads(await r.readline())
                assert msg["event"] == "error", (raw, msg)
                assert msg["code"] == code and msg["retriable"] is False
            # the connection survived all of that: open a real stream
            msg = await _jsonl_roundtrip(r, w, {"op": "open", "id": 5})
            assert msg == {"event": "open_ok", "id": 5}
            msg = await _jsonl_roundtrip(r, w, {"op": "open", "id": 5})
            assert msg["code"] == "duplicate_id"
            # bad payloads fail the op, not the stream or connection:
            msg = await _jsonl_roundtrip(
                r, w, {"op": "frames", "id": 5,
                       "frames": [[float("nan")] * INPUT_DIM]})
            assert msg["code"] == "bad_request" and not msg["retriable"]
            msg = await _jsonl_roundtrip(
                r, w, {"op": "frames", "id": 5, "frames": ["junk"]})
            assert msg["code"] == "bad_request"
            # stream 5 still works end to end
            for j in range(0, len(feats[1]), 4):
                jline(w, {"op": "frames", "id": 5,
                          "frames": feats[1][j:j + 4].tolist()})
            jline(w, {"op": "close", "id": 5})
            await w.drain()
            rows = []
            while True:
                msg = json.loads(await r.readline())
                if msg["event"] == "done":
                    break
                assert msg["event"] == "partial"
                rows.append(np.asarray(msg["logits"], np.float32))
            assert np.array_equal(np.concatenate(rows), refs[1])
            # transport violation: an over-long line drops the connection
            w.write(b'{"op": "open", "id": 9, "pad": "'
                    + b"x" * (MAX_LINE_BYTES + 64) + b'"}\n')
            await w.drain()
            msg = json.loads(await r.readline())
            assert msg["code"] == "line_too_long"
            assert await r.readline() == b""        # closed
            w.close()
            # ...and the neighbour never noticed
            cid, streamed, done = await good
            assert cid == 7 and done["event"] == "done"
            assert np.array_equal(streamed, refs[0])
            tcp.close()
            await tcp.wait_closed()

    asyncio.run(run())


def test_demo_client_retries_through_shed(engines, workload):
    """The launcher's demo client rides out 'shed' answers with seeded
    backoff + token and still gets bit-identical logits."""
    _, eb = engines
    feats, refs = workload

    async def run():
        async with AsyncSpartusServer(
                eb, 1, chunk_frames=4, max_frames=16, offload_ticks=False,
                max_pending=1, overload_policy="shed") as srv:
            tcp = await asyncio.start_server(
                lambda r, w: handle_conn(srv, r, w), "127.0.0.1", 0,
                limit=MAX_LINE_BYTES)
            port = tcp.sockets[0].getsockname()[1]
            out = await asyncio.gather(
                *[demo_client(port, i, feats[i]) for i in range(4)])
            tcp.close()
            await tcp.wait_closed()
            return out

    for cid, streamed, done in asyncio.run(run()):
        assert done["event"] == "done"
        np.testing.assert_allclose(streamed, refs[cid], atol=1e-5)
