"""Serving engine: CBCSC-packed streaming inference == dense DeltaLSTM
forward (up to int8 quantization), telemetry plausibility."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply_cbtd
from repro.data.speech import SpeechConfig, SpeechDataset
from repro.models import lstm_am
from repro.serving.engine import EngineConfig, SpartusEngine
from repro.training.trainer import TrainConfig, train
from repro.training.optimizer import AdamWConfig


@pytest.fixture(scope="module")
def trained():
    cfg = TrainConfig(
        model=lstm_am.LSTMAMConfig(input_dim=123, hidden_dim=32, n_layers=2,
                                   n_classes=41),
        data=SpeechConfig(max_frames=48),
        opt=AdamWConfig(lr=3e-3),
        batch_size=8, steps_per_epoch=10,
        cbtd_gamma=0.75, cbtd_m=4, cbtd_delta_alpha=1.0,
    )
    res = train(cfg, epochs=2)
    return res.params, cfg


def test_engine_matches_dense_delta_forward(trained):
    params, cfg = trained
    ecfg = EngineConfig(theta=0.05, gamma=0.75, m=4, capacity_frac=1.0,
                        use_pallas=False)
    engine = SpartusEngine(params, cfg.model, ecfg)

    feats, *_ = next(SpeechDataset(cfg.data, 1))
    feats = feats[0, :16]
    logits_engine = engine.run_utterance(feats)

    # dense reference: quantize weights the same way, then run DeltaLSTM
    from repro.core import int8_pack
    from repro.core.delta_lstm import delta_lstm_layer

    x = feats
    for lp in params["lstm"]:
        qx, sx = int8_pack(lp["w_x"])
        qh, sh2 = int8_pack(lp["w_h"])
        # engine packs the stacked matrix with ONE scale; replicate that:
        from repro.core.delta_lstm import stacked_weight_matrix
        w = stacked_weight_matrix(lp)
        q, s = int8_pack(w)
        wq = q.astype(jnp.float32) * s * (w != 0)
        d = lp["w_x"].shape[1]
        lpq = {"w_x": wq[:, :d], "w_h": wq[:, d:], "b": lp["b"]}
        x, _, _ = delta_lstm_layer(lpq, x, theta=0.05)
    x = jax.nn.relu(x @ params["fcl"]["w"].T + params["fcl"]["b"])
    logits_ref = x @ params["logit"]["w"].T + params["logit"]["b"]

    np.testing.assert_allclose(np.asarray(logits_engine),
                               np.asarray(logits_ref), rtol=2e-2, atol=2e-2)


def test_engine_telemetry(trained):
    params, cfg = trained
    engine = SpartusEngine(params, cfg.model,
                           EngineConfig(theta=0.3, gamma=0.75, m=4))
    feats, *_ = next(SpeechDataset(cfg.data, 1))
    engine.run_utterance(feats[0, :24])
    sp = engine.measured_sparsity()
    assert 0.0 < sp["temporal_sparsity"] < 1.0
    assert sp["capacity_overflow_rate"] <= 0.2
    assert engine.weight_sparsity() == pytest.approx(0.75, abs=0.02)


def test_capacity_overflow_drops_smallest(trained):
    params, cfg = trained
    tight = SpartusEngine(params, cfg.model,
                          EngineConfig(theta=0.0, gamma=0.75, m=4,
                                       capacity_frac=0.05))
    feats, *_ = next(SpeechDataset(cfg.data, 1))
    tight.run_utterance(feats[0, :4])
    sp = tight.measured_sparsity()
    assert sp["capacity_overflow_rate"] > 0.5  # theta=0 floods the capacity
