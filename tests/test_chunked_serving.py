"""Chunked device tick loop + double-buffered serving.

`step_chunk` advances every active slot up to C frames in ONE dispatch
(`lax.scan` over the per-frame core) and banks logits in a per-slot
device output buffer; the chunked `SessionPool`/`serve_requests` path
overlaps retirement fetches and admission bookkeeping with the in-flight
chunk.  The per-frame `step_frames` path is the parity oracle: every test
here pins chunked logits/state/telemetry against it (or the batch-1
engine) at 1e-5.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import lstm_am
from repro.serving import telemetry as tele
from repro.serving import (
    BatchedSpartusEngine,
    EngineConfig,
    SpartusEngine,
    StreamRequest,
    serve_requests,
)
from repro.serving.scheduler import SessionPool

INPUT_DIM, HIDDEN, CLASSES = 20, 32, 11
GAMMA, M, THETA = 0.75, 4, 0.05


@pytest.fixture(scope="module")
def model():
    cfg = lstm_am.LSTMAMConfig(input_dim=INPUT_DIM, hidden_dim=HIDDEN,
                               n_layers=2, n_classes=CLASSES)
    params = lstm_am.init_params(jax.random.key(0), cfg)
    return lstm_am.cbtd_prune_stacks(params, gamma=GAMMA, m=M), cfg


@pytest.fixture(scope="module")
def engines(model):
    params, cfg = model
    ecfg = EngineConfig(theta=THETA, gamma=GAMMA, m=M, capacity_frac=1.0)
    return (SpartusEngine(params, cfg, ecfg),
            BatchedSpartusEngine(params, cfg, ecfg))


def _utterance(key, t):
    return np.asarray(
        jax.random.normal(jax.random.key(key), (t, INPUT_DIM)), np.float32)


# -- engine level ------------------------------------------------------------


def test_step_chunk_matches_step_frames(engines):
    """One chunk dispatch == the same frames through per-frame step_frames:
    identical logits in the output buffer, identical final layer state,
    cursor and telemetry — including slots that go inactive mid-chunk."""
    _, eb = engines
    lens = np.array([7, 4, 6], np.int32)
    feats = [_utterance(200 + i, int(t)) for i, t in enumerate(lens)]
    frames = np.zeros((3, 8, INPUT_DIM), np.float32)
    for i, f in enumerate(feats):
        frames[i, :lens[i]] = f
    frames = jnp.asarray(frames)

    s_ref = eb.init_state(3)
    ref_rows = [[] for _ in lens]
    for t in range(int(lens.max())):
        act = np.array([t < l for l in lens])
        s_ref, logits = eb.step_frames(s_ref, frames, act, np.full(3, t == 0))
        logits = np.asarray(logits)
        for b in range(3):
            if act[b]:
                ref_rows[b].append(logits[b])

    s = eb.init_state(3)
    out = eb.init_out_buf(3, 8)
    s, out = eb.step_chunk(s, frames, lens, np.ones(3, bool),
                           np.ones(3, bool), out, n_frames=8)
    out = np.asarray(out)
    for b in range(3):
        # rows past lens[b] are scratch (never consumed by any reader)
        np.testing.assert_allclose(out[b, :lens[b]], np.stack(ref_rows[b]),
                                   atol=1e-5)
    np.testing.assert_array_equal(np.asarray(s.cursor), lens)
    for a, b in zip(jax.tree.leaves(s_ref.layers), jax.tree.leaves(s.layers)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(s_ref.telemetry.steps),
                                  np.asarray(s.telemetry.steps))
    np.testing.assert_array_equal(np.asarray(s_ref.telemetry.nnz_sum),
                                  np.asarray(s.telemetry.nnz_sum))


def test_step_chunk_donates_state_and_out_buf(engines):
    """The chunk dispatch consumes (donates) the incoming PoolState and
    output buffer: the old device buffers are deleted, not copied."""
    _, eb = engines
    frames = jnp.asarray(np.stack([_utterance(210, 6), _utterance(211, 6)]))
    state = eb.init_state(2)
    out = eb.init_out_buf(2, 6)
    old_cursor, old_out = state.cursor, out
    state, out = eb.step_chunk(state, frames, np.array([6, 6]),
                               np.ones(2, bool), np.ones(2, bool), out,
                               n_frames=4)
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(old_cursor)
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(old_out)
    # the returned arrays are live and correct-shaped:
    assert np.asarray(out).shape == (2, 6, CLASSES)
    np.testing.assert_array_equal(np.asarray(state.cursor), [4, 4])


# -- scheduler level ---------------------------------------------------------


def test_chunked_vs_per_frame_parity_grid(engines):
    """Chunked serving == per-frame serving == batch-1 engine over a grid
    of (capacity, chunk_frames) with ragged utterance lengths, staggered
    arrivals, mid-chunk retirements and chunk-boundary admissions."""
    e1, eb = engines
    lens = [5, 9, 3, 12, 1, 7]
    feats = [_utterance(220 + i, t) for i, t in enumerate(lens)]
    refs = [np.asarray(e1.run_utterance(jnp.asarray(f))) for f in feats]
    reqs = [StreamRequest(i, arrival_step=2 * i, feats=feats[i])
            for i in range(len(lens))]

    for capacity in (2, 4):
        base, _ = serve_requests(eb, reqs, capacity=capacity)
        for chunk in (1, 3, 8, 32):
            results, stats = serve_requests(eb, reqs, capacity=capacity,
                                            chunk_frames=chunk)
            assert [r.req_id for r in results] == list(range(len(lens)))
            for r in results:
                np.testing.assert_allclose(r.logits, refs[r.req_id],
                                           atol=1e-5)
                # and bit-level against the per-frame pool path:
                np.testing.assert_allclose(
                    r.logits, base[r.req_id].logits, atol=1e-5)
            assert stats.total_frames == sum(lens)
            assert not stats.truncated
            assert stats.chunk_frames == chunk


def test_midchunk_retirement_and_boundary_admission(engines):
    """capacity 1, chunk 4: a 3-frame request retires mid-chunk (the slot's
    scan iterations past its length are masked no-ops), and the queued
    request is admitted at the next chunk boundary — tick 3, not 4."""
    e1, eb = engines
    feats = [_utterance(230, 3), _utterance(231, 5)]
    reqs = [StreamRequest(0, 0, feats[0]), StreamRequest(1, 0, feats[1])]
    results, stats = serve_requests(eb, reqs, capacity=1, chunk_frames=4)

    assert [r.req_id for r in results] == [0, 1]
    for r in results:
        ref = np.asarray(e1.run_utterance(jnp.asarray(feats[r.req_id])))
        np.testing.assert_allclose(r.logits, ref, atol=1e-5)
    # request 0: 3 frames, finishes at tick 2 inside a 3-tick chunk
    assert results[0].admit_step == 0 and results[0].finish_step == 2
    # request 1 waited for the boundary: admitted at tick 3, not 4
    assert results[1].admit_step == 3
    assert results[1].finish_step == 7
    assert stats.total_steps == 3 + 5


def test_chunked_max_steps_drains_partial(engines):
    """max_steps in chunked mode truncates at a chunk boundary: partial
    logits (chunk granularity) still match the batch-1 prefix."""
    e1, eb = engines
    feats = [_utterance(240, 8), _utterance(241, 8)]
    reqs = [StreamRequest(0, 0, feats[0]), StreamRequest(1, 0, feats[1])]
    results, stats = serve_requests(eb, reqs, capacity=2, chunk_frames=4,
                                    max_steps=4)
    assert stats.truncated
    assert [r.req_id for r in results] == [0, 1]
    for r in results:
        assert r.truncated and r.logits.shape[0] == 4
        ref = np.asarray(e1.run_utterance(jnp.asarray(feats[r.req_id])))
        np.testing.assert_allclose(r.logits, ref[:4], atol=1e-5)


def test_cancel_during_retirement_window_suppresses_result(engines):
    """The double-buffer audit: a session that finished inside an
    in-flight chunk sits in the retirement window (device snapshot taken,
    host fetch one chunk away).  cancel() during that window must be
    accepted (the session is not 'unknown' — no result was delivered
    yet), must suppress the stale result at resolve time, and the freed
    slot must serve a new session with clean numerics."""
    e1, eb = engines
    feats = [_utterance(280, 3), _utterance(281, 5)]
    pool = SessionPool(eb, capacity=1, max_frames=16, chunk_frames=4)

    assert pool.admit(StreamRequest(0, 0, feats[0]), 0)
    done_now = pool.step_chunk(now=0)
    assert done_now == []                 # double-buffered: fetch pending
    assert 0 not in pool._by_req          # retired from the live set...
    assert pool.has_pending               # ...but the fetch is outstanding
    pool.cancel(0)                        # <- inside the window

    # the freed slot serves the next session; the cancelled session's
    # pending snapshot resolves to NOTHING:
    assert pool.admit(StreamRequest(1, 1, feats[1]), 1)
    results = []
    for t in (1, 5):
        results.extend(pool.step_chunk(now=t))
    results.extend(pool.flush())
    assert [r.req_id for r in results] == [1]
    ref = np.asarray(e1.run_utterance(jnp.asarray(feats[1])))
    np.testing.assert_allclose(results[0].logits, ref, atol=1e-5)
    # a request the pool has never seen still raises:
    with pytest.raises(KeyError):
        pool.cancel(99)


def test_chunked_pool_rejects_per_frame_step_and_vice_versa(engines):
    _, eb = engines
    chunked = SessionPool(eb, capacity=2, chunk_frames=4)
    with pytest.raises(RuntimeError, match="step_chunk"):
        chunked.step(now=0)
    per_frame = SessionPool(eb, capacity=2)
    with pytest.raises(RuntimeError, match="chunk_frames=0"):
        per_frame.step_chunk(now=0)


def test_upload_growth_single_realloc_no_host_recopy(engines, monkeypatch):
    """Regression: a long utterance used to rebuild the whole frame slab.
    Growth must now (a) reallocate ONCE, straight to the new bucket,
    (b) stage only the new utterance's bytes host->device — the other
    slots' frames are copied device->device, bit-identically."""
    _, eb = engines
    staged = []
    real_device_put = jax.device_put
    real_asarray = jnp.asarray

    def counting_device_put(x, *a, **kw):
        if isinstance(x, np.ndarray):
            staged.append(x.nbytes)
        return real_device_put(x, *a, **kw)

    def counting_asarray(x, *a, **kw):
        if isinstance(x, np.ndarray):
            staged.append(x.nbytes)
        return real_asarray(x, *a, **kw)

    pool = SessionPool(eb, capacity=3, max_frames=16, chunk_frames=4)
    short = _utterance(250, 8)
    assert pool.admit(StreamRequest(0, 0, short), 0)
    pool._flush_uploads()
    resident_before = np.asarray(pool._frames[0, :8])

    monkeypatch.setattr(jax, "device_put", counting_device_put)
    monkeypatch.setattr(jnp, "asarray", counting_asarray)
    long = _utterance(251, 150)                      # 16 -> 256 bucket
    assert pool.admit(StreamRequest(1, 0, long), 0)
    pool._flush_uploads()
    monkeypatch.setattr(jax, "device_put", real_device_put)
    monkeypatch.setattr(jnp, "asarray", real_asarray)

    # one realloc, straight to the final bucket:
    assert pool.n_frame_grows == 1
    assert pool._t_buf == 256
    # only the new utterance (padded to its bucket) crossed host->device —
    # in particular NOT the other slots' frames (capacity x bucket = the
    # slab the old jnp.pad growth rebuilt).  Small slack for the [R] slot
    # and length index vectors of the batched upload:
    bucket_bytes = 256 * INPUT_DIM * 4
    assert bucket_bytes <= sum(staged) <= bucket_bytes + 64
    assert sum(staged) < 3 * bucket_bytes        # capacity x bucket = slab
    # the resident slot's frames were carried over device-side, bit-exact:
    np.testing.assert_array_equal(np.asarray(pool._frames[0, :8]),
                                  resident_before)
    np.testing.assert_array_equal(np.asarray(pool._frames[1, :150]), long)
    # a later utterance within the bucket never grows again:
    assert pool.admit(StreamRequest(2, 0, _utterance(252, 100)), 0)
    pool._flush_uploads()
    assert pool.n_frame_grows == 1


def test_no_per_tick_reallocation(engines):
    """Steady-state chunked ticking reuses the donated state/output slabs:
    the number of live device arrays does not grow tick over tick."""
    _, eb = engines
    pool = SessionPool(eb, capacity=2, max_frames=64, chunk_frames=4)
    for i in range(2):
        pool.admit(StreamRequest(i, 0, _utterance(260 + i, 64)), 0)
    pool.step_chunk(now=0)                  # compile + first tick
    jax.block_until_ready(pool.state.cursor)
    n0 = len(jax.live_arrays())
    for t in range(3):
        pool.step_chunk(now=4 * (t + 1))
        jax.block_until_ready(pool.state.cursor)
        assert len(jax.live_arrays()) <= n0
    assert pool.n_active == 2               # nobody retired mid-measurement


def test_accumulate_layers_matches_per_layer_accumulate():
    """The vectorised whole-step telemetry fold equals L sequential
    per-layer accumulate() calls (the oracle it replaced in the step).
    Accumulators are per-(layer, slot) — the slot dim is reduced only in
    measured_sparsity, never in the step (the sharded pool depends on
    that: a per-step slot reduction would be a per-frame all-reduce)."""
    L, B = 3, 5
    rng = np.random.default_rng(0)
    nnz = jnp.asarray(rng.integers(0, 50, (L, B)), jnp.int32)
    dropped = jnp.asarray(rng.integers(0, 3, (L, B)), jnp.int32)
    active = jnp.asarray(rng.random(B) < 0.6)

    stacked = tele.accumulate_layers(tele.init_telemetry(L, B), nnz, dropped,
                                     active)
    looped = tele.init_telemetry(L, B)
    for li in range(L):
        looped = tele.accumulate(looped, li, nnz[li], dropped[li], active)
    for a, b in zip(stacked, looped):
        assert a.shape == (L, B)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dispatch_amortisation_metrics(engines):
    """ServeStats surfaces the dispatch economy: C-frame chunks issue
    ~1/C the dispatches of the per-frame path, and the overlap fraction
    is a sane [0, 1) number."""
    _, eb = engines
    reqs = [StreamRequest(i, 0, _utterance(270 + i, 16)) for i in range(4)]
    _, per_frame = serve_requests(eb, reqs, capacity=4)
    _, chunked = serve_requests(eb, reqs, capacity=4, chunk_frames=8)

    assert per_frame.n_dispatches == 16      # one per tick
    assert per_frame.dispatches_per_frame == pytest.approx(16 / 64)
    assert chunked.n_dispatches == 2         # 16 frames / 8-frame chunks
    assert chunked.dispatches_per_frame == pytest.approx(2 / 64)
    assert chunked.total_steps == 16
    assert 0.0 <= chunked.host_overlap_frac < 1.0
    assert per_frame.host_overlap_frac == 0.0
