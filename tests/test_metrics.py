"""Unit tests for the observability primitives (repro.serving.metrics):
registry get-or-create semantics, histogram bucket math, Prometheus
exposition, the bounded time-series ring, and the Chrome tracer.

These are pure host-side tests — no engine, no device work."""
import json
import threading

import pytest

from repro.serving.metrics import (
    DEFAULT_TIMESERIES_LEN,
    NULL_TRACER,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    PoolObservability,
    TimeSeries,
    Tracer,
)


# ---------------------------------------------------------------- registry

def test_registry_get_or_create_identity():
    r = MetricsRegistry()
    c1 = r.counter("spartus_x_total", "help one")
    c2 = r.counter("spartus_x_total", "different help, same metric")
    assert c1 is c2
    # distinct labels are distinct metrics:
    c3 = r.counter("spartus_x_total", labels={"shard": "0"})
    assert c3 is not c1


def test_registry_type_conflict_raises():
    r = MetricsRegistry()
    r.counter("spartus_y_total")
    with pytest.raises(ValueError, match="already registered"):
        r.gauge("spartus_y_total")
    with pytest.raises(ValueError, match="already registered"):
        r.histogram("spartus_y_total")


def test_counter_rejects_negative():
    r = MetricsRegistry()
    c = r.counter("c_total")
    c.inc(3)
    c.inc(0)
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1)
    assert c.value == 3.0


def test_gauge_set_and_inc():
    g = MetricsRegistry().gauge("g")
    g.set(2.5)
    g.inc(-0.5)          # gauges may go down
    assert g.value == 2.0


def test_histogram_cumulative_buckets():
    h = MetricsRegistry().histogram("h_seconds", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.1, 0.5, 5.0, 100.0):
        h.observe(v)
    cum = dict(h.cumulative())
    # le-semantics: 0.1 counts the two observations <= 0.1
    assert cum[0.1] == 2
    assert cum[1.0] == 3
    assert cum[10.0] == 4
    assert cum[float("inf")] == 5
    assert h.count == 5
    assert h.sum == pytest.approx(105.65)


def test_snapshot_shapes():
    r = MetricsRegistry()
    r.counter("a_total").inc(2)
    r.gauge("b").set(7)
    r.histogram("c_seconds", buckets=(1.0,)).observe(0.5)
    snap = r.snapshot()
    assert snap["a_total"] == {"type": "counter", "value": 2.0}
    assert snap["b"] == {"type": "gauge", "value": 7.0}
    assert snap["c_seconds"]["type"] == "histogram"
    assert snap["c_seconds"]["count"] == 1
    # snapshot must be JSON-serializable as-is (admin endpoint contract):
    json.dumps(snap)


def test_render_prometheus_format():
    r = MetricsRegistry()
    r.counter("spartus_frames_total", "frames").inc(42)
    r.gauge("spartus_occupancy").set(3)
    r.gauge("spartus_shard_load", labels={"shard": "1"}).set(2)
    h = r.histogram("spartus_chunk_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    text = r.render_prometheus()
    assert "# TYPE spartus_frames_total counter" in text
    assert "spartus_frames_total 42" in text
    assert 'spartus_shard_load{shard="1"} 2' in text
    assert 'spartus_chunk_seconds_bucket{le="0.1"} 1' in text
    assert 'spartus_chunk_seconds_bucket{le="+Inf"} 1' in text
    assert "spartus_chunk_seconds_count 1" in text
    assert text.endswith("\n")


# ------------------------------------------------------------- time series

def test_timeseries_ring_bound_and_drop_count():
    ts = TimeSeries(maxlen=4)
    for i in range(10):
        ts.append({"chunk": i})
    assert len(ts) == 4
    assert ts.n_appended == 10
    assert ts.n_dropped == 6
    assert [s["chunk"] for s in ts.snapshot()] == [6, 7, 8, 9]
    assert [s["chunk"] for s in ts.snapshot(last=2)] == [8, 9]


def test_timeseries_update_last_merges():
    ts = TimeSeries(maxlen=8)
    ts.append({"chunk": 1, "lagging": 0})
    ts.update_last({"lagging": 3, "partial_queue_depth_max": 5})
    (s,) = ts.snapshot()
    assert s["lagging"] == 3
    assert s["partial_queue_depth_max"] == 5
    # snapshot returns copies — mutating them must not touch the ring:
    s["lagging"] = 99
    assert ts.snapshot()[0]["lagging"] == 3


def test_timeseries_update_last_on_empty_is_noop():
    ts = TimeSeries(maxlen=2)
    ts.update_last({"x": 1})
    assert ts.snapshot() == []


def test_timeseries_rejects_zero_len():
    with pytest.raises(ValueError):
        TimeSeries(maxlen=0)


# ------------------------------------------------------------------ tracer

def test_tracer_records_loadable_chrome_json():
    tr = Tracer(enabled=True)
    with tr.span("dispatch"):
        pass
    with tr.span("snapshot_fetch"):
        pass
    tr.instant("note", {"k": "v"})
    doc = json.loads(tr.to_json())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    names = {e["name"] for e in doc["traceEvents"]}
    assert names == {"dispatch", "snapshot_fetch", "note"}
    for e in doc["traceEvents"]:
        assert e["ph"] in ("X", "i")
        assert e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 0


def test_tracer_bounded_events():
    tr = Tracer(enabled=True, max_events=3)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert tr.n_events == 3
    assert tr.phase_names() == ["s7", "s8", "s9"]


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("dispatch"):
        pass
    assert tr.n_events == 0
    assert NULL_TRACER.n_events == 0
    assert json.loads(NULL_TRACER.to_json())["traceEvents"] == []


def test_tracer_dump(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("pacing_idle"):
        pass
    path = tmp_path / "trace.json"
    tr.dump(str(path))
    doc = json.loads(path.read_text())
    assert doc["traceEvents"][0]["name"] == "pacing_idle"


# ------------------------------------------------------- PoolObservability

def test_fold_chunk_counters_and_sample():
    obs = PoolObservability(timeseries_len=8)
    s = obs.fold_chunk(occupancy=3, capacity=4, n_active=2,
                       frames_advanced=64, dispatch_s=1e-3, chunk_s=2e-3,
                       host_overlap_frac=0.5, admissions=3, retirements=1,
                       shard_loads=[2, 1])
    assert obs.c_dispatches.value == 1.0
    assert obs.c_frames.value == 64.0
    assert obs.g_occupancy.value == 3.0
    assert obs.g_active_frac.value == pytest.approx(0.5)
    assert s["chunk"] == 1
    assert s["shard_loads"] == [2, 1]
    assert s["temporal_sparsity_inc"] == 0.0      # no totals yet
    snap = obs.registry.snapshot()
    assert snap['spartus_shard_load{shard="0"}']["value"] == 2.0
    assert snap['spartus_shard_load{shard="1"}']["value"] == 1.0


def test_fold_chunk_diffs_totals_one_boundary_later():
    import numpy as np
    obs = PoolObservability()
    # boundary 1 enqueues totals [nnz/cols, overflow, steps] = [5, 0, 10]
    obs.fold_chunk(occupancy=1, capacity=1, n_active=1, frames_advanced=10,
                   dispatch_s=0.0, chunk_s=0.0, host_overlap_frac=0.0,
                   admissions=0, retirements=0,
                   telemetry_totals=np.array([5.0, 0.0, 10.0]))
    # boundary 2 fetches them: window sparsity = 1 - 5/10
    s2 = obs.fold_chunk(occupancy=1, capacity=1, n_active=1,
                        frames_advanced=10, dispatch_s=0.0, chunk_s=0.0,
                        host_overlap_frac=0.0, admissions=0, retirements=0,
                        telemetry_totals=np.array([8.0, 1.0, 20.0]))
    assert s2["temporal_sparsity_inc"] == pytest.approx(0.5)
    assert s2["samples_inc"] == 10.0
    assert obs.g_sparsity.value == pytest.approx(0.5)
    # end of run resolves the second window: (8-5)/(20-10)
    obs.flush_totals()
    assert obs._last_totals[2] == 20.0


def test_fold_results_classifies_truncated():
    class R:
        def __init__(self, truncated):
            self.truncated = truncated

    obs = PoolObservability()
    obs.fold_results([R(False), R(True), R(False)])
    assert obs.c_completed.value == 2.0
    assert obs.c_truncated.value == 1.0


def test_timeseries_drop_counter_wired():
    obs = PoolObservability(timeseries_len=2)
    for _ in range(5):
        obs.fold_chunk(occupancy=1, capacity=1, n_active=1,
                       frames_advanced=1, dispatch_s=0.0, chunk_s=0.0,
                       host_overlap_frac=0.0, admissions=0, retirements=0)
    assert len(obs.timeseries) == 2
    assert obs.c_ts_dropped.value == 3.0


def test_shared_registry_across_bundles():
    r = MetricsRegistry()
    a = PoolObservability(registry=r)
    b = PoolObservability(registry=r)
    a.c_dispatches.inc()
    b.c_dispatches.inc()
    assert r.snapshot()["spartus_dispatches_total"]["value"] == 2.0


def test_default_timeseries_len():
    assert PoolObservability().timeseries.maxlen == DEFAULT_TIMESERIES_LEN


def test_concurrent_folds_are_consistent():
    """The async driver folds from a worker thread while the admin
    endpoint scrapes — hammer both sides and check totals."""
    obs = PoolObservability(timeseries_len=64)
    N, T = 200, 4

    def fold():
        for _ in range(N):
            obs.fold_chunk(occupancy=1, capacity=2, n_active=1,
                           frames_advanced=2, dispatch_s=1e-4, chunk_s=2e-4,
                           host_overlap_frac=0.1, admissions=0,
                           retirements=0)

    threads = [threading.Thread(target=fold) for _ in range(T)]
    for t in threads:
        t.start()
    for _ in range(50):
        obs.registry.snapshot()
        obs.registry.render_prometheus()
        obs.timeseries.snapshot(last=8)
    for t in threads:
        t.join()
    assert obs.c_dispatches.value == N * T
    assert obs.c_frames.value == 2 * N * T
    assert obs.timeseries.n_appended == N * T
