"""Slot-dimension data parallelism for the serving pool
(`SessionPool(n_devices=N)`, serving/sharding.py).

Three layers of coverage:

* pure spec logic (`slot_spec`, shard bounds) on abstract meshes — no
  devices needed;
* in-process parity: ``n_devices=1`` always runs; the multi-device grid
  (n_devices in {2, 4} x capacity x chunk_frames x ragged lengths,
  non-divisible-capacity fallback, mid-chunk retirement on a non-zero
  shard, admission skew) runs when the interpreter was started with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
  multi-device CI job does);
* a subprocess leg (slow) that sets the flag itself, so the tier-1 suite
  exercises the multi-device path on any machine — including the pin
  that the compiled sharded chunk contains ZERO collective ops (the
  steady state must not communicate; an iota-indexed frame gather once
  put an all-gather + all-reduce in every scan iteration).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import slot_spec
from repro.models import lstm_am
from repro.serving import (
    AsyncSpartusServer,
    BatchedSpartusEngine,
    EngineConfig,
    SpartusEngine,
    StreamRequest,
    serve_requests,
)
from repro.serving import sharding as shardlib
from repro.serving.scheduler import SessionPool

INPUT_DIM, HIDDEN, CLASSES = 20, 32, 11
GAMMA, M, THETA = 0.75, 4, 0.05
LENS = [5, 9, 3, 12, 1, 7, 8, 2]

N_DEV = jax.device_count()
multi_device = pytest.mark.skipif(
    N_DEV < 4, reason="needs >= 4 devices; run with "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def model():
    cfg = lstm_am.LSTMAMConfig(input_dim=INPUT_DIM, hidden_dim=HIDDEN,
                               n_layers=2, n_classes=CLASSES)
    params = lstm_am.init_params(jax.random.key(0), cfg)
    return lstm_am.cbtd_prune_stacks(params, gamma=GAMMA, m=M), cfg


@pytest.fixture(scope="module")
def engines(model):
    params, cfg = model
    ecfg = EngineConfig(theta=THETA, gamma=GAMMA, m=M, capacity_frac=1.0)
    return (SpartusEngine(params, cfg, ecfg),
            BatchedSpartusEngine(params, cfg, ecfg))


def _utterance(key, t):
    return np.asarray(
        jax.random.normal(jax.random.key(key), (t, INPUT_DIM)), np.float32)


@pytest.fixture(scope="module")
def workload(engines):
    e1, _ = engines
    feats = [_utterance(500 + i, t) for i, t in enumerate(LENS)]
    refs = [np.asarray(e1.run_utterance(jnp.asarray(f))) for f in feats]
    reqs = [StreamRequest(i, 2 * i, feats[i]) for i in range(len(LENS))]
    return feats, refs, reqs


# -- spec logic (no devices) --------------------------------------------------


def _abstract_mesh(shape, axes):
    try:  # jax < 0.5: a tuple of (name, size) pairs
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
    except (TypeError, ValueError):  # jax >= 0.5: (axis_sizes, axis_names)
        return jax.sharding.AbstractMesh(shape, axes)


MESH4 = _abstract_mesh((4,), ("data",))
MESH1 = _abstract_mesh((1,), ("data",))


def test_slot_spec_divisible_shards_dim():
    assert slot_spec((8, 3), MESH4) == P("data", None)
    assert slot_spec((8,), MESH4) == P("data")
    assert slot_spec((2, 8, 5), MESH4, dim=1) == P(None, "data", None)


def test_slot_spec_never_invalid():
    # non-divisible slot dim, or a trivial mesh: replicate, never error
    assert slot_spec((6, 3), MESH4) == P(None, None)
    assert slot_spec((8, 3), MESH1) == P(None, None)
    assert slot_spec((2, 6, 5), MESH4, dim=1) == P(None, None, None)


def test_shard_bounds_and_counts():
    assert shardlib.shard_bounds(8, 4) == [(0, 2), (2, 4), (4, 6), (6, 8)]
    assert shardlib.shard_bounds(8, 1) == [(0, 8)]
    assert shardlib.n_pool_shards(MESH4, 8) == 4
    assert shardlib.n_pool_shards(MESH4, 6) == 1   # fallback: replicate
    assert shardlib.n_pool_shards(MESH1, 8) == 1


# -- single-device mesh (always runs) ----------------------------------------


def test_sharded_pool_n_devices_1_parity(engines, workload):
    """n_devices=1 builds the mesh/placement path end to end (trivially
    replicated) and must be bit-comparable to the unsharded pool."""
    _, eb = engines
    feats, refs, reqs = workload
    for chunk in (0, 4):
        base, _ = serve_requests(eb, reqs, capacity=4, chunk_frames=chunk)
        res, stats = serve_requests(eb, reqs, capacity=4, chunk_frames=chunk,
                                    n_devices=1)
        for r in res:
            np.testing.assert_allclose(r.logits, refs[r.req_id], atol=1e-5)
            np.testing.assert_allclose(r.logits, base[r.req_id].logits,
                                       atol=1e-5)
        assert stats.sparsity      # telemetry survived the mesh path


def test_n_devices_overcommit_raises():
    with pytest.raises(ValueError, match="device"):
        shardlib.make_pool_mesh(max(N_DEV * 2, 1024))


# -- multi-device grid (emulated-device CI job) -------------------------------


@multi_device
def test_sharded_parity_grid(engines, workload):
    """Sharded pools (2 and 4 devices) reproduce the single-device logits
    at 1e-5 over (capacity, chunk_frames) with ragged lengths and
    staggered arrivals — including a capacity NOT divisible by the
    device count, which must fall back to replication (never-invalid)
    and still be correct."""
    _, eb = engines
    feats, refs, reqs = workload
    for n_dev in (2, 4):
        for capacity, chunk in ((4, 0), (4, 4), (8, 8), (6, 4)):
            res, _ = serve_requests(eb, reqs, capacity=capacity,
                                    chunk_frames=chunk, n_devices=n_dev)
            assert [r.req_id for r in res] == list(range(len(LENS)))
            for r in res:
                np.testing.assert_allclose(
                    r.logits, refs[r.req_id], atol=1e-5,
                    err_msg=f"n_dev={n_dev} cap={capacity} chunk={chunk} "
                            f"req={r.req_id}")


@multi_device
def test_least_loaded_shard_admission_and_skew(engines):
    """Admissions spread across shards (least-loaded placement), and a
    deliberately skewed occupancy re-balances as new sessions arrive."""
    _, eb = engines
    pool = SessionPool(eb, capacity=8, max_frames=16, chunk_frames=4,
                       n_devices=4)
    assert pool.n_shards == 4
    for i in range(4):
        assert pool.admit(StreamRequest(i, 0, _utterance(600 + i, 8)), 0)
    assert pool.shard_loads() == [1, 1, 1, 1]      # one per shard
    # skew: free shards 1..3 by cancelling their sessions, keep shard 0
    for i in range(1, 4):
        pool.cancel(i)
    pool.step_chunk(now=0)
    assert pool.shard_loads() == [1, 0, 0, 0]
    # the next admissions go to the empty shards, not next to slot 0:
    for i in range(10, 13):
        assert pool.admit(StreamRequest(i, 1, _utterance(610 + i, 8)), 1)
    assert pool.shard_loads() == [1, 1, 1, 1]
    pool.drain(now=2)


@multi_device
def test_sharded_midchunk_retirement_on_nonzero_shard(engines):
    """A session living on a non-zero shard retires mid-chunk; its slot
    is reused; logits parity holds throughout."""
    e1, eb = engines
    pool = SessionPool(eb, capacity=4, max_frames=16, chunk_frames=4,
                       n_devices=4)
    lens = [8, 3, 8, 8]                  # slot 1 (shard 1) dies mid-chunk
    feats = [_utterance(620 + i, t) for i, t in enumerate(lens)]
    for i in range(4):
        assert pool.admit(StreamRequest(i, 0, feats[i]), 0)
    assert pool.shard_loads() == [1, 1, 1, 1]
    results = []
    results.extend(pool.step_chunk(0))     # session 1 retires mid-chunk
    assert pool.shard_loads() == [1, 0, 1, 1]
    # the freed shard-1 slot is the least-loaded choice for the next
    # admission (slot reuse while its old snapshot is still in flight):
    assert pool.admit(StreamRequest(9, 4, _utterance(630, 5)), 4)
    assert pool.shard_loads() == [1, 1, 1, 1]
    now = 4
    for _ in range(3):
        results.extend(pool.step_chunk(now))
        now += 4
    results.extend(pool.flush())
    got = {r.req_id: r.logits for r in results}
    for i, f in enumerate(feats):
        ref = np.asarray(e1.run_utterance(jnp.asarray(f)))
        np.testing.assert_allclose(got[i], ref, atol=1e-5)
    ref9 = np.asarray(e1.run_utterance(jnp.asarray(_utterance(630, 5))))
    np.testing.assert_allclose(got[9], ref9, atol=1e-5)


@multi_device
def test_sharded_async_server_parity(engines, workload):
    """The asyncio front-end over a 4-device sharded pool streams the
    oracle logits (admission-while-running exercises per-shard placement
    and per-shard retirement fetches)."""
    import asyncio
    _, eb = engines
    feats, refs, _ = workload

    async def run():
        async with AsyncSpartusServer(eb, capacity=4, chunk_frames=4,
                                      max_frames=16, offload_ticks=False,
                                      n_devices=4) as srv:
            return await asyncio.gather(
                *[srv.submit(feats[i], want_partials=True)
                  for i in range(len(feats))])

    results = asyncio.run(run())
    for i, r in enumerate(results):
        np.testing.assert_allclose(r.logits, refs[i], atol=1e-5)


# -- subprocess leg: multi-device on ANY machine (tier-1) ---------------------


SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.models import lstm_am
    from repro.serving import (BatchedSpartusEngine, EngineConfig,
                               SpartusEngine, StreamRequest, serve_requests)

    cfg = lstm_am.LSTMAMConfig(input_dim=20, hidden_dim=32, n_layers=2,
                               n_classes=11)
    params = lstm_am.cbtd_prune_stacks(
        lstm_am.init_params(jax.random.key(0), cfg), gamma=0.75, m=4)
    ecfg = EngineConfig(theta=0.05, gamma=0.75, m=4, capacity_frac=1.0)
    e1 = SpartusEngine(params, cfg, ecfg)
    eb = BatchedSpartusEngine(params, cfg, ecfg)
    lens = [5, 9, 3, 12, 1, 7, 8, 2]
    feats = [np.asarray(jax.random.normal(jax.random.key(700 + i), (t, 20)),
                        np.float32) for i, t in enumerate(lens)]
    refs = [np.asarray(e1.run_utterance(jnp.asarray(f))) for f in feats]
    reqs = [StreamRequest(i, 2 * i, feats[i]) for i in range(len(lens))]

    # compact grid: one sharded config plus the non-divisible replication
    # fallback — this test exists so EVERY tier-1 run exercises the
    # multi-device path; the full grid (n_devices in {1, 2, 4}, per-frame
    # path, 8-way, admission skew, async) runs in-process in the
    # multi-device CI job where the flag is set for the whole suite:
    max_err = 0.0
    for n_dev, cap, chunk in ((4, 8, 4), (4, 6, 4)):
        res, _ = serve_requests(eb, reqs, capacity=cap, chunk_frames=chunk,
                                n_devices=n_dev)
        for r in res:
            max_err = max(max_err, float(np.max(np.abs(
                r.logits - refs[r.req_id]))))

    # zero-communication pin: compile the sharded chunk and count
    # collective ops (the steady state must not communicate).  The
    # lowering recipe and the token scan are the shared analyzer's —
    # the same code `python -m tools.lint --contracts` runs in CI:
    from repro.analysis.cases import lower_pool_chunk
    from repro.analysis.hlo import count_collectives
    txt = lower_pool_chunk(eb, feats, capacity=8, n_devices=4)
    colls = count_collectives(txt)
    print(json.dumps({"devices": len(jax.devices()), "max_err": max_err,
                      "collectives": colls}))
""")


@pytest.mark.slow
def test_sharded_serving_subprocess_4dev():
    """4 emulated host devices in a subprocess: the sharded pool matches
    the batch-1 oracle at 1e-5 (including the non-divisible replication
    fallback), and the compiled sharded chunk contains no collective ops
    at all."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_SCRIPT],
        capture_output=True, text=True, timeout=900,
        # JAX_PLATFORMS=cpu: the emulated host devices ARE the cpu
        # platform, and without the pin a box with a TPU plugin installed
        # burns ~8 minutes of metadata-probe timeouts before falling back
        env={"PYTHONPATH": os.path.join(repo_root, "src"),
             "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd=repo_root,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["devices"] == 4
    assert data["max_err"] <= 1e-5
    assert data["collectives"] == 0
