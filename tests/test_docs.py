"""Documentation stays honest: every executable ```python snippet in
docs/*.md + README.md runs, and every intra-repo markdown link resolves
(tools/check_docs.py; the CI docs job runs the same checker standalone)."""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

from check_docs import check_repo, doc_files, extract_python_blocks  # noqa: E402


def test_docs_exist():
    names = {p.name for p in doc_files(ROOT)}
    assert {"README.md", "architecture.md", "kernels.md",
            "serving.md"} <= names


def test_docs_have_executable_snippets():
    """The checker must actually be checking something."""
    n = sum(len(extract_python_blocks(p.read_text()))
            for p in doc_files(ROOT))
    assert n >= 3


def test_docs_snippets_run_and_links_resolve():
    problems = check_repo(ROOT)
    assert not problems, "\n".join(problems)
