"""CBCSC (Alg. 3) round-trip and SpMV-from-format correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    apply_cbtd,
    blen_for,
    cbcsc_decode,
    cbcsc_encode,
    cbcsc_spmv_reference,
    keep_count,
)


def _pruned_matrix(seed, h, q, m, gamma):
    w = jax.random.normal(jax.random.key(seed), (h, q)) + 0.01
    return apply_cbtd(w, gamma, m, alpha=1.0)


@st.composite
def _case(draw):
    m = draw(st.sampled_from([2, 4, 8]))
    s = draw(st.integers(2, 12))
    q = draw(st.integers(1, 16))
    gamma = draw(st.sampled_from([0.5, 0.75, 0.9]))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, s, q, gamma, seed


@given(_case())
@settings(max_examples=40, deadline=None)
def test_roundtrip_exact(case):
    m, s, q, gamma, seed = case
    h = m * s
    w = _pruned_matrix(seed, h, q, m, gamma)
    enc = cbcsc_encode(w, m, blen=blen_for(h, m, gamma))
    np.testing.assert_array_equal(np.asarray(cbcsc_decode(enc)), np.asarray(w))


@given(_case())
@settings(max_examples=25, deadline=None)
def test_spmv_from_format(case):
    m, s, q, gamma, seed = case
    h = m * s
    w = _pruned_matrix(seed, h, q, m, gamma)
    enc = cbcsc_encode(w, m)
    ds = jax.random.normal(jax.random.key(seed + 1), (q,))
    np.testing.assert_allclose(
        np.asarray(cbcsc_spmv_reference(enc, ds)),
        np.asarray(w @ ds),
        rtol=1e-5, atol=1e-5,
    )


def test_blen_matches_paper():
    # Alg. 3: BLEN = ceil(H/M * (1-gamma)); Table notation M=64, H=4096
    assert blen_for(4096, 64, 0.94) == 4
    assert blen_for(4096, 64, 0.9375) == 4
    assert keep_count(4096, 64, 0.94) == 4


def test_occupancy_violation_raises():
    w = jnp.ones((8, 4))  # dense — every subcolumn full
    with pytest.raises(ValueError):
        cbcsc_encode(w, m=2, blen=1)


def test_stream_order_matches_alg3():
    """Alg. 3 order: outer j (columns), then i (PEs), then k (local)."""
    # 4x2 matrix, M=2 PEs => subcolumns of length 2.
    # rows: r=0 -> PE0 k0, r=1 -> PE1 k0, r=2 -> PE0 k1, r=3 -> PE1 k1
    w = jnp.array(
        [
            [1.0, 5.0],
            [2.0, 0.0],
            [0.0, 6.0],
            [4.0, 8.0],
        ]
    )
    enc = cbcsc_encode(w, m=2, blen=2)
    val, lidx = enc.to_stream()
    # col j=0: PE0 subcol=[1,0] -> [1, pad]; PE1 subcol=[2,4] -> [2,4]
    # col j=1: PE0 subcol=[5,6] -> [5,6];    PE1 subcol=[0,8] -> [8, pad]
    expect_val = [1.0, 0.0, 2.0, 4.0, 5.0, 6.0, 8.0, 0.0]
    expect_idx = [0, 0, 0, 1, 0, 1, 1, 0]
    np.testing.assert_allclose(np.asarray(val), expect_val)
    np.testing.assert_array_equal(np.asarray(lidx), expect_idx)


def test_nbytes_accounting():
    w = _pruned_matrix(0, 64, 32, 4, 0.75)
    enc = cbcsc_encode(w, 4)
    # paper: INT8 VAL + 8-bit LIDX
    assert enc.nbytes(8, 8) == 2 * enc.val.size
    # Edge-Spartus: 10-bit LIDX
    assert enc.nbytes(8, 10) == (enc.val.size * 18 + 7) // 8


def test_global_row_idx_roundtrip():
    w = _pruned_matrix(3, 24, 6, 4, 0.5)
    enc = cbcsc_encode(w, 4)
    gidx = np.asarray(enc.global_row_idx())
    val = np.asarray(enc.val)
    valid = np.asarray(enc.valid)
    dense = np.zeros((enc.h, enc.q), dtype=np.float32)
    for j in range(enc.q):
        for i in range(enc.m):
            for b in range(enc.blen):
                if valid[j, i, b]:
                    dense[gidx[j, i, b], j] = val[j, i, b]
    np.testing.assert_array_equal(dense, np.asarray(w))
