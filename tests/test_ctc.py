"""CTC loss vs brute-force alignment enumeration + decoder/PER tests."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.ctc import (
    ctc_loss,
    ctc_loss_brute_force,
    edit_distance,
    greedy_decode,
    phone_error_rate,
)


def _rand_case(key, t, v, l):
    k1, k2 = jax.random.split(key)
    logits = jax.random.normal(k1, (t, v))
    labels = jax.random.randint(k2, (l,), 1, v)  # 0 is blank
    return logits, labels


@pytest.mark.parametrize("t,v,l", [(3, 3, 1), (4, 3, 2), (5, 4, 2), (6, 3, 3)])
def test_matches_brute_force(t, v, l):
    logits, labels = _rand_case(jax.random.key(t * 100 + v * 10 + l), t, v, l)
    log_probs = np.asarray(jax.nn.log_softmax(logits, axis=-1))
    expect = ctc_loss_brute_force(log_probs, np.asarray(labels))
    got = float(
        ctc_loss(
            logits[None], labels[None], jnp.array([t]), jnp.array([l])
        )
    )
    assert got == pytest.approx(expect, rel=1e-4)


def test_padded_frames_ignored():
    t, v, l = 5, 4, 2
    logits, labels = _rand_case(jax.random.key(0), t, v, l)
    # pad with garbage frames beyond logit_len
    padded = jnp.concatenate([logits, 100 * jnp.ones((3, v))], axis=0)
    a = float(ctc_loss(logits[None], labels[None], jnp.array([t]), jnp.array([l])))
    b = float(ctc_loss(padded[None], labels[None], jnp.array([t]), jnp.array([l])))
    assert a == pytest.approx(b, rel=1e-5)


def test_padded_labels_ignored():
    t, v, l = 6, 4, 2
    logits, labels = _rand_case(jax.random.key(1), t, v, l)
    padded_labels = jnp.concatenate([labels, jnp.array([3, 1])])
    a = float(ctc_loss(logits[None], labels[None], jnp.array([t]), jnp.array([l])))
    b = float(
        ctc_loss(logits[None], padded_labels[None], jnp.array([t]), jnp.array([l]))
    )
    assert a == pytest.approx(b, rel=1e-5)


def test_impossible_label_longer_than_frames():
    # L > T: no valid alignment => very large loss
    logits = jnp.zeros((2, 4))
    labels = jnp.array([1, 2, 3])
    loss = float(ctc_loss(logits[None], labels[None], jnp.array([2]), jnp.array([3])))
    assert loss > 1e20


def test_gradient_is_finite():
    logits, labels = _rand_case(jax.random.key(2), 8, 5, 3)
    g = jax.grad(
        lambda lg: ctc_loss(lg[None], labels[None], jnp.array([8]), jnp.array([3]))
    )(logits)
    assert bool(jnp.all(jnp.isfinite(g)))
    # CTC gradient wrt logits sums to ~0 per frame (softmax property)
    np.testing.assert_allclose(np.asarray(jnp.sum(g, -1)), 0.0, atol=1e-5)


def test_greedy_decode_collapses():
    # path: blank a a blank b -> [a, b]
    v = 3
    path = [0, 1, 1, 0, 2]
    logits = jax.nn.one_hot(jnp.array(path), v)[None] * 10
    out = greedy_decode(logits, jnp.array([5]))
    assert out == [[1, 2]]


def test_edit_distance_and_per():
    assert edit_distance([1, 2, 3], [1, 2, 3]) == 0
    assert edit_distance([1, 2, 3], [1, 3]) == 1
    assert edit_distance([], [1, 2]) == 2
    assert phone_error_rate([[1, 2]], [[1, 2, 3]]) == pytest.approx(1 / 3)
