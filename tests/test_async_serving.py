"""Asyncio streaming front-end (serving/async_server.py).

The async server is pinned against the synchronous `serve_requests`
oracle: per-chunk streamed partial logits concatenate to exactly the
logits the drain loop produces (1e-5) over a (capacity, chunk_frames,
ragged-length) grid, including mid-stream admission, cancellation
mid-utterance, and backpressure when admissions exceed capacity.
"""
import asyncio

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import lstm_am
from repro.serving import (
    AsyncSpartusServer,
    BatchedSpartusEngine,
    EngineConfig,
    SpartusEngine,
    StreamClosed,
    StreamRequest,
    serve_requests,
)

INPUT_DIM, HIDDEN, CLASSES = 20, 32, 11
GAMMA, M, THETA = 0.75, 4, 0.05
LENS = [5, 9, 3, 12, 1, 7]


@pytest.fixture(scope="module")
def model():
    cfg = lstm_am.LSTMAMConfig(input_dim=INPUT_DIM, hidden_dim=HIDDEN,
                               n_layers=2, n_classes=CLASSES)
    params = lstm_am.init_params(jax.random.key(0), cfg)
    return lstm_am.cbtd_prune_stacks(params, gamma=GAMMA, m=M), cfg


@pytest.fixture(scope="module")
def engines(model):
    params, cfg = model
    ecfg = EngineConfig(theta=THETA, gamma=GAMMA, m=M, capacity_frac=1.0)
    return (SpartusEngine(params, cfg, ecfg),
            BatchedSpartusEngine(params, cfg, ecfg))


def _utterance(key, t):
    return np.asarray(
        jax.random.normal(jax.random.key(key), (t, INPUT_DIM)), np.float32)


@pytest.fixture(scope="module")
def workload(engines):
    e1, _ = engines
    feats = [_utterance(300 + i, t) for i, t in enumerate(LENS)]
    refs = [np.asarray(e1.run_utterance(jnp.asarray(f))) for f in feats]
    return feats, refs


async def _stream_client(server, feats, rng, slice_hi=4):
    """Feed an utterance in random 1..slice_hi-frame blocks, yielding the
    loop between sends (mid-chunk arrival), and collect every partial."""
    handle = await server.stream(want_partials=True)
    j = 0
    while j < len(feats):
        n = int(rng.integers(1, slice_hi))
        await handle.send(feats[j:j + n])
        j += n
        await asyncio.sleep(0)
    handle.close()
    parts = [p async for p in handle]
    result = await handle.result()
    return parts, result


def test_async_streamed_parity_grid(engines, workload):
    """Streamed-per-chunk logits == final result == serve_requests output
    at 1e-5 over (capacity, chunk_frames) with ragged lengths; partials
    arrive in frame order and concatenate to the full utterance."""
    _, eb = engines
    feats, refs = workload
    reqs = [StreamRequest(i, 0, feats[i]) for i in range(len(feats))]

    for capacity, chunk in ((2, 4), (4, 8), (3, 1)):
        sync_results, _ = serve_requests(eb, reqs, capacity=capacity,
                                         chunk_frames=chunk)

        async def run():
            async with AsyncSpartusServer(
                    eb, capacity, chunk_frames=chunk, max_frames=16,
                    offload_ticks=False) as srv:
                rngs = [np.random.default_rng(7 * i + capacity)
                        for i in range(len(feats))]
                return await asyncio.gather(*[
                    _stream_client(srv, feats[i], rngs[i])
                    for i in range(len(feats))])

        out = asyncio.run(run())
        for i, (parts, result) in enumerate(out):
            assert [p.t0 for p in parts] == sorted(p.t0 for p in parts)
            streamed = np.concatenate([p.rows for p in parts])
            assert streamed.shape[0] == LENS[i]
            np.testing.assert_allclose(streamed, refs[i], atol=1e-5)
            np.testing.assert_allclose(result.logits, refs[i], atol=1e-5)
            np.testing.assert_allclose(
                result.logits, sync_results[i].logits, atol=1e-5)


def test_async_submit_matches_oracle(engines, workload):
    """Whole-utterance submit (no partial streaming) returns the oracle
    logits, and TTFL/queue-wait stats are populated and consistent."""
    _, eb = engines
    feats, refs = workload

    async def run():
        async with AsyncSpartusServer(eb, capacity=2, chunk_frames=4,
                                      max_frames=16,
                                      offload_ticks=False) as srv:
            results = await asyncio.gather(
                *[srv.submit(feats[i]) for i in range(len(feats))])
            return results, srv.stats()

    results, stats = asyncio.run(run())
    for i, r in enumerate(results):
        np.testing.assert_allclose(r.logits, refs[i], atol=1e-5)
        assert 0 <= r.queue_wait_s <= r.wall_latency_s + 1e-9
        assert 0 < r.ttfl_s <= r.wall_latency_s + 1e-9
    assert stats.n_requests == len(feats)
    assert stats.total_frames == sum(LENS)
    assert stats.p50_ttfl_s > 0
    assert stats.p99_latency_s >= stats.p50_latency_s


def test_async_mid_stream_admission(engines, workload):
    """A client admitted while another is mid-utterance: the first is
    still streaming (not finished) at the second's admission, and both
    produce oracle logits."""
    _, eb = engines
    feats, refs = workload

    async def run():
        async with AsyncSpartusServer(eb, capacity=2, chunk_frames=2,
                                      max_frames=16,
                                      offload_ticks=False) as srv:
            h1 = await srv.stream(want_partials=True)
            await h1.send(feats[3][:2])          # 12-frame utterance, drip-fed
            # wait until the first client's logits start streaming back:
            first = await h1.__anext__()
            assert first.t0 == 0
            # now admit a second client mid-utterance-1:
            h2 = await srv.stream(feats[0], want_partials=False)
            h2.close()
            await h2.admitted.wait()
            assert srv.n_connected == 2          # 1 still open while 2 admitted
            # finish feeding client 1:
            await h1.send(feats[3][2:])
            h1.close()
            parts = [first] + [p async for p in h1]
            r1 = await h1.result()
            r2 = await h2.result()
            return parts, r1, r2

    parts, r1, r2 = asyncio.run(run())
    np.testing.assert_allclose(
        np.concatenate([p.rows for p in parts]), refs[3], atol=1e-5)
    np.testing.assert_allclose(r1.logits, refs[3], atol=1e-5)
    np.testing.assert_allclose(r2.logits, refs[0], atol=1e-5)


def test_async_cancellation_mid_utterance(engines, workload):
    """Cancelling a stream mid-utterance frees its slot (a queued client
    gets admitted and completes), result() raises CancelledError, sending
    after cancel raises StreamClosed, and the neighbour session's logits
    are unaffected."""
    _, eb = engines
    feats, refs = workload

    async def run():
        async with AsyncSpartusServer(eb, capacity=1, chunk_frames=4,
                                      max_frames=16,
                                      offload_ticks=False) as srv:
            victim = await srv.stream(feats[1][:4], want_partials=True)
            await victim.admitted.wait()
            survivor_task = asyncio.create_task(srv.submit(feats[2]))
            await asyncio.sleep(0.01)
            assert not survivor_task.done()      # pool full: it queues
            victim.cancel()
            with pytest.raises(asyncio.CancelledError):
                await victim.result()
            with pytest.raises(StreamClosed):
                await victim.send(feats[1][4:6])
            survivor = await survivor_task      # admitted into the freed slot
            return survivor

    survivor = asyncio.run(run())
    np.testing.assert_allclose(survivor.logits, refs[2], atol=1e-5)


def test_async_backpressure_bounds_admission_queue(engines, workload):
    """max_pending bounds the admission queue: with capacity 1 and
    max_pending 1, a third concurrent stream() call cannot return until a
    slot frees; every client still completes with oracle logits, and the
    later arrivals record positive queue wait."""
    _, eb = engines
    feats, refs = workload

    async def run():
        async with AsyncSpartusServer(eb, capacity=1, chunk_frames=4,
                                      max_frames=16, max_pending=1,
                                      offload_ticks=False) as srv:
            h1 = await srv.stream(feats[0])      # takes the slot and HOLDS
            await h1.admitted.wait()             # it (stream left open)
            h2 = await srv.stream(feats[2])      # fills the admission queue
            h2.close()
            opened3 = asyncio.Event()

            async def third():
                h3 = await srv.stream(feats[4])  # must WAIT: queue is full
                opened3.set()
                h3.close()
                return await h3.result()

            t3 = asyncio.create_task(third())
            await asyncio.sleep(0.02)
            assert not opened3.is_set()          # blocked on backpressure
            h1.close()                           # slot frees -> h2 admitted
            r1 = await h1.result()
            r2 = await h2.result()
            r3 = await t3
            assert opened3.is_set()
            return r1, r2, r3

    r1, r2, r3 = asyncio.run(run())
    np.testing.assert_allclose(r1.logits, refs[0], atol=1e-5)
    np.testing.assert_allclose(r2.logits, refs[2], atol=1e-5)
    np.testing.assert_allclose(r3.logits, refs[4], atol=1e-5)
    assert r3.queue_wait_s > 0
    assert r3.queue_wait_s <= r3.wall_latency_s + 1e-9


def test_async_submit_stream_iterator(engines, workload):
    """The AsyncIterator feeding path (submit_stream) drives a session to
    the same logits."""
    _, eb = engines
    feats, refs = workload

    async def blocks(f):
        for j in range(0, len(f), 3):
            yield f[j:j + 3]
            await asyncio.sleep(0)

    async def run():
        async with AsyncSpartusServer(eb, capacity=2, chunk_frames=4,
                                      max_frames=16,
                                      offload_ticks=False) as srv:
            handles = [await srv.submit_stream(blocks(feats[i]))
                       for i in (1, 5)]
            return await asyncio.gather(*[h.result() for h in handles])

    r1, r5 = asyncio.run(run())
    np.testing.assert_allclose(r1.logits, refs[1], atol=1e-5)
    np.testing.assert_allclose(r5.logits, refs[5], atol=1e-5)


def test_async_offloaded_ticks_parity(engines, workload):
    """offload_ticks=True (device sync in a worker thread) produces the
    same logits — the default serving configuration."""
    _, eb = engines
    feats, refs = workload

    async def run():
        async with AsyncSpartusServer(eb, capacity=2, chunk_frames=4,
                                      max_frames=16,
                                      offload_ticks=True) as srv:
            return await asyncio.gather(
                *[srv.submit(feats[i]) for i in range(4)])

    results = asyncio.run(run())
    for i, r in enumerate(results):
        np.testing.assert_allclose(r.logits, refs[i], atol=1e-5)


def test_async_bad_request_fails_only_itself(engines, workload):
    """A malformed request (wrong feature dim, or an utterance past the
    growth limit) is a per-request error: the offending client's call or
    result raises, the driver stays up, and other clients complete."""
    _, eb = engines
    feats, refs = workload

    async def run():
        async with AsyncSpartusServer(eb, capacity=2, chunk_frames=4,
                                      max_frames=16, max_buffer_frames=32,
                                      offload_ticks=False) as srv:
            with pytest.raises(ValueError, match="feature dim"):
                await srv.submit(np.zeros((4, INPUT_DIM + 3), np.float32))
            with pytest.raises(ValueError, match="growth limit"):
                await srv.submit(np.zeros((100, INPUT_DIM), np.float32))
            h = await srv.stream(feats[0][:2])
            with pytest.raises(ValueError, match="feature dim"):
                await h.send(np.zeros((2, 5), np.float32))
            h.cancel()
            # the server survived all of it and still serves:
            return await srv.submit(feats[2])

    survivor = asyncio.run(run())
    np.testing.assert_allclose(survivor.logits, refs[2], atol=1e-5)
    assert survivor.logits.shape[0] == LENS[2]


def test_async_stats_total_steps_counts_dispatching_ticks(engines, workload):
    """ServeStats.total_steps from the async server counts frames
    advanced by dispatching ticks only — flush-only iterations (the
    double-buffer tail) must not inflate it (same invariant as the sync
    driver)."""
    _, eb = engines
    feats, refs = workload

    async def run():
        async with AsyncSpartusServer(eb, capacity=2, chunk_frames=4,
                                      max_frames=16,
                                      offload_ticks=False) as srv:
            await asyncio.gather(srv.submit(feats[0]), srv.submit(feats[2]))
            return srv.stats()

    stats = asyncio.run(run())
    # 5- and 3-frame utterances, capacity 2: the longest session bounds
    # the dispatched frame count; flush ticks add nothing.
    assert stats.total_frames == LENS[0] + LENS[2]
    assert stats.total_steps == max(LENS[0], LENS[2])


def test_async_slow_consumer_bounded_queue(engines):
    """The slow-consumer fix: a client that stops draining its partials
    queue buffers at most ``partial_queue_len`` blocks host-side — the
    session goes lagging (snapshots paused), the driver never blocks,
    a concurrently served healthy client is unaffected, and once the
    stalled client drains it still receives EVERY row (backfilled from
    the device logits bank / final result) in frame order."""
    e1, eb = engines
    bound = 3
    a_feats = _utterance(400, 10)
    b_feats = _utterance(401, 48)        # 24 chunks at chunk_frames=2:
    #                                      vastly more than the bound
    a_ref = np.asarray(e1.run_utterance(jnp.asarray(a_feats)))
    b_ref = np.asarray(e1.run_utterance(jnp.asarray(b_feats)))

    async def run():
        async with AsyncSpartusServer(
                eb, capacity=2, chunk_frames=2, max_frames=64,
                partial_queue_len=bound, offload_ticks=False) as srv:
            hb = await srv.stream(b_feats[:4], want_partials=True)
            qsizes, mid_parts = [], []

            async def feeder():
                for j in range(4, 48, 4):
                    await hb.send(b_feats[j:j + 4])
                    await asyncio.sleep(0.002)   # let chunks run: B stalls
                    qsizes.append(hb._partials.qsize())
                    if j == 24:
                        # drain two blocks mid-stream: the driver must
                        # backfill the skipped range and resume streaming
                        mid_parts.append(await hb.__anext__())
                        mid_parts.append(await hb.__anext__())
                hb.close()

            # the healthy client is served while B is stalling:
            ra, _ = await asyncio.gather(srv.submit(a_feats), feeder())
            rb = await hb.result()
            tail = [p async for p in hb]
            return ra, rb, mid_parts + tail, qsizes

    ra, rb, parts, qsizes = asyncio.run(run())
    # the bound held the whole time (this is the memory guarantee):
    assert max(qsizes) <= bound
    # ...and it actually bound something (the stall really saturated it):
    assert max(qsizes) == bound
    # the healthy neighbour is untouched:
    np.testing.assert_allclose(ra.logits, a_ref, atol=1e-5)
    # the stalled client still got the complete, in-order stream:
    np.testing.assert_allclose(rb.logits, b_ref, atol=1e-5)
    assert [p.t0 for p in parts] == sorted(p.t0 for p in parts)
    streamed = np.concatenate([p.rows for p in parts])
    assert streamed.shape[0] == 48
    np.testing.assert_allclose(streamed, b_ref, atol=1e-5)
    # lagging coalesced skipped chunks into catch-up blocks (at least one
    # block wider than a chunk proves the pause/backfill path ran):
    assert max(p.rows.shape[0] for p in parts) > 2


def test_async_cancel_in_retirement_window(engines, workload):
    """A session cancelled between its in-chunk retirement snapshot and
    the one-chunk-later host fetch must vanish: no result, no partials,
    no stats pollution, and its slot is cleanly reused.  The window is
    caught by polling for 'left the live set but result not yet
    resolved'; attempts that miss it retry."""
    _, eb = engines
    feats, refs = workload

    async def attempt(srv):
        h = await srv.stream(feats[1], want_partials=True)
        h.close()
        for _ in range(10_000):
            if h.req_id not in srv.pool._by_req:
                break
            await asyncio.sleep(0)
        if h._result.done():
            return None                  # missed the window; retry
        h.cancel()                       # <- lands inside the window
        with pytest.raises(asyncio.CancelledError):
            await h.result()
        return [p async for p in h]

    async def run():
        async with AsyncSpartusServer(eb, capacity=1, chunk_frames=4,
                                      max_frames=16,
                                      offload_ticks=False) as srv:
            caught, misses = None, 0
            for _ in range(25):
                caught = await attempt(srv)
                if caught is not None:
                    break
                misses += 1              # raced past the window: that
                #                          attempt completed normally
            # the slot is reusable and numerically clean afterwards:
            survivor = await srv.submit(feats[2])
            return caught, misses, survivor, srv.stats(), \
                len(srv._completed)

    caught, misses, survivor, stats, n_completed = asyncio.run(run())
    assert caught is not None, "never caught the retirement window"
    np.testing.assert_allclose(survivor.logits, refs[2], atol=1e-5)
    # the in-window cancel never surfaces anywhere — not in results, not
    # in the completed/stats accounting (it used to be silently appended
    # to _completed even though no client could ever see it):
    assert n_completed == misses + 1
    assert stats.n_requests == misses + 1
    assert stats.total_frames == misses * LENS[1] + LENS[2]


def test_async_wall_clock_pacing(engines, workload):
    """target_chunk_ms paces chunk boundaries: serving a 12-frame
    utterance in 4-frame chunks at 30 ms/chunk takes >= 2 pacing sleeps
    (the last chunk doesn't wait), and still matches the oracle."""
    _, eb = engines
    feats, refs = workload
    import time

    async def run():
        async with AsyncSpartusServer(eb, capacity=1, chunk_frames=4,
                                      max_frames=16, target_chunk_ms=30.0,
                                      offload_ticks=False) as srv:
            t0 = time.perf_counter()
            r = await srv.submit(feats[3])       # 12 frames = 3 chunks
            return r, time.perf_counter() - t0

    r, wall = asyncio.run(run())
    np.testing.assert_allclose(r.logits, refs[3], atol=1e-5)
    assert wall >= 0.06                          # >= 2 full chunk periods
