"""Roofline analyzer units: HLO collective parsing + term arithmetic."""
import pytest

from repro.launch.roofline import (
    HBM_BW, ICI_BW, PEAK_FLOPS, collective_bytes, _shape_bytes, model_flops,
)
from repro.models.config import SHAPES
from repro.configs import get_arch

HLO = """
ENTRY %main {
  %ag = bf16[16,4096,896]{2,1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[256,1024]{1,0} all-reduce(%p1), replica_groups=[32,8]<=[256], to_apply=%add
  %rs = f32[8,128]{1,0} reduce-scatter(%p2), replica_groups={{0,1}}, dimensions={0}
  %a2a = bf16[64,64]{1,0} all-to-all(%p3), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = u8[1024]{0} collective-permute(%p4), source_target_pairs={{0,1}}
  %tup = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-reduce(%p5, %p6), replica_groups={{0,1}}
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[16,4096,896]") == 16 * 4096 * 896 * 2
    assert _shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert _shape_bytes("(f32[2,2], s8[4])") == 16 + 4


def test_collective_parse_and_ring_model():
    total, kinds = collective_bytes(HLO, n_devices=256)
    ag = 16 * 4096 * 896 * 2 * (3 / 4)            # group of 4
    ar = 2 * 256 * 1024 * 4 * (7 / 8)             # iota groups of 8
    rs = 8 * 128 * 4 * 1                           # group of 2: r*(n-1)
    a2a = 64 * 64 * 2 * (7 / 8)
    cp = 1024
    tup = 2 * (16 + 16) * (1 / 2)
    assert kinds["all-gather"] == pytest.approx(ag)
    assert kinds["all-reduce"] == pytest.approx(ar + tup)
    assert kinds["reduce-scatter"] == pytest.approx(rs)
    assert kinds["all-to-all"] == pytest.approx(a2a)
    assert kinds["collective-permute"] == pytest.approx(cp)
    assert total == pytest.approx(ag + ar + rs + a2a + cp + tup)


def test_group_size_defaults_to_world():
    total, kinds = collective_bytes(
        "%x = f32[4]{0} all-reduce(%p), to_apply=%add\n", n_devices=4
    )
    assert kinds["all-reduce"] == pytest.approx(2 * 16 * (3 / 4))


def test_model_flops_kinds():
    cfg = get_arch("qwen3-1.7b")
    cells = {c.name: c for c in SHAPES}
    n = 2_000_000_000
    head = cfg.vocab * cfg.d_model
    train = model_flops(cfg, cells["train_4k"], n)
    assert train == pytest.approx(6 * n * 256 * 4096)
    pre = model_flops(cfg, cells["prefill_32k"], n)
    assert pre == pytest.approx(2 * (n - head) * 32 * 32768 + 2 * head * 32)
    dec = model_flops(cfg, cells["decode_32k"], n)
    assert dec == pytest.approx(2 * n * 128)


def test_constants_match_assignment():
    assert PEAK_FLOPS == 197e12 and HBM_BW == 819e9 and ICI_BW == 50e9
