"""Per-architecture smoke tests: instantiate a REDUCED config of each
assigned family, run one forward/train step + one decode step on CPU,
assert output shapes + finiteness + a gradient step works.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct,
no allocation) — see launch/dryrun.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models import api
from repro.models.config import SHAPES, shape_applicable

ARCHS = sorted(REGISTRY)


@pytest.fixture(scope="module")
def rng():
    return jax.random.key(0)


def _reduced(name):
    return REGISTRY[name].reduced()


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_smoke(name, rng):
    cfg = _reduced(name)
    params = api.init_params(cfg, rng)
    batch = api.make_train_batch(cfg, rng, batch=2, seq=32)

    loss, grads = jax.value_and_grad(
        lambda p: api.train_loss(p, cfg, batch)
    )(params)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    gleaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in gleaves), f"{name}: NaN grads"
    # at least one nonzero gradient per arch
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in gleaves)


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step_smoke(name, rng):
    cfg = _reduced(name)
    params = api.init_params(cfg, rng)
    cache = api.init_cache(cfg, batch=2, s_cache=16)
    if cfg.family == "vlm":
        inputs = jax.random.normal(rng, (2, 1, cfg.d_model))
    else:
        inputs = jnp.zeros((2, 1), jnp.int32)
    logits, cache = api.serve_step(params, cfg, inputs, cache)
    assert logits.shape == (2, 1, cfg.vocab), f"{name}: {logits.shape}"
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: non-finite logits"
    # second step advances position
    logits2, cache2 = api.serve_step(params, cfg, inputs, cache)
    assert int(cache2["pos"]) == 2
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("name", ["qwen2-0.5b", "mamba2-130m",
                                  "recurrentgemma-9b", "seamless-m4t-medium"])
def test_decode_matches_prefill(name, rng):
    """Step-by-step decode logits == teacher-forced forward logits (the
    cache machinery is consistent with the parallel path)."""
    cfg = _reduced(name)
    params = api.init_params(cfg, rng)
    t = 8
    toks = jax.random.randint(jax.random.key(1), (1, t), 0, cfg.vocab)

    if cfg.family == "audio":
        from repro.models import encdec
        frames = jax.random.normal(jax.random.key(2), (1, 12, cfg.d_model))
        enc_out = encdec.encode(params, cfg, frames)
        full = encdec.decode_train(params, cfg, toks, enc_out)
        cache = encdec.init_cache(cfg, 1, enc_len=12)
        cross = encdec.build_cross_cache(params, cfg, enc_out)
        cache["cross"] = cross
        outs = []
        for i in range(t):
            lg, cache = encdec.decode_step(params, cfg, toks[:, i : i + 1], cache)
            outs.append(lg[:, 0])
    else:
        from repro.models import transformer, mamba2, rglru
        mod = {"dense": transformer, "ssm": mamba2, "hybrid": rglru}[cfg.family]
        full = mod.forward(params, cfg, toks)
        cache = api.init_cache(cfg, batch=1, s_cache=t)
        outs = []
        for i in range(t):
            lg, cache = api.serve_step(params, cfg, toks[:, i : i + 1], cache)
            outs.append(lg[:, 0])

    stepped = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(stepped, np.float32), np.asarray(full, np.float32),
        rtol=2e-2, atol=2e-3,
    )


@pytest.mark.parametrize("name", ARCHS)
def test_cbtd_applies_to_arch(name, rng):
    """The paper's pruning covers every linear of every assigned arch."""
    from repro.core.cbtd import cbtd_prune_tree
    from repro.core import tree_weight_sparsity

    cfg = _reduced(name)
    params = api.init_params(cfg, rng)
    layout = api.cbtd_layout(cfg, gamma=0.5, m=4)
    pruned = cbtd_prune_tree(params, layout, alpha=1.0)
    # embeddings untouched
    np.testing.assert_array_equal(np.asarray(pruned["embed"]),
                                  np.asarray(params["embed"]))
    # a known linear got ~50% sparsity
    flat = jax.tree_util.tree_flatten_with_path(pruned)[0]
    hit = 0
    for path, leaf in flat:
        pname = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if any(pat in pname for pat in layout) and leaf.ndim >= 2:
            sp = float(jnp.mean(leaf == 0))
            assert 0.4 <= sp <= 0.6, f"{name} {pname}: sparsity {sp}"
            hit += 1
    # stacked leaves cover all layers, so even 2 matches (e.g. mamba2's
    # in/out projections) span the whole network
    assert hit >= 2, f"{name}: CBTD matched only {hit} weights"


def test_shape_applicability_rules():
    cells = {c.name: c for c in SHAPES}
    # full-attention archs skip long_500k
    for name in ["qwen2-0.5b", "granite-34b", "olmoe-1b-7b", "pixtral-12b",
                 "seamless-m4t-medium"]:
        ok, reason = shape_applicable(REGISTRY[name], cells["long_500k"])
        assert not ok and "full-attention" in reason
    # sub-quadratic archs run it
    for name in ["mamba2-130m", "recurrentgemma-9b"]:
        ok, _ = shape_applicable(REGISTRY[name], cells["long_500k"])
        assert ok
    # everything runs the other cells
    for name in ARCHS:
        for cell in ["train_4k", "prefill_32k", "decode_32k"]:
            ok, _ = shape_applicable(REGISTRY[name], cells[cell])
            assert ok


def test_full_configs_match_assignment():
    """Pin the exact assigned hyperparameters (guards against drift)."""
    c = REGISTRY["qwen2-0.5b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        24, 896, 14, 2, 4864, 151936) and c.qkv_bias
    c = REGISTRY["qwen3-1.7b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        28, 2048, 16, 8, 6144, 151936) and c.qk_norm
    c = REGISTRY["granite-34b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        88, 6144, 48, 1, 24576, 49152)
    c = REGISTRY["internlm2-20b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        48, 6144, 48, 8, 16384, 92544)
    c = REGISTRY["mamba2-130m"]
    assert (c.n_layers, c.d_model, c.vocab, c.ssm_state) == (24, 768, 50280, 128)
    c = REGISTRY["pixtral-12b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        40, 5120, 32, 8, 14336, 131072)
    c = REGISTRY["granite-moe-1b-a400m"]
    assert (c.n_layers, c.d_model, c.d_ff, c.n_experts, c.top_k, c.vocab) == (
        24, 1024, 512, 32, 8, 49155)
    c = REGISTRY["olmoe-1b-7b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.n_experts, c.top_k, c.vocab) == (
        16, 2048, 1024, 64, 8, 50304)
    c = REGISTRY["seamless-m4t-medium"]
    assert (c.n_enc_layers, c.n_dec_layers, c.d_model, c.d_ff, c.vocab) == (
        12, 12, 1024, 4096, 256206)
    c = REGISTRY["recurrentgemma-9b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == (
        38, 4096, 16, 1, 12288, 256000)
    assert c.block_pattern == ("rglru", "rglru", "attn") and c.attn_window == 2048
