"""CBTD (Alg. 1/2) property tests — the balance invariant is the whole
point of the method, so it is tested with hypothesis across shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    alpha_at,
    apply_cbtd,
    cbtd_mask,
    cbtd_tile_mask,
    drop_count,
    keep_count,
)
from repro.core.cbtd import CBTDConfig, cbtd_prune_tree


@st.composite
def _cbtd_case(draw):
    m = draw(st.sampled_from([2, 4, 8]))
    s = draw(st.integers(2, 16))  # subcolumn length
    q = draw(st.integers(1, 24))
    gamma = draw(st.sampled_from([0.25, 0.5, 0.75, 0.9]))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, s, q, gamma, seed


@given(_cbtd_case())
@settings(max_examples=40, deadline=None)
def test_balance_invariant(case):
    """At alpha=1 every subcolumn of every column keeps exactly
    S - floor(S*gamma) nonzeros (assuming no pre-existing zeros)."""
    m, s, q, gamma, seed = case
    h = m * s
    w = np.asarray(
        jax.random.normal(jax.random.key(seed), (h, q))
    ) + 0.01  # avoid exact zeros
    pruned = np.asarray(apply_cbtd(jnp.asarray(w), gamma, m, alpha=1.0))
    keep = keep_count(h, m, gamma)
    # subcolumn view: row r -> (PE r%m, local r//m)
    sub = pruned.reshape(s, m, q)
    nnz = (sub != 0).sum(axis=0)  # [m, q]
    assert (nnz == keep).all(), f"unbalanced: {np.unique(nnz)} vs keep={keep}"


@given(_cbtd_case())
@settings(max_examples=30, deadline=None)
def test_drops_smallest_magnitudes(case):
    m, s, q, gamma, seed = case
    h = m * s
    w = np.asarray(jax.random.normal(jax.random.key(seed), (h, q))) + 0.01
    mask = np.asarray(cbtd_mask(jnp.asarray(w), gamma, m, alpha=1.0))
    sub_w = np.abs(w.reshape(s, m, q))
    sub_m = mask.reshape(s, m, q)
    # within every subcolumn, every kept element is >= every dropped element
    for i in range(m):
        for j in range(q):
            kept = sub_w[sub_m[:, i, j], i, j]
            dropped = sub_w[~sub_m[:, i, j], i, j]
            if kept.size and dropped.size:
                assert kept.min() >= dropped.max() - 1e-7


def test_alpha_zero_keeps_everything():
    w = jax.random.normal(jax.random.key(0), (32, 8)) + 0.01
    mask = cbtd_mask(w, 0.9, 4, alpha=0.0, key=jax.random.key(1))
    assert bool(jnp.all(mask))


def test_alpha_intermediate_drops_partially():
    w = jax.random.normal(jax.random.key(0), (64, 32)) + 0.01
    k = jax.random.key(2)
    m_half = cbtd_mask(w, 0.9, 4, alpha=0.5, key=k)
    m_full = cbtd_mask(w, 0.9, 4, alpha=1.0)
    dropped_half = int(jnp.sum(~m_half))
    dropped_full = int(jnp.sum(~m_full))
    assert 0 < dropped_half < dropped_full
    # stochastic drops are a subset of the alpha=1 candidate set:
    assert bool(jnp.all(m_half | ~m_full | m_full))
    assert bool(jnp.all((~m_half) <= (~m_full)))


def test_alpha_schedule():
    assert float(alpha_at(0, 1 / 30)) == 0.0
    assert float(alpha_at(15, 1 / 30)) == pytest.approx(0.5)
    assert float(alpha_at(30, 1 / 30)) == 1.0
    assert float(alpha_at(100, 1 / 30)) == 1.0


def test_achieved_sparsity_matches_gamma():
    """Paper Table II: gamma=0.94, M=64, H=4096 -> 93.75% weight sparsity."""
    h, q, m, gamma = 4096, 128, 64, 0.94
    w = jax.random.normal(jax.random.key(0), (h, q)) + 0.01
    pruned = apply_cbtd(w, gamma, m, alpha=1.0)
    ws = float(jnp.mean(pruned == 0))
    assert ws == pytest.approx(drop_count(h, m, gamma) / (h // m))
    assert ws == pytest.approx(0.9375)


def test_tile_mask_balance():
    w = jax.random.normal(jax.random.key(0), (64, 512)) + 0.01
    mask = cbtd_tile_mask(w, gamma=0.75, tile=(8, 128), alpha=1.0)
    keep_tiles = mask.reshape(8, 8, 4, 128)[:, 0, :, 0]  # [tile_r, tile_c]
    per_col = jnp.sum(keep_tiles.astype(jnp.int32), axis=0)
    assert bool(jnp.all(per_col == per_col[0]))
    assert int(per_col[0]) == 8 - int(8 * 0.75)


def test_prune_tree_respects_layout():
    params = {
        "lstm": {"w_x": jnp.ones((8, 4)), "b": jnp.ones((4,))},
        "head": {"w": jnp.ones((8, 4))},
    }
    layout = {"w_x": CBTDConfig(gamma=0.5, m=2)}
    out = cbtd_prune_tree(params, layout, alpha=1.0)
    assert float(jnp.mean(out["lstm"]["w_x"] == 0)) == pytest.approx(0.5)
    assert bool(jnp.all(out["head"]["w"] == 1.0))  # untouched
    assert bool(jnp.all(out["lstm"]["b"] == 1.0))  # 1-D untouched


def test_wildcard_layout_prunes_all_2d():
    params = {"a": jnp.ones((8, 4)), "b": {"c": jnp.ones((16, 2))}}
    out = cbtd_prune_tree(params, {"*": CBTDConfig(gamma=0.5, m=2)}, alpha=1.0)
    for leaf in jax.tree.leaves(out):
        assert float(jnp.mean(leaf == 0)) == pytest.approx(0.5)
