"""Shared fixtures: the opt-in session-wide lock-order recorder.

Setting ``SPARTUS_LOCK_ORDER=1`` (the chaos CI job does) installs a
:class:`repro.analysis.lockorder.LockOrderRecorder` for the whole pytest
session, so every lock the serving stack creates through ``make_lock``
is instrumented.  At session end the acquisition-order graph must be
acyclic (a cycle is a potential deadlock even if this run never hung)
and the full report is written to ``SPARTUS_LOCK_ORDER_REPORT``
(default ``lock_order_report.json``) for the CI artifact upload.

Unset, this fixture is a no-op: ``make_lock`` hands out plain
``threading.Lock`` objects and the serving stack pays nothing.
"""
import json
import os

import pytest


@pytest.fixture(scope="session", autouse=True)
def lock_order_recorder():
    if not os.environ.get("SPARTUS_LOCK_ORDER"):
        yield None
        return
    from repro.analysis import lockorder

    rec = lockorder.LockOrderRecorder()
    prev = lockorder.current()
    lockorder.install(rec)
    try:
        yield rec
    finally:
        if prev is not None:
            lockorder.install(prev)
        else:
            lockorder.uninstall()
        path = os.environ.get("SPARTUS_LOCK_ORDER_REPORT",
                              "lock_order_report.json")
        with open(path, "w") as f:
            json.dump(rec.report(), f, indent=2)
        rec.assert_acyclic()
