"""Concurrency analyzer tests: the static guarded-by/lockset pass and
await-under-lock rule (repro.analysis.concurrency), the runtime
lock-order recorder (repro.analysis.lockorder), and the live-pool
concurrency stress — concurrent admin scrapers, a checkpoint thread and
offloaded ticks against one chunked pool.

Acceptance mutations (ISSUE 10): stripping the lock from
``SessionPool.measured_sparsity`` must trip the static checker on the
real scheduler source, and an injected out-of-order acquisition must
show up as a cycle in the recorder's acquisition graph.
"""
import json
import os
import subprocess
import sys
import textwrap
import threading
import time
from pathlib import Path

import numpy as np
import jax
import pytest

from repro.analysis import concurrency, lockorder
from repro.models import lstm_am
from repro.serving import (
    BatchedSpartusEngine,
    EngineConfig,
    PoolObservability,
    StreamRequest,
    Tracer,
)
from repro.serving.scheduler import SessionPool

REPO_ROOT = Path(__file__).resolve().parent.parent
SCHEDULER = REPO_ROOT / "src" / "repro" / "serving" / "scheduler.py"

INPUT_DIM, HIDDEN, CLASSES = 20, 32, 11


@pytest.fixture(scope="module")
def engine():
    cfg = lstm_am.LSTMAMConfig(input_dim=INPUT_DIM, hidden_dim=HIDDEN,
                               n_layers=2, n_classes=CLASSES)
    params = lstm_am.cbtd_prune_stacks(
        lstm_am.init_params(jax.random.key(0), cfg), gamma=0.75, m=4)
    ecfg = EngineConfig(theta=0.05, gamma=0.75, m=4, capacity_frac=1.0)
    return BatchedSpartusEngine(params, cfg, ecfg)


def _check(src: str, path: str = "src/repro/serving/fake.py"):
    return concurrency.check_source(textwrap.dedent(src), path)


@pytest.fixture()
def recorder():
    """A fresh recorder installed for one test; the previous (possibly
    session-wide, see conftest) recorder is restored afterwards."""
    rec = lockorder.LockOrderRecorder()
    prev = lockorder.current()
    lockorder.install(rec)
    yield rec
    if prev is not None:
        lockorder.install(prev)
    else:
        lockorder.uninstall()


# ------------------------------------------------ guarded-by: rule basics


def test_unguarded_read_and_write_flagged():
    findings = _check("""
        class P:
            _guarded_by_ = {"state": "_lk", "_out": "_lk"}
            def __init__(self):
                self.state = 0
            def read(self):
                return self.state
            def write(self):
                self._out = 1
    """)
    assert [f.rule for f in findings] == ["guarded-by", "guarded-by"]
    assert "read of `self.state`" in findings[0].message
    assert "write to `self._out`" in findings[1].message


def test_guarded_twin_is_clean():
    assert _check("""
        class P:
            _guarded_by_ = {"state": "_lk", "_out": "_lk"}
            def read(self):
                with self._lk:
                    return self.state
            def write(self):
                with self._lk:
                    self._out = 1
    """) == []


def test_multi_item_with_counts():
    """``with self._tracer.span(...), self._lk:`` — the scheduler's
    dispatch shape — must register the lock."""
    assert _check("""
        class P:
            _guarded_by_ = {"state": "_lk"}
            def step(self):
                with self.tracer.span("dispatch"), self._lk:
                    self.state = self.f(self.state)
    """) == []


def test_init_is_exempt():
    assert _check("""
        class P:
            _guarded_by_ = {"state": "_lk"}
            def __init__(self):
                self.state = 0
    """) == []


def test_unrelated_lock_does_not_count():
    findings = _check("""
        class P:
            _guarded_by_ = {"state": "_lk"}
            def read(self):
                with self._other:
                    return self.state
    """)
    assert [f.rule for f in findings] == ["guarded-by"]


def test_undeclared_class_is_ignored():
    assert _check("""
        class P:
            def read(self):
                return self.state
    """) == []


def test_malformed_guard_table_flagged():
    findings = _check("""
        class P:
            _guarded_by_ = {"state": LOCK}
            def read(self):
                return self.state
    """)
    assert len(findings) == 1
    assert "literal" in findings[0].message


# ------------------------------------- guarded-by: one-hop call resolution


def test_helper_with_all_callsites_locked_is_clean():
    assert _check("""
        class P:
            _guarded_by_ = {"state": "_lk"}
            def _helper(self):
                return self.state
            def caller(self):
                with self._lk:
                    return self._helper()
            def caller2(self):
                with self._lk:
                    if self.flag:
                        return self._helper()
    """) == []


def test_helper_with_one_unlocked_callsite_flagged():
    findings = _check("""
        class P:
            _guarded_by_ = {"state": "_lk"}
            def _helper(self):
                return self.state
            def caller(self):
                with self._lk:
                    return self._helper()
            def rogue(self):
                return self._helper()
    """)
    assert [f.rule for f in findings] == ["guarded-by"]
    assert "_helper" in findings[0].message


def test_resolution_is_one_hop_not_transitive():
    """A two-hop chain (locked caller -> mid -> helper) is NOT resolved:
    shallow on purpose, like the wallclock-in-jit rule."""
    findings = _check("""
        class P:
            _guarded_by_ = {"state": "_lk"}
            def _helper(self):
                return self.state
            def _mid(self):
                return self._helper()
            def caller(self):
                with self._lk:
                    return self._mid()
    """)
    assert [f.rule for f in findings] == ["guarded-by"]


# ------------------------------------------------ guarded-by: pragma escape


def test_pragma_suppresses_named_rule_only():
    src = """
        class P:
            _guarded_by_ = {"state": "_lk"}
            def audited(self):
                return self.state  # lint: allow(guarded-by) tick-thread-only
            def rogue(self):
                return self.state  # lint: allow(eager-scatter)
    """
    findings = _check(src)
    assert len(findings) == 1
    assert "rogue" in findings[0].message


# --------------------------------------------------------- await-under-lock


def test_await_under_lock_flagged_and_twin_clean():
    bad = _check("""
        class S:
            async def pump(self):
                with self._state_lock:
                    await self.q.get()
    """, path="src/repro/serving/async_server.py")
    assert [f.rule for f in bad] == ["await-under-lock"]
    good = _check("""
        class S:
            async def pump(self):
                with self._state_lock:
                    q = self.q
                await q.get()
    """, path="src/repro/serving/async_server.py")
    assert good == []


def test_await_under_lock_scoped_to_serving():
    src = """
        class S:
            async def pump(self):
                with self._lock:
                    await self.q.get()
    """
    assert _check(src, path="src/repro/training/x.py") == []
    assert len(_check(src, path="src/repro/serving/x.py")) == 1


# ------------------------------------------- repo-clean + acceptance (static)


def test_repo_is_concurrency_clean():
    findings = concurrency.check_repo(REPO_ROOT)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_acceptance_mutation_lock_stripped_measured_sparsity():
    """ISSUE 10 acceptance: strip the lock from the REAL scheduler's
    ``measured_sparsity`` (the PR 6 race site) and the checker must fire
    on the now-unguarded ``self.state`` read."""
    src = SCHEDULER.read_text()
    guarded = ("        with self._state_lock:\n"
               "            return self.engine.measured_sparsity(self.state)")
    assert guarded in src, "measured_sparsity lock site moved; update test"
    mutated = src.replace(guarded, guarded.replace(
        "with self._state_lock:", "if True:"))
    rel = str(SCHEDULER.relative_to(REPO_ROOT))
    assert concurrency.check_source(src, rel) == []
    findings = concurrency.check_source(mutated, rel)
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "guarded-by"
    assert "measured_sparsity" in f.message and "self.state" in f.message


def test_lint_cli_concurrency_smoke(tmp_path):
    report = tmp_path / "report.json"
    env = {**os.environ, "PYTHONPATH": str(REPO_ROOT / "src"),
           "JAX_PLATFORMS": "cpu", "SPARTUS_LINT_NO_FORCE_DEVICES": "1"}
    out = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--concurrency",
         "--report", str(report)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "concurrency lint: clean" in out.stdout
    assert json.loads(report.read_text())["concurrency"] == []


# ------------------------------------------------- lock-order recorder


def test_make_lock_plain_without_recorder():
    prev = lockorder.current()
    lockorder.uninstall()
    try:
        lk = lockorder.make_lock("x")
        assert not isinstance(lk, lockorder.InstrumentedLock)
        with lk:
            pass
    finally:
        if prev is not None:
            lockorder.install(prev)


def test_make_lock_instrumented_with_recorder(recorder):
    lk = lockorder.make_lock("x")
    assert isinstance(lk, lockorder.InstrumentedLock)
    with lk:
        assert lk.locked()
    assert recorder.hold_times()["x"]["count"] == 1


def test_acceptance_mutation_out_of_order_acquisition(recorder):
    """ISSUE 10 acceptance: two threads taking two locks in opposite
    orders — never deadlocking in THIS run — must still surface as a
    cycle in the acquisition-order graph."""
    a = lockorder.InstrumentedLock("lock_a", recorder)
    b = lockorder.InstrumentedLock("lock_b", recorder)

    def ab():
        with a:
            with b:
                pass

    def ba():
        with b:
            with a:
                pass

    for fn in (ab, ba):           # sequential: records order, cannot hang
        t = threading.Thread(target=fn)
        t.start()
        t.join()
    cycles = recorder.cycles()
    assert cycles and any(set(c) >= {"lock_a", "lock_b"} for c in cycles)
    with pytest.raises(AssertionError, match="lock-order cycles"):
        recorder.assert_acyclic()


def test_consistent_order_twin_is_acyclic(recorder):
    a = lockorder.InstrumentedLock("lock_a", recorder)
    b = lockorder.InstrumentedLock("lock_b", recorder)

    def ab():
        with a:
            with b:
                pass

    threads = [threading.Thread(target=ab) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert recorder.cycles() == []
    recorder.assert_acyclic()
    assert recorder.edges()[("lock_a", "lock_b")] == 4


def test_hold_times_and_slow_holds():
    rec = lockorder.LockOrderRecorder(slow_hold_s=0.02)
    lk = lockorder.InstrumentedLock("slow", rec)
    with lk:
        time.sleep(0.05)
    with lk:
        pass
    h = rec.hold_times()["slow"]
    assert h["count"] == 2 and h["max_s"] >= 0.05
    assert [s[0] for s in rec.slow_holds()] == ["slow"]


def test_reacquire_of_held_lock_is_a_violation(recorder):
    lk = lockorder.InstrumentedLock("re", recorder)
    assert lk.acquire()
    assert not lk.acquire(blocking=False)   # would self-deadlock if blocking
    lk.release()
    assert any("re-acquire" in v for v in recorder.violations())
    with pytest.raises(AssertionError, match="violations"):
        recorder.assert_acyclic()


def test_report_is_json_ready(recorder):
    with lockorder.InstrumentedLock("x", recorder):
        pass
    doc = json.loads(json.dumps(recorder.report()))
    assert set(doc) == {"edges", "cycles", "violations", "hold_times",
                        "slow_holds"}


# ---------------------------------------- live-pool races + stress (satellites)


def _rand_feats(rng, lo=3, hi=24):
    return rng.standard_normal(
        (int(rng.integers(lo, hi)), INPUT_DIM)).astype(np.float32)


def test_pool_state_readers_survive_donating_ticks(engine):
    """Regression mirroring the PR 6 ``measured_sparsity`` race, for the
    readers this PR audited: ``bytes_per_slot`` / ``peek_rows`` /
    ``shard_loads`` hammered from another thread while chunked ticks
    donate-and-rebind the device buffers.  Unlocked, the readers can
    fetch a deleted buffer (RuntimeError from jax)."""
    pool = SessionPool(engine, capacity=3, max_frames=32, chunk_frames=4)
    stop = threading.Event()
    errors = []

    def reader():
        try:
            while not stop.is_set():
                pool.bytes_per_slot()
                pool.measured_sparsity()
                pool.shard_loads()
                for rid in list(pool._by_req)[:1]:
                    try:
                        pool.peek_rows(rid)
                    except KeyError:
                        pass          # retired between listing and peeking
        except Exception as e:        # the deleted-buffer fetch lands here
            errors.append(e)

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    rng = np.random.default_rng(7)
    now, rid = 0, 0
    deadline = time.perf_counter() + 2.0
    try:
        while time.perf_counter() < deadline and not errors:
            while pool.n_free:
                pool.admit(StreamRequest(rid, now, _rand_feats(rng)), now)
                rid += 1
            _, adv = pool.tick(now)
            now += max(adv, 1)
        pool.drain(now)
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors, errors[0]
    assert rid > 3                    # the pool actually cycled sessions


def test_stress_scrapers_checkpointer_offloaded_ticks(engine):
    """ISSUE 10 satellite: hammer one live chunked pool with concurrent
    admin scrapers (stats/metrics/timeseries), a periodic checkpoint
    thread and offloaded ticks; assert no deleted-buffer fetches, no
    torn metrics, and an acyclic lock-order graph."""
    rec = lockorder.LockOrderRecorder(slow_hold_s=30.0)
    prev = lockorder.current()
    lockorder.install(rec)          # stays installed for the whole run
    obs = PoolObservability(tracer=Tracer(enabled=True))
    pool = SessionPool(engine, capacity=4, max_frames=32, chunk_frames=4,
                       stream_partials=True, observability=obs)
    assert isinstance(pool._state_lock, lockorder.InstrumentedLock)

    stop = threading.Event()
    errors = []
    n_results = [0]

    def forever(body):
        def run():
            try:
                while not stop.is_set():
                    body()
            except Exception as e:
                errors.append(e)
                stop.set()
        return run

    def scrape_pool():
        pool.measured_sparsity()
        pool.bytes_per_slot()
        _ = pool.has_pending

    def scrape_metrics():
        snap = obs.registry.snapshot()
        for key, m in snap.items():
            if m["type"] == "histogram":
                cum = list(m["buckets"].values())
                assert cum == sorted(cum), f"torn buckets: {key}"
                assert m["count"] >= (cum[-1] if cum else 0), \
                    f"torn count: {key}"
        obs.registry.render_prometheus()
        obs.timeseries.snapshot(last=64)
        _ = obs.timeseries.n_dropped
        _ = obs.tracer.n_events

    def checkpointer():
        pool.snapshot()               # one gathered D2H fetch, under lock
        time.sleep(0.03)

    def driver():
        rng = np.random.default_rng(11)
        now, rid = 0, 0
        deadline = time.perf_counter() + 3.0
        while time.perf_counter() < deadline and not stop.is_set():
            while pool.n_free:
                pool.admit(StreamRequest(rid, now, _rand_feats(rng)), now)
                rid += 1
            res, adv = pool.tick(now)
            n_results[0] += len(res)
            pool.take_partials()
            now += max(adv, 1)
        n_results[0] += len(pool.drain(now))
        stop.set()

    def driver_once():
        try:
            driver()
        except Exception as e:
            errors.append(e)
        finally:
            stop.set()

    threads = [threading.Thread(target=forever(f))
               for f in (scrape_pool, scrape_metrics, checkpointer)]
    threads.append(threading.Thread(target=driver_once))
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        hung = [t for t in threads if t.is_alive()]
        stop.set()
    finally:
        if prev is not None:
            lockorder.install(prev)
        else:
            lockorder.uninstall()
    assert not hung, "stress threads hung (potential deadlock)"
    assert not errors, errors[0]
    assert n_results[0] > 0
    rec.assert_acyclic()
    holds = rec.hold_times()
    assert holds.get("SessionPool._state_lock", {}).get("count", 0) > 0
    assert holds.get("MetricsRegistry._lock", {}).get("count", 0) > 0
