"""Hardware-model validation against the paper's published numbers."""
import numpy as np
import pytest

from repro.hwsim.memory import FetchModel, fig14_table, weight_bits_per_frame
from repro.hwsim.spartus_model import (
    EDGE_SPARTUS,
    SPARTUS,
    TEST_LAYER,
    blen,
    comparison_table,
    dense_baseline,
    evaluate,
    step_cycles_from_masks,
    table4_ladder,
)


def test_eq9_peak_throughput():
    assert SPARTUS.peak_ops() / 1e9 == pytest.approx(204.8)   # Table V
    assert EDGE_SPARTUS.peak_ops() / 1e9 == pytest.approx(1.0)  # Table VI


def test_test_layer_matches_table5_params():
    # Table V: #Parameters 4.70 M
    assert TEST_LAYER.dense_macs == pytest.approx(4.70e6, rel=0.01)


def test_dense_baseline_46us():
    # Sec. VIII: "theoretical peak ... runs a dense LSTM layer with 1024
    # neurons in 46 us"
    rep = dense_baseline(SPARTUS, TEST_LAYER)
    assert rep.latency_us == pytest.approx(46.0, rel=0.05)


def test_blen_matches_paper():
    # H=4096, M=64, gamma=93.75% -> BLEN=4 (Alg. 3)
    assert blen(SPARTUS, TEST_LAYER, 0.9375) == 4


def test_table4_ladder_reproduced():
    """Paper Table IV (Spartus column), modelled within ~20%:
       no-opt >46 us; +CBTD 3.3 us; +Delta(0.1) 1.6 us; +Delta(0.3) 1.0 us."""
    ladder = table4_ladder()
    assert ladder["no_opt"].latency_us == pytest.approx(46.0, rel=0.05)
    assert ladder["cbtd"].latency_us == pytest.approx(3.3, rel=0.25)
    assert ladder["delta_0.1"].latency_us == pytest.approx(1.6, rel=0.25)
    assert ladder["delta_0.3"].latency_us == pytest.approx(1.0, rel=0.25)
    # headline: ~9.4 TOp/s effective batch-1 throughput, ~46x speedup
    eff = ladder["delta_0.3"].batch1_throughput_gops
    assert eff == pytest.approx(9447.8, rel=0.25)
    speedup = ladder["no_opt"].latency_us / ladder["delta_0.3"].latency_us
    assert speedup == pytest.approx(46.0, rel=0.25)


def test_trace_driven_matches_analytic():
    rng = np.random.default_rng(0)
    t, f = 200, TEST_LAYER.n_cols + 1  # padded to 1148 cols internally
    ts = 0.9
    masks = rng.random((200, TEST_LAYER.n_cols)) > ts
    cyc = step_cycles_from_masks(SPARTUS, TEST_LAYER, 0.9375, masks)
    rep = evaluate(SPARTUS, TEST_LAYER, 0.9375, delta_masks=masks)
    # iid masks are nearly balanced -> close to analytic at BR~0.9
    rep_a = evaluate(SPARTUS, TEST_LAYER, 0.9375, temporal_sparsity=ts,
                     balance_ratio=0.9)
    assert rep.latency_us == pytest.approx(rep_a.latency_us, rel=0.15)


def test_edge_spartus_bandwidth_bound():
    """Edge-Spartus fetches weights off-chip: Table VI latency 121.7 us at
    ts=82.56%, gamma=93.75%."""
    rep = evaluate(EDGE_SPARTUS, TEST_LAYER, 0.9375, temporal_sparsity=0.8256,
                   balance_ratio=1.0)  # N=1: single array is always balanced
    assert rep.latency_us == pytest.approx(121.7, rel=0.35)
    assert rep.batch1_throughput_gops == pytest.approx(77.3, rel=0.35)


def test_comparison_table_ratios():
    ladder = table4_ladder()
    table = comparison_table(ladder["delta_0.3"], power_w=8.4)
    # paper: 4x higher batch-1 effective throughput than BBS
    assert table["BBS"]["throughput_ratio"] == pytest.approx(4.0, rel=0.3)
    # ~8x higher effective throughput than DeltaRNN
    assert table["DeltaRNN"]["throughput_ratio"] == pytest.approx(8.0, rel=0.3)
    # ~1.1 TOp/s/W wall-power efficiency
    assert table["ours"]["power_eff_gopsw"] == pytest.approx(1124.7, rel=0.3)


def test_dram_energy_reduction():
    """Sec. VII-C: 'DRAM access energy can be reduced by 91.7x'."""
    n_weights = TEST_LAYER.dense_macs
    tbl = fig14_table(n_weights, gamma=0.9375, temporal_sparsity=0.8256)
    # (1/((1-g)(1-ts))) / index-overhead = 91.7x against 8-bit dense
    assert tbl["reduction"]["dense_over_st"] == pytest.approx(91.7, rel=0.2)
    # DDR3L row dominates HBM2 by the Table VII energy ratio
    assert (tbl["DDR3L"]["dense_uj"] / tbl["HBM2"]["dense_uj"]
            == pytest.approx(16.5 / 3.9, rel=0.01))


def test_sparsity_monotone_latency():
    lat = [
        evaluate(SPARTUS, TEST_LAYER, 0.9375, ts).latency_us
        for ts in [0.0, 0.5, 0.74, 0.9]
    ]
    assert lat == sorted(lat, reverse=True)
