"""DeltaLSTM correctness: eqs. (3)-(7), equivalence to LSTM at Theta=0,
no-error-accumulation property, temporal sparsity behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    delta_gru_layer,
    delta_lstm_layer,
    delta_lstm_layer_batched,
    delta_threshold,
    gru_layer,
    init_gru_params,
    init_lstm_params,
    lstm_layer,
    summarize_delta_aux,
)


@pytest.fixture(scope="module")
def params():
    return init_lstm_params(jax.random.key(0), input_dim=16, hidden_dim=24)


def _smooth_sequence(key, t, d, tau=0.9):
    """OU-like smooth trajectory — the signal class delta networks target."""
    steps = jax.random.normal(key, (t, d)) * jnp.sqrt(1 - tau**2)

    def step(x, e):
        x = tau * x + e
        return x, x

    _, xs = jax.lax.scan(step, jnp.zeros((d,)), steps)
    return xs


def test_delta_lstm_equals_lstm_at_theta_zero(params):
    xs = _smooth_sequence(jax.random.key(1), 50, 16)
    hs_ref = lstm_layer(params, xs)
    hs_delta, _, _ = delta_lstm_layer(params, xs, theta=0.0)
    np.testing.assert_allclose(hs_ref, hs_delta, rtol=2e-5, atol=2e-6)


def test_delta_gru_equals_gru_at_theta_zero():
    p = init_gru_params(jax.random.key(3), 16, 24)
    xs = _smooth_sequence(jax.random.key(4), 50, 16)
    hs_ref = gru_layer(p, xs)
    hs_delta, _, _ = delta_gru_layer(p, xs, theta=0.0)
    np.testing.assert_allclose(hs_ref, hs_delta, rtol=2e-5, atol=2e-6)


def test_threshold_masks_small_deltas():
    cur = jnp.array([1.0, 1.05, 2.0])
    ref = jnp.array([1.0, 1.0, 1.0])
    delta, new_ref = delta_threshold(cur, ref, theta=0.1)
    np.testing.assert_allclose(delta, [0.0, 0.0, 1.0])
    # reference only updates where the delta fired (eqs. 5/7):
    np.testing.assert_allclose(new_ref, [1.0, 1.0, 2.0])


def test_no_error_accumulation(params):
    """A constant-then-step input: after the step the delta fires exactly
    once with the *full* accumulated difference (x̂ semantics), so the
    delta memory equals the dense pre-activation — no drift."""
    d = 16
    xs = jnp.concatenate(
        [jnp.full((30, d), 0.049), jnp.full((30, d), 5.0)], axis=0
    )  # small wiggle below theta, then a big jump
    hs_delta, state, aux = delta_lstm_layer(params, xs, theta=0.1)
    hs_ref = lstm_layer(params, xs)
    # Exact agreement at the end is not required (sub-threshold dynamics are
    # intentionally dropped) but the post-jump response must match the dense
    # LSTM driven by the same big input closely:
    np.testing.assert_allclose(hs_delta[-1], hs_ref[-1], atol=0.05)


def test_temporal_sparsity_increases_with_theta(params):
    xs = _smooth_sequence(jax.random.key(2), 200, 16, tau=0.98)
    sp = []
    for theta in [0.0, 0.05, 0.2, 0.5]:
        _, _, aux = delta_lstm_layer(params, xs, theta=theta)
        sp.append(summarize_delta_aux(aux, 16, 24)["temporal_sparsity"])
    assert sp == sorted(sp), f"sparsity not monotone in theta: {sp}"
    assert sp[-1] > 0.5, f"high theta should give high sparsity, got {sp[-1]}"


def test_batched_matches_loop(params):
    xs = _smooth_sequence(jax.random.key(5), 20, 16)
    xs_b = jnp.stack([xs, xs * 0.5])
    hs_b, _, _ = delta_lstm_layer_batched(params, xs_b, theta=0.1)
    for b in range(2):
        hs, _, _ = delta_lstm_layer(params, xs_b[b], theta=0.1)
        np.testing.assert_allclose(hs_b[b], hs, rtol=1e-6, atol=1e-6)


def test_delta_state_carries_across_chunks(params):
    """Streaming inference: running two chunks with carried state equals
    one long sequence (the serving engine depends on this)."""
    xs = _smooth_sequence(jax.random.key(6), 40, 16)
    hs_full, _, _ = delta_lstm_layer(params, xs, theta=0.1)
    hs_a, st, _ = delta_lstm_layer(params, xs[:17], theta=0.1)
    hs_b, _, _ = delta_lstm_layer(params, xs[17:], theta=0.1, state=st)
    np.testing.assert_allclose(
        jnp.concatenate([hs_a, hs_b]), hs_full, rtol=1e-6, atol=1e-6
    )


def test_aux_counts_match_masks(params):
    xs = _smooth_sequence(jax.random.key(7), 30, 16)
    _, _, aux = delta_lstm_layer(params, xs, theta=0.1)
    np.testing.assert_array_equal(
        aux["nnz_dx"], jnp.sum(aux["dx_masks"], axis=-1).astype(jnp.int32)
    )
    np.testing.assert_array_equal(
        aux["nnz_dh"], jnp.sum(aux["dh_masks"], axis=-1).astype(jnp.int32)
    )
