"""Partition-rule unit tests on an abstract 16x16 production mesh
(no devices needed — pure spec logic)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.distributed.sharding import batch_spec, cache_spec, param_spec
from repro import perf

def _abstract_mesh(shape, axes):
    try:  # jax < 0.5: a tuple of (name, size) pairs
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
    except (TypeError, ValueError):  # jax >= 0.5: (axis_sizes, axis_names)
        return jax.sharding.AbstractMesh(shape, axes)


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_attention_tp_when_heads_divide():
    cfg = get_arch("granite-34b")  # 48 heads % 16 == 0
    s = param_spec("layers/attn/q/w", (88, 6144, 6144), MESH, cfg)
    assert s == P(None, "model", "data")
    s = param_spec("layers/attn/o/w", (88, 6144, 6144), MESH, cfg)
    assert s == P(None, "data", "model")


def test_attention_fsdp_fallback_when_heads_dont_divide():
    cfg = get_arch("qwen2-0.5b")  # 14 heads % 16 != 0
    s = param_spec("layers/attn/q/w", (24, 896, 896), MESH, cfg)
    assert s == P(None, None, "data")  # no model sharding


def test_kv_projection_follows_kv_heads():
    cfg = get_arch("qwen3-1.7b")  # q heads 16 ok, kv heads 8 not
    assert param_spec("layers/attn/q/w", (28, 2048, 2048), MESH, cfg) == P(
        None, "model", "data")
    assert param_spec("layers/attn/k/w", (28, 1024, 2048), MESH, cfg) == P(
        None, None, "data")


def test_mlp_and_head_rules():
    cfg = get_arch("internlm2-20b")
    assert param_spec("layers/mlp/gate/w", (48, 16384, 6144), MESH, cfg) == P(
        None, "model", "data")
    assert param_spec("layers/mlp/down/w", (48, 6144, 16384), MESH, cfg) == P(
        None, "data", "model")
    assert param_spec("lm_head/w", (92544, 6144), MESH, cfg) == P(
        "model", "data")
    # embeddings: gather-local, FSDP on feature dim only
    assert param_spec("embed", (92544, 6144), MESH, cfg) == P(None, "data")


def test_moe_expert_parallel():
    cfg = get_arch("olmoe-1b-7b")
    assert param_spec("layers/moe/gate", (16, 64, 1024, 2048), MESH, cfg) == P(
        None, "model", None, "data")
    assert param_spec("layers/moe/router/w", (16, 64, 2048), MESH, cfg) == P()


def test_norms_and_scalars_replicate():
    assert param_spec("layers/attn_norm/scale", (88, 6144), MESH) == P()
    assert param_spec("opt/step", (), MESH) == P()


def test_non_divisible_dims_fall_back():
    cfg = get_arch("mamba2-130m")
    # in_proj out dim 3352 % 16 != 0 -> no model sharding; in dim 768 % 16
    s = param_spec("layers/in_proj/w", (24, 3352, 768), MESH, cfg)
    assert s == P(None, None, "data")


def test_batch_specs():
    assert batch_spec((256, 4096), MESH) == P("data", None)
    assert batch_spec((256, 4096), MESH3) == P(("pod", "data"), None)
    assert batch_spec((1, 524288), MESH) == P(None, None)  # B=1 replicates


def test_cache_specs_head_vs_seq():
    # kv heads 16 -> head sharding
    assert cache_spec("kv/k", (16, 128, 32768, 16, 128), MESH) == P(
        None, "data", None, "model", None)
    # MQA kv=1 -> sequence sharding fallback
    assert cache_spec("kv/k", (88, 128, 32768, 1, 128), MESH) == P(
        None, "data", "model", None, None)
    # scalar position replicates
    assert cache_spec("pos", (), MESH) == P()


def test_fsdp_sp_variant_disables_tp():
    cfg = get_arch("granite-34b")
    with perf.variant(perf.PerfVariant(fsdp_sp=True)):
        s = param_spec("layers/attn/q/w", (88, 6144, 6144), MESH, cfg)
    assert s == P(None, "model", "data")  # 2-D storage sharding
    with perf.variant(perf.PerfVariant(fsdp_sp=True)):
        s = param_spec("layers/mlp/gate/w", (88, 16384, 6144), MESH, cfg)
    assert s == P(None, "model", "data")


def test_pod_axis_in_multi_mesh():
    # params never shard over pod; batch does (tested above)
    cfg = get_arch("granite-34b")
    s = param_spec("layers/attn/q/w", (88, 6144, 6144), MESH3, cfg)
    assert "pod" not in jax.tree.leaves(tuple(s)) if s else True
    assert s == P(None, "model", "data")
