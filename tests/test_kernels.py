"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(kernel bodies execute on CPU; BlockSpec tiling semantics fully exercised).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import apply_cbtd, blen_for, cbcsc_decode, cbcsc_encode
from repro.kernels import ops, ref
from repro.kernels.delta_encode import delta_encode_pallas
from repro.kernels.lstm_pointwise import lstm_pointwise_pallas
from repro.kernels.stsp_spmv import (
    stsp_spmv_pallas,
    stsp_spmv_scatter_batch_pallas,
)

TOL = {jnp.float32: dict(rtol=1e-6, atol=1e-6),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# -- delta_encode -----------------------------------------------------------


@pytest.mark.parametrize("f", [1024, 2048, 8192])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("theta", [0.0, 0.1, 0.5])
def test_delta_encode_kernel(f, dtype, theta):
    k1, k2 = jax.random.split(jax.random.key(f + int(theta * 10)))
    x = jax.random.normal(k1, (f,), dtype)
    x_hat = x + jax.random.normal(k2, (f,), dtype) * 0.2
    d, xh, nnz = delta_encode_pallas(x, x_hat, theta, interpret=True)
    d_ref, xh_ref, nnz_ref = ref.delta_encode_ref(x, x_hat, theta)
    np.testing.assert_allclose(np.asarray(d, np.float32),
                               np.asarray(d_ref, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(xh, np.float32),
                               np.asarray(xh_ref, np.float32), **TOL[dtype])
    assert int(jnp.sum(nnz)) == int(nnz_ref)


def test_delta_encode_wrapper_pads_ragged():
    x = jax.random.normal(jax.random.key(0), (1147,))
    x_hat = jnp.zeros((1147,))
    d, xh, nnz = ops.delta_encode(x, x_hat, 0.3, use_pallas=True)
    d_ref, xh_ref, nnz_ref = ref.delta_encode_ref(x, x_hat, 0.3)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref), rtol=1e-6)
    assert int(nnz) == int(nnz_ref)
    assert d.shape == (1147,)


# -- lstm_pointwise ---------------------------------------------------------


@pytest.mark.parametrize("h", [512, 1024, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_pointwise_kernel(h, dtype):
    k1, k2 = jax.random.split(jax.random.key(h))
    dm = jax.random.normal(k1, (4, h), dtype)
    c = jax.random.normal(k2, (h,), dtype)
    hh, cc = lstm_pointwise_pallas(dm, c, interpret=True)
    h_ref, c_ref = ref.lstm_pointwise_ref(dm, c)
    np.testing.assert_allclose(np.asarray(hh, np.float32),
                               np.asarray(h_ref, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(cc, np.float32),
                               np.asarray(c_ref, np.float32), **TOL[dtype])


def test_lstm_pointwise_wrapper_ragged():
    dm = jax.random.normal(jax.random.key(1), (4, 700))
    c = jax.random.normal(jax.random.key(2), (700,))
    hh, cc = ops.lstm_pointwise(dm, c, use_pallas=True)
    h_ref, c_ref = ref.lstm_pointwise_ref(dm, c)
    np.testing.assert_allclose(np.asarray(hh), np.asarray(h_ref), rtol=1e-5,
                               atol=1e-6)


# -- stsp_spmv --------------------------------------------------------------


def _cbcsc_case(seed, h, q, m, gamma):
    w = apply_cbtd(
        jax.random.normal(jax.random.key(seed), (h, q)) + 0.01, gamma, m, 1.0
    )
    return w, cbcsc_encode(w, m, blen=blen_for(h, m, gamma))


@pytest.mark.parametrize("h,q,m,gamma,k", [
    (64, 32, 8, 0.75, 8),
    (128, 96, 16, 0.9, 16),
    (256, 128, 32, 0.5, 32),
    (512, 256, 64, 0.94, 24),
])
def test_stsp_spmv_kernel_vs_dense(h, q, m, gamma, k):
    w, enc = _cbcsc_case(h + q, h, q, m, gamma)
    kd, kv = jax.random.split(jax.random.key(k))
    idx = jax.random.permutation(kd, q)[:k].astype(jnp.int32)
    ds_vals = jax.random.normal(kv, (k,))
    y = stsp_spmv_pallas(enc.val, enc.lidx, idx, ds_vals, s=enc.s, interpret=True)
    # dense oracle: sparse delta vector through the dense pruned matrix
    ds = jnp.zeros((q,)).at[idx].set(ds_vals)
    y_dense = w @ ds
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-4)
    # and vs the jnp oracle of the kernel math:
    y_ref = ref.stsp_spmv_ref(enc.val, enc.lidx, idx, ds_vals, enc.s)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_stsp_spmv_padding_is_noop():
    w, enc = _cbcsc_case(7, 64, 32, 8, 0.75)
    idx = jnp.array([3, 10, 0, 0], jnp.int32)   # 2 padded slots pointing at col 0
    ds_vals = jnp.array([1.0, -2.0, 0.0, 0.0])
    y = stsp_spmv_pallas(enc.val, enc.lidx, idx, ds_vals, s=enc.s, interpret=True)
    y_expect = w[:, 3] * 1.0 + w[:, 10] * (-2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_expect),
                               rtol=1e-5, atol=1e-5)


def test_stsp_spmv_duplicate_indices_accumulate():
    w, enc = _cbcsc_case(9, 64, 32, 8, 0.5)
    idx = jnp.array([5, 5], jnp.int32)
    ds_vals = jnp.array([1.0, 1.0])
    y = stsp_spmv_pallas(enc.val, enc.lidx, idx, ds_vals, s=enc.s, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(2.0 * w[:, 5]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stsp_spmv_dtypes(dtype):
    w, enc = _cbcsc_case(11, 128, 64, 16, 0.75)
    enc_t = type(enc)(val=enc.val.astype(dtype), lidx=enc.lidx, valid=enc.valid,
                      h=enc.h, m=enc.m, blen=enc.blen)
    idx = jnp.arange(12, dtype=jnp.int32)
    ds_vals = jax.random.normal(jax.random.key(1), (12,), dtype)
    y = stsp_spmv_pallas(enc_t.val, enc_t.lidx, idx, ds_vals, s=enc.s,
                         interpret=True)
    y_ref = ref.stsp_spmv_ref(enc_t.val, enc_t.lidx, idx, ds_vals, enc.s)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **TOL[dtype])


# -- batched scatter kernel + dense-gather fallback -------------------------
#
# Parity sweep of every batched SpMV implementation against the per-row
# one-hot oracle (ref.stsp_spmv_ref) over (S, M, BLEN, K, B) shapes spanning
# both regimes of the path heuristic: S*(1-gamma) < 1 (scatter wins) and
# >= 1 (dense-gather mirror wins).


def _batched_case(seed, h, q, m, gamma, k, b):
    w, enc = _cbcsc_case(seed, h, q, m, gamma)
    keys = jax.random.split(jax.random.key(seed + 1), b)
    idx = jax.vmap(lambda kk: jax.random.permutation(kk, q)[:k])(keys)
    idx = idx.astype(jnp.int32)
    ds = jax.random.normal(jax.random.key(seed + 2), (b, k))
    y_ref = jnp.stack([ref.stsp_spmv_ref(enc.val, enc.lidx, idx[i], ds[i],
                                         enc.s) for i in range(b)])
    return w, enc, idx, ds, y_ref


# (h, q, m, gamma, k, b): s = h/m in {4, 8, 16, 32, 128}, blen in {1..8}
BATCH_SWEEP = [
    (32, 16, 8, 0.75, 4, 1),        # s=4,  blen=1, single slot
    (64, 32, 8, 0.75, 8, 3),        # s=8,  blen=2
    (128, 96, 16, 0.9, 16, 4),      # s=8,  blen=1
    (256, 128, 16, 0.9375, 24, 5),  # s=16, blen=1 (paper's gamma)
    (256, 128, 8, 0.5, 32, 2),      # s=32, blen=16, half-dense
    (2048, 256, 16, 0.9375, 48, 8), # s=128: the old one-hot cliff regime
]


@pytest.mark.parametrize("h,q,m,gamma,k,b", BATCH_SWEEP)
def test_scatter_batch_kernel_parity_sweep(h, q, m, gamma, k, b):
    _, enc, idx, ds, y_ref = _batched_case(h + q + b, h, q, m, gamma, k, b)
    y = stsp_spmv_scatter_batch_pallas(enc.val, enc.lidx, idx, ds, s=enc.s,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("h,q,m,gamma,k,b", BATCH_SWEEP)
def test_dense_gather_batch_parity_sweep(h, q, m, gamma, k, b):
    _, enc, idx, ds, y_ref = _batched_case(h + q + b, h, q, m, gamma, k, b)
    w_dense = cbcsc_decode(enc, jnp.float32)
    y = ops.delta_spmv_dense_gather_batch(w_dense, idx, ds)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # and through the public batched entry point's w_dense route:
    y2 = ops.stsp_spmv_batch(enc.val, enc.lidx, idx, ds, s=enc.s,
                             w_dense=w_dense)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


@pytest.mark.parametrize("h,q,m,gamma,k,b", BATCH_SWEEP)
def test_scatter_ref_matches_onehot_ref(h, q, m, gamma, k, b):
    _, enc, idx, ds, y_ref = _batched_case(h + q + b, h, q, m, gamma, k, b)
    y = jnp.stack([ref.stsp_spmv_scatter_ref(enc.val, enc.lidx, idx[i],
                                             ds[i], enc.s) for i in range(b)])
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_scatter_batch_kernel_duplicate_columns_accumulate():
    """A column listed twice in one slot's NZI list contributes twice —
    the scatter-add must accumulate, not overwrite."""
    w, enc = _cbcsc_case(9, 64, 32, 8, 0.5)
    idx = jnp.array([[5, 5, 7], [7, 5, 5]], jnp.int32)
    ds = jnp.array([[1.0, 1.0, 0.5], [0.5, 1.0, 1.0]])
    y = stsp_spmv_scatter_batch_pallas(enc.val, enc.lidx, idx, ds, s=enc.s,
                                       interpret=True)
    expect = 2.0 * w[:, 5] + 0.5 * w[:, 7]
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y[1]), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    y_d = ops.delta_spmv_dense_gather_batch(cbcsc_decode(enc, jnp.float32),
                                            idx, ds)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y),
                               rtol=1e-5, atol=1e-5)


def test_scatter_batch_kernel_padding_is_noop():
    """Padded NZI slots (idx=0, ds=0) must not perturb the accumulator even
    though their CBCSC slab is fetched and scattered."""
    w, enc = _cbcsc_case(7, 64, 32, 8, 0.75)
    idx = jnp.array([[3, 10, 0, 0]], jnp.int32)
    ds = jnp.array([[1.0, -2.0, 0.0, 0.0]])
    y = stsp_spmv_scatter_batch_pallas(enc.val, enc.lidx, idx, ds, s=enc.s,
                                       interpret=True)
    expect = w[:, 3] * 1.0 + w[:, 10] * (-2.0)
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_scatter_batch_kernel_padded_lidx_duplicates():
    """BLEN-padding entries all carry lidx=0 (duplicate local indices with
    val=0): the scatter must add exact zeros at row 0, not corrupt it.
    Forced by encoding with blen > max occupancy."""
    h, q, m = 64, 24, 8
    w = apply_cbtd(jax.random.normal(jax.random.key(3), (h, q)) + 0.01,
                   0.75, m, 1.0)
    enc = cbcsc_encode(w, m, blen=blen_for(h, m, 0.75) + 3)  # extra padding
    idx = jnp.array([[1, 4, 9]], jnp.int32)
    ds = jnp.array([[0.3, -1.2, 2.0]])
    y = stsp_spmv_scatter_batch_pallas(enc.val, enc.lidx, idx, ds, s=enc.s,
                                       interpret=True)
    expect = 0.3 * w[:, 1] - 1.2 * w[:, 4] + 2.0 * w[:, 9]
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_stsp_spmv_batch_all_paths_agree():
    """Public batched entry point: XLA scatter, Pallas scatter and dense
    mirror must agree on the same inputs."""
    _, enc, idx, ds, y_ref = _batched_case(77, 128, 64, 16, 0.875, 12, 4)
    y_xla = ops.stsp_spmv_batch(enc.val, enc.lidx, idx, ds, s=enc.s)
    y_pal = ops.stsp_spmv_batch(enc.val, enc.lidx, idx, ds, s=enc.s,
                                use_pallas=True)
    y_den = ops.stsp_spmv_batch(enc.val, enc.lidx, idx, ds, s=enc.s,
                                w_dense=cbcsc_decode(enc, jnp.float32))
    for y in (y_xla, y_pal, y_den):
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)


def test_spmv_path_heuristic():
    """Large-S models must route to the dense mirror (never the O(S) path);
    small-S CBCSC stays on the scatter kernel."""
    assert ops.spmv_use_dense_gather(s=128, gamma=0.9375)   # 8 >= 1
    assert ops.spmv_use_dense_gather(s=32, gamma=0.75)      # 8 >= 1
    assert not ops.spmv_use_dense_gather(s=8, gamma=0.9375)  # 0.5 < 1
    assert not ops.spmv_use_dense_gather(s=15, gamma=0.9375)


# -- CBCSC pack-time BLEN enforcement (clip mode) ----------------------------
# (lives here, not in test_cbcsc.py, because that module importorskips on
# hypothesis and these regressions must always run)


def test_cbcsc_overflow_clip_keeps_largest():
    """on_overflow='clip' enforces BLEN by dropping the smallest-|w|
    nonzeros per subcolumn; survivors decode exactly, dropped become 0."""
    # one column, M=1, S=4: subcolumn [1, -3, 2, -0.5], BLEN=2
    w = jnp.array([[1.0], [-3.0], [2.0], [-0.5]])
    enc = cbcsc_encode(w, m=1, blen=2, on_overflow="clip")
    dec = np.asarray(cbcsc_decode(enc))
    np.testing.assert_allclose(dec[:, 0], [0.0, -3.0, 2.0, 0.0])
    assert int(np.asarray(enc.valid).sum()) == 2


def test_cbcsc_overflow_clip_is_lossless_when_balanced():
    """Clip mode on an already-balanced matrix == raise-mode encoding."""
    w = apply_cbtd(jax.random.normal(jax.random.key(5), (32, 8)) + 0.01,
                   0.75, 4, 1.0)
    blen = blen_for(32, 4, 0.75)
    a = cbcsc_encode(w, 4, blen=blen)
    b = cbcsc_encode(w, 4, blen=blen, on_overflow="clip")
    np.testing.assert_array_equal(np.asarray(a.val), np.asarray(b.val))
    np.testing.assert_array_equal(np.asarray(a.lidx), np.asarray(b.lidx))
    np.testing.assert_array_equal(np.asarray(a.valid), np.asarray(b.valid))


def test_cbcsc_overflow_clip_preserves_stream_order():
    """Survivors keep Alg. 3's ascending-k order inside each subcolumn
    even though selection is by magnitude."""
    w = jnp.array([[0.5], [0.0], [3.0], [-2.0]])   # M=1, S=4, k order
    enc = cbcsc_encode(w, m=1, blen=2, on_overflow="clip")
    np.testing.assert_array_equal(np.asarray(enc.lidx).ravel(), [2, 3])
    np.testing.assert_allclose(np.asarray(enc.val).ravel(), [3.0, -2.0])


# -- wrapper-level integration ----------------------------------------------


def test_select_active_columns_basic():
    delta = jnp.array([0.0, 0.5, 0.0, -2.0, 0.1, 0.0])
    idx, vals, dropped = ops.select_active_columns(delta, capacity=4)
    got = sorted((int(i), float(v)) for i, v in zip(idx, vals) if v != 0)
    assert [g[0] for g in got] == [1, 3, 4]
    assert [g[1] for g in got] == pytest.approx([0.5, -2.0, 0.1])
    assert int(dropped) == 0


def test_select_active_columns_overflow_keeps_largest():
    delta = jnp.array([0.1, -0.9, 0.5, 0.0, 0.3])
    idx, vals, dropped = ops.select_active_columns(delta, capacity=2)
    kept = {int(i) for i, v in zip(idx, vals) if v != 0}
    assert kept == {1, 2}          # two largest magnitudes
    assert int(dropped) == 2       # 0.1 and 0.3 dropped


def test_dense_topk_fused_matches_select_plus_gather():
    """The fused dense-mirror SpMV (capacity clip in the dense domain,
    lax.cond-guarded) must reproduce select_active_columns_batch +
    delta_spmv_dense_gather_batch BIT-exactly — including boundary ties
    (broken toward the lower index), rows that overflow capacity, rows
    that don't, and all-zero rows."""
    b, q, h, k = 6, 48, 32, 12
    w = jnp.asarray(
        np.asarray(jax.random.normal(jax.random.key(0), (h, q))))
    rng = np.random.default_rng(1)
    cases = []
    dense = rng.standard_normal((b, q)).astype(np.float32)       # overflow
    cases.append(dense)
    sparse = dense * (rng.random((b, q)) < 0.1)                  # underflow
    cases.append(sparse.astype(np.float32))
    tied = np.zeros((b, q), np.float32)                          # boundary tie
    tied[:, : k + 4] = 0.5
    tied[:, 1] = -0.5                                            # sign-tie too
    cases.append(tied)
    cases.append(np.zeros((b, q), np.float32))                   # nothing fired
    mixed = np.zeros((b, q), np.float32)                         # per-row mix
    mixed[0] = dense[0]
    mixed[2, :3] = 1.0
    cases.append(mixed)
    for delta in cases:
        delta = jnp.asarray(delta)
        idx, vals, dropped_ref = ops.select_active_columns_batch(delta, k)
        y_ref = ops.delta_spmv_dense_gather_batch(w, idx, vals)
        y, dropped = ops.delta_spmv_dense_topk_batch(
            jnp.asarray(w.T), delta, k)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
        np.testing.assert_array_equal(np.asarray(dropped),
                                      np.asarray(dropped_ref))
    # capacity >= Q short-circuit: nothing can drop, delta flows through
    y, dropped = ops.delta_spmv_dense_topk_batch(
        jnp.asarray(w.T), jnp.asarray(cases[0]), q)
    np.testing.assert_array_equal(np.asarray(dropped), 0)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(cases[0]) @ np.asarray(w).T, atol=1e-5)


def test_full_delta_step_via_kernels_matches_dense():
    """End-to-end single DeltaLinear step through the kernel trio equals the
    dense masked computation: encode -> select -> stsp_spmv."""
    h, q, m, gamma = 128, 96, 16, 0.75
    w, enc = _cbcsc_case(21, h, q, m, gamma)
    x = jax.random.normal(jax.random.key(22), (q,))
    x_hat = x + jax.random.normal(jax.random.key(23), (q,)) * 0.3
    theta = 0.2

    delta, new_xh, nnz = ops.delta_encode(x, x_hat, theta, use_pallas=True)
    idx, vals, dropped = ops.select_active_columns(delta, capacity=q)
    assert int(dropped) == 0
    y = ops.stsp_spmv(enc.val, enc.lidx, idx, vals, s=enc.s, use_pallas=True)

    d_ref, _, _ = ref.delta_encode_ref(x, x_hat, theta)
    np.testing.assert_allclose(np.asarray(y), np.asarray(w @ d_ref),
                               rtol=1e-4, atol=1e-4)


def test_xla_and_pallas_paths_agree():
    w, enc = _cbcsc_case(31, 256, 128, 32, 0.9)
    idx = jnp.arange(20, dtype=jnp.int32) * 3
    vals = jax.random.normal(jax.random.key(3), (20,))
    y_p = ops.stsp_spmv(enc.val, enc.lidx, idx, vals, s=enc.s, use_pallas=True)
    y_x = ops.stsp_spmv(enc.val, enc.lidx, idx, vals, s=enc.s, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_x), rtol=1e-5,
                               atol=1e-5)


def test_dense_gather_path():
    w = jax.random.normal(jax.random.key(5), (64, 32))
    idx = jnp.array([1, 5, 9], jnp.int32)
    vals = jnp.array([0.5, -1.0, 2.0])
    y = ops.delta_spmv_dense_gather(w, idx, vals)
    ds = jnp.zeros((32,)).at[idx].set(vals)
    np.testing.assert_allclose(np.asarray(y), np.asarray(w @ ds), rtol=1e-6)
