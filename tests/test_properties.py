"""Hypothesis property tests for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.delta_lstm import delta_threshold
from repro.kernels import ops


@st.composite
def _seq(draw):
    t = draw(st.integers(2, 20))
    f = draw(st.integers(1, 16))
    theta = draw(st.sampled_from([0.0, 0.05, 0.3, 1.0]))
    seed = draw(st.integers(0, 2**31 - 1))
    return t, f, theta, seed


@given(_seq())
@settings(max_examples=30, deadline=None)
def test_reference_state_invariant(case):
    """Eqs. (4)-(7) invariant: after every step, |x_t - x̂_t| <= theta
    (the reference never drifts further than the threshold), and x̂ is
    always an actually-observed past value (or the initial zero)."""
    t, f, theta, seed = case
    xs = np.asarray(jax.random.normal(jax.random.key(seed), (t, f)))
    ref = jnp.zeros((f,))
    for i in range(t):
        delta, ref = delta_threshold(jnp.asarray(xs[i]), ref, theta)
        # the reference never drifts further than the threshold...
        assert float(jnp.max(jnp.abs(jnp.asarray(xs[i]) - ref))) <= theta + 1e-6
        # ...and every reference entry is an observed past value (or 0)
        pool = np.concatenate([xs[: i + 1].ravel(), np.zeros(1)])
        refv = np.asarray(ref).ravel()
        dists = np.abs(refv[:, None] - pool[None, :]).min(axis=1)
        assert float(dists.max()) <= 1e-6


@given(_seq())
@settings(max_examples=30, deadline=None)
def test_delta_reconstruction(case):
    """Sum of emitted deltas == final reference state (no value is ever
    lost or double-counted — the no-error-accumulation property that
    justifies eq. (3)'s running delta memories)."""
    t, f, theta, seed = case
    xs = jax.random.normal(jax.random.key(seed), (t, f))
    ref = jnp.zeros((f,))
    acc = jnp.zeros((f,))
    for i in range(t):
        delta, ref = delta_threshold(xs[i], ref, theta)
        acc = acc + delta
    np.testing.assert_allclose(np.asarray(acc), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_select_active_columns_properties(f, capacity, seed):
    """The NZI list keeps exactly min(nnz, capacity) entries and they are
    the largest-magnitude deltas (drop-smallest overflow policy)."""
    key = jax.random.key(seed)
    delta = jax.random.normal(key, (f,)) * jax.random.bernoulli(
        jax.random.fold_in(key, 1), 0.5, (f,))
    idx, vals, dropped = ops.select_active_columns(delta, capacity)
    nnz = int(jnp.sum(delta != 0))
    kept = int(jnp.sum(vals != 0))
    assert kept == min(nnz, capacity)
    assert int(dropped) == max(nnz - capacity, 0)
    if kept and nnz > capacity:
        kept_mags = np.sort(np.abs(np.asarray(vals[vals != 0])))
        all_mags = np.sort(np.abs(np.asarray(delta[delta != 0])))
        np.testing.assert_allclose(kept_mags, all_mags[-capacity:], rtol=1e-6)
    # reconstruction: the valid (idx, val) pairs reproduce the kept deltas
    # (padding slots carry idx=0/val=0 and must be skipped — a raw scatter
    # would collide with a genuine delta at column 0)
    if nnz <= capacity:
        recon = np.zeros((f,))
        for i, v in zip(np.asarray(idx), np.asarray(vals)):
            if v != 0:
                recon[int(i)] = float(v)
        np.testing.assert_allclose(recon, np.asarray(delta), rtol=1e-5,
                                   atol=1e-7)
