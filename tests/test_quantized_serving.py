"""Quantized serving end-to-end: int8 weights, Q8.8 activations.

The quant mode (``EngineConfig.quant = QuantConfig()``) stores the CBCSC
weight payloads — values, 8-bit LIDX, and the dense mirrors — as int8 at
rest and dequantizes in the SpMV epilogue (``y * scale``, a power-of-two
per-tensor scale), while the delta threshold compares Q8.8-quantized
activations.  This suite pins the mode's three load-bearing claims:

* **parity**: the quantized pool equals the quantized batch-1 engine at
  the repo's 1e-5 oracle tolerance across (capacity, chunk, spmv_path,
  shard count) — pooling adds no quantization error;
* **divergence**: quantized logits differ from fp32 logits only through
  the Q8.8 activation snap, bounded well under any decodable margin;
* **off means off**: ``quant=None`` and ``QuantConfig(enabled=False)``
  are BIT-identical to the fp32 default — same logits, same compiled
  HLO text — so the flag cannot tax the default path.

Plus the memory story (int8 operands visible in the optimized HLO, no
fp32 mirror constant baked into the module, the 4x payload shrink in
``weight_payload_bytes`` / ``ServeStats.bytes_per_slot``) and the
checkpoint fingerprint that refuses cross-format restores.
"""
from collections import deque

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.cases import lower_pool_chunk
from repro.core.quantization import QuantConfig
from repro.models import lstm_am
from repro.serving import (
    BatchedSpartusEngine,
    EngineConfig,
    PoolObservability,
    SpartusEngine,
    StreamRequest,
    serve_requests,
)
from repro.serving import checkpoint as ckptlib
from repro.serving.scheduler import SessionPool

INPUT_DIM, HIDDEN, CLASSES = 20, 32, 11
GAMMA, M, THETA = 0.75, 4, 0.05
LENS = [5, 9, 3, 12, 1, 7]
N_DEV = jax.device_count()

multi_device = pytest.mark.skipif(
    N_DEV < 4, reason="needs 4 (emulated) devices; run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def model():
    cfg = lstm_am.LSTMAMConfig(input_dim=INPUT_DIM, hidden_dim=HIDDEN,
                               n_layers=2, n_classes=CLASSES)
    params = lstm_am.init_params(jax.random.key(0), cfg)
    return lstm_am.cbtd_prune_stacks(params, gamma=GAMMA, m=M), cfg


def _ecfg(spmv_path="auto", quant=QuantConfig()):
    return EngineConfig(theta=THETA, gamma=GAMMA, m=M, capacity_frac=1.0,
                        spmv_path=spmv_path, quant=quant)


@pytest.fixture(scope="module")
def qengines(model):
    params, cfg = model
    return (SpartusEngine(params, cfg, _ecfg()),
            BatchedSpartusEngine(params, cfg, _ecfg()))


@pytest.fixture(scope="module")
def fengines(model):
    params, cfg = model
    ecfg = _ecfg(quant=None)
    return (SpartusEngine(params, cfg, ecfg),
            BatchedSpartusEngine(params, cfg, ecfg))


def _utterance(key, t):
    return np.asarray(
        jax.random.normal(jax.random.key(key), (t, INPUT_DIM)), np.float32)


@pytest.fixture(scope="module")
def workload(qengines):
    e1q, _ = qengines
    feats = [_utterance(500 + i, t) for i, t in enumerate(LENS)]
    refs = [np.asarray(e1q.run_utterance(jnp.asarray(f))) for f in feats]
    return feats, refs


def _reqs(feats):
    return [StreamRequest(100 + i, 0, f) for i, f in enumerate(feats)]


def _drain(pool, pending, *, now=0, collected=None, max_iters=10_000):
    out = dict(collected or {})
    pending = deque(pending)
    for _ in range(max_iters):
        while pending and pool.n_free and pool.admit(pending[0], now):
            pending.popleft()
        if not (pending or pool.n_active or pool.has_pending):
            break
        finished, adv = pool.tick(now)
        for r in finished:
            out[r.req_id] = r.logits
        now += max(adv, 1)
    else:
        raise AssertionError("pool did not drain")
    for r in pool.flush():
        out[r.req_id] = r.logits
    return out


# -- weights at rest ----------------------------------------------------------


def test_quant_weights_are_int8_at_rest(qengines, fengines):
    _, ebq = qengines
    _, ebf = fengines
    for lq, lf in zip(ebq.layers, ebf.layers):
        assert lq.enc.val.dtype == jnp.int8
        assert lq.enc.lidx.dtype == jnp.int8      # the paper's 8-bit LIDX
        assert lf.enc.val.dtype == jnp.float32
        if lq.w_dense_t is not None:
            assert lq.w_dense_t.dtype == jnp.int8
            assert lf.w_dense_t.dtype == jnp.float32
        # pow2 scale: the dequant multiply is an exact FPGA shift
        s = float(lq.scale)
        assert s == 2.0 ** round(np.log2(s))


def test_quant_payload_shrinks_4x(qengines, fengines):
    _, ebq = qengines
    _, ebf = fengines
    # the quantized payload terms (values + lidx + mirrors) shrink 4x
    # exactly: every element goes f32 -> int8
    assert ebf.weight_payload_bytes() == 4 * ebq.weight_payload_bytes()
    # total weight bytes shrink less (fp32 head / biases / valid masks):
    assert ebf.weight_bytes() > ebq.weight_bytes()


def test_bytes_per_slot_accounting(qengines, fengines):
    _, ebq = qengines
    _, ebf = fengines
    feats = [_utterance(520 + i, t) for i, t in enumerate(LENS[:4])]
    obs = PoolObservability()
    _, qstats = serve_requests(ebq, _reqs(feats), capacity=4,
                               chunk_frames=4, observability=obs)
    _, fstats = serve_requests(ebf, _reqs(feats), capacity=4, chunk_frames=4)
    assert 0 < qstats.bytes_per_slot < fstats.bytes_per_slot
    # the stats row carries it, and the gauge mirrors the last fold:
    assert qstats.to_dict()["bytes_per_slot"] == qstats.bytes_per_slot
    snap = obs.registry.snapshot()
    assert snap["spartus_slot_bytes"]["value"] == pytest.approx(
        qstats.bytes_per_slot)


# -- parity: quantized pool vs quantized batch-1 oracle -----------------------


@pytest.mark.parametrize("spmv_path", ["auto", "scatter"])
def test_quant_pool_vs_batch1_parity_grid(model, spmv_path):
    """Quantized serving equals the quantized batch-1 engine over the
    same (capacity, chunk_frames) x ragged-lengths grid the fp32 chunked
    suite pins — on both SpMV routes."""
    params, cfg = model
    e1 = SpartusEngine(params, cfg, _ecfg(spmv_path))
    eb = BatchedSpartusEngine(params, cfg, _ecfg(spmv_path))
    feats = [_utterance(540 + i, t) for i, t in enumerate(LENS)]
    refs = [np.asarray(e1.run_utterance(jnp.asarray(f))) for f in feats]
    reqs = [StreamRequest(i, arrival_step=2 * i, feats=feats[i])
            for i in range(len(LENS))]
    for capacity in (2, 4):
        for chunk in (1, 3, 8, 32):
            results, stats = serve_requests(eb, reqs, capacity=capacity,
                                            chunk_frames=chunk)
            assert [r.req_id for r in results] == list(range(len(LENS)))
            for r in results:
                np.testing.assert_allclose(r.logits, refs[r.req_id],
                                           atol=1e-5)
            assert stats.total_frames == sum(LENS)


@multi_device
def test_quant_sharded_pool_parity(qengines, workload):
    """Slot-sharding a quantized pool changes placement, not numerics."""
    _, eb = qengines
    feats, refs = workload
    results, _ = serve_requests(eb, _reqs(feats), capacity=4,
                                chunk_frames=4, n_devices=4)
    for r in results:
        np.testing.assert_allclose(r.logits, refs[r.req_id - 100],
                                   atol=1e-5)


# -- divergence vs fp32 -------------------------------------------------------

#: The only quant-mode divergence source is the Q8.8 activation snap in
#: the delta threshold (the int8 weight grid is what fp32 packing already
#: uses).  Measured max-abs logit difference at this scale is ~5e-4; the
#: bound leaves two orders of headroom.
DIVERGENCE_BOUND = 0.05


def test_quant_vs_fp32_divergence_bounded(qengines, fengines, workload):
    _, ebq = qengines
    e1f, ebf = fengines
    feats, qrefs = workload
    fres, _ = serve_requests(ebf, _reqs(feats), capacity=4, chunk_frames=8)
    qres, _ = serve_requests(ebq, _reqs(feats), capacity=4, chunk_frames=8)
    fby = {r.req_id: r.logits for r in fres}
    div = max(float(np.max(np.abs(r.logits - fby[r.req_id]))) for r in qres)
    assert div <= DIVERGENCE_BOUND
    # and the batch-1 engines diverge by the same mechanism and bound:
    for f, qr in zip(feats, qrefs):
        fr = np.asarray(e1f.run_utterance(jnp.asarray(f)))
        assert float(np.max(np.abs(qr - fr))) <= DIVERGENCE_BOUND


# -- off means off: bit-identity of the disabled modes ------------------------


def test_quant_disabled_is_bit_identical_to_fp32(model, fengines, workload):
    """``QuantConfig(enabled=False)`` and ``quant=None`` are the same
    fp32 path: byte-identical compiled HLO, bit-identical logits."""
    params, cfg = model
    _, ebf = fengines
    eb_off = BatchedSpartusEngine(
        params, cfg, _ecfg(quant=QuantConfig(enabled=False)))
    feats, _ = workload
    base, _ = serve_requests(ebf, _reqs(feats), capacity=4, chunk_frames=4)
    off, _ = serve_requests(eb_off, _reqs(feats), capacity=4, chunk_frames=4)
    for a, b in zip(base, off):
        assert a.req_id == b.req_id
        np.testing.assert_array_equal(a.logits, b.logits)
    assert lower_pool_chunk(eb_off, feats[:4]) == \
        lower_pool_chunk(ebf, feats[:4])


# -- the compiled module: int8 operands, no baked fp32 mirror ----------------


def test_quant_hlo_keeps_int8_operands(qengines, fengines, workload):
    feats, _ = workload
    _, ebq = qengines
    _, ebf = fengines
    txt_q = lower_pool_chunk(ebq, feats[:4])
    txt_f = lower_pool_chunk(ebf, feats[:4])
    assert "s8[" in txt_q          # int8 payloads survive optimization
    assert "s8[" not in txt_f      # and never leak into the fp32 module
    for layer in ebq.layers:
        if layer.w_dense_t is None:
            continue
        r, c = layer.w_dense_t.shape
        # the mirror is an s8 constant; the ONLY f32 producer of its
        # shape is the runtime convert feeding the GEMM — a baked
        # f32 constant would mean XLA folded the dequant back in:
        assert any(f"s8[{r},{c}]" in ln and " constant(" in ln
                   for ln in txt_q.splitlines())
        assert not any(f"= f32[{r},{c}]" in ln and " constant(" in ln
                       for ln in txt_q.splitlines())


def test_quant_obs_on_off_hlo_identical(qengines, workload):
    """Observability folds stay host-side in quant mode too: attaching
    them changes not one byte of the compiled chunk step."""
    feats, _ = workload
    _, ebq = qengines
    assert lower_pool_chunk(ebq, feats[:4], PoolObservability()) == \
        lower_pool_chunk(ebq, feats[:4])


# -- checkpoint/restore -------------------------------------------------------


def test_quant_checkpoint_restore_capacity_migration(
        qengines, workload, tmp_path):
    """A quantized pool checkpointed mid-flight restores into a LARGER
    quantized pool and finishes with the uninterrupted run's logits —
    the recurrent state lives on the quantized grid, so migration has
    nothing to re-quantize."""
    _, eb = qengines
    feats, refs = workload
    pool = SessionPool(eb, 2, max_frames=16, chunk_frames=4)
    pending = deque(_reqs(feats[:4]))
    while pending and pool.n_free and pool.admit(pending[0], 0):
        pending.popleft()
    got = {r.req_id: r.logits for r in pool.tick(0)[0]}
    for r in pool.checkpoint(str(tmp_path / "qck")):
        got[r.req_id] = r.logits
    big = SessionPool(eb, 5, max_frames=16, chunk_frames=4)
    big.restore(str(tmp_path / "qck"))
    got = _drain(big, pending, now=4, collected=got)
    for i in range(4):
        assert np.array_equal(got[100 + i], refs[i])


def test_quant_fp32_restore_refusal(qengines, fengines, workload, tmp_path):
    """The engine fingerprint carries the quant format: a quantized
    checkpoint will not restore into an fp32 pool (or vice versa) — the
    recurrent state evolves on a different numeric grid, so resuming
    across formats would silently diverge rather than fail."""
    _, ebq = qengines
    _, ebf = fengines
    feats, _ = workload

    qpool = SessionPool(ebq, 2, max_frames=16, chunk_frames=4)
    assert qpool.admit(StreamRequest(0, 0, feats[1]), 0)
    qpool.tick(0)
    qpool.checkpoint(str(tmp_path / "q"))
    fpool = SessionPool(ebf, 2, max_frames=16, chunk_frames=4)
    with pytest.raises(ValueError, match="fingerprint"):
        fpool.restore(str(tmp_path / "q"))

    fpool2 = SessionPool(ebf, 2, max_frames=16, chunk_frames=4)
    assert fpool2.admit(StreamRequest(0, 0, feats[1]), 0)
    fpool2.tick(0)
    fpool2.checkpoint(str(tmp_path / "f"))
    qpool2 = SessionPool(ebq, 2, max_frames=16, chunk_frames=4)
    with pytest.raises(ValueError, match="fingerprint"):
        qpool2.restore(str(tmp_path / "f"))
    # and the fingerprints themselves disagree only on the quant entry:
    fq = ckptlib.engine_fingerprint(ebq)
    ff = ckptlib.engine_fingerprint(ebf)
    assert fq["quant"] == [8, 16, 8] and ff["quant"] is None
    assert {k: v for k, v in fq.items() if k != "quant"} == \
        {k: v for k, v in ff.items() if k != "quant"}
