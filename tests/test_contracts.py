"""The hot-path contract checker and the repo lint pass (repro.analysis).

Four layers of coverage:

* every registered contract case must pass on the real code (this is the
  tier-1 wiring of `python -m tools.lint --contracts`);
* a negative case for every contract CLAUSE: a minimal violating
  function/HLO the checker must flag, plus a compliant twin it must not;
* a negative case for every LINT RULE, same violating/compliant pairing,
  plus the pragma escape and jit-decorator recognition;
* mutation demonstrations: re-introducing the two bugs the contracts
  exist for — the iota-indexed frame gather (PR 5: an all-gather +
  all-reduce per scan iteration on the sharded pool) and the aliased
  ``init_telemetry`` buffers (PR 2: donation rejected at run time) — by
  actually compiling/executing the mutated variant and watching the
  checker fail.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import cases as caselib
from repro.analysis import contracts, hlo, lint
from repro.analysis.cases import BuiltCase

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- the real hot paths pass their contracts ---------------------------------


@pytest.mark.parametrize("case", caselib.build_cases(),
                         ids=lambda c: c.name)
def test_hot_path_contract(case):
    report = contracts.check_case(case)
    assert report.ok, "\n".join(str(v) for v in report.violations)


def test_every_registered_contract_has_a_case():
    """A contract without a case is a pin that never fires."""
    covered = {c.contract for c in caselib.build_cases(include_sharded=False)}
    assert covered == set(contracts.registered_contracts())


# -- negative cases: one per contract clause ---------------------------------

# a minimal synthetic optimized-HLO module; the header carries a real
# alias map and the body a fusion whose inner ops must be counted too.
_CANNED_OK = textwrap.dedent("""\
    HloModule jit_f, is_scheduled=true, input_output_alias={ {0}: (0, {}, may-alias) }
    %fused_computation (p0: f32[4,8]) -> f32[4,8] {
      %p0 = f32[4,8]{1,0} parameter(0)
      %transpose.1 = f32[8,4]{1,0} transpose(%p0), dimensions={1,0}
      ROOT %add.0 = f32[4,8]{1,0} add(%p0, %p0)
    }
    ENTRY %main (a: f32[4,8]) -> f32[4,8] {
      %a = f32[4,8]{1,0} parameter(0)
      ROOT %fusion = f32[4,8]{1,0} fusion(%a), kind=kLoop, calls=%fused_computation
    }
""")


def _contract(**kw):
    kw.setdefault("name", "test_clause")
    return contracts.HotpathContract(**kw)


def test_clause_no_collectives_flags_and_twin_passes():
    bad = _CANNED_OK + "  %ar = f32[4,8]{1,0} all-reduce(%a), replica_groups={}\n"
    vs = contracts.check_hlo(_contract(), bad)
    assert [v.clause for v in vs] == ["no_collectives"]
    assert contracts.check_hlo(_contract(), _CANNED_OK) == []


def test_clause_no_host_transfers_flags_compiled_callback():
    """The violating twin is COMPILED, not canned: a host callback inside
    jit lowers to an xla_python_cpu_callback custom-call."""
    def bad(x):
        jax.debug.print("x0={v}", v=x[0])
        return x * 2.0

    def good(x):
        return x * 2.0

    x = jnp.ones((8,), jnp.float32)
    txt_bad = hlo.compiled_text(jax.jit(bad), x)
    txt_good = hlo.compiled_text(jax.jit(good), x)
    assert [v.clause for v in contracts.check_hlo(_contract(), txt_bad)] \
        == ["no_host_transfers"]
    assert contracts.check_hlo(_contract(), txt_good) == []


def test_clause_max_dtype_flags_f64():
    bad = _CANNED_OK + "  %c = f64[4,8]{1,0} convert(%a)\n"
    vs = contracts.check_hlo(_contract(), bad)
    assert [v.clause for v in vs] == ["max_dtype"]
    # widening the ceiling disables the clause:
    assert contracts.check_hlo(_contract(max_dtype="float64"), bad) == []


def test_clause_forbid_ops_sees_inside_fusions():
    """The canned module's transpose lives in a fusion body; the op
    histogram must count it anyway."""
    vs = contracts.check_hlo(_contract(forbid_ops=("transpose",)), _CANNED_OK)
    assert [v.clause for v in vs] == ["forbid_ops"]
    assert contracts.check_hlo(_contract(forbid_ops=("sort",)),
                               _CANNED_OK) == []


def test_clause_op_budget_flags_real_compiled_excess():
    def two_dus(buf, x):
        buf = jax.lax.dynamic_update_slice(buf, x, (0,))
        return jax.lax.dynamic_update_slice(buf, x, (4,))

    txt = hlo.compiled_text(jax.jit(two_dus), jnp.zeros((16,), jnp.float32),
                            jnp.ones((4,), jnp.float32))
    over = contracts.check_hlo(
        _contract(op_budget={"dynamic-update-slice": 1}), txt)
    assert [v.clause for v in over] == ["op_budget"]
    assert contracts.check_hlo(
        _contract(op_budget={"dynamic-update-slice": 2}), txt) == []


def test_clause_donation_static_flags_dropped_alias():
    """donate_argnums on an argument that cannot alias any output leaves
    no entry in the alias map; the static clause must notice."""
    import warnings

    def no_alias(x):
        return x.sum()                    # output shape != donated shape

    def aliases(x):
        return x + 1.0

    x = jnp.ones((128,), jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # jax warns on unused donation
        txt_bad = hlo.compiled_text(jax.jit(no_alias, donate_argnums=0), x)
    txt_good = hlo.compiled_text(jax.jit(aliases, donate_argnums=0), x)
    bad = contracts.check_hlo(_contract(), txt_bad, donated_leaves=1)
    assert [v.clause for v in bad] == ["donation"]
    assert contracts.check_hlo(_contract(), txt_good, donated_leaves=1) == []


def test_clause_donation_runtime_flags_shared_buffer():
    """The aliased-buffer failure is invisible in the alias map (XLA
    still prints may-alias entries) and only fails in Execute(); the
    runtime probe must catch it — and must pass the un-aliased twin."""
    step = jax.jit(lambda pair: (pair[0] + 1.0, pair[1] * 2.0),
                   donate_argnums=0)

    z = jnp.zeros((64,), jnp.float32)
    bad = contracts.run_donation_probe(
        "test_clause", step, ((z, z),), {}, [(z, z)])
    assert [v.clause for v in bad] == ["donation"]
    assert "donate" in bad[0].message

    a, b = jnp.zeros((64,), jnp.float32), jnp.zeros((64,), jnp.float32)
    good = contracts.run_donation_probe(
        "test_clause", step, ((a, b),), {}, [(a, b)])
    assert good == []


def test_alias_count_parses_real_header():
    assert hlo.alias_count(_CANNED_OK) == 1
    assert hlo.alias_count("HloModule jit_f, is_scheduled=true") == 0
    many = ("HloModule m, input_output_alias={ "
            + ", ".join("{%d}: (%d, {}, may-alias)" % (i, i)
                        for i in range(13)) + " }, entry_layout={}")
    assert hlo.alias_count(many) == 13


# -- negative cases: one per lint rule ---------------------------------------


def _lint(src, path="src/repro/serving/fake.py"):
    return lint.lint_source(textwrap.dedent(src), path)


def test_rule_iota_gather_flags_and_twin_passes():
    bad = _lint("""
        import jax.numpy as jnp
        def gather(frames, cursor):
            return frames[jnp.arange(frames.shape[0]), cursor]
    """)
    assert [f.rule for f in bad] == ["iota-gather"]
    good = _lint("""
        import jax.numpy as jnp
        def gather(frames, cursor):
            idx = cursor[:, None, None]
            return jnp.take_along_axis(frames, idx, axis=1)[:, 0]
    """)
    assert good == []


def test_rule_iota_gather_ignores_at_updates():
    """`.at[arange(B), idx].add` is the scatter API, not the gather."""
    assert _lint("""
        import jax.numpy as jnp
        def scatter(buf, idx, vals):
            return buf.at[jnp.arange(buf.shape[0]), idx].add(vals)
    """, path="src/repro/kernels/fake.py") == []


def test_rule_eager_scatter_flags_and_twin_passes():
    bad = _lint("""
        def host_side(buf, x):
            return buf.at[0].set(x)
    """)
    assert [f.rule for f in bad] == ["eager-scatter"]
    # under jit (including functools.partial(jax.jit, ...)), allowed:
    assert _lint("""
        import functools, jax
        @functools.partial(jax.jit, donate_argnums=(0,))
        def device_side(buf, x):
            return buf.at[0].set(x)
    """) == []
    # outside serving/, out of scope for this rule:
    assert _lint("""
        def host_side(buf, x):
            return buf.at[0].set(x)
    """, path="src/repro/models/fake.py") == []


def test_rule_aliased_donation_flags_and_twin_passes():
    bad = _lint("""
        import jax.numpy as jnp
        def init(n):
            z = jnp.zeros((n,))
            return State(z, z, z)
    """)
    assert {f.rule for f in bad} == {"aliased-donation"}
    good = _lint("""
        import jax.numpy as jnp
        def init(n):
            def z():
                return jnp.zeros((n,))
            return State(z(), z(), z())
    """)
    assert good == []


def test_rule_blocking_in_driver_flags_and_twin_passes():
    path = "src/repro/serving/async_server.py"
    bad = _lint("""
        import numpy as np
        async def pump(out):
            val = np.asarray(out)
            ready = out.block_until_ready()
            x = float(out[0])
            return val, ready, x
    """, path)
    assert [f.rule for f in bad] == ["blocking-in-driver"] * 3
    good = _lint("""
        import numpy as np
        async def pump(loop, out):
            val = await loop.run_in_executor(None, _fetch, out)
            return val
        def _fetch(out):
            return np.asarray(out)   # sync helper, off the event loop
    """, path)
    assert good == []
    # same code outside the driver files is out of scope:
    assert _lint("""
        import numpy as np
        async def pump(out):
            return np.asarray(out)
    """, "src/repro/launch/fake.py") == []


def test_rule_wallclock_in_jit_flags_and_twin_passes():
    bad = _lint("""
        import time, jax
        def _inner(x):
            return x * time.time()
        @jax.jit
        def step(x):
            return _inner(x)
    """)
    assert [f.rule for f in bad] == ["wallclock-in-jit"]
    good = _lint("""
        import time, jax
        @jax.jit
        def step(x):
            return x * 2.0
        def drive(x):
            t0 = time.time()      # host side: fine
            return step(x), time.time() - t0
    """)
    assert good == []


def test_pragma_escape_suppresses_named_rule_only():
    src = """
        def host_side(buf, x):
            # lint: allow(eager-scatter) staged upload
            return buf.at[0].set(x)
    """
    assert _lint(src) == []
    wrong_rule = """
        def host_side(buf, x):
            # lint: allow(iota-gather)
            return buf.at[0].set(x)
    """
    assert [f.rule for f in _lint(wrong_rule)] == ["eager-scatter"]


def test_repo_is_lint_clean():
    from pathlib import Path
    findings = lint.lint_repo(Path(REPO_ROOT))
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lint_cli_smoke():
    out = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--ast"],
        cwd=REPO_ROOT, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO_ROOT, "src"),
             "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stdout + out.stderr
    assert "AST lint: clean" in out.stdout


# -- mutation demonstrations --------------------------------------------------


def test_realiased_init_telemetry_fails_donation_probe(monkeypatch):
    """Re-introduce the PR-2 bug: one zeros buffer aliased into all three
    TelemetryState fields.  The compiled alias map STILL lists every leaf
    as may-alias (the static clause passes), but executing the donating
    step must trip the runtime probe — exactly how the bug originally
    surfaced."""
    from repro.models import lstm_am
    from repro.serving import BatchedSpartusEngine, EngineConfig
    from repro.serving import telemetry as tele

    def aliased_init(n_layers, n_slots):
        z = jnp.zeros((n_layers, n_slots), jnp.float32)
        return tele.TelemetryState(nnz_sum=z, overflow_steps=z, steps=z)

    monkeypatch.setattr(tele, "init_telemetry", aliased_init)
    cfg = lstm_am.LSTMAMConfig(input_dim=caselib.INPUT_DIM,
                               hidden_dim=caselib.HIDDEN, n_layers=2,
                               n_classes=caselib.CLASSES)
    params = lstm_am.cbtd_prune_stacks(
        lstm_am.init_params(jax.random.key(0), cfg),
        gamma=caselib.GAMMA, m=caselib.M)
    engine = BatchedSpartusEngine(params, cfg, EngineConfig(
        theta=caselib.THETA, gamma=caselib.GAMMA, m=caselib.M,
        capacity_frac=1.0))

    def build():
        state = engine.init_state(4)
        frames = jax.random.normal(jax.random.key(3),
                                   (4, 8, caselib.INPUT_DIM), jnp.float32)
        return BuiltCase(fn=engine._step_frames,
                         args=(state, frames, jnp.ones((4,), bool),
                               jnp.zeros((4,), bool)),
                         kwargs={}, donate_argnums=(0,))

    case = caselib.ContractCase("step_frames/aliased-telemetry",
                                "step_frames", build)
    report = contracts.check_case(case)
    assert not report.ok
    assert [v.clause for v in report.violations] == ["donation"]
    assert "donate" in report.violations[0].message
    # the static alias map alone could NOT have caught it:
    assert report.alias_entries == report.donated_leaves


IOTA_REVERT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.analysis import cases as caselib
    from repro.analysis import contracts, hlo
    from repro.kernels import ops

    engine = caselib._engine()
    feats = caselib._feats(8)

    def lower():
        built = caselib.built_pool_chunk(engine, feats, capacity=8,
                                         n_devices=4)
        return hlo.compiled_text(built.fn, *built.args, **built.kwargs)

    healthy = hlo.count_collectives(lower())
    contract = contracts.get_contract("step_chunk")
    healthy_viol = [v.clause for v in contracts.check_hlo(
        contract, lower()) if v.clause == "no_collectives"]

    # revert to the pre-PR-5 gather: batch-iota advanced indexing.  GSPMD
    # cannot keep it local per shard, so the compiled sharded scan grows
    # an all-gather + all-reduce per iteration:
    def iota_gather(frames, cursor):
        t_buf = frames.shape[1]
        idx = jnp.minimum(cursor, t_buf - 1).astype(jnp.int32)
        return frames[jnp.arange(frames.shape[0]), idx]

    ops.gather_frames = iota_gather
    engine._step_chunk = jax.jit(engine._step_chunk_impl,
                                 static_argnames=("n_frames",),
                                 donate_argnums=(0, 5))
    mutated_txt = lower()
    mutated = hlo.count_collectives(mutated_txt)
    mutated_viol = [v.clause for v in contracts.check_hlo(
        contract, mutated_txt) if v.clause == "no_collectives"]
    print(json.dumps({
        "devices": len(jax.devices()),
        "healthy_collectives": healthy,
        "healthy_violations": healthy_viol,
        "mutated_collectives": mutated,
        "mutated_violations": mutated_viol,
    }))
""")


@pytest.mark.slow
def test_iota_gather_revert_breaks_sharded_contract():
    """Re-introduce the PR-5 bug in a 4-emulated-device subprocess and
    compile the REAL sharded chunk both ways: the take_along_axis gather
    must check clean, the iota revert must make the no_collectives clause
    fire (GSPMD inserts collectives into the scan)."""
    env = {"PYTHONPATH": os.path.join(REPO_ROOT, "src"),
           "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
           "JAX_PLATFORMS": "cpu"}
    out = subprocess.run([sys.executable, "-c", IOTA_REVERT_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=REPO_ROOT, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["devices"] == 4
    assert payload["healthy_collectives"] == 0
    assert payload["healthy_violations"] == []
    assert payload["mutated_collectives"] > 0
    assert payload["mutated_violations"] == ["no_collectives"]


# -- hlo helper unit coverage -------------------------------------------------


def test_op_histogram_counts_fusion_bodies_and_folds_versions():
    h = hlo.op_histogram(_CANNED_OK)
    assert h["transpose"] == 1      # inside the fusion computation
    assert h["add"] == 1            # add.0 folded onto 'add'
    assert h["fusion"] == 1


def test_collective_and_host_transfer_tokens_match_legacy_pins():
    """The analyzer's token lists are the SAME strings the PR-5/PR-6
    test pins greped for — migrating the tests must not have changed
    what counts as a violation."""
    assert hlo.COLLECTIVE_TOKENS == (
        "all-reduce", "all-gather", "collective-permute", "all-to-all",
        "reduce-scatter")
    assert hlo.HOST_TRANSFER_TOKENS == (
        "outfeed", "infeed", "xla_python_cpu_callback", "host_callback",
        "SendToHost", "RecvFromHost")
