"""Integration tests for the serving observability layer: the compiled
step must be bit-identical (and host-transfer-free) with observability on
or off, the live counters must agree exactly with `ServeStats`, the
tick-loop tracer must cover all five driver phases, and the admin
endpoint must answer every command against a live async pool under load.
"""
import asyncio
import json
import re
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo as hlolib
from repro.analysis.cases import lower_pool_chunk
from repro.models import lstm_am
from repro.serving import (
    AsyncSpartusServer,
    BatchedSpartusEngine,
    EngineConfig,
    PoolObservability,
    StreamRequest,
    Tracer,
    serve_requests,
)
from repro.serving.scheduler import SessionPool

INPUT_DIM, HIDDEN, CLASSES = 20, 32, 11
GAMMA, M, THETA = 0.75, 4, 0.05
LENS = [5, 9, 3, 12, 1, 7, 8, 2]


@pytest.fixture(scope="module")
def engine():
    cfg = lstm_am.LSTMAMConfig(input_dim=INPUT_DIM, hidden_dim=HIDDEN,
                               n_layers=2, n_classes=CLASSES)
    params = lstm_am.cbtd_prune_stacks(
        lstm_am.init_params(jax.random.key(0), cfg), gamma=GAMMA, m=M)
    ecfg = EngineConfig(theta=THETA, gamma=GAMMA, m=M, capacity_frac=1.0)
    return BatchedSpartusEngine(params, cfg, ecfg)


@pytest.fixture(scope="module")
def workload():
    return [np.asarray(
        jax.random.normal(jax.random.key(900 + i), (t, INPUT_DIM)),
        np.float32) for i, t in enumerate(LENS)]


def _requests(feats):
    return [StreamRequest(i, 0, f) for i, f in enumerate(feats)]


# ------------------------------------------- zero-added-host-transfer pin
# The chunk-lowering recipe and the forbidden-token scan live in
# repro.analysis (cases.lower_pool_chunk / hlo.host_transfer_lines): the
# same code the contract checker and `python -m tools.lint --contracts`
# run, so this pin and CI can never drift apart.


def test_compiled_chunk_identical_with_and_without_obs(engine, workload):
    """The boundary-fold rule, pinned at the HLO level: attaching
    observability must not change the compiled scan by one byte — every
    metric source folds host-side at chunk boundaries, never inside the
    step — and the scan itself must contain no host-transfer ops
    (outfeed/infeed/callback), i.e. zero added host syncs per scan
    iteration."""
    hlo_off = lower_pool_chunk(engine, workload, observability=None)
    hlo_on = lower_pool_chunk(engine, workload,
                              observability=PoolObservability())
    assert hlo_on == hlo_off
    hits = hlolib.host_transfer_lines(hlo_on)
    assert hits == [], f"host-transfer ops in compiled chunk: {hits[:5]}"


def test_telemetry_totals_reduction_is_transfer_free(engine):
    """The one device-side observability signal — the [3] totals the
    boundary fold diffs — must itself lower without host callbacks."""
    txt = engine._tel_totals.lower(engine.init_state(4).telemetry) \
        .compile().as_text()
    assert hlolib.host_transfer_lines(txt) == []


# ----------------------------------------------- counter/ServeStats parity

@pytest.mark.parametrize("cap,chunk,max_steps", [
    (3, 4, None),     # chunked, multiple admission waves
    (2, 2, None),     # chunked, tiny chunks
    (4, 8, None),     # chunked, whole-utterance chunks
    (3, 0, None),     # per-frame path
    (2, 4, 6),        # truncated by max_steps mid-run
])
def test_counters_match_servestats(engine, workload, cap, chunk, max_steps):
    """The live counters and `ServeStats` are two views of one run and
    must agree EXACTLY: dispatches, frames, and delivered results split
    by the same `truncated` flag."""
    obs = PoolObservability()
    results, stats = serve_requests(engine, _requests(workload),
                                    capacity=cap, chunk_frames=chunk,
                                    max_steps=max_steps, observability=obs)
    n_trunc = sum(1 for r in results if r.truncated)
    assert obs.c_dispatches.value == stats.n_dispatches
    assert obs.c_frames.value == stats.total_frames
    assert obs.c_completed.value == len(results) - n_trunc
    assert obs.c_truncated.value == n_trunc
    assert obs.c_admissions.value == len(results)
    if max_steps is not None:
        assert stats.truncated and n_trunc > 0
    # one time-series sample per dispatch boundary:
    assert obs.timeseries.n_appended == stats.n_dispatches
    samples = obs.timeseries.snapshot()
    assert sum(s["frames"] for s in samples) == stats.total_frames
    assert sum(s["admissions"] for s in samples) == len(results)
    # retirements land in the boundary that RESOLVED them; results still
    # pending at the final flush() surface outside any dispatch boundary:
    assert sum(s["retirements"] for s in samples) <= len(results)


def test_observability_does_not_change_results(engine, workload):
    """Logits with observability attached are bit-identical to without."""
    res_off, _ = serve_requests(engine, _requests(workload), capacity=3,
                                chunk_frames=4)
    res_on, _ = serve_requests(engine, _requests(workload), capacity=3,
                               chunk_frames=4,
                               observability=PoolObservability())
    for a, b in zip(sorted(res_off, key=lambda r: r.req_id),
                    sorted(res_on, key=lambda r: r.req_id)):
        np.testing.assert_array_equal(a.logits, b.logits)


def test_incremental_sparsity_converges_to_measured(engine, workload):
    """The boundary-diffed running totals telescope to the run's
    cumulative measured sparsity: after `flush_totals` resolves the tail
    window, the accumulated [nnz/cols, overflow, steps] must reproduce
    `stats.sparsity` exactly — and every per-window increment in the
    time series is a valid sparsity with sample weights that sum to at
    most the run total (the tail window resolves after the last
    boundary, outside the ring)."""
    obs = PoolObservability()
    _, stats = serve_requests(engine, _requests(workload), capacity=4,
                              chunk_frames=4, observability=obs)
    tot = obs._last_totals          # flushed by serve_requests
    assert tot[2] > 0
    assert 1.0 - tot[0] / tot[2] == pytest.approx(
        stats.sparsity["temporal_sparsity"], abs=1e-9)
    assert tot[1] / tot[2] == pytest.approx(
        stats.sparsity["capacity_overflow_rate"], abs=1e-9)
    samples = obs.timeseries.snapshot()
    w = np.array([s["samples_inc"] for s in samples])
    sp = np.array([s["temporal_sparsity_inc"] for s in samples])
    assert w.sum() > 0
    assert w.sum() <= tot[2]
    assert ((0.0 <= sp) & (sp <= 1.0)).all()


def test_idle_pool_sparsity_summary(engine):
    """Satellite regression at the pool level: a pool that never stepped
    reports the full zeroed sparsity key set, not {}."""
    from repro.serving.telemetry import measured_sparsity
    state = engine.init_state(4)
    summ = measured_sparsity(state.telemetry, engine.n_cols)
    assert summ == {"temporal_sparsity": 0.0,
                    "capacity_overflow_rate": 0.0,
                    "mean_active_columns": 0.0}


# ------------------------------------------- bench report schema stamping

def _load_bench_module():
    import importlib.util
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "serving_bench", os.path.join(root, "benchmarks",
                                      "serving_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_writer_stamps_and_refuses_mixed_schemas(tmp_path):
    """BENCH_serving.json carries one schema_version on the report and on
    every row; a row from a different schema refuses to write rather
    than producing a half-old, half-new file."""
    sb = _load_bench_module()
    path = tmp_path / "BENCH.json"
    sb._write_report(str(path), {"leg": {"frames_per_s": 1.0}})
    doc = json.loads(path.read_text())
    assert doc["schema_version"] == sb.SCHEMA_VERSION
    assert doc["leg"]["schema_version"] == sb.SCHEMA_VERSION

    stale_row = {"leg": {"schema_version": sb.SCHEMA_VERSION - 1}}
    with pytest.raises(ValueError, match="refusing to mix"):
        sb._write_report(str(path), stale_row)
    stale_top = {"schema_version": sb.SCHEMA_VERSION + 1}
    with pytest.raises(ValueError, match="refusing to mix"):
        sb._write_report(str(path), stale_top)
    # current-version stamps pass through idempotently:
    sb._write_report(str(path), doc)


# --------------------------------------------- tracer + admin end-to-end

FIVE_PHASES = {"admission_upload", "dispatch", "snapshot_fetch",
               "delivery_pump", "pacing_idle"}


async def _admin_query(reader, writer, msg):
    writer.write((json.dumps(msg) + "\n").encode())
    await writer.drain()
    return json.loads(await reader.readline())


def test_async_trace_and_admin_endpoint(engine, workload):
    """One live async run, under client load, covering the tentpole's
    operator surface end to end: the tracer records all five tick-loop
    phases as loadable Chrome trace JSON, and the admin endpoint answers
    healthz/stats/metrics/timeseries (plus in-band errors) while the
    pool is actively serving."""
    from repro.launch.serve import start_admin_server

    obs = PoolObservability(tracer=Tracer(enabled=True))

    async def client(server, feats):
        handle = await server.stream(want_partials=True)
        for j in range(0, len(feats), 3):
            await handle.send(feats[j:j + 3])
            await asyncio.sleep(0)
        handle.close()
        async for _ in handle:
            pass
        return await handle.result()

    async def run():
        async with AsyncSpartusServer(engine, capacity=3, chunk_frames=4,
                                      observability=obs) as server:
            admin = await start_admin_server(server, obs, port=0)
            port = admin.sockets[0].getsockname()[1]
            tasks = [asyncio.ensure_future(client(server, f))
                     for f in workload[:6]]
            reader, writer = await asyncio.open_connection("127.0.0.1", port)
            # query every command while clients are mid-stream:
            health = await _admin_query(reader, writer, {"cmd": "healthz"})
            stats = await _admin_query(reader, writer, {"cmd": "stats"})
            await _admin_query(reader, writer, {"cmd": "metrics"})
            results = await asyncio.gather(*tasks)
            # re-scrape after the load completes, so counter assertions
            # below see the whole run:
            metrics = await _admin_query(reader, writer, {"cmd": "metrics"})
            ts = await _admin_query(reader, writer,
                                    {"cmd": "timeseries", "last": 4})
            bad = await _admin_query(reader, writer, {"cmd": "nope"})
            not_obj = await _admin_query(reader, writer, [1, 2])
            writer.close()
            admin.close()
            await admin.wait_closed()
            return health, stats, metrics, ts, bad, not_obj, results

    health, stats, metrics, ts, bad, not_obj, results = asyncio.run(run())

    assert health["ok"] is True and health["capacity"] == 3
    assert "n_dispatches" in stats["stats"]
    assert metrics["metrics"]["spartus_dispatches_total"]["value"] > 0
    assert "# TYPE spartus_frames_total counter" in metrics["prometheus"]
    assert len(ts["timeseries"]) <= 4 and ts["n_appended"] > 0
    for s in ts["timeseries"]:
        assert {"chunk", "occupancy", "frames", "dispatch_s",
                "temporal_sparsity_inc"} <= set(s)
    assert "error" in bad and "error" in not_obj
    assert len(results) == 6 and all(r.logits.size for r in results)
    # the delivered-result counters agree with what the clients saw:
    assert obs.c_completed.value == 6.0
    # all five driver phases traced, and the trace round-trips as JSON:
    doc = json.loads(obs.tracer.to_json())
    names = {e["name"] for e in doc["traceEvents"]}
    assert FIVE_PHASES <= names, f"missing phases: {FIVE_PHASES - names}"
    assert all(e["ph"] in ("X", "i") for e in doc["traceEvents"])


# ----------------------------------------- scrape-vs-update thread safety

_BUCKET_RE = re.compile(r"^(\w+)_bucket\{(.*)\} (\d+)$")
_COUNT_RE = re.compile(r"^(\w+)_count(?:\{(.*)\})? (\d+)$")


def _assert_prometheus_consistent(text):
    """Every histogram family in one exposition must be self-consistent:
    the +Inf bucket equals ``_count`` and cumulative buckets are
    monotone.  A scrape interleaved with an ``observe`` used to tear
    (buckets, sum and count were read under separate lock
    acquisitions)."""
    inf_buckets, buckets = {}, {}
    for line in text.splitlines():
        m = _BUCKET_RE.match(line)
        if m:
            name, labels, v = m.group(1), m.group(2), int(m.group(3))
            rest = ",".join(p for p in labels.split(",")
                            if not p.startswith('le="'))
            buckets.setdefault((name, rest), []).append(v)
            if 'le="+Inf"' in labels:
                inf_buckets[(name, rest)] = v
            continue
        m = _COUNT_RE.match(line)
        if m:
            key = (m.group(1), m.group(2) or "")
            assert inf_buckets[key] == int(m.group(3)), \
                f"torn scrape: {key} +Inf bucket != count in\n{line}"
    for key, vals in buckets.items():
        assert vals == sorted(vals), f"non-monotone buckets for {key}"
    return len(inf_buckets)


def test_metrics_scrape_consistency_under_hammer():
    """Pure-registry stress: observer threads hammer one histogram (plus
    a counter) while scraper threads render/snapshot concurrently; every
    single scrape must be internally consistent."""
    from repro.serving.metrics import MetricsRegistry

    reg = MetricsRegistry()
    hist = reg.histogram("stress_seconds", "stress", buckets=(0.1, 1.0, 10.0))
    ctr = reg.counter("stress_total", "stress")
    stop = threading.Event()
    errors = []

    def observer():
        i = 0
        while not stop.is_set():
            hist.observe(0.01 * (i % 400))   # spans all buckets + overflow
            ctr.inc()
            i += 1

    def scraper():
        try:
            while not stop.is_set():
                _assert_prometheus_consistent(reg.render_prometheus())
                snap = reg.snapshot()["stress_seconds"]
                cum = [snap["buckets"][k] for k in ("0.1", "1.0", "10.0")]
                assert cum == sorted(cum)
                assert snap["count"] >= cum[-1]
        except AssertionError as e:   # surfaced after join
            errors.append(e)

    threads = ([threading.Thread(target=observer) for _ in range(3)]
               + [threading.Thread(target=scraper) for _ in range(3)])
    for t in threads:
        t.start()
    time.sleep(1.0)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[0]
    # quiescent ground truth: totals survived the concurrency intact
    count, total, cum = hist.stats()
    assert count == hist.count == cum[-1][1]
    assert total == pytest.approx(hist.sum)


def test_metrics_scrape_consistency_against_ticking_pool(engine, workload):
    """End-to-end stress: scrape the live registry while a real pool
    run folds metrics at every chunk boundary."""
    obs = PoolObservability()
    done = threading.Event()
    errors = []

    def scraper():
        n_scrapes = 0
        try:
            while not done.is_set() or n_scrapes == 0:
                _assert_prometheus_consistent(obs.registry.render_prometheus())
                obs.registry.snapshot()
                n_scrapes += 1
        except AssertionError as e:
            errors.append(e)

    threads = [threading.Thread(target=scraper) for _ in range(2)]
    for t in threads:
        t.start()
    try:
        results, _ = serve_requests(engine, _requests(workload), capacity=3,
                                    chunk_frames=4, observability=obs)
    finally:
        done.set()
        for t in threads:
            t.join()
    assert not errors, errors[0]
    assert len(results) == len(workload)
    # a final quiescent scrape sees the full run:
    n_hist = _assert_prometheus_consistent(obs.registry.render_prometheus())
    assert n_hist >= 2      # dispatch_seconds, chunk_seconds, ...
